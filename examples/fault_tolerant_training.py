"""Fault-tolerant training demo: inject two node failures; the supervised
driver restarts from the last committed checkpoint and produces the exact
same trajectory as an uninterrupted run.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import dataclasses
import tempfile

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import FailureInjector, StragglerMonitor, run_supervised
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step


def main():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), vocab=256)
    tcfg = TrainConfig(opt=opt.OptConfig(lr=1e-3, warmup_steps=3, total_steps=40))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    step_fn = make_train_step(cfg, tcfg, None, None)

    def make_state():
        params = tfm.init_params(jax.random.key(0), cfg)
        return {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        print("reference run (no failures)...")
        ref = run_supervised(
            n_steps=30, make_state=make_state, train_step=step_fn,
            batch_fn=pipe.batch, ckpt_dir=d1, ckpt_every=10,
        )
        print(f"  final loss {ref.losses[-1]:.4f}")

        print("run with injected failures at steps 12 and 23...")
        rep = run_supervised(
            n_steps=30, make_state=make_state, train_step=step_fn,
            batch_fn=pipe.batch, ckpt_dir=d2, ckpt_every=10,
            injector=FailureInjector(fail_at={12, 23}),
            monitor=StragglerMonitor(),
        )
        print(f"  {rep.restarts} restarts; final loss {rep.losses[-1]:.4f}")
        match = np.isclose(rep.losses[-1], ref.losses[-1], rtol=1e-6)
        print(f"  trajectories match: {bool(match)} "
              "(checkpoint/restart is bit-exact with deterministic data skip)")


if __name__ == "__main__":
    main()
