"""Quickstart: build one Allan-Poe hybrid index, query it with every path
combination — zero reconstruction between them.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, FusionSpec, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams, search
from repro.core.usms import weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, ndcg_at_k, recall_at_k
from repro.kernels import ops


def main():
    print("=== Allan-Poe quickstart ===")
    corpus = make_corpus(CorpusConfig(
        n_docs=2048, n_queries=32, n_topics=32, d_dense=64,
        nnz_sparse=16, nnz_lexical=8, seed=42,
    ))
    print(f"corpus: {corpus.docs.n} docs "
          f"(dense d={corpus.docs.dense.shape[1]}, "
          f"sparse nnz<={corpus.docs.learned.nnz_cap}, "
          f"lexical nnz<={corpus.docs.lexical.nnz_cap})")

    cfg = BuildConfig(
        knn=KnnConfig(k=32, iters=5, node_chunk=2048),
        prune=PruneConfig(degree=32, keyword_degree=8, node_chunk=512),
        path_refine_iters=2,
    )
    index = build_index(
        corpus.docs, cfg,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities,
        n_entities=corpus.kg.n_entities,
    )
    sizes = index.edge_nbytes()
    print(f"index built: degree={index.degree}, "
          f"edges={sum(v for k, v in sizes.items() if k != 'vectors')/1e6:.2f}MB "
          f"vectors={sizes['vectors']/1e6:.1f}MB")

    params = SearchParams(k=10, iters=48, pool_size=64)
    print("\npath combination -> vector recall@10 / end-to-end nDCG@10 "
          "(same index, weights changed at query time):")
    for name, spec in [
        ("dense only      ", FusionSpec.weighted(1, 0, 0)),
        ("sparse only     ", FusionSpec.weighted(0, 1, 0)),
        ("full-text only  ", FusionSpec.weighted(0, 0, 1)),
        ("dense+sparse    ", FusionSpec.weighted(1, 1, 0)),
        ("three-path      ", FusionSpec.three_path()),
        ("custom 0.7/0.3  ", FusionSpec.weighted(0.7, 0.3, 0.1)),
    ]:
        res = search(index, corpus.queries, spec, params)
        qw = weighted_query(corpus.queries, spec.weights)
        truth = jax.lax.top_k(ops.pairwise_scores_chunked(qw, corpus.docs), 10)[1]
        rec = recall_at_k(np.asarray(res.ids), np.asarray(truth))
        nd = ndcg_at_k(np.asarray(res.ids), corpus.query_relevant, 10)
        print(f"  {name} recall={rec:.3f}  ndcg={nd:.3f}")

    # fusion modes beyond weighted-sum (DESIGN.md §11): same index, same
    # compiled executable — the mode is traced query data
    print("\nfusion mode -> end-to-end nDCG@10 (same executable, no recompile):")
    for name, spec in [
        ("weighted_sum", FusionSpec.three_path()),
        ("minmax      ", FusionSpec.minmax()),
        ("zscore      ", FusionSpec.zscore()),
        ("rrf         ", FusionSpec.rrf()),
    ]:
        res = search(index, corpus.queries, spec, params)
        nd = ndcg_at_k(np.asarray(res.ids), corpus.query_relevant, 10)
        print(f"  {name} ndcg={nd:.3f}")

    # keyword-constrained search (§4.2.2)
    kw = jnp.asarray(corpus.query_keywords)
    res = search(
        index, corpus.queries, FusionSpec.three_path(),
        SearchParams(k=10, iters=48, pool_size=64, use_keywords=True),
        keywords=kw,
    )
    print(f"\nkeyword-constrained: every result contains the required keyword "
          f"(checked: {int((np.asarray(res.ids) >= 0).sum())} results)")

    # knowledge-graph multi-hop (§4.2.3)
    base = search(index, corpus.queries, FusionSpec.three_path(), params)
    kg = search(
        index, corpus.queries, FusionSpec.weighted(1, 1, 1, kg=30.0),
        SearchParams(k=10, iters=48, pool_size=64, use_kg=True),
        entities=jnp.asarray(corpus.query_entities),
    )
    t = corpus.query_multihop_target[:, None]
    print(f"multi-hop chain-tail recall: semantic-only="
          f"{recall_at_k(np.asarray(base.ids), t):.3f}  "
          f"+logical-edges={recall_at_k(np.asarray(kg.ids), t):.3f}")


if __name__ == "__main__":
    main()
