"""Segment-sharded distributed search on a (pod, data, model) mesh — the
production layout of the hybrid index, demonstrated with 8 fake devices.

    PYTHONPATH=src python examples/distributed_search.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax

from repro.core import BuildConfig, FusionSpec, KnnConfig, PruneConfig
from repro.core.distributed import (
    build_segmented_index,
    make_distributed_search,
    place_segmented_index,
)
from repro.core.search import SearchParams
from repro.core.usms import weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.kernels import ops


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)} = {mesh.devices.size} devices")
    corpus = make_corpus(CorpusConfig(n_docs=2048, n_queries=16, d_dense=48, seed=5))

    n_segments = 4  # pod x data groups
    seg = build_segmented_index(
        corpus.docs, n_segments,
        BuildConfig(knn=KnnConfig(k=16, iters=4, node_chunk=1024),
                    prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
                    path_refine_iters=1),
    )
    seg = place_segmented_index(seg, mesh)
    print(f"{n_segments} segments x {seg.global_ids.shape[1]} docs, "
          f"queries sharded over the model axis")

    spec = FusionSpec.three_path()
    params = SearchParams(k=10, iters=32, pool_size=64)
    run = make_distributed_search(mesh, spec, params)
    res = run(seg, corpus.queries)

    qw = weighted_query(corpus.queries, spec.weights)
    truth = jax.lax.top_k(ops.pairwise_scores_chunked(qw, corpus.docs), 10)[1]
    rec = recall_at_k(np.asarray(res.ids), np.asarray(truth))
    print(f"global recall@10 vs brute force: {rec:.3f}")
    print(f"total nodes expanded across devices: {int(res.expanded[0])}")


if __name__ == "__main__":
    main()
