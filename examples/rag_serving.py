"""End-to-end driver (the paper's kind: retrieval serving): build the hybrid
index over a corpus, then serve batched retrieval-augmented generation
requests — hybrid search -> context assembly -> batched decode. Retrieval
runs through ``HybridSearchService``, so RAG traffic is micro-batched into
shape-bucketed executables and would share the index snapshot with any other
search client.

    PYTHONPATH=src python examples/rag_serving.py
"""

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.obs.export import write_chrome_trace
from repro.core.search import SearchParams
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.models import transformer as tfm
from repro.serving.batcher import BatcherConfig
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
from repro.serving.rag import RagConfig, RagPipeline


def main():
    print("=== retrieval-augmented serving (end-to-end) ===")
    n_docs, n_requests = 4096, 16
    corpus = make_corpus(CorpusConfig(
        n_docs=n_docs, n_queries=n_requests, n_topics=64, d_dense=64, seed=3,
    ))

    t0 = time.perf_counter()
    index = build_index(
        corpus.docs,
        BuildConfig(
            knn=KnnConfig(k=24, iters=4, node_chunk=2048),
            prune=PruneConfig(degree=24, keyword_degree=8, node_chunk=512),
            path_refine_iters=1,
        ),
    )
    print(f"index over {n_docs} docs built in {time.perf_counter()-t0:.1f}s")

    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), vocab=2048)
    params = tfm.init_params(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, ServeConfig(max_len=256, batch=n_requests))

    rng = np.random.default_rng(0)
    doc_tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(n_docs, 16)), jnp.int32)
    search_params = SearchParams(k=3, iters=40, pool_size=64)
    service = HybridSearchService(
        index, search_params,
        ServiceConfig(batcher=BatcherConfig(flush_size=n_requests,
                                            max_batch=n_requests)),
    )
    rag = RagPipeline(
        engine, index, doc_tokens,
        RagConfig(top_k=3, ctx_tokens_per_doc=16, search=search_params),
        service=service,
    )

    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(n_requests, 8)), jnp.int32)
    t0 = time.perf_counter()
    # every span of the request — admission, queue wait, batch phases,
    # context assembly, generation — lands in one trace context
    with service.tracer.trace("rag_answer", requests=n_requests) as ctx:
        out, res = rag.answer(corpus.queries, prompts, n_tokens=24, trace=ctx)
    dt = time.perf_counter() - t0

    rec = recall_at_k(np.asarray(res.ids), corpus.query_relevant[:, :1])
    print(f"{n_requests} requests: retrieve(top-3) + generate(24 tok) "
          f"in {dt:.1f}s  ({n_requests * 24 / dt:.1f} tok/s)")
    print(f"retrieval recall of planted docs: {rec:.2f}")
    print(f"output shape: {out.shape} (context 3x16 + prompt 8 + 24 generated)")
    print(f"service: {service.stats.batches} batches, "
          f"{service.stats.compiles} compiled executables, "
          f"{service.stats.requests} requests")

    # observability artifacts: a perfetto-loadable span tree of the request
    # (chrome://tracing or https://ui.perfetto.dev) and the Prometheus view
    write_chrome_trace("results/rag_trace.json", service.tracer)
    spans = sorted({s.name for s in ctx.spans()})
    print(f"trace: {len(ctx.spans())} spans ({', '.join(spans)})")
    print("trace written to results/rag_trace.json — "
          "open it in https://ui.perfetto.dev")
    print("metrics exposition (excerpt):")
    for line in service.metrics.render().splitlines():
        if line.startswith("allanpoe_serving_requests_total"):
            print(f"  {line}")


if __name__ == "__main__":
    main()
