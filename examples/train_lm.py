"""End-to-end training example: a llama-family model trained for a few
hundred steps with checkpointing and restart-exact data skip.

    PYTHONPATH=src python examples/train_lm.py                # ~8M, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --size 100m    # ~100M (TPU)
"""

import argparse
import time

import jax

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step

SIZES = {
    # ~8M params: a few hundred CPU steps in minutes
    "tiny": ModelConfig(
        name="llama-tiny", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=704, vocab=4096, head_dim=32, tie_embeddings=True,
        remat="none", dtype="float32",
    ),
    # ~100M params: the assignment's e2e training target (run on accelerators)
    "100m": ModelConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=32000, tie_embeddings=True,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = SIZES[args.size]
    tcfg = TrainConfig(
        opt=opt.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    )
    pipe = TokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=1)
    )
    params = tfm.init_params(jax.random.key(0), cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} — {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    step_fn = make_train_step(cfg, tcfg, None, None)
    state = {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}
    t0 = time.perf_counter()
    first = None
    for s in range(args.steps):
        state, m = step_fn(state, pipe.batch(s))
        loss = float(m["loss"])
        if first is None:
            first = loss
        if s % 25 == 0 or s == args.steps - 1:
            tok_s = args.batch * args.seq * (s + 1) / (time.perf_counter() - t0)
            print(f"step {s:4d}  loss {loss:.4f}  tok/s {tok_s:,.0f}", flush=True)
    print(f"\nloss: {first:.3f} -> {loss:.3f} "
          f"({'LEARNED' if loss < first - 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
