"""Bring your own documents: raw text -> ingest -> build -> hybrid query.

End to end on the bundled real-text corpus (tests/data/paragraphs.jsonl):
the ingestion pipeline turns paragraphs into USMS vectors, keywords, and
knowledge-graph triplets; ``build_index`` assembles the all-in-one graph;
queries are plain strings run through the SAME analyzer (double-quoted
phrases become required keywords, capitalized names become KG entities).
Finally the (index, vocab/stats) pair is saved and restored to show an
ingested index surviving a restart.

    PYTHONPATH=src python examples/ingest_text.py
"""

import tempfile

import numpy as np

from repro.checkpoint import load_index, load_ingest, save_index
from repro.core import BuildConfig, FusionSpec, KnnConfig, PruneConfig
from repro.core.search import SearchParams, search
from repro.ingest import adaptive_fusion_for
from repro.data.textcorpus import load_bundled_corpus
from repro.ingest import IngestConfig, IngestPipeline


def main():
    print("=== Allan-Poe text ingestion quickstart ===")
    corpus = load_bundled_corpus()
    texts, titles = corpus.texts, corpus.titles
    print(f"corpus: {len(texts)} raw paragraphs "
          f"({len(set(corpus.topics))} topics)")

    # 1. ingest: one fitting pass freezes df/avg_dl + the entity vocab
    pipe = IngestPipeline(IngestConfig(d_dense=64))
    ingested = pipe.fit(texts)
    print(f"ingested: dense d=64, learned nnz<={ingested.docs.learned.nnz_cap}, "
          f"lexical nnz<={ingested.docs.lexical.nnz_cap}, "
          f"{len(pipe.entity_vocab)} entities, "
          f"{len(ingested.kg.triplets)} KG triplets")

    # 2. build the all-in-one hybrid index
    index = pipe.build(ingested, BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=128),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
        path_refine_iters=1,
    ))
    print(f"index built: {index.n} nodes, degree {index.degree}")

    # 3. query with plain strings — any path combination, zero rebuild
    questions = [
        'How do I feed a sourdough starter with rye flour?',
        'Why did the Rocket win at Rainhill?',
        'How did Amundsen lay depots for the pole?',
    ]
    enc = pipe.encode_queries(questions)
    params = SearchParams(k=5, iters=48, pool_size=64)
    for f_name, spec in [("dense-only", FusionSpec.weighted(1, 0, 0)),
                         ("hybrid    ", FusionSpec.three_path()),
                         ("rrf       ", FusionSpec.rrf()),
                         ("adaptive  ", adaptive_fusion_for(enc))]:
        res = search(index, enc.vectors, spec, params)
        print(f"\n{f_name} top-3:")
        for q, row in zip(questions, np.asarray(res.ids)):
            tops = ", ".join(titles[d] for d in row[:3] if d >= 0)
            print(f"  {q[:48]:50s} -> {tops}")

    # 4. required keywords: quote a phrase and every hit must contain it
    enc = pipe.encode_queries(['the voyage home "scurvy"'])
    res = search(index, enc.vectors, FusionSpec.three_path(),
                 SearchParams(k=5, iters=48, pool_size=64, use_keywords=True),
                 keywords=enc.keywords)
    hits = [titles[d] for d in np.asarray(res.ids)[0] if d >= 0]
    print(f'\nkeyword-constrained "scurvy" -> {hits}')

    # 5. persistence: the ingested index + vocab/stats survive a restart
    with tempfile.TemporaryDirectory() as tmp:
        save_index(tmp, index, ingest=pipe)
        index2, pipe2 = load_index(tmp), load_ingest(tmp)
        enc2 = pipe2.encode_queries([questions[0]])
        res2 = search(index2, enc2.vectors, FusionSpec.three_path(), params)
        print(f"\nrestored from disk: top hit for {questions[0]!r} -> "
              f"{titles[int(np.asarray(res2.ids)[0, 0])]!r}")


if __name__ == "__main__":
    main()
