"""Roofline report generator: reads results/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (single-pod baselines per the assignment) and
ranks cells for the perf hillclimb."""

from __future__ import annotations

import argparse
import json
import pathlib


def load(out_dir: str, mesh: str = "16x16") -> list[dict]:
    rows = []
    for f in sorted(pathlib.Path(out_dir).glob("*.json")):
        if f.name == "summary.json":
            continue
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            rows.append(r)
    return rows


def step_time_and_fraction(r: dict) -> tuple[float, float]:
    """Bound step time = max of terms (idealized overlap); roofline fraction =
    ideal compute time on *useful* (model) flops / bound time."""
    rl = r.get("roofline", {})
    bound = max(rl.get("compute_s", 0), rl.get("memory_s", 0), rl.get("collective_s", 0))
    from repro.launch.hlo_analysis import PEAK_FLOPS_BF16

    useful = r.get("model_flops_per_device", 0) / PEAK_FLOPS_BF16
    frac = useful / bound if bound > 0 else 0.0
    return bound, frac


def table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL/HLO flops | roofline frac | what would move the bound |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if str(r.get("status", "")).startswith("SKIP"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP(full-attn) | — | — | "
                f"O(L²) attention at 524k tokens; run on ssm/hybrid archs only |"
            )
            continue
        rl = r.get("roofline", {})
        bound, frac = step_time_and_fraction(r)
        ratio = 1.0 / r["useful_flops_ratio"] if r.get("useful_flops_ratio") else 0
        dom = rl.get("dominant", "?").replace("_s", "")
        fix = {
            "compute": "more chips or lower-precision matmuls",
            "memory": "fuse attention (avoid L×S materialization), better remat policy",
            "collective": "sequence-parallel activations / larger per-device batch / compressed DP reduce",
        }.get(dom, "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl.get('compute_s', 0):.4f} | "
            f"{rl.get('memory_s', 0):.4f} | {rl.get('collective_s', 0):.4f} | "
            f"{dom} | {r.get('useful_flops_ratio', 0):.2f} | {frac:.3f} | {fix} |"
        )
    return "\n".join(lines)


def pick_hillclimb(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "OK" and "roofline" in r
          and r["arch"] != "allanpoe-retrieval"]
    worst_frac = min(ok, key=lambda r: step_time_and_fraction(r)[1])
    coll_bound = max(
        ok,
        key=lambda r: r["roofline"]["collective_s"]
        / max(r["roofline"]["compute_s"], 1e-12),
    )
    return {"worst_fraction": worst_frac, "most_collective_bound": coll_bound}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(table(rows))
    picks = pick_hillclimb(rows)
    print("\nhillclimb picks:")
    for k, r in picks.items():
        bound, frac = step_time_and_fraction(r)
        print(f"  {k}: {r['arch']} x {r['shape']} (frac={frac:.3f}, "
              f"dominant={r['roofline']['dominant']})")


if __name__ == "__main__":
    main()
