"""Roofline report generator: reads results/dryrun/*.json plus the fused-
kernel sweep (results/BENCH_kernel.json) into the EXPERIMENTS.md §Roofline
table (single-pod baselines per the assignment) and ranks cells for the perf
hillclimb."""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

if __package__ in (None, ""):  # script mode: python benchmarks/roofline.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

from repro.launch.hlo_analysis import PEAK_FLOPS_BF16

KERNEL_BENCH = "results/BENCH_kernel.json"


def load(out_dir: str, mesh: str = "16x16") -> list[dict]:
    rows = []
    for f in sorted(pathlib.Path(out_dir).glob("*.json")):
        if f.name == "summary.json":
            continue
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            rows.append(r)
    return rows


def load_kernel_rows(path: str = KERNEL_BENCH) -> list[dict]:
    """Map the fused-selection sweep (kernel_bench.py) into table rows.

    Each (c_tile, k, expand) pair becomes one row: the analytic roofline of
    the fused kernel on its padded candidate grid; ``useful_flops_ratio`` is
    the candidate-lane utilization (live candidates / padded lanes), so the
    roofline fraction reflects padding waste exactly like the training rows.
    """
    p = pathlib.Path(path)
    if not p.exists():
        return []
    bench = json.loads(p.read_text())
    rows = []
    from repro.launch.hlo_analysis import HBM_BW

    for name, row in bench.get("sweep", {}).items():
        rl = row.get("roofline", {})
        util = row.get("model", {}).get("lane_util_candidates", 1.0)
        rows.append({
            "arch": "allanpoe-retrieval",
            "shape": name,
            "status": "OK",
            "roofline": {
                "compute_s": rl.get("compute_s", 0.0),
                "memory_s": rl.get("memory_s", 0.0),
                "collective_s": rl.get("collective_s", 0.0),
                "dominant": rl.get("dominant", "?"),
            },
            "model_flops_per_device": rl.get("model_flops", 0) * util,
            "useful_flops_ratio": util,
        })
        # the quantized twin of the same grid point: identical flops,
        # memory term re-derived from the int8 bytes model, so the table
        # shows how far dequant-in-tile moves the memory bound
        bq = row.get("model", {}).get("bytes_quantized")
        if bq is not None:
            mem_q = bq / HBM_BW
            comp = rl.get("compute_s", 0.0)
            rows.append({
                "arch": "allanpoe-retrieval",
                "shape": name + "_q",
                "status": "OK",
                "roofline": {
                    "compute_s": comp,
                    "memory_s": mem_q,
                    "collective_s": rl.get("collective_s", 0.0),
                    "dominant": "memory" if mem_q > comp else "compute",
                },
                "model_flops_per_device": rl.get("model_flops", 0) * util,
                "useful_flops_ratio": util,
            })
    return rows


def step_time_and_fraction(r: dict) -> tuple[float, float]:
    """Bound step time = max of terms (idealized overlap); roofline fraction =
    ideal compute time on *useful* (model) flops / bound time."""
    rl = r.get("roofline", {})
    bound = max(rl.get("compute_s", 0), rl.get("memory_s", 0), rl.get("collective_s", 0))
    useful = r.get("model_flops_per_device", 0) / PEAK_FLOPS_BF16
    frac = useful / bound if bound > 0 else 0.0
    return bound, frac


def table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "MODEL/HLO flops | roofline frac | what would move the bound |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if str(r.get("status", "")).startswith("SKIP"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP(full-attn) | — | — | "
                f"O(L²) attention at 524k tokens; run on ssm/hybrid archs only |"
            )
            continue
        rl = r.get("roofline", {})
        bound, frac = step_time_and_fraction(r)
        dom = rl.get("dominant", "?").replace("_s", "")
        fix = {
            "compute": "more chips or lower-precision matmuls",
            "memory": "fuse attention (avoid L×S materialization), better remat policy",
            "collective": "sequence-parallel activations / larger per-device batch / compressed DP reduce",
        }.get(dom, "")
        if r.get("arch") == "allanpoe-retrieval":
            fix = {
                "compute": "bf16 candidate tiles / larger C_TILE on the MXU",
                "memory": "fused selection removes the score round-trip; "
                          "int8 corpus storage (the _q rows) shrinks the "
                          "candidate stream itself",
            }.get(dom, fix)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl.get('compute_s', 0):.4f} | "
            f"{rl.get('memory_s', 0):.4f} | {rl.get('collective_s', 0):.4f} | "
            f"{dom} | {r.get('useful_flops_ratio', 0):.2f} | {frac:.3f} | {fix} |"
        )
    return "\n".join(lines)


def pick_hillclimb(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "OK" and "roofline" in r]
    if not ok:
        return {}
    worst_frac = min(ok, key=lambda r: step_time_and_fraction(r)[1])
    coll_bound = max(
        ok,
        key=lambda r: r["roofline"]["collective_s"]
        / max(r["roofline"]["compute_s"], 1e-12),
    )
    return {"worst_fraction": worst_frac, "most_collective_bound": coll_bound}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--kernel-bench", default=KERNEL_BENCH)
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh) + load_kernel_rows(args.kernel_bench)
    if not rows:
        print(f"SKIP: no results under {args.dir} and no {args.kernel_bench} — "
              "run the dryrun launcher or benchmarks/kernel_bench.py first")
        return
    print(table(rows))
    picks = pick_hillclimb(rows)
    if not picks:
        print("\nhillclimb picks: SKIP (no OK rows with a roofline)")
        return
    print("\nhillclimb picks:")
    for k, r in picks.items():
        bound, frac = step_time_and_fraction(r)
        print(f"  {k}: {r['arch']} x {r['shape']} (frac={frac:.3f}, "
              f"dominant={r['roofline']['dominant']})")


if __name__ == "__main__":
    main()
