"""Paper Table 3 + Table 4: knowledge-graph augmentation on multi-hop
queries — nDCG/recall and QPS with and without logical edges, across path
configurations."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import default_build, multihop_corpus, timed
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import ndcg_at_k, recall_at_k


def run(n_docs=4096, n_queries=64):
    corpus = multihop_corpus(n_docs, n_queries)
    cfg = default_build(corpus.docs.n)
    index = build_index(
        corpus.docs, cfg,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities,
        n_entities=corpus.kg.n_entities,
    )
    # ground truth for multi-hop: planted chain tails + relevant docs
    truth = np.concatenate(
        [corpus.query_relevant, corpus.query_multihop_target[:, None]], axis=1
    )
    nq = n_queries
    rows = []
    ents = jnp.asarray(corpus.query_entities)
    for pname, w in [
        ("dense", PathWeights.make(1, 0, 0)),
        ("sparse", PathWeights.make(0, 1, 0)),
        ("full", PathWeights.make(0, 0, 1)),
        ("three", PathWeights.three_path()),
    ]:
        base_params = SearchParams(k=10, iters=48, pool_size=64)
        ids, sec = timed(lambda: search(index, corpus.queries, w, base_params).ids)
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        mh = recall_at_k(np.asarray(ids), corpus.query_multihop_target[:, None])
        rows.append((f"table3.{pname}", sec * 1e6 / nq,
                     f"ndcg={nd:.3f};multihop_recall={mh:.3f};qps={nq/sec:.0f}"))

        w_kg = PathWeights(w.dense, w.sparse, w.full, jnp.float32(30.0))
        kg_params = SearchParams(k=10, iters=48, pool_size=64, use_kg=True)
        ids, sec = timed(
            lambda: search(index, corpus.queries, w_kg, kg_params, entities=ents).ids
        )
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        mh = recall_at_k(np.asarray(ids), corpus.query_multihop_target[:, None])
        rows.append((f"table3.{pname}+KG", sec * 1e6 / nq,
                     f"ndcg={nd:.3f};multihop_recall={mh:.3f};qps={nq/sec:.0f}"))
    return rows
