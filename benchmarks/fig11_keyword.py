"""Paper Figure 11: effect of keyword edges — keyword-constrained queries
with and without the recycled keyword edges (and the keyword filter)."""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from benchmarks.common import default_build, simple_corpus, timed
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import ndcg_at_k


def run(n_docs=4096, n_queries=64):
    corpus = simple_corpus(n_docs, n_queries)
    truth = corpus.query_relevant
    kw = jnp.asarray(corpus.query_keywords)
    cfg = default_build(corpus.docs.n)
    index = build_index(corpus.docs, cfg)
    # index without keyword edges (ablation)
    index_nokw = dataclasses.replace(
        index, keyword_edges=jnp.full_like(index.keyword_edges, -1)
    )
    rows = []
    for pname, w in [("full", PathWeights.make(0, 0, 1)),
                     ("three", PathWeights.three_path())]:
        for label, idx, use_kw in [
            ("plain", index, False),
            ("kw-filter-no-edges", index_nokw, True),
            ("kw-edges", index, True),
        ]:
            params = SearchParams(k=10, iters=40, pool_size=64, use_keywords=use_kw)
            ids, sec = timed(
                lambda idx=idx, params=params: search(
                    idx, corpus.queries, w, params,
                    keywords=kw if use_kw else None,
                ).ids
            )
            nd = ndcg_at_k(np.asarray(ids), truth, 10)
            rows.append((f"fig11.{pname}.{label}", sec * 1e6 / n_queries,
                         f"ndcg={nd:.3f};qps={n_queries/sec:.0f}"))
    return rows
