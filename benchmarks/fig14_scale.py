"""Paper Figure 14 at serving scale: build throughput and query QPS/p99 of
the data-parallel replica tier at {10k, 100k} synthetic docs × {1, 2, 4}
replicas, with the scaling-efficiency metric the nightly CI gate enforces.

Per (n_docs, R) cell, ``data.syncorpus`` streams domain-templated documents
through the fitted ``IngestPipeline`` batch by batch (the raw corpus never
materializes in host memory); every doc's home replica comes from the SAME
consistent-hash ring ``serving.replica_router`` uses online, and each
replica's shard is sealed into fixed-capacity ``SegmentPool`` segments
behind its own ``HybridSearchService`` (own snapshot, own AOT executable
cache).

Scaling metrics — measured honestly on ONE host:

  * ``iso_qps`` — each replica's QPS over the full query stream measured in
    ISOLATION. This is the share-nothing model: deployed replicas are
    separate hosts, and the tier's scatter-gather throughput is bounded by
    its slowest member, so ``model_qps = min(iso_qps)``.
  * ``scaling_efficiency = model_qps@R / (R × model_qps@1)`` — the GATED
    number. With hash placement a replica holds ~1/R of the segments, so
    per-query work shrinks ~R×; what efficiency < 1 measures is the real
    overhead the tier pays: pow2 capacity padding, per-query fixed cost,
    and consistent-hash shard imbalance.
  * ``tier_qps``/``p50``/``p99`` — the REAL in-process scatter-gather path
    (``ReplicaRouter.search`` fanning out on its thread pool). On a single
    CPU host every replica shares the same cores, so this number cannot
    scale with R; it is reported for the record and never gated.

    PYTHONPATH=src python benchmarks/fig14_scale.py [--docs 10000,100000]
        [--replicas 1,2,4] [--dry-run] [--out results/BENCH_scale.json]
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode: python benchmarks/fig14_scale.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.search import SearchParams
from repro.core.segment_pool import (
    SegmentPool,
    append_segment,
    build_pool_segment,
    place_pool,
)
from repro.core.usms import PathWeights
from repro.data.syncorpus import SynCorpus, SynCorpusConfig
from repro.ingest import IngestPipeline
from repro.obs.metrics import MetricsRegistry
from repro.serving.batcher import BatcherConfig, _next_pow2
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
from repro.serving.replica_router import (
    Replica,
    ReplicaRouter,
    ReplicaTierConfig,
    build_ring,
    ring_homes,
)
from repro.serving.segment_router import RouterConfig, SegmentRouter

W = PathWeights.make(1.0, 1.0, 1.0)
SEED = 0
N_QUERIES = 64


def _build_cfg(n_docs: int) -> BuildConfig:
    return BuildConfig(
        knn=KnnConfig(k=16, iters=2, node_chunk=min(n_docs, 1024)),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
        path_refine_iters=0,
    )


def _tree_rows(tree, rows):
    return jax.tree.map(lambda a: np.asarray(a)[rows], tree)


def _tree_concat(parts):
    return jax.tree.map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
        *parts,
    )


def build_tier(
    gen: SynCorpus,
    pipe: IngestPipeline,
    kg,
    n_docs: int,
    n_replicas: int,
    build_cfg: BuildConfig,
    params: SearchParams,
    *,
    segment_docs: int = 1024,
    encode_batch: int = 1024,
    virtual_nodes: int = 512,
) -> ReplicaRouter:
    """Stream the corpus into an R-replica tier: encode batch by batch,
    scatter rows to their consistent-hash home, seal every ``segment_docs``
    rows of a shard into one pooled segment. Peak host memory is
    O(encode_batch + R × segment_docs) encoded rows, never O(n_docs)."""
    from jax.sharding import Mesh

    names = [f"replica{i}" for i in range(n_replicas)]
    ring = build_ring(names, virtual_nodes)
    kg_kwargs = (
        dict(kg_triplets=kg.triplets, n_entities=kg.n_entities)
        if kg is not None
        else {}
    )
    pools: list[SegmentPool | None] = [None] * n_replicas
    bufs: list[list] = [[] for _ in range(n_replicas)]
    counts = [0] * n_replicas
    seg_no = 0

    def _flush(i: int, final: bool = False) -> None:
        nonlocal seg_no
        while counts[i] >= segment_docs or (final and counts[i] > 0):
            docs = _tree_concat([p[0] for p in bufs[i]])
            ents = np.concatenate([p[1] for p in bufs[i]], axis=0)
            gids = np.concatenate([p[2] for p in bufs[i]], axis=0)
            take = min(segment_docs, counts[i])
            seg_kw = dict(kg_kwargs)
            if seg_kw:
                seg_kw["doc_entities"] = ents[:take]
            seg = build_pool_segment(
                jax.tree.map(lambda a: a[:take], docs),
                gids[:take],
                build_cfg,
                capacity=_next_pow2(take),
                key=jax.random.fold_in(jax.random.key(41), seg_no),
                **seg_kw,
            )
            seg_no += 1
            pools[i] = (
                SegmentPool.from_segmented(seg)
                if pools[i] is None
                else append_segment(pools[i], seg)[0]
            )
            counts[i] -= take
            bufs[i] = (
                [(jax.tree.map(lambda a: a[take:], docs),
                  ents[take:], gids[take:])]
                if counts[i]
                else []
            )

    next_gid = 0
    for batch in gen.doc_batches(encode_batch, stop=n_docs):
        docs, ents = pipe.encode_docs([d.text for d in batch])
        gids = np.arange(next_gid, next_gid + len(batch), dtype=np.int64)
        next_gid += len(batch)
        homes = ring_homes(ring, gids)
        for i in np.unique(homes):
            rows = np.flatnonzero(homes == i)
            bufs[int(i)].append((_tree_rows(docs, rows), ents[rows], gids[rows]))
            counts[int(i)] += int(rows.size)
            if counts[int(i)] >= segment_docs:
                _flush(int(i))
    for i in range(n_replicas):
        _flush(i, final=True)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    replicas = []
    for i, pool in enumerate(pools):
        if pool is None:
            raise RuntimeError(
                f"replica {i} received no docs — corpus too small for "
                f"{n_replicas} replicas"
            )
        pool = place_pool(pool, mesh)
        svc = HybridSearchService(
            pool,
            params,
            ServiceConfig(
                batcher=BatcherConfig(
                    flush_size=32, max_batch=32, flush_deadline_s=0.05
                )
            ),
            mesh=mesh,
        )
        router = SegmentRouter(
            svc, build_cfg, RouterConfig(seal_threshold=10**9), **kg_kwargs
        )
        replicas.append(Replica(svc, router, name=names[i]))
    return ReplicaRouter(
        replicas, ReplicaTierConfig(virtual_nodes=virtual_nodes)
    )


def _measure(search_fn, query_batches, n_requests: int, batch: int):
    """Closed-loop batched client: warm one batch (compile), then drive
    ``n_requests`` requests. Per-batch wall latencies stream into the same
    fixed-bucket histogram the serving stack exposes (one local series, no
    sample array), and percentiles come from its interpolated quantiles —
    bench and production share one latency implementation."""
    np.asarray(search_fn(query_batches[0]).ids)  # warmup / compile
    hist = MetricsRegistry().histogram(
        "fig14_batch_latency_seconds", "per-batch scatter-read wall time"
    )
    done = 0
    i = 0
    t0 = time.perf_counter()
    while done < n_requests:
        t1 = time.perf_counter()
        np.asarray(search_fn(query_batches[i % len(query_batches)]).ids)
        hist.observe(time.perf_counter() - t1)
        done += batch
        i += 1
    wall = time.perf_counter() - t0
    return done / wall, hist.snapshot()


def bench_scale(
    n_docs: int,
    replicas_grid=(1, 2, 4),
    *,
    n_requests: int = 256,
    batch: int = 32,
    segment_docs: int = 256,
    encode_batch: int = 1024,
    k: int = 10,
    seed: int = SEED,
) -> dict:
    """One corpus size across the replica grid; returns the JSON payload
    for this scale (per-R build + QPS numbers, scaling efficiency)."""
    params = SearchParams(k=k, iters=32, pool_size=64)
    build_cfg = _build_cfg(n_docs)
    gen = SynCorpus(
        SynCorpusConfig(n_docs=n_docs, seed=seed, n_queries=N_QUERIES)
    )
    pipe = IngestPipeline()
    fitted = pipe.fit(gen.fit_sample(min(2048, n_docs)))
    kg = fitted.kg if len(fitted.kg.triplets) else None
    enc = pipe.encode_queries([q.text for q in gen.queries(N_QUERIES)])
    query_batches = [
        jax.tree.map(lambda a: a[lo:lo + batch], enc.vectors)
        for lo in range(0, N_QUERIES - batch + 1, batch)
    ]

    out: dict = {"replicas": {}}
    for n_rep in replicas_grid:
        t0 = time.perf_counter()
        tier = build_tier(
            gen, pipe, kg, n_docs, n_rep, build_cfg, params,
            segment_docs=segment_docs, encode_batch=encode_batch,
        )
        build_s = time.perf_counter() - t0
        try:
            iso = []
            for r in tier.replicas:
                qps, _ = _measure(
                    lambda q, s=r.service: s.search(q, W, k=k),
                    query_batches, n_requests, batch,
                )
                iso.append(qps)
            tier_qps, lats = _measure(
                lambda q: tier.search(q, W, k=k),
                query_batches, n_requests, batch,
            )
            out["replicas"][str(n_rep)] = {
                "build_s": build_s,
                "build_docs_per_s": n_docs / build_s,
                "shard_docs": tier.shard_sizes(),
                "pool_segments": [
                    r.router.pool.n_segments for r in tier.replicas
                ],
                "iso_qps": iso,
                "model_qps": min(iso),
                "tier_qps": tier_qps,
                "tier_p50_ms": float(lats.quantile(0.5)) * 1e3,
                "tier_p99_ms": float(lats.quantile(0.99)) * 1e3,
            }
        finally:
            tier.close()

    base = out["replicas"][str(replicas_grid[0])]["model_qps"]
    base_r = replicas_grid[0]
    for n_rep in replicas_grid:
        e = out["replicas"][str(n_rep)]
        e["scaling_efficiency"] = (
            (e["model_qps"] / base) * (base_r / n_rep)
        )
    out["scaling_efficiency"] = out["replicas"][str(replicas_grid[-1])][
        "scaling_efficiency"
    ]
    return out


def run(
    n_docs=10_000,
    replicas=(1, 2, 4),
    *,
    n_requests: int = 256,
    batch: int = 32,
    segment_docs: int = 256,
    encode_batch: int = 1024,
    out_path: str = "results/BENCH_scale.json",
):
    """Full bench across one or more corpus sizes; writes
    ``results/BENCH_scale.json`` and returns harness CSV rows."""
    sizes = (n_docs,) if isinstance(n_docs, int) else tuple(n_docs)
    payload = {
        "config": {
            "docs": list(sizes),
            "replicas": list(replicas),
            "n_requests": n_requests,
            "batch": batch,
            "n_queries": N_QUERIES,
            "segment_docs": segment_docs,
            "virtual_nodes": 512,
            "k": 10,
            "seed": SEED,
            "backend": jax.default_backend(),
        },
        "scales": {},
    }
    rows = []
    for n in sizes:
        scale = bench_scale(
            n, replicas, n_requests=n_requests, batch=batch,
            segment_docs=segment_docs, encode_batch=encode_batch,
        )
        payload["scales"][str(n)] = scale
        for n_rep in replicas:
            e = scale["replicas"][str(n_rep)]
            rows.append(
                (
                    f"fig14.n{n}_r{n_rep}",
                    1e6 / e["model_qps"],
                    f"build_s={e['build_s']:.1f};"
                    f"model_qps={e['model_qps']:.0f};"
                    f"tier_qps={e['tier_qps']:.0f};"
                    f"tier_p99_ms={e['tier_p99_ms']:.1f};"
                    f"eff={e['scaling_efficiency']:.2f}",
                )
            )
    out = pathlib.Path(out_path)
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--docs", default="10000",
        help="comma list of corpus sizes (default 10000)",
    )
    ap.add_argument(
        "--replicas", default="1,2,4", help="comma list of replica counts"
    )
    ap.add_argument(
        "--dry-run", action="store_true",
        help="tiny smoke run (CI entry-point check): ~1k docs, 1-2 replicas",
    )
    ap.add_argument(
        "--segment-docs", type=int, default=256,
        help="docs sealed per pool segment (finer segmentation spreads "
        "work across replicas more evenly; must match the baseline)",
    )
    ap.add_argument("--out", default="results/BENCH_scale.json")
    args = ap.parse_args()
    kw: dict = dict(out_path=args.out, segment_docs=args.segment_docs)
    if args.dry_run:
        sizes: tuple = (1024,)
        replicas = (1, 2)
        kw.update(n_requests=64, segment_docs=128, encode_batch=512)
    else:
        sizes = tuple(int(s) for s in args.docs.split(","))
        replicas = tuple(int(r) for r in args.replicas.split(","))
    print("name,us_per_call,derived")
    for r in run(sizes, replicas, **kw):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
