"""Paper Figure 14: scalability — build time, index size, and query latency
vs corpus size (CPU-scaled sizes; the trends are the claim)."""

from __future__ import annotations

import time


from benchmarks.common import default_build, simple_corpus, timed
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights


def run(sizes=(2048, 4096, 8192, 16384), n_queries=32):
    rows = []
    w = PathWeights.three_path()
    params = SearchParams(k=10, iters=48, pool_size=64)
    for n in sizes:
        corpus = simple_corpus(n, n_queries, seed=17)
        cfg = default_build(n)
        t0 = time.perf_counter()
        index = build_index(corpus.docs, cfg)
        build_s = time.perf_counter() - t0
        size_mb = sum(index.edge_nbytes().values()) / 1e6
        ids, sec = timed(lambda: search(index, corpus.queries, w, params).ids)
        rows.append((f"fig14.n{n}", sec * 1e6 / n_queries,
                     f"build_s={build_s:.1f};size_mb={size_mb:.1f};qps={n_queries/sec:.0f}"))
    return rows
