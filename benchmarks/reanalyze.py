"""Re-run the HLO analysis over saved .hlo.gz artifacts (no recompilation)
and refresh the dry-run JSON records in place."""

from __future__ import annotations

import argparse
import gzip
import json
import pathlib

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def reanalyze(json_path: pathlib.Path) -> bool:
    hlo_path = json_path.with_suffix(".hlo.gz")
    if not hlo_path.exists():
        return False
    record = json.loads(json_path.read_text())
    if str(record.get("status", "")).startswith("SKIP"):
        return False
    text = gzip.open(hlo_path, "rt").read()
    hlo = analyze_hlo(text)
    record["hlo"] = hlo
    if record.get("model_flops_per_device") and hlo["dot_flops"] > 0:
        record["useful_flops_ratio"] = (
            record["model_flops_per_device"] / hlo["dot_flops"]
        )
    record["roofline"] = roofline_terms(
        hlo_flops=hlo["dot_flops"],
        hlo_bytes=hlo["hbm_bytes"],
        coll_bytes_per_device=hlo["collective_bytes"],
        n_chips=record["n_devices"],
    )
    json_path.write_text(json.dumps(record, indent=1))
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    n = 0
    for f in sorted(pathlib.Path(args.dir).glob("*.json")):
        if f.name == "summary.json":
            continue
        if reanalyze(f):
            n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
