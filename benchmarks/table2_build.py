"""Paper Table 2: index build time and index size across methods.

Index size counts index structures + stored vectors (the unified index
stores one copy of the vectors; ThreeRoute needs three graphs; the paper's
headline is exactly this storage reduction)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    IVFFusion,
    SparseInvertedIndex,
    ThreeRoute,
    default_build,
    simple_corpus,
)
from repro.core import build_index


def run(n_docs=8192):
    corpus = simple_corpus(n_docs, 8)
    cfg = default_build(corpus.docs.n)
    rows = []

    t0 = time.perf_counter()
    index = build_index(corpus.docs, cfg)
    ap_time = time.perf_counter() - t0
    sizes = index.edge_nbytes()
    ap_size = sum(sizes.values())
    rows.append(("table2.allanpoe.build_s", ap_time * 1e6, f"size_mb={ap_size/1e6:.1f};edges_mb={(ap_size-sizes['vectors'])/1e6:.2f}"))

    tr = ThreeRoute.build(corpus.docs, cfg)
    rows.append(("table2.three_route.build_s", tr.build_s * 1e6, f"size_mb={tr.nbytes()/1e6:.1f}"))

    inv = SparseInvertedIndex(corpus.docs)
    rows.append(("table2.sparse_inverted.build_s", inv.build_s * 1e6, f"size_mb={inv.nbytes()/1e6:.1f}"))

    ivf = IVFFusion(corpus.docs, n_clusters=max(n_docs // 128, 16))
    rows.append(("table2.ivf_fusion.build_s", ivf.build_s * 1e6, f"size_mb={ivf.nbytes()/1e6:.1f}"))
    return rows
