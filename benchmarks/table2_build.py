"""Paper Table 2: index build time and index size across methods, plus the
build perf trajectory record (BENCH_build.json).

Index size counts index structures + stored vectors (the unified index
stores one copy of the vectors; ThreeRoute needs three graphs; the paper's
headline is exactly this storage reduction).

BENCH_build.json tracks the device-resident pipeline vs the legacy
host-driven path across PRs: build wall-clock (cold = first build including
compile, warm = steady-state), host->device dispatch count (see
repro/runtime/dispatch.py for what is counted), and peak process RSS for
the pipeline path (measured first; ru_maxrss is a process-lifetime
high-water mark, so only the first-measured path's peak is attributable).
"""

from __future__ import annotations

import json
import pathlib
import resource
import sys
import time

import jax

from benchmarks.common import (
    IVFFusion,
    SparseInvertedIndex,
    ThreeRoute,
    default_build,
    simple_corpus,
)
from repro.core import build_index
from repro.runtime import dispatch


def _peak_rss_bytes() -> int:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru * (1 if sys.platform == "darwin" else 1024)


def _timed_build(docs, cfg, *, pipeline: bool, record_rss: bool) -> tuple[object, dict]:
    with dispatch.track() as t:
        t0 = time.perf_counter()
        index = build_index(docs, cfg, pipeline=pipeline)
        jax.block_until_ready(jax.tree.leaves(index))
        cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = build_index(docs, cfg, pipeline=pipeline)
    jax.block_until_ready(jax.tree.leaves(warm))
    warm_s = time.perf_counter() - t0
    sizes = index.edge_nbytes()
    return index, {
        "build_s_cold": cold_s,
        "build_s_warm": warm_s,
        "dispatches": t.count,
        # ru_maxrss is a process-lifetime high-water mark, so it is only
        # attributable to the path measured FIRST (the pipeline); later
        # paths inherit the earlier peak and would compare as >= regardless
        "peak_rss_bytes": _peak_rss_bytes() if record_rss else None,
        "index_bytes": sum(sizes.values()),
        "edge_bytes": sum(sizes.values()) - sizes["vectors"],
    }


def run(n_docs=8192, out_dir="results"):
    corpus = simple_corpus(n_docs, 8)
    cfg = default_build(corpus.docs.n)
    rows = []

    index, pipe = _timed_build(corpus.docs, cfg, pipeline=True, record_rss=True)
    _, legacy = _timed_build(corpus.docs, cfg, pipeline=False, record_rss=False)
    rows.append((
        "table2.allanpoe.build_s",
        pipe["build_s_warm"] * 1e6,
        f"size_mb={pipe['index_bytes']/1e6:.1f};edges_mb={pipe['edge_bytes']/1e6:.2f}",
    ))
    rows.append((
        "table2.allanpoe_legacy.build_s",
        legacy["build_s_warm"] * 1e6,
        f"dispatch_ratio={legacy['dispatches']/max(pipe['dispatches'],1):.0f}x",
    ))

    bench = {
        "config": {"n_docs": n_docs, "degree": cfg.prune.degree, "knn_k": cfg.knn.k,
                   "knn_iters": cfg.knn.iters, "backend": jax.default_backend()},
        "pipeline": pipe,
        "legacy": legacy,
        "speedup_warm": legacy["build_s_warm"] / pipe["build_s_warm"],
        "dispatch_ratio": legacy["dispatches"] / max(pipe["dispatches"], 1),
    }
    out = pathlib.Path(out_dir)
    out.mkdir(exist_ok=True)
    (out / "BENCH_build.json").write_text(json.dumps(bench, indent=2) + "\n")

    tr = ThreeRoute.build(corpus.docs, cfg)
    rows.append(("table2.three_route.build_s", tr.build_s * 1e6, f"size_mb={tr.nbytes()/1e6:.1f}"))

    inv = SparseInvertedIndex(corpus.docs)
    rows.append(("table2.sparse_inverted.build_s", inv.build_s * 1e6, f"size_mb={inv.nbytes()/1e6:.1f}"))

    ivf = IVFFusion(corpus.docs, n_clusters=max(n_docs // 128, 16))
    rows.append(("table2.ivf_fusion.build_s", ivf.build_s * 1e6, f"size_mb={ivf.nbytes()/1e6:.1f}"))
    return rows
