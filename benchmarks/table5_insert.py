"""Paper Table 5 + Figure 13: insertion cost vs full rebuild, and the
retrieval quality of updated indexes."""

from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import default_build, simple_corpus
from repro.core import build_index, insert
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights, weighted_query
from repro.data.corpus import recall_at_k
from repro.kernels import ops


def run(n_docs=4096, n_queries=64):
    corpus = simple_corpus(n_docs, n_queries)
    cfg = default_build(n_docs)
    w = PathWeights.three_path()
    params = SearchParams(k=10, iters=40, pool_size=64)
    qw = weighted_query(corpus.queries, w)
    scores = ops.pairwise_scores_chunked(qw, corpus.docs)
    _, truth = jax.lax.top_k(scores, 10)
    truth = np.asarray(truth)

    t0 = time.perf_counter()
    full_index = build_index(corpus.docs, cfg)
    rebuild_s = time.perf_counter() - t0
    res = search(full_index, corpus.queries, w, params)
    rec_full = recall_at_k(np.asarray(res.ids), truth)
    rows = [("table5.rebuild", rebuild_s * 1e6, f"recall={rec_full:.3f}")]

    for frac in (0.05, 0.10, 0.20):
        n_keep = int(n_docs * (1 - frac))
        base = build_index(corpus.docs[slice(0, n_keep)], cfg)
        new_docs = corpus.docs[slice(n_keep, n_docs)]
        t0 = time.perf_counter()
        upd = insert(base, new_docs, cfg)
        ins_s = time.perf_counter() - t0
        res = search(upd, corpus.queries, w, params)
        rec = recall_at_k(np.asarray(res.ids), truth)
        rows.append((f"table5.insert_{int(frac*100)}pct", ins_s * 1e6,
                     f"recall={rec:.3f};vs_rebuild={ins_s/rebuild_s:.2%}"))
    return rows
