"""Shared benchmark substrate: datasets, competitor methods, timing.

The paper's six datasets are modeled by two synthetic corpora (simple /
multi-hop; see data/corpus.py). Competitors are faithful CPU analogues of the
paper's baselines:

  bruteforce   — exact hybrid top-k (ground truth + QPS floor)
  sparse-inv   — SEISMIC-style inverted index over learned sparse vectors
  ivf-fusion   — IVF over [dense ; JL-projected sparse] fused vectors
  three-route  — one single-path graph index per path + weighted-sum fusion
                 (the paper's ThreeRouteGPU)
  allan-poe-*  — our unified index, one build, every path combination
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.index import HybridIndex
from repro.core.search import SearchParams, search
from repro.core.usms import PAD_IDX, FusedVectors, PathWeights, weighted_query
from repro.data.corpus import (
    CorpusConfig,
    SyntheticCorpus,
    make_corpus,
    ndcg_at_k,
    recall_at_k,
)
from repro.kernels import ops


def default_build(n_docs: int) -> BuildConfig:
    return BuildConfig(
        knn=KnnConfig(k=32, iters=5, node_chunk=min(n_docs, 2048)),
        prune=PruneConfig(degree=32, keyword_degree=8, node_chunk=512),
        path_refine_iters=2,
    )


def simple_corpus(n_docs=8192, n_queries=64, seed=11) -> SyntheticCorpus:
    """NQ/MS-like: single-hop, mixed informative paths."""
    return make_corpus(
        CorpusConfig(n_docs=n_docs, n_queries=n_queries, n_topics=max(n_docs // 64, 8),
                     d_dense=96, nnz_sparse=24, nnz_lexical=12, seed=seed)
    )


def multihop_corpus(n_docs=4096, n_queries=64, seed=13) -> SyntheticCorpus:
    """WM/HP-like: entity chains, multi-hop ground truth."""
    return make_corpus(
        CorpusConfig(n_docs=n_docs, n_queries=n_queries, n_topics=max(n_docs // 64, 8),
                     d_dense=96, nnz_sparse=24, nnz_lexical=12, chain_len=3, seed=seed)
    )


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, seconds) — median of `repeats` after one warmup."""
    fn(*args, **kw)  # warmup / compile
    ts = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, jax.Array
        ) else None
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


# ---------------------------------------------------------------------------
# competitor: brute force
# ---------------------------------------------------------------------------


def bruteforce_topk(corpus, queries, weights, k=10):
    qw = weighted_query(queries, weights)
    scores = ops.pairwise_scores_chunked(qw, corpus)
    top, ids = jax.lax.top_k(scores, k)
    return np.asarray(ids)


# ---------------------------------------------------------------------------
# competitor: SEISMIC-style sparse inverted index
# ---------------------------------------------------------------------------


class SparseInvertedIndex:
    """Learned-sparse-only retrieval via an inverted index with top-p static
    pruning (the SEISMIC recipe, numpy analogue)."""

    def __init__(self, docs: FusedVectors, posting_cap: int = 256):
        t0 = time.perf_counter()
        idx = np.asarray(docs.learned.idx)
        val = np.asarray(docs.learned.val)
        self.vocab_lists: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        flat_t = idx.reshape(-1)
        flat_v = val.reshape(-1)
        flat_d = np.repeat(np.arange(idx.shape[0]), idx.shape[1])
        ok = flat_t >= 0
        order = np.lexsort((-flat_v[ok], flat_t[ok]))
        t_sorted = flat_t[ok][order]
        v_sorted = flat_v[ok][order]
        d_sorted = flat_d[ok][order]
        bounds = np.searchsorted(t_sorted, np.unique(t_sorted))
        uniq = np.unique(t_sorted)
        for i, term in enumerate(uniq):
            lo = bounds[i]
            hi = bounds[i + 1] if i + 1 < len(bounds) else len(t_sorted)
            hi = min(hi, lo + posting_cap)  # static pruning
            self.vocab_lists[int(term)] = (d_sorted[lo:hi], v_sorted[lo:hi])
        self.n_docs = idx.shape[0]
        self.build_s = time.perf_counter() - t0

    def nbytes(self) -> int:
        return sum(d.nbytes + v.nbytes for d, v in self.vocab_lists.values())

    def query(self, q_idx: np.ndarray, q_val: np.ndarray, k: int = 10) -> np.ndarray:
        out = np.zeros((len(q_idx), k), np.int32)
        for qi in range(len(q_idx)):
            acc = np.zeros(self.n_docs, np.float32)
            for t, v in zip(q_idx[qi], q_val[qi]):
                if t < 0:
                    continue
                lst = self.vocab_lists.get(int(t))
                if lst is None:
                    continue
                acc[lst[0]] += v * lst[1]
            out[qi] = np.argsort(-acc)[:k]
        return out


# ---------------------------------------------------------------------------
# competitor: IVF-Fusion (JL-projected sparse + dense, inverted file)
# ---------------------------------------------------------------------------


class IVFFusion:
    def __init__(self, docs: FusedVectors, n_clusters: int = 64, jl_dim: int = 64,
                 seed: int = 0, kmeans_iters: int = 8):
        t0 = time.perf_counter()
        rng = np.random.default_rng(seed)
        dense = np.asarray(docs.dense, np.float32)
        sp_idx = np.asarray(docs.learned.idx)
        sp_val = np.asarray(docs.learned.val, np.float32)
        vocab_guess = int(sp_idx.max()) + 1
        self._jl = rng.normal(0, 1 / np.sqrt(jl_dim), size=(vocab_guess, jl_dim)).astype(
            np.float32
        )
        self.fused = np.concatenate([dense, self._project(sp_idx, sp_val)], axis=1)
        # k-means
        cents = self.fused[rng.choice(len(self.fused), n_clusters, replace=False)]
        for _ in range(kmeans_iters):
            assign = np.argmax(self.fused @ cents.T, axis=1)
            for c in range(n_clusters):
                m = assign == c
                if m.any():
                    cents[c] = self.fused[m].mean(0)
        self.cents = cents
        self.assign = np.argmax(self.fused @ cents.T, axis=1)
        self.lists = [np.nonzero(self.assign == c)[0] for c in range(n_clusters)]
        self.build_s = time.perf_counter() - t0

    def _project(self, idx, val):
        out = np.zeros((len(idx), self._jl.shape[1]), np.float32)
        for r in range(len(idx)):
            ok = idx[r] >= 0
            if ok.any():
                out[r] = val[r][ok] @ self._jl[idx[r][ok]]
        return out

    def nbytes(self) -> int:
        return self.fused.nbytes + self.cents.nbytes + sum(l.nbytes for l in self.lists)

    def query(self, queries: FusedVectors, weights: PathWeights, k=10, nprobe=8):
        qd = np.asarray(queries.dense, np.float32) * float(weights.dense)
        qs = self._project(
            np.asarray(queries.learned.idx), np.asarray(queries.learned.val)
        ) * float(weights.sparse)
        qf = np.concatenate([qd, qs], axis=1)
        out = np.zeros((len(qf), k), np.int32)
        for qi in range(len(qf)):
            probes = np.argsort(-(qf[qi] @ self.cents.T))[:nprobe]
            cand = np.concatenate([self.lists[c] for c in probes])
            scores = self.fused[cand] @ qf[qi]
            out[qi] = cand[np.argsort(-scores)[:k]]
        return out


# ---------------------------------------------------------------------------
# competitor: ThreeRoute (separate per-path graph indexes + fusion)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ThreeRoute:
    """The paper's ThreeRouteGPU: one graph index per retrieval path, results
    fused by weighted sum of path scores over the union of top-k'."""

    indexes: list  # [dense, sparse, full] single-path HybridIndexes
    build_s: float

    @classmethod
    def build(cls, docs: FusedVectors, cfg: BuildConfig):
        from repro.core.knn_graph import build_knn_graph
        from repro.core.pruning import rng_ip_prune

        t0 = time.perf_counter()
        base = build_index(
            docs,
            dataclasses.replace(
                cfg, path_refine_iters=0, knn=dataclasses.replace(cfg.knn, iters=0)
            ),
        )
        idxs = []
        for w in (PathWeights.make(1, 0, 0), PathWeights.make(0, 1, 0),
                  PathWeights.make(0, 0, 1)):
            # a single-path index: build the graph under that path's metric
            qcorp = weighted_query(docs, w)
            knn_ids, knn_scores = build_knn_graph(
                docs, cfg.knn, jax.random.key(0), queries=qcorp
            )
            sem, kw = rng_ip_prune(docs, knn_ids, knn_scores, cfg.prune)
            idxs.append(dataclasses.replace(base, semantic_edges=sem, keyword_edges=kw))
        return cls(idxs, time.perf_counter() - t0)

    def nbytes(self) -> int:
        return sum(
            i.edge_nbytes()["semantic"] + i.edge_nbytes()["keyword"] for i in self.indexes
        ) + self.indexes[0].edge_nbytes()["vectors"]

    def query(self, queries: FusedVectors, weights: PathWeights, params: SearchParams,
              k=10, k_route=30):
        """Search each route for top-k', fuse by weighted hybrid score."""
        single = [PathWeights.make(1, 0, 0), PathWeights.make(0, 1, 0),
                  PathWeights.make(0, 0, 1)]
        route_params = dataclasses.replace(params, k=k_route)
        all_ids = []
        for idx, w in zip(self.indexes, single):
            res = search(idx, queries, w, route_params)
            all_ids.append(np.asarray(res.ids))
        union = np.concatenate(all_ids, axis=1)  # (B, 3k')
        # rescore the union under the full hybrid weights (weighted-sum fusion)
        qw = weighted_query(queries, weights)
        ids = jnp.asarray(union)
        scores = ops.hybrid_scores_vs_ids(qw, self.indexes[0].corpus, ids)
        # dedup by id
        from repro.core.knn_graph import dedup_mask

        keep = jax.vmap(dedup_mask)(ids)
        scores = jnp.where(keep, scores, -jnp.inf)
        top, pos = jax.lax.top_k(scores, k)
        return np.asarray(jnp.take_along_axis(ids, pos, axis=-1))
