"""CI perf-regression gate over the build bench (results/BENCH_build.json).

Compares the fresh bench against the committed baseline
(results/BENCH_build_baseline.json) and fails the job when the
device-resident pipeline regresses:

  * ``pipeline.dispatches`` may NEVER rise — the single-dispatch build is a
    structural contract (DESIGN.md §3), not a timing, so this check is
    exact and noise-free;
  * ``speedup_warm`` (legacy warm build / pipeline warm build) may not drop
    more than ``--tol`` (default 20%) below the baseline — a ratio of two
    same-machine timings, so it tolerates absolute CPU-speed differences
    between runners, and the wide tolerance absorbs CI scheduler noise.

Wall-clock fields are reported but never gated: absolute seconds are
machine-dependent and would flake.

The baseline must have been produced by the SAME bench config the gate run
used (the kernel-smoke job runs ``python -m benchmarks.run --quick --only
table2``); a config mismatch fails with instructions rather than comparing
apples to oranges.

    PYTHONPATH=src python benchmarks/check_regression.py \
        [--bench results/BENCH_build.json] \
        [--baseline results/BENCH_build_baseline.json] [--tol 0.20]

Exit code 0 = pass, 1 = regression (or unusable inputs).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python -m benchmarks.run --quick "
    "--only table2 && cp results/BENCH_build.json "
    "results/BENCH_build_baseline.json"
)


def check(bench: dict, baseline: dict, tol: float) -> list[str]:
    """Returns the list of failure messages (empty = gate passes)."""
    failures: list[str] = []

    cfg_b, cfg_base = bench.get("config", {}), baseline.get("config", {})
    mismatched = {
        k: (cfg_base.get(k), cfg_b.get(k))
        for k in set(cfg_base) | set(cfg_b)
        if cfg_base.get(k) != cfg_b.get(k)
    }
    if mismatched:
        return [
            f"bench config does not match the baseline ({mismatched}); "
            f"the comparison would be meaningless — {REGEN_HINT}"
        ]

    disp = bench["pipeline"]["dispatches"]
    disp_base = baseline["pipeline"]["dispatches"]
    if disp > disp_base:
        failures.append(
            f"pipeline.dispatches rose {disp_base} -> {disp}: the fused "
            "build program is issuing extra host->device round trips "
            "(single-dispatch contract, DESIGN.md §3)"
        )

    speedup = bench["speedup_warm"]
    speedup_base = baseline["speedup_warm"]
    floor = speedup_base * (1.0 - tol)
    if speedup < floor:
        failures.append(
            f"speedup_warm dropped {speedup_base:.3f} -> {speedup:.3f} "
            f"(> {tol:.0%} below baseline; floor {floor:.3f})"
        )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="results/BENCH_build.json")
    ap.add_argument("--baseline", default="results/BENCH_build_baseline.json")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.20,
        help="allowed fractional speedup_warm drop vs baseline (CPU noise)",
    )
    args = ap.parse_args()

    bench_path = pathlib.Path(args.bench)
    base_path = pathlib.Path(args.baseline)
    if not bench_path.exists():
        print(f"FAIL: {bench_path} missing — run the build bench first")
        return 1
    if not base_path.exists():
        print(f"FAIL: {base_path} missing — {REGEN_HINT}")
        return 1
    bench = json.loads(bench_path.read_text())
    baseline = json.loads(base_path.read_text())

    print(
        f"bench:    dispatches={bench['pipeline']['dispatches']} "
        f"speedup_warm={bench['speedup_warm']:.3f} "
        f"warm_s={bench['pipeline']['build_s_warm']:.2f}"
    )
    print(
        f"baseline: dispatches={baseline['pipeline']['dispatches']} "
        f"speedup_warm={baseline['speedup_warm']:.3f} "
        f"warm_s={baseline['pipeline']['build_s_warm']:.2f}"
    )

    failures = check(bench, baseline, args.tol)
    for f in failures:
        print(f"FAIL: {f}")
    if not failures:
        print(f"PASS: no build perf regression (tol={args.tol:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
