"""CI perf-regression gates over the bench JSONs.

**Build gate** (default): compares results/BENCH_build.json against the
committed results/BENCH_build_baseline.json and fails the job when the
device-resident pipeline regresses:

  * ``pipeline.dispatches`` may NEVER rise — the single-dispatch build is a
    structural contract (DESIGN.md §3), not a timing, so this check is
    exact and noise-free;
  * ``speedup_warm`` (legacy warm build / pipeline warm build) may not drop
    more than ``--tol`` (default 20%) below the baseline — a ratio of two
    same-machine timings, so it tolerates absolute CPU-speed differences
    between runners, and the wide tolerance absorbs CI scheduler noise.

**Serving gate** (``--serving-only``): compares results/BENCH_serving.json
against results/BENCH_serving_baseline.json. The first-pass thresholds were
deliberately lenient; with CI runs establishing the noise floor they are now
tightened (the ROADMAP item):

  * per-bucket steady QPS may not drop below ``1 - --qps-tol`` (default
    allows a 50% drop) of the baseline — absolute QPS is machine-dependent,
    so only a collapse fails;
  * per-bucket steady p99 may not rise above ``1 + --p99-tol`` (default
    allows a 1.5x rise, i.e. a 2.5x ceiling) of the baseline;
  * ``streaming.sealed_cache_stable`` must stay true — exact and
    noise-free: false means streaming inserts evicted sealed executables
    (the grow-segment scheme's core invariant, DESIGN.md §6);
  * ``compaction.incremental.sealed_cache_stable`` must stay true — false
    means an incremental compaction evicted executables of untouched
    segments (the segment-pool cache-survival guarantee, DESIGN.md §8).

**Scale gate** (``--all --only scale``, the nightly job): compares
results/BENCH_scale.json against results/BENCH_scale_baseline.json:

  * ``scaling_efficiency`` (replica-tier QPS efficiency from 1 to max
    replicas, see ``benchmarks/fig14_scale.py``) must stay at or above the
    ABSOLUTE floor (0.6) — this is the paper-facing scale-out claim, not a
    relative drift check;
  * per-replica-count ``model_qps`` may not collapse below
    ``1 - replica_qps_tol`` of the baseline.

**Kernel gate** (``--all --only kernel``): compares results/BENCH_kernel.json
against results/BENCH_kernel_baseline.json (both from
``benchmarks/kernel_bench.py --dry-run`` in CI):

  * every sweep pair's modeled ``bytes_fused`` must stay strictly below
    ``bytes_unfused`` — exact and noise-free: the fused selection kernel's
    whole point is eliminating the (B, C) score round-trip through HBM
    (DESIGN.md §10), so a model regression means the fused path re-acquired
    it;
  * modeled selection-lane utilization must match the baseline exactly
    (deterministic — it only moves if the K-padding rule changes);
  * per-pair fused latency may not rise above ``1 + latency_tol`` of the
    baseline (generous: dry-run shapes are dispatch-dominated);
  * the MEAN fused/unfused latency ratio across the sweep may not rise
    above ``1 + ratio_tol`` of the baseline mean — per-pair ratios on a
    CPU runner are noise (both strategies lower to XLA there), but the
    12-pair mean is stable enough to catch the fused path regressing
    relative to the unfused one.

**Quantized gate** (``--all --only quantized``): reads the SAME
results/BENCH_kernel.json pair as the kernel gate (kernel_bench.py emits
both sections):

  * every sweep point's modeled ``bytes_quantized`` must stay strictly
    below ``bytes_fused`` — exact and noise-free: the int8 corpus path
    must shrink the candidate stream itself, not just the score
    round-trip (DESIGN.md §13);
  * bundled-corpus recall@10 of quantized traversal + full-precision
    rescore may not fall more than ``recall_drop_tol`` below the fp32
    recall from the same run (deterministic up to tie order);
  * search_padded trace counts are gated EXACTLY: the fp32-vs-int8 sweep
    must trace the baseline count and repeat searches must trace ZERO
    times — corpus dtype is a build/cache-key property, not traced data
    (zero-recompile contract, DESIGN.md §11);
  * ``interpret_check_quantized`` must be "ok" on dry runs (Pallas
    dequant-in-tile vs jnp oracle).

**``--all`` mode**: run every gate in one invocation, driven by the
committed ``results/gate_config.json`` — per-metric tolerances live in
DATA, so tightening a gate is a one-line data diff, and the three
historical CLI invocations collapse into one. ``--only build,serving,kernel``
filters. The legacy single-gate flags keep working for local use.

Wall-clock fields are reported but never gated: absolute seconds are
machine-dependent and would flake.

The baseline must have been produced by the SAME bench config the gate run
used (the kernel-smoke job runs ``python -m benchmarks.run --quick --only
table2``); a config mismatch fails with instructions rather than comparing
apples to oranges.

    PYTHONPATH=src python benchmarks/check_regression.py --all \
        [--config results/gate_config.json] [--only build,serving,scale]

Exit code 0 = pass, 1 = regression (or unusable inputs).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python -m benchmarks.run --quick "
    "--only table2 && cp results/BENCH_build.json "
    "results/BENCH_build_baseline.json"
)

SERVING_REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python benchmarks/serving_bench.py "
    "--dry-run && cp results/BENCH_serving.json "
    "results/BENCH_serving_baseline.json"
)

SCALE_REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python benchmarks/fig14_scale.py "
    "--docs 10000 && cp results/BENCH_scale.json "
    "results/BENCH_scale_baseline.json"
)

KERNEL_REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python benchmarks/kernel_bench.py "
    "--dry-run && cp results/BENCH_kernel.json "
    "results/BENCH_kernel_baseline.json"
)

FUSION_REGEN_HINT = (
    "regenerate with: PYTHONPATH=src python benchmarks/fig12_weights.py "
    "--dry-run && cp results/BENCH_fusion.json "
    "results/BENCH_fusion_baseline.json"
)


def _config_mismatch(cfg_base: dict, cfg_b: dict) -> dict:
    return {
        k: (cfg_base.get(k), cfg_b.get(k))
        for k in set(cfg_base) | set(cfg_b)
        if cfg_base.get(k) != cfg_b.get(k)
    }


def check_serving(
    bench: dict, baseline: dict, qps_tol: float, p99_tol: float
) -> list[str]:
    """Lenient first-pass serving gate; returns failure messages."""
    failures: list[str] = []
    steady_b = bench.get("steady", {})
    steady_base = baseline.get("steady", {})
    if not steady_b or not steady_base:
        return ["steady section missing from bench or baseline — "
                + SERVING_REGEN_HINT]
    mismatched = _config_mismatch(
        steady_base.get("config", {}), steady_b.get("config", {})
    )
    if mismatched:
        return [
            f"serving bench config does not match the baseline "
            f"({mismatched}); the comparison would be meaningless — "
            f"{SERVING_REGEN_HINT}"
        ]
    for bucket, base_vals in steady_base.get("buckets", {}).items():
        vals = steady_b.get("buckets", {}).get(bucket)
        if vals is None:
            failures.append(f"steady bucket {bucket} missing from bench")
            continue
        qps_floor = base_vals["qps"] * (1.0 - qps_tol)
        if vals["qps"] < qps_floor:
            failures.append(
                f"bucket {bucket}: steady QPS collapsed "
                f"{base_vals['qps']:.0f} -> {vals['qps']:.0f} "
                f"(> {qps_tol:.0%} below baseline; floor {qps_floor:.0f})"
            )
        p99_ceiling = base_vals["p99_ms"] * (1.0 + p99_tol)
        if vals["p99_ms"] > p99_ceiling:
            failures.append(
                f"bucket {bucket}: p99 blew up "
                f"{base_vals['p99_ms']:.1f}ms -> {vals['p99_ms']:.1f}ms "
                f"(> {1 + p99_tol:.0f}x baseline; ceiling {p99_ceiling:.1f}ms)"
            )
    streaming = bench.get("streaming")
    if streaming is not None and not streaming.get("sealed_cache_stable", True):
        failures.append(
            "streaming.sealed_cache_stable is false: inserts evicted "
            "sealed-segment executables (grow-segment invariant, DESIGN.md §6)"
        )
    incremental = bench.get("compaction", {}).get("incremental")
    if incremental is not None and not incremental.get(
        "sealed_cache_stable", True
    ):
        failures.append(
            "compaction.incremental.sealed_cache_stable is false: an "
            "incremental compaction evicted executables of untouched "
            "segments (segment-pool cache-survival guarantee, DESIGN.md §8)"
        )
    return failures


def check(bench: dict, baseline: dict, tol: float) -> list[str]:
    """Returns the list of failure messages (empty = gate passes)."""
    failures: list[str] = []

    mismatched = _config_mismatch(
        baseline.get("config", {}), bench.get("config", {})
    )
    if mismatched:
        return [
            f"bench config does not match the baseline ({mismatched}); "
            f"the comparison would be meaningless — {REGEN_HINT}"
        ]

    disp = bench["pipeline"]["dispatches"]
    disp_base = baseline["pipeline"]["dispatches"]
    if disp > disp_base:
        failures.append(
            f"pipeline.dispatches rose {disp_base} -> {disp}: the fused "
            "build program is issuing extra host->device round trips "
            "(single-dispatch contract, DESIGN.md §3)"
        )

    speedup = bench["speedup_warm"]
    speedup_base = baseline["speedup_warm"]
    floor = speedup_base * (1.0 - tol)
    if speedup < floor:
        failures.append(
            f"speedup_warm dropped {speedup_base:.3f} -> {speedup:.3f} "
            f"(> {tol:.0%} below baseline; floor {floor:.3f})"
        )
    return failures


def check_scale(
    bench: dict,
    baseline: dict,
    efficiency_floor: float,
    replica_qps_tol: float,
) -> list[str]:
    """Nightly scale gate: absolute scaling-efficiency floor plus relative
    per-replica-count model-QPS collapse; returns failure messages."""
    failures: list[str] = []
    mismatched = _config_mismatch(
        baseline.get("config", {}), bench.get("config", {})
    )
    if mismatched:
        return [
            f"scale bench config does not match the baseline ({mismatched}); "
            f"the comparison would be meaningless — {SCALE_REGEN_HINT}"
        ]
    for size, base_scale in baseline.get("scales", {}).items():
        scale = bench.get("scales", {}).get(size)
        if scale is None:
            failures.append(f"scale {size} missing from bench")
            continue
        eff = scale.get("scaling_efficiency", 0.0)
        if eff < efficiency_floor:
            failures.append(
                f"n={size}: scaling efficiency {eff:.2f} below the "
                f"{efficiency_floor:.2f} floor — replica-tier QPS no longer "
                "scales (benchmarks/fig14_scale.py)"
            )
        for n_rep, base_vals in base_scale.get("replicas", {}).items():
            vals = scale.get("replicas", {}).get(n_rep)
            if vals is None:
                failures.append(f"n={size} R={n_rep} missing from bench")
                continue
            floor = base_vals["model_qps"] * (1.0 - replica_qps_tol)
            if vals["model_qps"] < floor:
                failures.append(
                    f"n={size} R={n_rep}: per-replica QPS collapsed "
                    f"{base_vals['model_qps']:.0f} -> "
                    f"{vals['model_qps']:.0f} (> {replica_qps_tol:.0%} "
                    f"below baseline; floor {floor:.0f})"
                )
    return failures


def check_kernel(
    bench: dict, baseline: dict, ratio_tol: float, latency_tol: float
) -> list[str]:
    """Fused-selection kernel gate; returns failure messages."""
    failures: list[str] = []
    mismatched = _config_mismatch(
        baseline.get("config", {}), bench.get("config", {})
    )
    if mismatched:
        return [
            f"kernel bench config does not match the baseline ({mismatched}); "
            f"the comparison would be meaningless — {KERNEL_REGEN_HINT}"
        ]
    sweep_b = bench.get("sweep", {})
    sweep_base = baseline.get("sweep", {})
    if not sweep_b or not sweep_base:
        return ["sweep section missing from bench or baseline — "
                + KERNEL_REGEN_HINT]
    ratios_b: list[float] = []
    ratios_base: list[float] = []
    for name, base_vals in sweep_base.items():
        vals = sweep_b.get(name)
        if vals is None:
            failures.append(f"sweep pair {name} missing from bench")
            continue
        model = vals.get("model", {})
        if model.get("bytes_fused", 1) >= model.get("bytes_unfused", 0):
            failures.append(
                f"{name}: modeled bytes_fused "
                f"{model.get('bytes_fused')} >= bytes_unfused "
                f"{model.get('bytes_unfused')} — the fused path no longer "
                "eliminates the (B, C) score round-trip (DESIGN.md §10)"
            )
        base_model = base_vals.get("model", {})
        if model.get("lane_util_selection") != base_model.get(
            "lane_util_selection"
        ):
            failures.append(
                f"{name}: selection lane utilization drifted "
                f"{base_model.get('lane_util_selection')} -> "
                f"{model.get('lane_util_selection')} — the K-padding rule "
                "changed (k_pad, DESIGN.md §10)"
            )
        ceiling = base_vals["fused_us_per_pair"] * (1.0 + latency_tol)
        if vals["fused_us_per_pair"] > ceiling:
            failures.append(
                f"{name}: fused per-pair latency blew up "
                f"{base_vals['fused_us_per_pair']:.3f}us -> "
                f"{vals['fused_us_per_pair']:.3f}us "
                f"(> {1 + latency_tol:.0f}x baseline; ceiling {ceiling:.3f}us)"
            )
        ratios_b.append(vals["fused_ratio"])
        ratios_base.append(base_vals["fused_ratio"])
    if ratios_b:
        mean_b = sum(ratios_b) / len(ratios_b)
        mean_base = sum(ratios_base) / len(ratios_base)
        mean_ceiling = mean_base * (1.0 + ratio_tol)
        if mean_b > mean_ceiling:
            failures.append(
                f"mean fused/unfused ratio regressed "
                f"{mean_base:.3f} -> {mean_b:.3f} "
                f"(> {ratio_tol:.0%} above baseline; ceiling "
                f"{mean_ceiling:.3f}) — the fused path lost its edge over "
                "score-then-top_k"
            )
    if bench.get("config", {}).get("dry_run") and (
        bench.get("interpret_check") != "ok"
    ):
        failures.append(
            "interpret_check missing or failed: the dry-run sweep must "
            "verify Pallas-vs-oracle equality (kernel_bench.py --dry-run)"
        )
    return failures


def check_quantized(
    bench: dict, baseline: dict, recall_drop_tol: float
) -> list[str]:
    """Quantized-corpus gate over BENCH_kernel.json (the same artifact the
    kernel gate reads — kernel_bench.py emits both); returns failure
    messages. Everything here is exact or deterministic:

      * every sweep point's modeled ``bytes_quantized`` must stay strictly
        below ``bytes_fused`` — the int8 corpus path's whole point is
        shrinking the candidate stream itself (DESIGN.md §13); a model
        regression means dequant-in-tile re-acquired fp32 traffic;
      * bundled-corpus ``recall_at_10_int8`` (quantized traversal +
        full-precision rescore) may not fall more than ``recall_drop_tol``
        below ``recall_at_10_fp32`` from the SAME run — a same-machine
        comparison, so the floor is tight;
      * ``sweep_traces`` must match the baseline and ``repeat_traces`` must
        be ZERO: corpus dtype is a build/cache-key property, not traced
        data, so searching fp32 and int8 indexes back-to-back must not
        retrace search_padded (zero-recompile contract, DESIGN.md §11);
      * ``interpret_check_quantized`` must be "ok" on dry runs — the
        Pallas dequant-in-tile kernel vs the jnp oracle, bit-for-bit
        positions.
    """
    failures: list[str] = []
    sweep_b = bench.get("sweep", {})
    if not sweep_b:
        return ["sweep section missing from bench — " + KERNEL_REGEN_HINT]
    for name, vals in sorted(sweep_b.items()):
        model = vals.get("model", {})
        bq = model.get("bytes_quantized")
        if bq is None:
            failures.append(
                f"{name}: bytes_quantized missing from the bytes model — "
                "the quantized sweep rows were dropped"
            )
            continue
        if bq >= model.get("bytes_fused", 0):
            failures.append(
                f"{name}: modeled bytes_quantized {bq} >= bytes_fused "
                f"{model.get('bytes_fused')} — the int8 corpus path no "
                "longer shrinks the candidate stream (DESIGN.md §13)"
            )
    q_b = bench.get("quantized", {})
    q_base = baseline.get("quantized", {})
    if not q_b or not q_base:
        return failures + [
            "quantized section missing from bench or baseline — "
            + KERNEL_REGEN_HINT
        ]
    fp32 = q_b.get("recall_at_10_fp32", 0.0)
    int8 = q_b.get("recall_at_10_int8", 0.0)
    floor = fp32 - recall_drop_tol
    if int8 < floor:
        failures.append(
            f"quantized recall@10 {int8:.3f} fell below the fp32 floor "
            f"{floor:.3f} (fp32={fp32:.3f}, drop_tol={recall_drop_tol}) — "
            "the full-precision rescore no longer recovers the quantization "
            "error (DESIGN.md §13)"
        )
    if q_b.get("sweep_traces") != q_base.get("sweep_traces"):
        failures.append(
            f"quantized sweep traced {q_b.get('sweep_traces')} time(s), "
            f"baseline {q_base.get('sweep_traces')}: the fp32/int8 trace "
            "budget changed (corpus dtype must stay a cache-key property)"
        )
    if q_b.get("repeat_traces") != 0:
        failures.append(
            f"repeat searches retraced search_padded "
            f"{q_b.get('repeat_traces')} time(s), expected 0: corpus dtype "
            "leaked into the trace signature (zero-recompile contract, "
            "DESIGN.md §11)"
        )
    if bench.get("config", {}).get("dry_run") and (
        bench.get("interpret_check_quantized") != "ok"
    ):
        failures.append(
            "interpret_check_quantized missing or failed: the dry-run sweep "
            "must verify the dequant-in-tile Pallas kernel against the jnp "
            "oracle (kernel_bench.py --dry-run)"
        )
    return failures


def check_fusion(bench: dict, baseline: dict, recall_tol: float) -> list[str]:
    """Fusion-sweep recall gate (benchmarks/fig12_weights.py); returns
    failure messages. Recall on the bundled corpus is deterministic up to
    tie order, so the tolerance is a small absolute slack, and the sweep's
    trace count is gated EXACTLY: more than one trace means fusion params
    leaked into the trace signature (the zero-recompile contract,
    DESIGN.md §11)."""
    failures: list[str] = []
    # dry_run only flags the artifact (same corpus, same accuracy): the one
    # config field allowed to differ between CI dry-runs and local full runs
    strip = lambda cfg: {k: v for k, v in cfg.items() if k != "dry_run"}
    mismatched = _config_mismatch(
        strip(baseline.get("config", {})), strip(bench.get("config", {}))
    )
    if mismatched:
        return [
            f"fusion bench config does not match the baseline ({mismatched}); "
            f"the comparison would be meaningless — {FUSION_REGEN_HINT}"
        ]
    rec_b = bench.get("recall_at_10", {})
    rec_base = baseline.get("recall_at_10", {})
    if not rec_b or not rec_base:
        return ["recall_at_10 missing from bench or baseline — "
                + FUSION_REGEN_HINT]
    for cell, base_val in rec_base.items():
        val = rec_b.get(cell)
        if val is None:
            failures.append(f"fusion cell {cell} missing from bench")
            continue
        floor = base_val - recall_tol
        if val < floor:
            failures.append(
                f"{cell}: recall@10 dropped {base_val:.3f} -> {val:.3f} "
                f"(below floor {floor:.3f})"
            )
    dense = rec_b.get("weighted_sum.dense_only")
    if dense is not None and bench.get("hybrid_best", 0.0) < dense:
        failures.append(
            f"best hybrid fusion recall {bench.get('hybrid_best'):.3f} fell "
            f"below dense-only {dense:.3f} — fusion must not hurt accuracy"
        )
    traces = bench.get("sweep_traces")
    if traces != 1:
        failures.append(
            f"fusion sweep traced {traces} time(s), expected exactly 1: "
            "switching mode/weights/stats retraced search_padded "
            "(zero-recompile contract, DESIGN.md §11)"
        )
    return failures


def check_obs(
    bench: dict, baseline: dict, hit_rate_tol: float, snapshot_path: str
) -> list[str]:
    """Observability gate over the serving bench's ``obs`` section (written
    by ``serving_bench.run`` from the live metrics registries):

      * AOT executable-cache hit rate may not drop more than
        ``hit_rate_tol`` (absolute) below the baseline — a falling hit rate
        means request/bucket keys started missing the cache (recompiles on
        the serving path);
      * ``search_padded_traces`` across the steady section is gated
        EXACTLY — retraces are deterministic, so any drift means the jit
        cache key changed (zero-recompile contract, DESIGN.md §11);
      * the METRICS_snapshot.json artifact must exist and carry the serving
        series (the CI-uploaded exposition is the same data the gate read).
    """
    failures: list[str] = []
    obs_b = bench.get("obs", {})
    obs_base = baseline.get("obs", {})
    if not obs_b or not obs_base:
        return ["obs section missing from bench or baseline — "
                + SERVING_REGEN_HINT]
    cache_b = obs_b.get("executable_cache", {})
    cache_base = obs_base.get("executable_cache", {})
    floor = cache_base.get("hit_rate", 0.0) - hit_rate_tol
    if cache_b.get("hit_rate", 0.0) < floor:
        failures.append(
            f"executable-cache hit rate dropped "
            f"{cache_base.get('hit_rate', 0.0):.3f} -> "
            f"{cache_b.get('hit_rate', 0.0):.3f} (below floor {floor:.3f}): "
            "serving requests started missing the AOT cache"
        )
    traces_b = obs_b.get("search_padded_traces")
    traces_base = obs_base.get("search_padded_traces")
    if traces_b != traces_base:
        failures.append(
            f"search_padded retrace count drifted {traces_base} -> "
            f"{traces_b}: the padded entry point's jit cache key changed "
            "(zero-recompile contract, DESIGN.md §11)"
        )
    snap_p = pathlib.Path(snapshot_path)
    if not snap_p.exists():
        failures.append(
            f"{snap_p} missing — serving_bench.run() writes it; the CI "
            "artifact upload depends on it"
        )
    else:
        try:
            snap = json.loads(snap_p.read_text())
        except ValueError:
            snap = None
        if not isinstance(snap, dict) or not any(
            k.startswith("allanpoe_serving_") for k in snap
        ):
            failures.append(
                f"{snap_p} is not a valid metrics snapshot (no "
                "allanpoe_serving_* series)"
            )
    return failures


def _load_pair(
    bench_path: str, base_path: str, hint: str
) -> tuple[dict, dict] | list[str]:
    bp, sp = pathlib.Path(bench_path), pathlib.Path(base_path)
    if not bp.exists():
        return [f"{bp} missing — run the bench first"]
    if not sp.exists():
        return [f"{sp} missing — {hint}"]
    return json.loads(bp.read_text()), json.loads(sp.read_text())


def run_gate(kind: str, cfg: dict) -> list[str]:
    """Run one named gate from a gate_config.json section; prints the
    bench-vs-baseline summary and returns failure messages."""
    if kind == "build":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_build.json"),
            cfg.get("baseline", "results/BENCH_build_baseline.json"),
            REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            print(
                f"[build] {name}: dispatches={data['pipeline']['dispatches']} "
                f"speedup_warm={data['speedup_warm']:.3f} "
                f"warm_s={data['pipeline']['build_s_warm']:.2f}"
            )
        return check(bench, baseline, cfg.get("tol", 0.20))
    if kind == "serving":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_serving.json"),
            cfg.get("baseline", "results/BENCH_serving_baseline.json"),
            SERVING_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            buckets = data.get("steady", {}).get("buckets", {})
            line = " ".join(
                f"b{k}:qps={v['qps']:.0f},p99={v['p99_ms']:.1f}ms"
                for k, v in sorted(buckets.items())
            )
            print(f"[serving] {name}: {line}")
        return check_serving(
            bench, baseline, cfg.get("qps_tol", 0.50), cfg.get("p99_tol", 1.5)
        )
    if kind == "obs":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_serving.json"),
            cfg.get("baseline", "results/BENCH_serving_baseline.json"),
            SERVING_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            obs = data.get("obs", {})
            cache = obs.get("executable_cache", {})
            print(
                f"[obs] {name}: cache_hits={cache.get('hits')} "
                f"cache_misses={cache.get('misses')} "
                f"hit_rate={cache.get('hit_rate', float('nan')):.3f} "
                f"search_padded_traces={obs.get('search_padded_traces')}"
            )
        return check_obs(
            bench,
            baseline,
            cfg.get("hit_rate_tol", 0.05),
            cfg.get("snapshot", "results/METRICS_snapshot.json"),
        )
    if kind == "scale":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_scale.json"),
            cfg.get("baseline", "results/BENCH_scale_baseline.json"),
            SCALE_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            line = " ".join(
                f"n{size}:eff={s.get('scaling_efficiency', 0.0):.2f},"
                + ",".join(
                    f"r{r}={v['model_qps']:.0f}qps"
                    for r, v in sorted(
                        s.get("replicas", {}).items(), key=lambda kv: int(kv[0])
                    )
                )
                for size, s in sorted(data.get("scales", {}).items())
            )
            print(f"[scale] {name}: {line}")
        return check_scale(
            bench,
            baseline,
            cfg.get("efficiency_floor", 0.6),
            cfg.get("replica_qps_tol", 0.5),
        )
    if kind == "kernel":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_kernel.json"),
            cfg.get("baseline", "results/BENCH_kernel_baseline.json"),
            KERNEL_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            sweep = data.get("sweep", {})
            ratios = [v["fused_ratio"] for v in sweep.values()]
            mean = sum(ratios) / len(ratios) if ratios else float("nan")
            print(
                f"[kernel] {name}: pairs={len(sweep)} "
                f"mean_fused_ratio={mean:.3f} "
                f"backend={data.get('config', {}).get('backend')} "
                f"use_kernel={data.get('config', {}).get('use_kernel')}"
            )
        return check_kernel(
            bench, baseline,
            cfg.get("ratio_tol", 0.5), cfg.get("latency_tol", 3.0),
        )
    if kind == "quantized":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_kernel.json"),
            cfg.get("baseline", "results/BENCH_kernel_baseline.json"),
            KERNEL_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            q = data.get("quantized", {})
            ratios = [
                v["model"].get("quantized_saved_ratio")
                for v in data.get("sweep", {}).values()
                if v.get("model", {}).get("quantized_saved_ratio") is not None
            ]
            mean = sum(ratios) / len(ratios) if ratios else float("nan")
            print(
                f"[quantized] {name}: mean_bytes_saved={mean:.3f} "
                f"recall_fp32={q.get('recall_at_10_fp32', float('nan')):.3f} "
                f"recall_int8={q.get('recall_at_10_int8', float('nan')):.3f} "
                f"traces={q.get('sweep_traces')} "
                f"repeat_traces={q.get('repeat_traces')}"
            )
        return check_quantized(
            bench, baseline, cfg.get("recall_drop_tol", 0.02)
        )
    if kind == "fusion":
        pair = _load_pair(
            cfg.get("bench", "results/BENCH_fusion.json"),
            cfg.get("baseline", "results/BENCH_fusion_baseline.json"),
            FUSION_REGEN_HINT,
        )
        if isinstance(pair, list):
            return pair
        bench, baseline = pair
        for name, data in (("bench", bench), ("baseline", baseline)):
            rec = data.get("recall_at_10", {})
            print(
                f"[fusion] {name}: cells={len(rec)} "
                f"hybrid_best={data.get('hybrid_best', float('nan')):.3f} "
                f"dense_only="
                f"{rec.get('weighted_sum.dense_only', float('nan')):.3f} "
                f"traces={data.get('sweep_traces')}"
            )
        return check_fusion(bench, baseline, cfg.get("recall_tol", 0.05))
    return [f"unknown gate '{kind}' in gate config"]


def run_all(config_path: str, only: str | None) -> int:
    path = pathlib.Path(config_path)
    if not path.exists():
        print(f"FAIL: gate config {path} missing")
        return 1
    gates: dict = json.loads(path.read_text())
    if only:
        keep = {s.strip() for s in only.split(",")}
        unknown = keep - set(gates)
        if unknown:
            print(f"FAIL: --only names absent from {path}: {sorted(unknown)}")
            return 1
        gates = {k: v for k, v in gates.items() if k in keep}
    rc = 0
    for kind, cfg in gates.items():
        failures = run_gate(kind, cfg)
        for f in failures:
            print(f"FAIL [{kind}]: {f}")
        if failures:
            rc = 1
        else:
            print(f"PASS [{kind}]: no regression")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--all",
        action="store_true",
        help="run every gate listed in the committed gate config (one "
        "invocation replaces the per-gate CLI runs; tolerances come from "
        "the config file, not argparse defaults)",
    )
    ap.add_argument("--config", default="results/gate_config.json")
    ap.add_argument(
        "--only",
        default=None,
        help="with --all: comma list of gate names to run "
        "(build,serving,scale,kernel,quantized,fusion,obs)",
    )
    ap.add_argument("--bench", default="results/BENCH_build.json")
    ap.add_argument("--baseline", default="results/BENCH_build_baseline.json")
    ap.add_argument(
        "--tol",
        type=float,
        default=0.20,
        help="allowed fractional speedup_warm drop vs baseline (CPU noise)",
    )
    ap.add_argument(
        "--serving-only",
        action="store_true",
        help="gate the serving bench instead of the build bench",
    )
    ap.add_argument("--serving-bench", default="results/BENCH_serving.json")
    ap.add_argument(
        "--serving-baseline", default="results/BENCH_serving_baseline.json"
    )
    ap.add_argument(
        "--qps-tol", type=float, default=0.50,
        help="allowed fractional steady-QPS drop vs baseline (runner "
        "speeds differ; tightened from the lenient 0.80 first pass)",
    )
    ap.add_argument(
        "--p99-tol", type=float, default=1.5,
        help="allowed fractional p99 rise vs baseline (1.5 = 2.5x ceiling; "
        "tightened from the lenient 4.0 first pass)",
    )
    args = ap.parse_args()

    if args.all:
        return run_all(args.config, args.only)

    if args.serving_only:
        bench_path = pathlib.Path(args.serving_bench)
        base_path = pathlib.Path(args.serving_baseline)
        if not bench_path.exists():
            print(f"FAIL: {bench_path} missing — run the serving bench first")
            return 1
        if not base_path.exists():
            print(f"FAIL: {base_path} missing — {SERVING_REGEN_HINT}")
            return 1
        bench = json.loads(bench_path.read_text())
        baseline = json.loads(base_path.read_text())
        for name, data in (("bench", bench), ("baseline", baseline)):
            buckets = data.get("steady", {}).get("buckets", {})
            line = " ".join(
                f"b{k}:qps={v['qps']:.0f},p99={v['p99_ms']:.1f}ms"
                for k, v in sorted(buckets.items())
            )
            print(f"{name}: {line}")
        failures = check_serving(bench, baseline, args.qps_tol, args.p99_tol)
        for f in failures:
            print(f"FAIL: {f}")
        if not failures:
            print(
                f"PASS: no serving perf regression "
                f"(qps-tol={args.qps_tol:.0%}, p99-tol={args.p99_tol:.1f}x)"
            )
        return 1 if failures else 0

    bench_path = pathlib.Path(args.bench)
    base_path = pathlib.Path(args.baseline)
    if not bench_path.exists():
        print(f"FAIL: {bench_path} missing — run the build bench first")
        return 1
    if not base_path.exists():
        print(f"FAIL: {base_path} missing — {REGEN_HINT}")
        return 1
    bench = json.loads(bench_path.read_text())
    baseline = json.loads(base_path.read_text())

    print(
        f"bench:    dispatches={bench['pipeline']['dispatches']} "
        f"speedup_warm={bench['speedup_warm']:.3f} "
        f"warm_s={bench['pipeline']['build_s_warm']:.2f}"
    )
    print(
        f"baseline: dispatches={baseline['pipeline']['dispatches']} "
        f"speedup_warm={baseline['speedup_warm']:.3f} "
        f"warm_s={baseline['pipeline']['build_s_warm']:.2f}"
    )

    failures = check(bench, baseline, args.tol)
    for f in failures:
        print(f"FAIL: {f}")
    if not failures:
        print(f"PASS: no build perf regression (tol={args.tol:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
