"""Serving-path benchmark: sustained QPS and p50/p99 request latency of the
micro-batched ``HybridSearchService`` across bucket sizes and path-weight
mixes — the online counterpart of fig8's offline batched-search numbers.

Per configuration, a closed-loop client replays a request stream (every
request a random one of several ``PathWeights`` combinations, so every batch
is weight-heterogeneous and still hits ONE cached executable) and measures
per-request submit->result latency and wall-clock QPS after a warmup flush
that absorbs compilation.

    PYTHONPATH=src python benchmarks/serving_bench.py [--quick] [--dry-run]
"""

from __future__ import annotations

import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode: python benchmarks/serving_bench.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.serving.batcher import BatcherConfig, SearchRequest
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig

WEIGHT_MIXES = [
    ("dense", PathWeights.make(1.0, 0.0, 0.0)),
    ("sparse+full", PathWeights.make(0.0, 1.0, 1.0)),
    ("three-path", PathWeights.make(1.0, 1.0, 1.0)),
    ("skewed", PathWeights.make(0.6, 0.3, 0.1)),
]


def _drive(service, queries, n_requests, rng, k):
    """Closed-loop client: submit the stream, recording per-request latency
    (submit -> result delivery, i.e. queue wait + batch execution)."""
    b = queries.dense.shape[0]
    t_submit = np.zeros(n_requests)
    t_done = np.zeros(n_requests)
    pendings = []
    t0 = time.perf_counter()
    for i in range(n_requests):
        req = SearchRequest(
            query=queries[int(rng.integers(b))],
            weights=WEIGHT_MIXES[int(rng.integers(len(WEIGHT_MIXES)))][1],
            k=k,
        )
        t_submit[i] = time.perf_counter()
        pendings.append(service.submit(req))
        # requests completed by a size-triggered flush get their finish time
        for j in range(i + 1):
            if t_done[j] == 0.0 and pendings[j].done:
                t_done[j] = time.perf_counter()
    service.flush()
    now = time.perf_counter()
    t_done[t_done == 0.0] = now
    wall = now - t0
    lat_ms = (t_done[:n_requests] - t_submit[:n_requests]) * 1e3
    return wall, lat_ms


def run(n_docs: int = 4096, n_requests: int = 256, dry_run: bool = False):
    rows = []
    if dry_run:
        n_docs, n_requests = 512, 32
    rng = np.random.default_rng(7)
    corpus = make_corpus(
        CorpusConfig(
            n_docs=n_docs, n_queries=64, n_topics=max(n_docs // 64, 8),
            d_dense=64, nnz_sparse=16, nnz_lexical=8, seed=7,
        )
    )
    index = build_index(
        corpus.docs,
        BuildConfig(
            knn=KnnConfig(k=16, iters=3, node_chunk=min(n_docs, 2048)),
            prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
            path_refine_iters=0,
        ),
    )
    params = SearchParams(k=10, iters=32, pool_size=64)

    for bucket in (8, 32):
        service = HybridSearchService(
            index,
            params,
            ServiceConfig(
                batcher=BatcherConfig(
                    flush_size=bucket, max_batch=bucket, flush_deadline_s=0.05
                )
            ),
        )
        # warmup: one full bucket through every shape so compile time is
        # excluded from the steady-state measurement
        _drive(service, corpus.queries, bucket, np.random.default_rng(0), params.k)
        wall, lat_ms = _drive(service, corpus.queries, n_requests, rng, params.k)
        qps = n_requests / wall
        rows.append(
            (
                f"serving.qps_bucket{bucket}",
                wall * 1e6 / n_requests,
                f"qps={qps:.0f};p50_ms={np.percentile(lat_ms, 50):.1f};"
                f"p99_ms={np.percentile(lat_ms, 99):.1f};"
                f"executables={len(service.executable_cache)};"
                f"weight_mixes={len(WEIGHT_MIXES)}",
            )
        )

    # per-mix latency at the larger bucket: one homogeneous stream per path
    # combination, all through the SAME service (and executable)
    service = HybridSearchService(
        index,
        params,
        ServiceConfig(batcher=BatcherConfig(flush_size=32, max_batch=32)),
    )
    _drive(service, corpus.queries, 32, np.random.default_rng(0), params.k)
    for name, w in WEIGHT_MIXES:
        pend = []
        t0 = time.perf_counter()
        for i in range(32):
            pend.append(
                service.submit(
                    SearchRequest(query=corpus.queries[i % 64], weights=w, k=params.k)
                )
            )
        service.flush()
        dt = time.perf_counter() - t0
        rows.append(
            (
                f"serving.path_{name}",
                dt * 1e6 / 32,
                f"qps={32 / dt:.0f};executables={len(service.executable_cache)}",
            )
        )
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller corpus")
    ap.add_argument(
        "--dry-run", action="store_true", help="tiny smoke run (CI entry-point check)"
    )
    args = ap.parse_args()
    kw = {}
    if args.quick:
        kw = dict(n_docs=1024, n_requests=64)
    print("name,us_per_call,derived")
    for r in run(dry_run=args.dry_run, **kw):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
