"""Serving-path benchmark: sustained QPS and p50/p99 request latency of the
micro-batched ``HybridSearchService`` across bucket sizes and fusion
mixes — the online counterpart of fig8's offline batched-search numbers.

Per configuration, a closed-loop client replays a request stream (every
request a random one of several ``FusionSpec`` combinations — different
weights AND different fusion modes, so every batch is fusion-heterogeneous
and still hits ONE cached executable) and measures per-request
submit->result latency and wall-clock QPS after a warmup flush that absorbs
compilation.

``--streaming`` adds the grow-segment router bench: insert QPS and search
latency (p50/p99) measured WHILE a writer thread streams insert batches
through ``SegmentRouter`` — the mixed read/write serving scenario. Results
land in ``results/BENCH_serving.json`` (the ``--dry-run`` CI path emits the
same file, so the perf trajectory is tracked per commit as a workflow
artifact).

    PYTHONPATH=src python benchmarks/serving_bench.py [--quick] [--dry-run]
                                                      [--streaming]
"""

from __future__ import annotations

import json
import pathlib
import sys
import threading
import time

if __package__ in (None, ""):  # script mode: python benchmarks/serving_bench.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

import jax

from repro.core import BuildConfig, FusionSpec, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams
from repro.data.corpus import CorpusConfig, make_corpus
from repro.obs.export import write_metrics_snapshot
from repro.obs.metrics import GLOBAL
from repro.serving.batcher import BatcherConfig, SearchRequest
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig

FUSION_MIXES = [
    ("dense", FusionSpec.weighted(1.0, 0.0, 0.0)),
    ("sparse+full", FusionSpec.weighted(0.0, 1.0, 1.0)),
    ("three-path", FusionSpec.three_path()),
    ("skewed", FusionSpec.weighted(0.6, 0.3, 0.1)),
    ("rrf", FusionSpec.rrf()),
    ("zscore", FusionSpec.zscore()),
]


def _drive(service, queries, n_requests, rng, k):
    """Closed-loop client: submit the stream; returns (wall_s, latency
    HistogramSnapshot). Latency percentiles come from the service's OWN
    ``allanpoe_serving_request_latency_seconds`` histogram (arrival ->
    result fulfillment) — the bench consumes the production metrics code
    path instead of keeping a second stopwatch (DESIGN.md §12), and the
    snapshot delta across the drive isolates this drive's requests from
    any earlier warmup traffic."""
    b = queries.dense.shape[0]
    hist = service.metrics.get("allanpoe_serving_request_latency_seconds")
    before = hist.snapshot()
    t0 = time.perf_counter()
    for i in range(n_requests):
        service.submit(
            SearchRequest(
                query=queries[int(rng.integers(b))],
                fusion=FUSION_MIXES[int(rng.integers(len(FUSION_MIXES)))][1],
                k=k,
            )
        )
    service.flush()
    wall = time.perf_counter() - t0
    return wall, hist.snapshot().minus(before)


def _p_ms(snap, q: float) -> float:
    """Interpolated histogram quantile, in milliseconds."""
    return float(snap.quantile(q)) * 1e3


def _update_bench_json(section: str, payload: dict, out_dir: str = "results") -> None:
    """Merge one section into results/BENCH_serving.json (steady-state and
    streaming runs each own a section, so either can run alone)."""
    out = pathlib.Path(out_dir)
    out.mkdir(exist_ok=True)
    path = out / "BENCH_serving.json"
    data: dict = {}
    if path.exists():
        try:
            data = json.loads(path.read_text())
        except ValueError:
            data = {}
    data[section] = payload
    path.write_text(json.dumps(data, indent=2) + "\n")


def run(n_docs: int = 4096, n_requests: int = 256, dry_run: bool = False):
    rows = []
    if dry_run:
        n_docs, n_requests = 512, 32
    traces0 = GLOBAL.value("allanpoe_core_search_padded_traces_total")
    services = []  # every service of this section, for the obs roll-up
    rng = np.random.default_rng(7)
    corpus = make_corpus(
        CorpusConfig(
            n_docs=n_docs, n_queries=64, n_topics=max(n_docs // 64, 8),
            d_dense=64, nnz_sparse=16, nnz_lexical=8, seed=7,
        )
    )
    index = build_index(
        corpus.docs,
        BuildConfig(
            knn=KnnConfig(k=16, iters=3, node_chunk=min(n_docs, 2048)),
            prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
            path_refine_iters=0,
        ),
    )
    params = SearchParams(k=10, iters=32, pool_size=64)

    steady = {
        "config": {
            "n_docs": n_docs,
            "n_requests": n_requests,
            "backend": jax.default_backend(),
        },
        "buckets": {},
    }
    for bucket in (8, 32):
        service = HybridSearchService(
            index,
            params,
            ServiceConfig(
                batcher=BatcherConfig(
                    flush_size=bucket, max_batch=bucket, flush_deadline_s=0.05
                )
            ),
        )
        services.append(service)
        # warmup: one full bucket through every shape so compile time is
        # excluded from the steady-state measurement
        _drive(service, corpus.queries, bucket, np.random.default_rng(0), params.k)
        wall, lat = _drive(service, corpus.queries, n_requests, rng, params.k)
        qps = n_requests / wall
        p50, p99 = _p_ms(lat, 0.5), _p_ms(lat, 0.99)
        steady["buckets"][str(bucket)] = {
            "qps": qps,
            "p50_ms": p50,
            "p99_ms": p99,
        }
        rows.append(
            (
                f"serving.qps_bucket{bucket}",
                wall * 1e6 / n_requests,
                f"qps={qps:.0f};p50_ms={p50:.1f};p99_ms={p99:.1f};"
                f"executables={len(service.executable_cache)};"
                f"fusion_mixes={len(FUSION_MIXES)}",
            )
        )
    _update_bench_json("steady", steady)

    # per-mix latency at the larger bucket: one homogeneous stream per fusion
    # combination, all through the SAME service (and executable)
    service = HybridSearchService(
        index,
        params,
        ServiceConfig(batcher=BatcherConfig(flush_size=32, max_batch=32)),
    )
    services.append(service)
    _drive(service, corpus.queries, 32, np.random.default_rng(0), params.k)
    for name, spec in FUSION_MIXES:
        pend = []
        t0 = time.perf_counter()
        for i in range(32):
            pend.append(
                service.submit(
                    SearchRequest(query=corpus.queries[i % 64], fusion=spec, k=params.k)
                )
            )
        service.flush()
        dt = time.perf_counter() - t0
        rows.append(
            (
                f"serving.path_{name}",
                dt * 1e6 / 32,
                f"qps={32 / dt:.0f};executables={len(service.executable_cache)}",
            )
        )
    # obs roll-up (the check_regression "obs" gate input): AOT executable
    # cache behaviour and search_padded retraces across this section, read
    # from the same registries the serving exposition renders
    hits = sum(
        int(s.metrics.value(
            "allanpoe_serving_executable_cache_total", outcome="hit"
        ))
        for s in services
    )
    misses = sum(
        int(s.metrics.value(
            "allanpoe_serving_executable_cache_total", outcome="miss"
        ))
        for s in services
    )
    obs = {
        "executable_cache": {
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(hits + misses, 1),
        },
        "search_padded_traces": int(
            GLOBAL.value("allanpoe_core_search_padded_traces_total") - traces0
        ),
    }
    _update_bench_json("obs", obs)
    write_metrics_snapshot(
        "results/METRICS_snapshot.json",
        *[s.metrics for s in services],
        GLOBAL,
    )
    return rows


def run_streaming(
    n_docs: int = 1024,
    insert_batches: int = 8,
    insert_batch: int = 16,
    n_requests: int = 128,
    dry_run: bool = False,
):
    """Mixed read/write serving: a writer thread streams insert batches
    through the grow-segment router while the closed-loop client measures
    search latency. Reports insert docs/s, search QPS + p50/p99, and
    whether the sealed executables survived every insert (the cache-key
    invariant of the grow-segment scheme)."""
    from jax.sharding import Mesh

    from repro.core.distributed import (
        build_segmented_index,
        place_segmented_index,
    )
    from repro.serving.segment_router import RouterConfig, SegmentRouter

    if dry_run:
        n_docs, insert_batches, insert_batch, n_requests = 256, 3, 8, 24
    total = n_docs + insert_batches * insert_batch
    corpus = make_corpus(
        CorpusConfig(
            n_docs=total, n_queries=64, n_topics=max(n_docs // 64, 8),
            d_dense=64, nnz_sparse=16, nnz_lexical=8, seed=11,
        )
    )
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=3, node_chunk=min(n_docs, 2048)),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
        path_refine_iters=0,
    )
    seg = build_segmented_index(corpus.docs[:n_docs], 1, cfg)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(seg, mesh)
    params = SearchParams(k=10, iters=32, pool_size=64)
    service = HybridSearchService(
        seg,
        params,
        ServiceConfig(
            batcher=BatcherConfig(
                flush_size=8, max_batch=8, flush_deadline_s=0.01
            ),
            pump_interval_s=0.005,
        ),
        mesh=mesh,
    )
    SegmentRouter(service, cfg, RouterConfig(seal_threshold=10**9))

    # warmup: first insert (grow-segment birth) + one bucket of searches, so
    # the steady measurement sees warm sealed executables
    service.insert(corpus.docs[n_docs:n_docs + insert_batch])
    _drive(service, corpus.queries, 8, np.random.default_rng(0), params.k)
    sealed_keys = set(service.executable_cache)

    insert_s: list[float] = []

    def writer():
        for b in range(1, insert_batches):
            lo = n_docs + b * insert_batch
            t0 = time.perf_counter()
            service.insert(corpus.docs[lo:lo + insert_batch])
            insert_s.append(time.perf_counter() - t0)

    thread = threading.Thread(target=writer)
    thread.start()
    wall, lat = _drive(
        service, corpus.queries, n_requests, np.random.default_rng(3), params.k
    )
    thread.join()
    service.stop_pump()

    sealed_stable = sealed_keys <= set(service.executable_cache)
    docs_inserted = (insert_batches - 1) * insert_batch
    insert_docs_per_s = docs_inserted / max(sum(insert_s), 1e-9)
    qps = n_requests / wall
    p50, p99 = _p_ms(lat, 0.5), _p_ms(lat, 0.99)
    _update_bench_json(
        "streaming",
        {
            "config": {
                "n_docs": n_docs,
                "insert_batches": insert_batches,
                "insert_batch": insert_batch,
                "n_requests": n_requests,
                "backend": jax.default_backend(),
            },
            "insert_docs_per_s": insert_docs_per_s,
            "search_qps": qps,
            "p50_ms": p50,
            "p99_ms": p99,
            "sealed_cache_stable": bool(sealed_stable),
            "grow_docs_final": int(service._snap.grow_gids.shape[0])
            if service._snap.grow_gids is not None
            else 0,
        },
    )
    return [
        (
            "serving.streaming",
            wall * 1e6 / n_requests,
            f"qps={qps:.0f};p50_ms={p50:.1f};p99_ms={p99:.1f};"
            f"insert_docs_per_s={insert_docs_per_s:.0f};"
            f"sealed_cache_stable={sealed_stable}",
        )
    ]


def run_compaction(
    n_docs: int = 1024,
    grow_docs: int = 48,
    n_requests: int = 96,
    dry_run: bool = False,
):
    """Compaction-concurrency bench: search p99 measured WHILE a compaction
    runs, full-rebuild vs incremental. Per mode it reports the search QPS +
    p50/p99 of a closed-loop client racing the compaction, the compaction's
    wall-clock, its ``dispatch.build_rows`` work (the O(corpus) vs O(grow)
    contrast), and whether the warm sealed executables survived — for the
    incremental path they must (the segment-pool cache-survival guarantee,
    DESIGN.md §8)."""
    from jax.sharding import Mesh

    from repro.core.distributed import (
        build_segmented_index,
        place_segmented_index,
    )
    from repro.runtime import dispatch
    from repro.serving.segment_router import RouterConfig, SegmentRouter

    if dry_run:
        n_docs, grow_docs, n_requests = 256, 16, 24
    corpus = make_corpus(
        CorpusConfig(
            n_docs=n_docs + 2 * grow_docs, n_queries=64,
            n_topics=max(n_docs // 64, 8),
            d_dense=64, nnz_sparse=16, nnz_lexical=8, seed=17,
        )
    )
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=3, node_chunk=min(n_docs, 2048)),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=512),
        path_refine_iters=0,
    )
    params = SearchParams(k=10, iters=32, pool_size=64)
    rows = []
    payload = {
        "config": {
            "n_docs": n_docs,
            "grow_docs": grow_docs,
            "n_requests": n_requests,
            "backend": jax.default_backend(),
        },
    }
    for mode_i, mode in enumerate(("full", "incremental")):
        seg = build_segmented_index(corpus.docs[:n_docs], 1, cfg)
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        seg = place_segmented_index(seg, mesh)
        service = HybridSearchService(
            seg, params,
            ServiceConfig(
                batcher=BatcherConfig(
                    flush_size=8, max_batch=8, flush_deadline_s=0.01
                ),
                pump_interval_s=0.005,
            ),
            mesh=mesh,
        )
        router = SegmentRouter(
            service, cfg,
            RouterConfig(seal_threshold=10**9, compaction=mode),
        )
        lo = n_docs + mode_i * grow_docs
        service.insert(corpus.docs[lo:lo + grow_docs])
        # warm: sealed + grow executables compiled before the measurement
        _drive(service, corpus.queries, 8, np.random.default_rng(0), params.k)
        sealed_keys = {
            k: v for k, v in service.executable_cache.items()
        }

        rows_before = dispatch.build_rows()
        compact_s = [0.0]

        def compactor():
            t0 = time.perf_counter()
            router.compact()
            compact_s[0] = time.perf_counter() - t0

        thread = threading.Thread(target=compactor)
        thread.start()
        wall, lat = _drive(
            service, corpus.queries, n_requests, np.random.default_rng(5),
            params.k,
        )
        thread.join()
        service.stop_pump()
        built = dispatch.build_rows() - rows_before
        stable = all(
            service.executable_cache.get(k) is v for k, v in sealed_keys.items()
        )
        qps = n_requests / wall
        p50, p99 = _p_ms(lat, 0.5), _p_ms(lat, 0.99)
        payload[mode] = {
            "search_qps": qps,
            "p50_ms": p50,
            "p99_ms": p99,
            "compact_s": compact_s[0],
            "built_rows": int(built),
            "sealed_cache_stable": bool(stable),
            "pool_segments": (
                router.pool.n_segments if router.pool is not None else 1
            ),
        }
        rows.append(
            (
                f"serving.compaction_{mode}",
                wall * 1e6 / n_requests,
                f"qps={qps:.0f};p50_ms={p50:.1f};p99_ms={p99:.1f};"
                f"compact_s={compact_s[0]:.2f};built_rows={built};"
                f"sealed_cache_stable={stable}",
            )
        )
    _update_bench_json("compaction", payload)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller corpus")
    ap.add_argument(
        "--dry-run", action="store_true", help="tiny smoke run (CI entry-point check)"
    )
    ap.add_argument(
        "--streaming",
        action="store_true",
        help="grow-segment router bench: insert QPS + p99 under concurrent inserts",
    )
    ap.add_argument(
        "--compaction",
        action="store_true",
        help="p99 during concurrent compaction, full rebuild vs incremental",
    )
    args = ap.parse_args()
    kw = {}
    if args.quick:
        kw = dict(n_docs=1024, n_requests=64)
    print("name,us_per_call,derived")
    rows = run(dry_run=args.dry_run, **kw)
    # the dry-run CI path always includes a tiny streaming pass, so
    # BENCH_serving.json tracks both sections on every commit; --quick gets
    # a reduced-but-meaningful config (dry-run scale is smoke, not signal)
    if args.streaming or args.dry_run:
        stream_kw = {}
        if args.quick and not args.dry_run:
            stream_kw = dict(
                n_docs=512, insert_batches=4, insert_batch=16, n_requests=64
            )
        rows += run_streaming(dry_run=args.dry_run, **stream_kw)
    # likewise the dry-run always exercises both compaction modes, so the
    # full-vs-incremental p99/work contrast lands in every CI artifact
    if args.compaction or args.dry_run:
        comp_kw = {}
        if args.quick and not args.dry_run:
            comp_kw = dict(n_docs=512, grow_docs=32, n_requests=64)
        rows += run_compaction(dry_run=args.dry_run, **comp_kw)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
