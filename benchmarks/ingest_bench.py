"""Ingestion benchmark on the bundled real-text corpus: ingest throughput
(fit + frozen-stats encode docs/s) and end-to-end retrieval accuracy —
recall@10 of hybrid (dense+lexical+learned) vs dense-only — demonstrating
that the lexical path actually lifts accuracy on real text (paper §3.1's
full-text component; "Balancing the Blend", arXiv:2508.01405).

Ground truth: the bundled paragraphs (tests/data/paragraphs.jsonl) are
topic-clustered prose with recurring named entities; a query's relevant set
is its topic's paragraphs. Results land in ``results/BENCH_ingest.json``
(uploaded with the other CI bench artifacts). Exit code 1 if hybrid falls
below dense-only — the acceptance gate of the ingestion subsystem.

    PYTHONPATH=src python benchmarks/ingest_bench.py [--dry-run]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import recall_at_k
from repro.data.textcorpus import load_bundled_corpus, topic_truth
from repro.ingest import IngestConfig, IngestPipeline

WEIGHTS = [
    ("dense_only", PathWeights.make(1, 0, 0)),
    ("lexical_only", PathWeights.make(0, 0, 1)),
    ("learned_only", PathWeights.make(0, 1, 0)),
    ("hybrid", PathWeights.three_path()),
]


def run(dry_run: bool = False) -> dict:
    corpus = load_bundled_corpus()
    texts, topics = corpus.texts, corpus.topics
    q_texts, q_topics = corpus.query_texts, corpus.query_topics
    repeats = 1 if dry_run else 3

    pipe = IngestPipeline(IngestConfig(d_dense=64))
    t0 = time.perf_counter()
    ingested = pipe.fit(texts)
    fit_s = time.perf_counter() - t0

    # frozen-stats encode throughput (the streaming-insert hot path)
    t0 = time.perf_counter()
    for _ in range(repeats):
        pipe.encode_docs(texts)
    encode_s = (time.perf_counter() - t0) / repeats

    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=128),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
        path_refine_iters=1,
    )
    t0 = time.perf_counter()
    index = pipe.build(ingested, cfg)
    jax.block_until_ready(index.semantic_edges)
    build_s = time.perf_counter() - t0

    enc = pipe.encode_queries(q_texts)
    truth = topic_truth(q_topics, topics)
    params = SearchParams(k=10, iters=48, pool_size=64)
    recall = {}
    for name, w in WEIGHTS:
        res = search(index, enc.vectors, w, params)
        recall[name] = float(recall_at_k(np.asarray(res.ids), truth))

    out = {
        "config": {
            "n_docs": len(texts),
            "n_queries": len(q_texts),
            "d_dense": 64,
            "backend": jax.default_backend(),
            "dry_run": dry_run,
        },
        "ingest": {
            "fit_s": fit_s,
            "fit_docs_per_s": len(texts) / max(fit_s, 1e-9),
            "encode_docs_per_s": len(texts) / max(encode_s, 1e-9),
            "build_s": build_s,
            "n_entities": len(pipe.entity_vocab),
            "n_triplets": int(len(ingested.kg.triplets)),
        },
        "recall_at_10": recall,
        "hybrid_lift": recall["hybrid"] - recall["dense_only"],
    }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="single-pass timing (CI entry-point check; same corpus/accuracy)",
    )
    ap.add_argument("--out", default="results/BENCH_ingest.json")
    args = ap.parse_args()

    out = run(dry_run=args.dry_run)
    path = pathlib.Path(args.out)
    path.parent.mkdir(exist_ok=True)
    path.write_text(json.dumps(out, indent=2) + "\n")

    ing, rec = out["ingest"], out["recall_at_10"]
    print(
        f"ingest: fit {ing['fit_docs_per_s']:.0f} docs/s, "
        f"encode {ing['encode_docs_per_s']:.0f} docs/s, "
        f"build {ing['build_s']:.2f}s, "
        f"{ing['n_entities']} entities / {ing['n_triplets']} triplets"
    )
    for name, _ in WEIGHTS:
        print(f"recall@10 {name:13s} {rec[name]:.3f}")
    lift = out["hybrid_lift"]
    if lift < 0:
        print(f"FAIL: hybrid recall fell {-lift:.3f} BELOW dense-only — the "
              "lexical path must not hurt accuracy on real text")
        return 1
    print(f"PASS: hybrid >= dense-only (lift {lift:+.3f}); wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
