"""Paper Figure 10: RNG-IP joint pruning vs RNG-only vs IP-only —
QPS/recall trade-off of the pruning strategy."""

from __future__ import annotations

import dataclasses

import numpy as np

import jax

from benchmarks.common import default_build, simple_corpus, timed
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights, weighted_query
from repro.data.corpus import recall_at_k
from repro.kernels import ops


def run(n_docs=4096, n_queries=64):
    corpus = simple_corpus(n_docs, n_queries)
    w = PathWeights.three_path()
    qw = weighted_query(corpus.queries, w)
    scores = ops.pairwise_scores_chunked(qw, corpus.docs)
    _, truth = jax.lax.top_k(scores, 10)
    truth = np.asarray(truth)

    rows = []
    for mode in ("joint", "rng", "ip"):
        cfg = default_build(corpus.docs.n)
        cfg = dataclasses.replace(
            cfg, prune=dataclasses.replace(cfg.prune, mode=mode)
        )
        index = build_index(corpus.docs, cfg)
        params = SearchParams(k=10, iters=40, pool_size=64)
        ids, sec = timed(lambda: search(index, corpus.queries, w, params).ids)
        rec = recall_at_k(np.asarray(ids), truth)
        rows.append((f"fig10.{mode}", sec * 1e6 / n_queries,
                     f"recall@10={rec:.3f};qps={n_queries/sec:.0f}"))
    return rows
