"""Paper Figure 8: QPS vs nDCG@10 for all methods on simple + multi-hop
corpora, hybrid paths at equal weights."""

from __future__ import annotations


import numpy as np


from benchmarks.common import (
    IVFFusion,
    SparseInvertedIndex,
    ThreeRoute,
    bruteforce_topk,
    default_build,
    multihop_corpus,
    simple_corpus,
    timed,
)
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import ndcg_at_k


def run(n_docs=8192, n_queries=64):
    rows = []
    for ds_name, corpus in (
        ("simple", simple_corpus(n_docs, n_queries)),
        ("multihop", multihop_corpus(n_docs // 2, n_queries)),
    ):
        truth = corpus.query_relevant
        cfg = default_build(corpus.docs.n)
        index = build_index(corpus.docs, cfg)
        params = SearchParams(k=10, iters=48, pool_size=64)
        nq = corpus.queries.dense.shape[0]

        def bench(name, fn):
            ids, sec = timed(fn, repeats=3)
            qps = nq / sec
            nd = ndcg_at_k(np.asarray(ids), truth, k=10)
            rows.append((f"fig8.{ds_name}.{name}", sec * 1e6 / nq, f"qps={qps:.0f};ndcg@10={nd:.3f}"))

        # Allan-Poe path configurations — same index, zero reconstruction
        for pname, w in [
            ("allanpoe-dense", PathWeights.make(1, 0, 0)),
            ("allanpoe-sparse", PathWeights.make(0, 1, 0)),
            ("allanpoe-full", PathWeights.make(0, 0, 1)),
            ("allanpoe-two", PathWeights.make(1, 1, 0)),
            ("allanpoe-three", PathWeights.three_path()),
        ]:
            bench(pname, lambda w=w: search(index, corpus.queries, w, params).ids)

        # brute force
        bench("bruteforce-three",
              lambda: bruteforce_topk(corpus.docs, corpus.queries, PathWeights.three_path()))

        # SEISMIC-style sparse inverted
        inv = SparseInvertedIndex(corpus.docs)
        qs_i = np.asarray(corpus.queries.learned.idx)
        qs_v = np.asarray(corpus.queries.learned.val)
        bench("sparse-inverted", lambda: inv.query(qs_i, qs_v))

        # IVF-Fusion
        ivf = IVFFusion(corpus.docs, n_clusters=max(corpus.docs.n // 128, 16))
        bench("ivf-fusion",
              lambda: ivf.query(corpus.queries, PathWeights.make(1, 1, 0)))

        # ThreeRoute separate multi-path
        tr = ThreeRoute.build(corpus.docs, cfg)
        bench("three-route",
              lambda: tr.query(corpus.queries, PathWeights.three_path(), params))
    return rows
