"""Fused top-k kernel sweep: measured fused-vs-unfused latency + bytes model.

Sweeps the fused selection kernel over (C_TILE, K, expand) and times the two
expansion-round strategies end to end on the current backend:

  unfused : hybrid_scores_vs_ids -> (B, C) scores in HBM -> lax.top_k
  fused   : fused_topk_vs_ids    -> (B, K_pad) ids+scores, selection in VMEM

Per pair it reports µs/candidate-pair, the fused/unfused ratio, the modeled
HBM bytes for both strategies (the fused path must eliminate the (B, C) score
round-trip — gated exactly in check_regression.py), modeled selection-lane
utilization (k / k_pad), and the analytic TPU roofline of the fused kernel.

Results land in results/BENCH_kernel.json; the committed baseline is
results/BENCH_kernel_baseline.json (regenerate with --dry-run to match CI).

    PYTHONPATH=src python benchmarks/kernel_bench.py [--dry-run] [--out PATH]
"""

from __future__ import annotations

import pathlib
import sys

if __package__ in (None, ""):  # script mode: python benchmarks/kernel_bench.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import argparse
import functools
import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.usms import quantize_corpus
from repro.kernels import ops, ref
from repro.kernels.fused_topk import k_pad
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16
from tests.helpers import random_fused

from benchmarks.common import timed

C_TILES = (128, 256)
KS = (10, 32, 64)
EXPANDS = (1, 4)

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "BENCH_kernel.json"


@functools.partial(jax.jit, static_argnames=("k", "c_tile", "use_kernel"))
def _unfused(q, corpus, ids, k, c_tile, use_kernel):
    scores = ops.hybrid_scores_vs_ids(
        q, corpus, ids, c_tile=c_tile, use_kernel=use_kernel
    )
    return jax.lax.top_k(scores, k)


def _bytes_model(*, b, c, dd, ps, pf, k, c_tile, bpe):
    """Modeled HBM traffic for one expansion round, both strategies.

    Inputs (queries + gathered candidate tiles) are identical; the strategies
    differ only in what crosses HBM after scoring: unfused writes the full
    (B, C_pad) score matrix and top_k reads it back, fused writes only the
    (B, K_pad) winner lanes.
    """
    c_pad = -(-c // c_tile) * c_tile
    kp = k_pad(k)
    vec_bytes = dd * bpe + ps * 8 + pf * 8  # dense + two ELL (idx i32 + val f32)
    # quantized storage: int8 dense + 4-byte per-row scale, ELL ids stay
    # int32 but vals drop to fp16; the query side stays fp32
    vec_bytes_q = dd * 1 + 4 + ps * 6 + pf * 6
    inputs = b * vec_bytes + b * c_pad * (vec_bytes + 4)  # +4: candidate id lane
    inputs_q = b * vec_bytes + b * c_pad * (vec_bytes_q + 4)
    score_roundtrip = 2 * b * c_pad * 4  # write (B, C_pad) f32, top_k reads it back
    unfused = inputs + score_roundtrip + b * k * 8
    fused = inputs + b * kp * 8
    quantized = inputs_q + b * kp * 8  # fused selection over int8 storage
    return {
        "bytes_unfused": unfused,
        "bytes_fused": fused,
        "bytes_quantized": quantized,
        "score_roundtrip_bytes": score_roundtrip,
        "bytes_saved_ratio": round(1.0 - fused / unfused, 4),
        "quantized_saved_ratio": round(1.0 - quantized / fused, 4),
        "k_pad": kp,
        "lane_util_selection": round(k / kp, 4),
        "lane_util_candidates": round(c / c_pad, 4),
    }


def _roofline(*, b, c, dd, ps, pf, c_tile, bytes_fused):
    c_pad = -(-c // c_tile) * c_tile
    flops = b * c_pad * (2 * dd + 3 * ps * ps + 3 * pf * pf)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_fused / HBM_BW
    return {
        "model_flops": flops,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": 0.0,
        "dominant": "memory" if memory_s > compute_s else "compute",
    }


def run(dry_run: bool = False) -> dict:
    use_kernel = ops.resolve_use_kernel(None)
    if dry_run:
        b, w, dd, ps, pf, n_corpus = 2, 64, 32, 8, 4, 256
        vs, vf = 997, 251
    else:
        b, w, dd, ps, pf, n_corpus = 8, 256, 256, 64, 32, 4096
        vs, vf = 30522, 8192

    rng = np.random.default_rng(0)
    corpus = random_fused(rng, (n_corpus,), d_dense=dd, ps=ps, pf=pf, vs=vs, vf=vf)
    corpus_q = quantize_corpus(corpus)
    q = random_fused(rng, (b,), d_dense=dd, ps=ps, pf=pf, vs=vs, vf=vf)
    bpe = jnp.dtype(corpus.dense.dtype).itemsize

    sweep = {}
    for c_tile in C_TILES:
        for expand in EXPANDS:
            c = expand * w  # multi-node batching: `expand` nodes' tiles stacked
            ids = jnp.asarray(
                rng.integers(0, n_corpus, size=(b, c), dtype=np.int32)
            )
            for k in KS:
                k_eff = min(k, c)
                _, t_unfused = timed(
                    lambda: jax.block_until_ready(
                        _unfused(q, corpus, ids, k_eff, c_tile, use_kernel)
                    )
                )
                _, t_fused = timed(
                    lambda: jax.block_until_ready(
                        ops.fused_topk_vs_ids(
                            q, corpus, ids, k_eff, c_tile=c_tile, use_kernel=use_kernel
                        )
                    )
                )
                _, t_quant = timed(
                    lambda: jax.block_until_ready(
                        ops.fused_topk_vs_ids(
                            q, corpus_q, ids, k_eff, c_tile=c_tile, use_kernel=use_kernel
                        )
                    )
                )
                n_pairs = b * c
                model = _bytes_model(
                    b=b, c=c, dd=dd, ps=ps, pf=pf, k=k_eff, c_tile=c_tile, bpe=bpe
                )
                row = {
                    "c_tile": c_tile,
                    "k": k,
                    "expand": expand,
                    "n_candidates": c,
                    "unfused_us_per_pair": round(t_unfused * 1e6 / n_pairs, 4),
                    "fused_us_per_pair": round(t_fused * 1e6 / n_pairs, 4),
                    "quantized_us_per_pair": round(t_quant * 1e6 / n_pairs, 4),
                    "fused_ratio": round(t_fused / t_unfused, 4),
                    "quantized_ratio": round(t_quant / t_fused, 4),
                    "model": model,
                    "roofline": _roofline(
                        b=b, c=c, dd=dd, ps=ps, pf=pf, c_tile=c_tile,
                        bytes_fused=model["bytes_fused"],
                    ),
                }
                sweep[f"c{c_tile}_k{k}_e{expand}"] = row

    out = {
        "config": {
            "backend": jax.default_backend(),
            "use_kernel": use_kernel,
            "dry_run": dry_run,
            "b": b,
            "nbr_width": w,
            "d_dense": dd,
            "ps": ps,
            "pf": pf,
            "n_corpus": n_corpus,
        },
        "sweep": sweep,
    }

    if dry_run:
        # CI smoke: the Pallas kernel (interpret) must agree with the oracle.
        ids_s = jnp.asarray(rng.integers(0, n_corpus, size=(2, 96), dtype=np.int32))
        ks, ki = ops.fused_topk_vs_ids(
            q[:2] if b >= 2 else q, corpus, ids_s, 10,
            c_tile=32, use_kernel=True, interpret=True,
        )
        cands = jax.tree.map(
            lambda a: a.reshape((2, 96) + a.shape[1:]),
            corpus.take(ids_s.reshape(-1)),
        )
        ws, wi = ref.fused_topk_ref(q[:2] if b >= 2 else q, cands, ids_s, None, 10)
        # scores agree up to summation order (MXU dot vs oracle einsum);
        # positions agree exactly except across float-ulp ties
        np.testing.assert_allclose(
            np.asarray(ks), np.asarray(ws), rtol=1e-5, atol=1e-5,
            err_msg="fused != oracle",
        )
        flip = np.asarray(ki) != np.asarray(wi)
        assert np.all(
            np.abs(np.asarray(ks) - np.asarray(ws))[flip] < 1e-4
        ), "fused != oracle (pos beyond tie tolerance)"
        out["interpret_check"] = "ok"

        # same smoke over quantized storage: the dequant-in-tile kernel
        # (interpret) must agree with the scale-after-dot oracle
        qs, qi = ops.fused_topk_vs_ids(
            q[:2] if b >= 2 else q, corpus_q, ids_s, 10,
            c_tile=32, use_kernel=True, interpret=True,
        )
        cands_q = jax.tree.map(
            lambda a: a.reshape((2, 96) + a.shape[1:]),
            corpus_q.take(ids_s.reshape(-1)),
        )
        zs, zi = ref.fused_topk_quant_ref(
            q[:2] if b >= 2 else q, cands_q, ids_s, None, 10
        )
        np.testing.assert_allclose(
            np.asarray(qs), np.asarray(zs), rtol=1e-5, atol=1e-5,
            err_msg="quantized fused != oracle",
        )
        flip = np.asarray(qi) != np.asarray(zi)
        assert np.all(
            np.abs(np.asarray(qs) - np.asarray(zs))[flip] < 1e-4
        ), "quantized fused != oracle (pos beyond tie tolerance)"
        out["interpret_check_quantized"] = "ok"

    out["quantized"] = run_quantized_recall()
    return out


def run_quantized_recall() -> dict:
    """Recall@10 of quantized-traversal + full-precision-rescore vs the fp32
    index on the bundled ingest corpus — the committed floor the quantized
    gate enforces, plus the search_padded trace accounting (corpus dtype is
    a treedef property: one trace per storage type, zero extra on repeats)."""
    import dataclasses as _dc

    from repro.core import BuildConfig, KnnConfig, PruneConfig
    from repro.core.fusion import FusionSpec
    from repro.core.search import SearchParams, search, search_padded_trace_count
    from repro.core.usms import quantize_corpus as _quant
    from repro.data.corpus import recall_at_k
    from repro.data.textcorpus import load_bundled_corpus, topic_truth
    from repro.ingest import IngestConfig, IngestPipeline

    corpus = load_bundled_corpus()
    pipe = IngestPipeline(IngestConfig(d_dense=64))
    ingested = pipe.fit(corpus.texts)
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=128),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
        path_refine_iters=1,
    )
    index = pipe.build(ingested, cfg)
    index_q = _dc.replace(index, corpus=_quant(index.corpus))
    enc = pipe.encode_queries(corpus.query_texts)
    truth = topic_truth(corpus.query_topics, corpus.topics)
    spec = FusionSpec.weighted(1.0, 1.0, 1.0)
    params = SearchParams(k=10, iters=48, pool_size=64)
    params_q = _dc.replace(params, corpus_dtype="int8")

    traces0 = search_padded_trace_count()
    r32 = recall_at_k(
        np.asarray(search(index, enc.vectors, spec, params).ids), truth
    )
    r8 = recall_at_k(
        np.asarray(search(index_q, enc.vectors, spec, params_q).ids), truth
    )
    traces_first = search_padded_trace_count() - traces0
    # repeats on both storage types must hit the existing traces
    search(index, enc.vectors, spec, params)
    search(index_q, enc.vectors, spec, params_q)
    traces_repeat = search_padded_trace_count() - traces0 - traces_first
    return {
        "n_docs": len(corpus.texts),
        "n_queries": len(corpus.query_texts),
        "recall_at_10_fp32": float(r32),
        "recall_at_10_int8": float(r8),
        "recall_drop": float(r32 - r8),
        "sweep_traces": int(traces_first),
        "repeat_traces": int(traces_repeat),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="tiny shapes + interpret-mode equality check (CI smoke)",
    )
    ap.add_argument("--out", type=pathlib.Path, default=RESULTS)
    args = ap.parse_args()

    out = run(dry_run=args.dry_run)
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(out, indent=2) + "\n")

    print("pair,unfused_us_per_pair,fused_us_per_pair,ratio,lane_util,bytes_saved")
    for name, row in out["sweep"].items():
        print(
            f"{name},{row['unfused_us_per_pair']:.3f},{row['fused_us_per_pair']:.3f},"
            f"{row['fused_ratio']:.3f},{row['model']['lane_util_selection']:.3f},"
            f"{row['model']['bytes_saved_ratio']:.3f}"
        )
    if "interpret_check" in out:
        print(f"interpret_check,{out['interpret_check']}")
    if "interpret_check_quantized" in out:
        print(f"interpret_check_quantized,{out['interpret_check_quantized']}")
    qz = out["quantized"]
    print(
        f"quantized_recall,fp32={qz['recall_at_10_fp32']:.3f},"
        f"int8={qz['recall_at_10_int8']:.3f},traces={qz['sweep_traces']},"
        f"repeat_traces={qz['repeat_traces']}"
    )
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
