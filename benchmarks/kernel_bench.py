"""Hybrid distance kernel micro-bench: interpret-mode correctness timing on
CPU + the analytic TPU roofline character of the kernel (it is the
distance-computation hot spot the paper's warp kernel targets).

    PYTHONPATH=src python benchmarks/kernel_bench.py [--dry-run]
"""

from __future__ import annotations

import pathlib
import sys

if __package__ in (None, ""):  # script mode: python benchmarks/kernel_bench.py
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

import jax

from repro.kernels import ops
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS_BF16
from tests.helpers import random_fused

from benchmarks.common import timed


def run(dry_run: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    b, c, dd, ps, pf = (2, 64, 64, 8, 4) if dry_run else (8, 512, 1024, 64, 32)
    q = random_fused(rng, (b,), d_dense=dd, ps=ps, pf=pf, vs=30522, vf=8192)
    cands = random_fused(rng, (b, c), d_dense=dd, ps=ps, pf=pf, vs=30522, vf=8192)

    _, t_oracle = timed(
        lambda: jax.block_until_ready(ops.hybrid_scores(q, cands, use_kernel=False))
    )
    _, t_kernel = timed(
        lambda: jax.block_until_ready(
            ops.hybrid_scores(q, cands, use_kernel=True, interpret=True)
        )
    )
    n_pairs = b * c
    rows.append(("kernel.oracle_xla_cpu", t_oracle * 1e6 / n_pairs, f"pairs={n_pairs}"))
    rows.append(("kernel.pallas_interpret", t_kernel * 1e6 / n_pairs,
                 "interpret-mode (correctness harness, not TPU perf)"))

    # analytic TPU roofline of one (query x C_TILE) grid cell
    c_tile = 128
    dense_flops = 2 * c_tile * dd
    sparse_flops = 3 * c_tile * ps * ps + 3 * c_tile * pf * pf  # cmp+mul+acc
    bytes_moved = c_tile * (dd * 2 + ps * 8 + pf * 8) + dd * 2 + ps * 8 + pf * 8
    ai = (dense_flops + sparse_flops) / bytes_moved
    t_compute = (dense_flops + sparse_flops) / PEAK_FLOPS_BF16
    t_memory = bytes_moved / HBM_BW
    rows.append((
        "kernel.tpu_roofline_per_tile",
        max(t_compute, t_memory) * 1e6,
        f"arith_intensity={ai:.1f}flops/B;bound={'memory' if t_memory > t_compute else 'compute'}",
    ))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="tiny shapes; verifies the kernel entry points run (CI smoke)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(dry_run=args.dry_run):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
