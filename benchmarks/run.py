"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8,table2,...]

Prints ``name,us_per_call,derived`` CSV rows (and writes
results/benchmarks.csv)."""

from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller corpora")
    ap.add_argument("--only", default=None, help="comma list of bench names")
    args = ap.parse_args()

    from benchmarks import (
        fig8_overall,
        fig10_pruning,
        fig11_keyword,
        fig12_weights,
        fig14_scale,
        kernel_bench,
        serving_bench,
        table2_build,
        table3_kg,
        table5_insert,
    )

    q = args.quick
    benches = {
        "fig8": lambda: fig8_overall.run(*((2048, 32) if q else (8192, 64))),
        "table2": lambda: table2_build.run(2048 if q else 8192),
        "table3": lambda: table3_kg.run(*((2048, 32) if q else (4096, 64))),
        "fig10": lambda: fig10_pruning.run(*((2048, 32) if q else (4096, 64))),
        "fig11": lambda: fig11_keyword.run(*((2048, 32) if q else (4096, 64))),
        "fig12": lambda: fig12_weights.run(*((2048, 32) if q else (4096, 64))),
        "table5": lambda: table5_insert.run(*((2048, 32) if q else (4096, 64))),
        "fig14": lambda: fig14_scale.run(
            n_docs=2048 if q else 10_000,
            replicas=(1, 2) if q else (1, 2, 4),
            n_requests=64 if q else 256,
            segment_docs=256,
        ),
        "kernel": kernel_bench.run,
        "serving": lambda: serving_bench.run(*((1024, 64) if q else (4096, 256))),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            rows = [(f"{name}.ERROR", 0.0, "failed")]
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
            all_rows.append(r)
        print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr, flush=True)

    out = pathlib.Path("results")
    out.mkdir(exist_ok=True)
    with open(out / "benchmarks.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in all_rows:
            f.write(f"{r[0]},{r[1]:.1f},{r[2]}\n")


if __name__ == "__main__":
    main()
