"""Paper Figure 12, extended into the dynamic-fusion sweep: the same index
(and the same compiled executable) serving every fusion mode x weight mix
with zero reconstruction and zero recompiles.

``run()`` is the original synthetic-corpus weight sweep (the benchmarks.run
harness entry). ``main()`` is the fusion sweep on the bundled real-text
corpus: recall@10 per (fusion mode, weight mix) cell, plus the trace count
across the whole sweep — the shape-stability evidence (DESIGN.md §11).
Results land in ``results/BENCH_fusion.json``; the recall-floor gate in
``benchmarks/check_regression.py --only fusion`` compares them against the
committed baseline.

    PYTHONPATH=src python benchmarks/fig12_weights.py [--dry-run]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

if __package__ in (None, ""):  # script mode
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]

import numpy as np

import jax

from repro.core import FusionSpec, build_index
from repro.core.fusion import FUSION_MODES, PathStats
from repro.core.search import SearchParams, search
from repro.obs.metrics import GLOBAL
from repro.data.corpus import ndcg_at_k


def run(n_docs=4096, n_queries=64):
    from benchmarks.common import default_build, simple_corpus, timed

    corpus = simple_corpus(n_docs, n_queries)
    truth = corpus.query_relevant
    cfg = default_build(corpus.docs.n)
    index = build_index(corpus.docs, cfg)
    params = SearchParams(k=10, iters=40, pool_size=64)
    rows = []
    best_alpha, best_nd = 0.5, -1.0
    for alpha in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0):
        spec = FusionSpec.weighted(alpha, 1 - alpha, 0.0)
        ids, sec = timed(
            lambda s=spec: search(index, corpus.queries, s, params).ids
        )
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        if nd > best_nd:
            best_alpha, best_nd = alpha, nd
        rows.append((f"fig12.two_path.a{alpha:.1f}", sec * 1e6 / n_queries,
                     f"ndcg={nd:.3f}"))
    for alpha in (0.1, 0.5, 0.9):
        # three-path: alpha * (dense + w_opt*sparse) + (1-alpha) * full
        w_opt = best_alpha and (1 - best_alpha) / max(best_alpha, 1e-6)
        spec = FusionSpec.weighted(alpha, alpha * w_opt, 1 - alpha)
        ids, sec = timed(
            lambda s=spec: search(index, corpus.queries, s, params).ids
        )
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        rows.append((f"fig12.three_path.a{alpha:.1f}", sec * 1e6 / n_queries,
                     f"ndcg={nd:.3f}"))
    # fusion modes at equal weights on the same index — the dynamic-fusion
    # extension of the figure (rrf/normalized vs weighted-sum)
    stats = PathStats.from_corpus(index.corpus, index.alive)
    for mode in FUSION_MODES:
        spec = FusionSpec.make(mode, 1.0, 1.0, 1.0, stats=stats)
        ids, sec = timed(
            lambda s=spec: search(index, corpus.queries, s, params).ids
        )
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        rows.append((f"fig12.mode_{mode}", sec * 1e6 / n_queries,
                     f"ndcg={nd:.3f}"))
    return rows


WEIGHT_MIXES = [
    ("dense_only", (1.0, 0.0, 0.0)),
    ("hybrid", (1.0, 1.0, 1.0)),
    ("skewed", (1.0, 0.5, 0.5)),
]


def run_fusion_sweep(dry_run: bool = False) -> dict:
    """mode x mix recall@10 on the bundled ingest corpus, all cells through
    one compiled executable (the trace counter is part of the artifact)."""
    from repro.core import BuildConfig, KnnConfig, PruneConfig
    from repro.data.corpus import recall_at_k
    from repro.data.textcorpus import load_bundled_corpus, topic_truth
    from repro.ingest import IngestConfig, IngestPipeline

    corpus = load_bundled_corpus()
    pipe = IngestPipeline(IngestConfig(d_dense=64))
    ingested = pipe.fit(corpus.texts)
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=128),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
        path_refine_iters=1,
    )
    index = pipe.build(ingested, cfg)
    jax.block_until_ready(index.semantic_edges)

    enc = pipe.encode_queries(corpus.query_texts)
    truth = topic_truth(corpus.query_topics, corpus.topics)
    params = SearchParams(k=10, iters=48, pool_size=64)
    stats = PathStats.from_corpus(index.corpus, index.alive)

    recall = {}
    t0 = time.perf_counter()
    _trace_metric = "allanpoe_core_search_padded_traces_total"
    traces0 = GLOBAL.value(_trace_metric)
    for mode in FUSION_MODES:
        for mix_name, (wd, ws, wf) in WEIGHT_MIXES:
            spec = FusionSpec.make(mode, wd, ws, wf, stats=stats)
            res = search(index, enc.vectors, spec, params)
            recall[f"{mode}.{mix_name}"] = float(
                recall_at_k(np.asarray(res.ids), truth)
            )
    sweep_s = time.perf_counter() - t0
    # every cell after the first reuses the one compiled executable: fusion
    # mode/weights/stats are traced data, never part of the trace signature
    # (counted by the process-wide registry series the obs gate also reads)
    traces = int(GLOBAL.value(_trace_metric) - traces0)

    hybrid_best = max(
        recall[f"{m}.hybrid"] for m in FUSION_MODES
    )
    return {
        "config": {
            "n_docs": len(corpus.texts),
            "n_queries": len(corpus.query_texts),
            "d_dense": 64,
            "modes": sorted(FUSION_MODES),
            "mixes": [m for m, _ in WEIGHT_MIXES],
            "backend": jax.default_backend(),
            "dry_run": dry_run,
        },
        "recall_at_10": recall,
        "hybrid_best": hybrid_best,
        "hybrid_lift": hybrid_best - recall["weighted_sum.dense_only"],
        "sweep_s": sweep_s,
        "sweep_traces": int(traces),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="CI entry-point check (same bundled corpus; flagged in config)",
    )
    ap.add_argument("--out", default="results/BENCH_fusion.json")
    args = ap.parse_args()

    out = run_fusion_sweep(dry_run=args.dry_run)
    path = pathlib.Path(args.out)
    path.parent.mkdir(exist_ok=True)
    path.write_text(json.dumps(out, indent=2) + "\n")

    rec = out["recall_at_10"]
    for key in sorted(rec):
        print(f"recall@10 {key:24s} {rec[key]:.3f}")
    print(
        f"sweep: {len(rec)} cells in {out['sweep_s']:.1f}s, "
        f"{out['sweep_traces']} trace(s)"
    )
    lift = out["hybrid_lift"]
    if lift < 0:
        print(f"FAIL: best hybrid fusion fell {-lift:.3f} BELOW dense-only")
        return 1
    print(f"PASS: best hybrid >= dense-only (lift {lift:+.3f}); wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
