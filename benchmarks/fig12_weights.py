"""Paper Figure 12: accuracy across fusion weights — the same index serving
every weight vector with zero reconstruction."""

from __future__ import annotations

import numpy as np

from benchmarks.common import default_build, simple_corpus, timed
from repro.core import build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import ndcg_at_k


def run(n_docs=4096, n_queries=64):
    corpus = simple_corpus(n_docs, n_queries)
    truth = corpus.query_relevant
    cfg = default_build(corpus.docs.n)
    index = build_index(corpus.docs, cfg)
    params = SearchParams(k=10, iters=40, pool_size=64)
    rows = []
    best_alpha, best_nd = 0.5, -1.0
    for alpha in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0):
        w = PathWeights.make(alpha, 1 - alpha, 0.0)
        ids, sec = timed(lambda w=w: search(index, corpus.queries, w, params).ids)
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        if nd > best_nd:
            best_alpha, best_nd = alpha, nd
        rows.append((f"fig12.two_path.a{alpha:.1f}", sec * 1e6 / n_queries,
                     f"ndcg={nd:.3f}"))
    for alpha in (0.1, 0.5, 0.9):
        # three-path: alpha * (dense + w_opt*sparse) + (1-alpha) * full
        w_opt = best_alpha and (1 - best_alpha) / max(best_alpha, 1e-6)
        w = PathWeights.make(alpha, alpha * w_opt, 1 - alpha)
        ids, sec = timed(lambda w=w: search(index, corpus.queries, w, params).ids)
        nd = ndcg_at_k(np.asarray(ids), truth, 10)
        rows.append((f"fig12.three_path.a{alpha:.1f}", sec * 1e6 / n_queries,
                     f"ndcg={nd:.3f}"))
    return rows
