"""Grow-segment streaming router for the segmented serving layer.

The paper's index supports incremental insertion without reconstruction
(§4.1 "Updates"), and PR 2 gave every segment a device-resident
``insert()`` program — but a sharded ``SegmentedIndex`` had no service-level
way to absorb writes: any change to the stacked sealed segments would
change their shapes and evict every AOT-compiled search executable. This
module closes that gap with the classic vector-DB grow-segment scheme
(Milvus growing segments, GRAB-ANNS bucketed incremental indexing):

  * **growing** — streaming ``insert()`` batches land in one small mutable
    ``HybridIndex`` (the *grow segment*), built on first insert via
    ``build_index`` and extended by ``core.build_pipeline.insert`` (the
    pipelined per-segment insert program). Sealed segments are never
    touched, so their compiled executables stay warm; the read path merges
    sealed + grow per-row top-k in global-id space
    (``HybridSearchService._merge_grow``). The published grow segment is
    padded to power-of-two capacity by default (``RouterConfig.grow_pow2``)
    so the read path's ``search_padded`` retraces O(log growth) times
    between compactions instead of once per insert batch;
  * **sealed** — the immutable stacked segments served through
    ``make_distributed_search_padded``'s cached executable. Deletions
    resolve global ids to (segment, local row) tombstones
    (``core.distributed.mark_deleted_segmented``) — shape-preserving, so no
    recompiles;
  * **compacted** — when the grow segment's live docs cross
    ``RouterConfig.seal_threshold``, ``compact()`` runs the configured
    compaction. ``compact_incremental`` (the default for pool-fronted
    services) seals the grow segment into ONE new pooled segment — O(grow)
    build work, tombstoned grow rows dropped, entity rows carried — and
    appends it to the ``core.segment_pool.SegmentPool`` at pow2 capacity;
    untouched shape groups keep their compiled executables (DESIGN.md §8),
    and a size-tiered ``merge_segments`` policy (``maybe_merge_segments``)
    bounds fragmentation LSM-style. ``seal_and_compact`` remains the full
    rebuild: ALL surviving docs (sealed minus tombstones, plus live grow
    docs) rebuilt into a fresh stacked index via ``build_index_sharded``
    (or the sequential ``build_segmented_index`` off-mesh), preserving
    global ids — O(corpus), total tombstone reclamation, every sealed
    executable recompiles.

Every mutation happens under the service's write lock and lands as one
atomic ``_Snapshot`` publish: readers either see (old sealed, old grow) or
(new sealed, new grow), never a half-updated pair. See DESIGN.md §6.

Knowledge-graph scope: give the router the triplets
(``SegmentRouter(..., kg_triplets=..., n_entities=...)``) and entity paths
survive compaction (logical edges are rebuilt over the surviving docs'
entities); a grow segment born from an entity-carrying insert gets its own
logical edges too, though docs from LATER inserts into the same grow
segment only gain logical edges at compaction. Constructing a router
without triplets over a KG-bearing sealed index fails fast unless
``RouterConfig.allow_kg_loss_on_compact`` is set.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.build_pipeline import (
    build_index,
    insert as index_insert,
    map_index_rows,
    pad_index_rows,
    slice_index_rows,
)
from repro.core.distributed import (
    alive_docs,
    compact_segmented_index,
    mark_deleted_segmented,
    mesh_segment_count,
    place_segmented_index,
    resolve_global_ids,
)
from repro.core.index import (
    BuildConfig,
    HybridIndex,
    mark_deleted as index_mark_deleted,
)
from repro.core.logical_edges import build_logical_edges
from repro.core.search import SearchParams
from repro.core.segment_pool import (
    SegmentPool,
    alive_docs_pool,
    append_segment,
    build_pool_segment,
    extract_segment_docs,
    live_counts,
    mark_deleted_pool,
    place_pool,
    remove_segments,
    resolve_global_ids_pool,
    widen_entities,
)
from repro.core.usms import PAD_IDX, FusedVectors, quantize_corpus
from repro.obs.metrics import MetricsRegistry
from repro.serving.batcher import _next_pow2
from repro.serving.hybrid_service import HybridSearchService


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    seal_threshold: int = 256  # live grow docs that trigger compaction
    auto_compact: bool = True  # compact from insert() when over threshold
    # optional override for the insert probe's search breadth (k and the
    # edge paths are forced by the build config; see build_pipeline.insert)
    insert_search: Optional[SearchParams] = None
    # opt-in acknowledgement that compacting a KG-bearing index WITHOUT
    # giving the router the triplets permanently drops the entity paths
    allow_kg_loss_on_compact: bool = False
    # shape-bucket the PUBLISHED grow segment: pad its capacity to the next
    # power of two so the read path's search_padded retraces O(log growth)
    # times between compactions instead of once per insert batch (pad rows
    # are dead — alive=False, PAD edges — and unreachable: no entry point or
    # edge ever references them)
    grow_pow2: bool = True
    # compaction mode: "incremental" seals the grow segment into ONE pooled
    # segment (O(grow) build work); "full" rebuilds every surviving doc into
    # a fresh stacked index (O(corpus), reclaims all tombstones). None =
    # auto: incremental when the service fronts a SegmentPool, full for a
    # plain SegmentedIndex (back-compat with pre-pool deployments)
    compaction: Optional[str] = None
    # quantize sealed pool-segment capacity to the next power of two, so
    # segments land in O(log corpus) shape groups and executables are reused
    seal_pow2: bool = True
    # size-tiered (LSM-style) merge invariant: at most tier_fanout segments
    # per pow2-capacity tier; maybe_merge_segments() coalesces the smallest
    # two of an offending tier. auto_merge runs it after each incremental
    # compaction
    tier_fanout: int = 4
    auto_merge: bool = True
    # run auto merges on a background worker thread (each merge still takes
    # the service write lock): compact_incremental returns as soon as the
    # new segment publishes instead of paying the merge cascade inline.
    # stop_pump()/stop_merge_worker() joins the worker; wait_merges() blocks
    # until the policy is quiescent (tests use it for determinism)
    background_merge: bool = True
    # auto-checkpoint: every N compactions persist the sealed pool (and the
    # paired ingest manifest) via checkpoint.index_io.save_pool, so a crash
    # can never lose more than the current grow segment. 0 = off.
    autocheckpoint_every: int = 0
    autocheckpoint_dir: Optional[str] = None


# retained names: the row pad/slice helpers moved to core.build_pipeline so
# the segment pool can share them (pool segments are shape-bucketed the same
# way the published grow segment is)
_map_grow_rows = map_index_rows
pad_grow_to_capacity = pad_index_rows
slice_grow_rows = slice_index_rows


class RouterStats:
    """Registry-backed view of the router's write-path counters.

    Every field is a ``allanpoe_router_*`` series in the owning service's
    metrics registry, so increments are atomic under the registry lock and
    the numbers in ``MetricsRegistry.render()`` are the numbers these
    properties report — there is no second bookkeeping path."""

    def __init__(self, metrics: MetricsRegistry):
        self._inserts = metrics.counter(
            "allanpoe_router_inserts_total",
            "insert() calls absorbed by the grow segment",
        )
        self._inserted_docs = metrics.counter(
            "allanpoe_router_inserted_docs_total",
            "documents appended to the grow segment",
        )
        self._deletes = metrics.counter(
            "allanpoe_router_deletes_total", "delete() calls"
        )
        self._deleted_docs = metrics.counter(
            "allanpoe_router_deleted_docs_total",
            "ids tombstoned, by where they lived "
            "(unknown = found nowhere, already compacted away?)",
            labels=("target",),
        )
        self._compactions = metrics.counter(
            "allanpoe_router_compactions_total",
            "grow-segment seals, full rebuilds vs incremental pool appends",
            labels=("mode",),
        )
        self._merges = metrics.counter(
            "allanpoe_router_merges_total", "background segment merges"
        )
        self._autocheckpoints = metrics.counter(
            "allanpoe_router_autocheckpoints_total",
            "pool checkpoints written by the router",
        )

    @property
    def inserts(self) -> int:
        return int(self._inserts.total())

    @property
    def inserted_docs(self) -> int:
        return int(self._inserted_docs.total())

    @property
    def deletes(self) -> int:
        return int(self._deletes.total())

    @property
    def deleted_sealed(self) -> int:
        return int(self._deleted_docs.value(target="sealed"))

    @property
    def deleted_grow(self) -> int:
        return int(self._deleted_docs.value(target="grow"))

    @property
    def unknown_deletes(self) -> int:
        return int(self._deleted_docs.value(target="unknown"))

    @property
    def compactions(self) -> int:
        return int(self._compactions.total())

    @property
    def incremental_compactions(self) -> int:
        return int(self._compactions.value(mode="incremental"))

    @property
    def merges(self) -> int:
        return int(self._merges.total())

    @property
    def autocheckpoints(self) -> int:
        return int(self._autocheckpoints.total())

    def __repr__(self) -> str:
        return (
            f"RouterStats(inserts={self.inserts}, "
            f"inserted_docs={self.inserted_docs}, deletes={self.deletes}, "
            f"deleted_sealed={self.deleted_sealed}, "
            f"deleted_grow={self.deleted_grow}, "
            f"unknown_deletes={self.unknown_deletes}, "
            f"compactions={self.compactions}, "
            f"incremental_compactions={self.incremental_compactions}, "
            f"merges={self.merges}, autocheckpoints={self.autocheckpoints})"
        )


class SegmentRouter:
    """Fronts a segmented ``HybridSearchService`` with a grow segment.

    Constructing a router attaches it to the service: ``service.insert`` /
    ``service.mark_deleted`` delegate here, and the service's read path
    starts merging the grow segment automatically once one exists."""

    def __init__(
        self,
        service: HybridSearchService,
        build_cfg: BuildConfig,
        config: Optional[RouterConfig] = None,
        *,
        kg_triplets: Optional[np.ndarray] = None,
        n_entities: int = 0,
        ingest=None,
    ):
        if not getattr(service, "_segmented", False):
            raise ValueError(
                "SegmentRouter fronts a SegmentedIndex service; a single "
                "HybridIndex already supports insert()/mark_deleted() directly"
            )
        self.service = service
        self.build_cfg = build_cfg
        self.config = config or RouterConfig()
        self.stats = RouterStats(service.metrics)
        # fitted IngestPipeline paired with auto-checkpoints (an index
        # restored without its frozen stats is silently wrong; DESIGN.md §7)
        self._ingest = ingest
        self._ckpt_lock = threading.Lock()  # serializes checkpoint writes
        self._last_ckpt_compactions = 0
        self._merge_lock = threading.Lock()  # merge-worker start/stop
        self._merge_thread: Optional[threading.Thread] = None
        self._merge_wake = threading.Event()
        self._merge_idle = threading.Event()
        self._merge_idle.set()
        self._merge_stop = threading.Event()
        self._kg_triplets = (
            None if kg_triplets is None else np.asarray(kg_triplets, np.int32)
        )
        self._n_entities = int(n_entities)
        # entity_adj is (1, 1) for a KG-less build (LogicalEdges.empty):
        # anything wider means the sealed index carries entity paths that a
        # triplet-less compaction would silently destroy — fail fast unless
        # the caller explicitly opted into that loss
        sealed = service._snap.index
        if isinstance(sealed, SegmentPool):
            sealed_has_kg = sealed.has_kg
            self._next_gid = sealed.max_global_id() + 1
        else:
            sealed_has_kg = sealed.index.entity_adj.shape[-1] > 1
            gids = np.asarray(sealed.global_ids)
            self._next_gid = int(gids.max()) + 1 if (gids >= 0).any() else 0
        if (
            sealed_has_kg
            and self._kg_triplets is None
            and not self.config.allow_kg_loss_on_compact
        ):
            raise ValueError(
                "the sealed index carries knowledge-graph data but the "
                "router has no kg_triplets: compaction would drop every "
                "entity path. Pass kg_triplets/n_entities, or set "
                "RouterConfig(allow_kg_loss_on_compact=True) to accept it."
            )
        self._grow_raw: Optional[HybridIndex] = None
        if service._snap.grow_gids is not None:
            # re-attaching over a live grow segment: its ids are allocated
            # past the sealed ones and must never be handed out again
            self._next_gid = max(
                self._next_gid, int(np.asarray(service._snap.grow_gids).max()) + 1
            )
            # recover the raw (unpadded) grow segment inserts extend — the
            # published one may carry a pow2 dead-row tail
            self._grow_raw = slice_grow_rows(
                service._snap.grow, int(service._snap.grow_gids.shape[0])
            )
        service._router = self

    # -- introspection ------------------------------------------------------

    @property
    def grow_size(self) -> int:
        """Real rows in the grow segment (including tombstoned ones,
        excluding pow2 shape-bucket padding)."""
        gids = self.service._snap.grow_gids
        return 0 if gids is None else int(gids.shape[0])

    @property
    def grow_capacity(self) -> int:
        """Published grow-segment capacity (= grow_size rounded up to a
        power of two when ``RouterConfig.grow_pow2`` is on)."""
        grow = self.service._snap.grow
        return 0 if grow is None else int(grow.n)

    @property
    def live_grow_size(self) -> int:
        """Non-tombstoned grow docs — the seal-threshold measure (pad rows
        are dead and never count)."""
        grow = self.service._snap.grow
        return 0 if grow is None else int(np.asarray(grow.alive).sum())

    @property
    def pool(self) -> Optional[SegmentPool]:
        """The sealed segment pool (None while fronting a plain stacked
        index that has never compacted incrementally)."""
        idx = self.service._snap.index
        return idx if isinstance(idx, SegmentPool) else None

    @property
    def compaction_mode(self) -> str:
        """Resolved ``RouterConfig.compaction``: explicit setting, else
        incremental for pool-fronted services and full otherwise."""
        if self.config.compaction is not None:
            return self.config.compaction
        return "incremental" if self.pool is not None else "full"

    @staticmethod
    def _entity_width(index) -> int:
        if isinstance(index, SegmentPool):
            return index.entity_width
        return int(index.index.doc_entities.shape[-1])

    @staticmethod
    def _as_pool(index) -> SegmentPool:
        """Wrap a stacked index as a single-group pool (no copy: its cached
        executable keeps serving — the keys are shape-identical)."""
        return (
            index
            if isinstance(index, SegmentPool)
            else SegmentPool.from_segmented(index)
        )

    def _corpus_dtype(self) -> str:
        """Sealed-segment storage dtype: follows the service's resolved
        SearchParams, so the AOT cache key and the storage always agree."""
        return self.service.params.corpus_dtype

    def _kg_kwargs(self, doc_entities: Optional[np.ndarray]) -> dict:
        if self._kg_triplets is None or self._n_entities <= 0:
            return {}
        return dict(
            kg_triplets=self._kg_triplets,
            doc_entities=doc_entities,
            n_entities=self._n_entities,
        )

    # -- writes (all under the service write lock, atomic publishes) --------

    def insert(
        self,
        new_docs: FusedVectors,
        *,
        key: Optional[jax.Array] = None,
        new_doc_entities: Optional[np.ndarray] = None,
        global_ids: Optional[np.ndarray] = None,
    ) -> int:
        """Absorb a batch of new docs into the grow segment; returns the new
        snapshot version. Never touches sealed segments (their executables
        stay cached). May trigger seal-and-compact when the grow segment
        crosses the threshold and ``auto_compact`` is on.

        ``global_ids`` pins the docs' ids instead of allocating them here —
        the replica-tier path (``serving.replica_router``), where placement
        is a function of the id and the TIER allocates: ids must be fresh
        (>= this router's next id) and strictly increasing, preserving the
        sorted-gid-map invariant the delete path relies on."""
        svc = self.service
        n_new = int(new_docs.n)
        if n_new == 0:
            return svc.snapshot_version
        if global_ids is not None:
            global_ids = np.asarray(global_ids, np.int64)
            if global_ids.shape != (n_new,):
                raise ValueError(
                    f"global_ids must be ({n_new},) to map every new doc"
                )
            if global_ids.size and (
                int(global_ids[0]) < self._next_gid
                or (np.diff(global_ids) <= 0).any()
            ):
                raise ValueError(
                    "pinned global_ids must be strictly increasing and >= "
                    f"the router's next id ({self._next_gid}): grow gids "
                    "stay sorted so deletes resolve by searchsorted"
                )
        if new_doc_entities is not None:
            if self._kg_triplets is None:
                raise ValueError(
                    "new_doc_entities given but the router has no knowledge "
                    "graph: pass kg_triplets/n_entities at construction"
                )
            new_doc_entities = np.asarray(new_doc_entities, np.int32)
            ent_width = self._entity_width(svc._snap.index)
            if new_doc_entities.shape != (n_new, ent_width):
                raise ValueError(
                    f"new_doc_entities must be ({n_new}, {ent_width}) to "
                    "match the sealed index's entity width"
                )
        with svc._write_lock:
            snap = svc._snap
            if key is None:
                key = jax.random.fold_in(jax.random.key(17), snap.version)
            new_gids = (
                np.arange(self._next_gid, self._next_gid + n_new, dtype=np.int32)
                if global_ids is None
                else global_ids.astype(np.int32)
            )
            if snap.grow is None:
                kg_kwargs = {}
                if self._kg_triplets is not None:
                    # a KG router ALWAYS births the grow segment with the
                    # sealed entity width (all-PAD rows when the batch has
                    # no entities), so later entity-carrying inserts never
                    # hit build_pipeline.insert's width check
                    ents = new_doc_entities
                    if ents is None:
                        width = self._entity_width(snap.index)
                        ents = np.full((n_new, width), PAD_IDX, np.int32)
                    kg_kwargs = dict(
                        kg_triplets=self._kg_triplets,
                        doc_entities=ents,
                        n_entities=self._n_entities,
                    )
                grow = build_index(new_docs, self.build_cfg, key=key, **kg_kwargs)
                gids = jnp.asarray(new_gids)
            else:
                # inserts always extend the RAW grow segment; the published
                # one may carry a pow2 dead-row tail that must not become
                # real neighbors
                grow = index_insert(
                    self._grow_raw,
                    new_docs,
                    self.build_cfg,
                    key=key,
                    new_doc_entities=new_doc_entities,
                    search_params=self.config.insert_search,
                )
                if new_doc_entities is not None:
                    # logical edges append INCREMENTALLY: docs inserted into
                    # an already-born grow segment get their entity paths
                    # now, not at the next compaction (host-side numpy over
                    # the small grow segment — O(grow))
                    grow = self._rebuild_grow_logical_edges(grow)
                gids = jnp.concatenate([snap.grow_gids, jnp.asarray(new_gids)])
            self._next_gid = int(new_gids[-1]) + 1
            self._grow_raw = grow
            if self.config.grow_pow2:
                grow = pad_grow_to_capacity(grow, _next_pow2(grow.n))
            svc._publish(snap.index, grow=grow, grow_gids=gids)
            self.stats._inserts.inc()
            self.stats._inserted_docs.inc(n_new)
            version = svc._snap.version
        if (
            self.config.auto_compact
            and self.live_grow_size >= self.config.seal_threshold
        ):
            return self.compact()
        return version

    def _rebuild_grow_logical_edges(self, grow: HybridIndex) -> HybridIndex:
        """Recompute the grow segment's logical edges over its FULL entity
        table (``build_pipeline.insert`` only appends PAD logical rows).
        Shape-stable: the caps and entity-table dims come from the build
        config and the frozen entity vocab."""
        if self._kg_triplets is None or self._n_entities <= 0:
            return grow
        log = build_logical_edges(
            self._kg_triplets,
            np.asarray(grow.doc_entities),
            self._n_entities,
            l_cap=self.build_cfg.logical_cap,
            m_cap=self.build_cfg.entity_doc_cap,
        )
        return dataclasses.replace(
            grow,
            logical_edges=jnp.asarray(log.edges),
            doc_entities=jnp.asarray(log.doc_entities),
            entity_to_docs=jnp.asarray(log.entity_to_docs),
            entity_adj=jnp.asarray(log.entity_adj),
        )

    def compact(self, *, key: Optional[jax.Array] = None) -> int:
        """Run the configured compaction: ``compact_incremental`` seals the
        grow segment into one pooled segment (O(grow) build work);
        ``seal_and_compact`` rebuilds everything (O(corpus))."""
        if self.compaction_mode == "incremental":
            return self.compact_incremental(key=key)
        return self.seal_and_compact(key=key)

    def delete(self, global_ids) -> int:
        """Tombstone docs by global id, wherever they live: sealed ids
        become (segment, local row) tombstones in the stacked alive mask,
        grow ids are mark-deleted in the grow segment. Both are
        shape-preserving — no executable is evicted. Returns the new
        snapshot version."""
        svc = self.service
        ids = np.atleast_1d(np.asarray(global_ids, np.int64))
        with svc._write_lock:
            snap = svc._snap
            pooled = isinstance(snap.index, SegmentPool)
            if pooled:
                grp, seg, loc = resolve_global_ids_pool(snap.index, ids)
                in_sealed = grp >= 0
            else:
                seg, loc = resolve_global_ids(snap.index, ids)
                in_sealed = seg >= 0
            grow, grow_gids = snap.grow, snap.grow_gids
            in_grow = np.zeros(ids.shape, bool)
            if grow is not None:
                gmap = np.asarray(grow_gids)
                in_grow = np.isin(ids, gmap) & ~in_sealed
                if in_grow.any():
                    # grow gids are allocated monotonically, so the map is
                    # sorted and searchsorted resolves local rows directly
                    # (row indices are identical in the raw and the padded
                    # view — padding only appends a dead tail)
                    rows = jnp.asarray(
                        np.searchsorted(gmap, ids[in_grow]), jnp.int32
                    )
                    grow = index_mark_deleted(grow, rows)
                    self._grow_raw = index_mark_deleted(self._grow_raw, rows)
            sealed = snap.index
            if in_sealed.any():
                if pooled:
                    sealed = mark_deleted_pool(
                        sealed, ids[in_sealed],
                        resolved=(grp[in_sealed], seg[in_sealed],
                                  loc[in_sealed]),
                    )
                else:
                    sealed = mark_deleted_segmented(
                        sealed, ids[in_sealed],
                        resolved=(seg[in_sealed], loc[in_sealed]),
                    )
            svc._publish(sealed, grow=grow, grow_gids=grow_gids)
            self.stats._deletes.inc()
            self.stats._deleted_docs.inc(int(in_sealed.sum()), target="sealed")
            self.stats._deleted_docs.inc(int(in_grow.sum()), target="grow")
            self.stats._deleted_docs.inc(
                int((~in_sealed & ~in_grow).sum()), target="unknown"
            )
            return svc._snap.version

    def seal_and_compact(self, *, key: Optional[jax.Array] = None) -> int:
        """Rebuild all surviving docs — sealed minus tombstones, plus live
        grow docs — into a fresh S-segment sealed index (S unchanged: the
        one-segment-per-device contract), remap the original global ids
        onto it, and publish atomically with the grow segment cleared.

        Physically drops every tombstoned id: this is the step that turns
        mark-deletion into reclaimed rows. Per-segment shapes change, so
        sealed executables recompile on the next read — the documented cost
        of compaction (DESIGN.md §6)."""
        svc = self.service
        with svc._write_lock:
            snap = svc._snap
            pooled = isinstance(snap.index, SegmentPool)
            if pooled:
                tombstoned = any(
                    bool(
                        (~np.asarray(g.index.alive)
                         & (np.asarray(g.global_ids) >= 0)).any()
                    )
                    for g in snap.index.groups
                )
                fragmented = snap.index.n_groups > 1
            else:
                tombstoned = bool(
                    (~np.asarray(snap.index.index.alive)
                     & (np.asarray(snap.index.global_ids) >= 0)).any()
                )
                fragmented = False
            if snap.grow is None and not tombstoned and not fragmented:
                return snap.version  # nothing growing, nothing to reclaim
            if pooled:
                sealed_corpus, sealed_gids, sealed_ents = alive_docs_pool(
                    snap.index
                )
            else:
                sealed_corpus, sealed_gids, sealed_ents = alive_docs(snap.index)
            parts_corpus, parts_gids = [sealed_corpus], [sealed_gids]
            parts_ents = [sealed_ents]
            ent_width = sealed_ents.shape[-1]
            if snap.grow is not None:
                live = np.flatnonzero(np.asarray(snap.grow.alive))
                if live.size:
                    parts_corpus.append(
                        jax.tree.map(
                            lambda a: jnp.asarray(np.asarray(a)[live]),
                            snap.grow.corpus,
                        )
                    )
                    parts_gids.append(np.asarray(snap.grow_gids)[live])
                    # grow entity rows, padded/clipped to the sealed width
                    # (a KG-less grow segment has width-1 all-PAD rows)
                    parts_ents.append(widen_entities(
                        np.asarray(snap.grow.doc_entities)[live], ent_width
                    ))
            corpus = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts_corpus
            )
            gids = np.concatenate(parts_gids)
            if key is None:
                key = jax.random.fold_in(jax.random.key(23), snap.version)
            kg_kwargs = {}
            if self._kg_triplets is not None:
                kg_kwargs = dict(
                    kg_triplets=self._kg_triplets,
                    doc_entities=np.concatenate(parts_ents, axis=0),
                    n_entities=self._n_entities,
                )
            if pooled:
                # a pool full-rebuild collapses every group into one fresh
                # stacked index spread over the mesh's segment devices —
                # total tombstone/fragmentation reclamation
                n_segments = (
                    mesh_segment_count(svc._mesh)
                    if svc._mesh is not None
                    else 1
                )
            else:
                n_segments = snap.index.n_segments
            new_seg = compact_segmented_index(
                corpus,
                gids,
                n_segments,
                self.build_cfg,
                mesh=svc._mesh,
                key=key,
                **kg_kwargs,
            )
            if self._corpus_dtype() == "int8":
                # builds are always fp32; sealed storage quantizes here
                new_seg = dataclasses.replace(
                    new_seg,
                    index=dataclasses.replace(
                        new_seg.index,
                        corpus=quantize_corpus(new_seg.index.corpus),
                    ),
                )
            if svc._mesh is not None:
                new_seg = place_segmented_index(new_seg, svc._mesh)
            published = self._as_pool(new_seg) if pooled else new_seg
            svc._publish(published, grow=None, grow_gids=None)
            self._grow_raw = None
            self.stats._compactions.inc(mode="full")
            version = svc._snap.version
        self._maybe_autocheckpoint()
        return version

    def compact_incremental(self, *, key: Optional[jax.Array] = None) -> int:
        """Seal the grow segment into ONE new pooled segment: rebuild only
        its live rows (O(grow segment) build work, asserted against the
        ``dispatch.build_rows`` counter by tests), carry their entity rows,
        drop its tombstones, and append to the pool — at pow2 capacity when
        ``RouterConfig.seal_pow2``, so segments land in reusable shape
        groups. Sealed segments are NEVER touched: their tombstones wait for
        ``merge_segments``/``seal_and_compact``, and every group the new
        segment does not join keeps its compiled executables byte-identical
        (verified by ``test_segment_pool.py``). Publishes atomically with
        the grow segment cleared; then runs the size-tier merge policy when
        ``auto_merge`` is on."""
        svc = self.service
        with svc._write_lock:
            snap = svc._snap
            if snap.grow is None:
                return snap.version
            pool = self._as_pool(snap.index)
            live = np.flatnonzero(np.asarray(snap.grow.alive))
            if live.size == 0:
                # every grow doc was tombstoned: dropping the grow segment
                # IS the compaction
                svc._publish(pool, grow=None, grow_gids=None)
                self._grow_raw = None
                self.stats._compactions.inc(mode="incremental")
                version = svc._snap.version
            else:
                grow_corpus = jax.tree.map(
                    lambda a: jnp.asarray(np.asarray(a)[live]), snap.grow.corpus
                )
                gids = np.asarray(snap.grow_gids)[live]
                ents = widen_entities(
                    np.asarray(snap.grow.doc_entities)[live],
                    self._entity_width(snap.index),
                )
                if key is None:
                    key = jax.random.fold_in(jax.random.key(29), snap.version)
                capacity = (
                    _next_pow2(int(live.size))
                    if self.config.seal_pow2
                    else int(live.size)
                )
                segment = build_pool_segment(
                    grow_corpus,
                    gids,
                    self.build_cfg,
                    capacity=capacity,
                    key=key,
                    corpus_dtype=self._corpus_dtype(),
                    **self._kg_kwargs(ents),
                )
                pool, _ = append_segment(pool, segment)
                pool = place_pool(pool, svc._mesh)
                svc._publish(pool, grow=None, grow_gids=None)
                self._grow_raw = None
                self.stats._compactions.inc(mode="incremental")
                version = svc._snap.version
        if self.config.auto_merge:
            if self.config.background_merge:
                self._notify_merge_worker()
            else:
                self.maybe_merge_segments()
                version = svc._snap.version
        self._maybe_autocheckpoint()
        return version

    def merge_segments(
        self,
        a: tuple[int, int],
        b: tuple[int, int],
        *,
        key: Optional[jax.Array] = None,
    ) -> int:
        """Coalesce two pooled segments — (group, segment-in-group) pairs —
        into one: gather their LIVE docs (tombstones are physically
        reclaimed here), rebuild one segment, remove the two old ones, and
        append the merged one. O(live docs of a + b); every group not
        holding a or b keeps its executables."""
        with self.service._write_lock:
            return self._merge_segments_locked(a, b, key=key)

    def _merge_segments_locked(
        self,
        a: tuple[int, int],
        b: tuple[int, int],
        *,
        key: Optional[jax.Array] = None,
    ) -> int:
        svc = self.service
        if a == b:
            raise ValueError("cannot merge a segment with itself")
        snap = svc._snap
        pool = self._as_pool(snap.index)
        for g, s in (a, b):
            if g >= pool.n_groups or s >= pool.groups[g].n_segments:
                raise ValueError(f"no pooled segment ({g}, {s})")
        ca, ga, ea = extract_segment_docs(pool, *a)
        cb, gb, eb = extract_segment_docs(pool, *b)
        width = max(ea.shape[-1], eb.shape[-1])
        corpus = jax.tree.map(
            lambda x, y: jnp.concatenate([x, y], axis=0), ca, cb
        )
        gids = np.concatenate([ga, gb])
        ents = np.concatenate(
            [widen_entities(ea, width), widen_entities(eb, width)], axis=0
        )
        pool = remove_segments(pool, [a, b])
        if corpus.n == 0:
            # both segments were fully tombstoned: removal is the merge
            if not pool.groups:
                return snap.version  # never publish an empty pool
        else:
            if key is None:
                key = jax.random.fold_in(jax.random.key(31), snap.version)
            capacity = (
                _next_pow2(int(corpus.n))
                if self.config.seal_pow2
                else int(corpus.n)
            )
            merged = build_pool_segment(
                corpus,
                gids,
                self.build_cfg,
                capacity=capacity,
                key=key,
                corpus_dtype=self._corpus_dtype(),
                **self._kg_kwargs(ents),
            )
            pool, _ = append_segment(pool, merged)
        pool = place_pool(pool, svc._mesh)
        svc._publish(pool, grow=snap.grow, grow_gids=snap.grow_gids)
        self.stats._merges.inc()
        return svc._snap.version

    def maybe_merge_segments(self, *, key: Optional[jax.Array] = None) -> int:
        """Enforce the size-tiered merge invariant: at most
        ``RouterConfig.tier_fanout`` segments per pow2-capacity tier. While
        a tier is over fanout, merge its two segments with the fewest live
        docs (LSM-style: merges migrate small segments up the tiers, so
        total merge work per doc is O(log corpus) over its lifetime).
        Each pick-and-merge runs atomically under the service write lock
        (a pick computed outside it could go stale against a concurrent
        compaction or merge). Returns the number of merges performed."""
        merges = 0
        while True:
            with self.service._write_lock:
                snap = self.service._snap
                if not isinstance(snap.index, SegmentPool):
                    return merges
                tiers: dict[int, list[tuple[int, int, int]]] = {}
                for g, s, cap, live in live_counts(snap.index):
                    tiers.setdefault(max(cap, 1).bit_length(), []).append(
                        (live, g, s)
                    )
                offending = [
                    members
                    for members in tiers.values()
                    if len(members) > self.config.tier_fanout
                ]
                if not offending:
                    return merges
                members = sorted(offending[0])
                a, b = members[0][1:], members[1][1:]
                v0 = snap.version
                self._merge_segments_locked(a, b, key=key)
                if self.service._snap.version == v0:
                    return merges  # merge declined (would empty the pool)
            merges += 1

    # -- background merge worker --------------------------------------------

    def _notify_merge_worker(self) -> None:
        """Wake (starting lazily if needed) the background merge worker.
        Called after each incremental compaction when ``background_merge``
        is on: the compaction returns as soon as the new segment publishes
        and the merge cascade runs off the caller's thread (each merge still
        takes the service write lock, so readers/writers stay correct)."""
        with self._merge_lock:
            if self._merge_thread is None or not self._merge_thread.is_alive():
                self._merge_stop.clear()
                self._merge_thread = threading.Thread(
                    target=self._merge_loop,
                    name="segment-router-merge",
                    daemon=True,
                )
                self._merge_thread.start()
            self._merge_wake.set()

    def _merge_loop(self) -> None:
        while True:
            self._merge_wake.wait()
            if self._merge_stop.is_set():
                return
            # order matters for wait_merges(): drop idle BEFORE consuming
            # the wake flag, so at every instant a pending merge shows as
            # either wake-set or idle-clear
            self._merge_idle.clear()
            self._merge_wake.clear()
            try:
                self.maybe_merge_segments()
            finally:
                self._merge_idle.set()

    def wait_merges(self, timeout_s: float = 120.0) -> None:
        """Block until the size-tier merge policy is quiescent: no pending
        wake-up and no merge cascade in flight. A no-op when nothing is
        pending; tests use it to make background merges deterministic."""
        deadline = time.monotonic() + timeout_s
        while self._merge_wake.is_set() or not self._merge_idle.is_set():
            if self._merge_stop.is_set():
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"merge worker still busy after {timeout_s}s"
                )
            time.sleep(0.005)

    def stop_merge_worker(self, timeout_s: float = 60.0) -> None:
        """Clean-shutdown join of the merge worker (idempotent;
        ``HybridSearchService.stop_pump`` calls it). An in-flight policy run
        finishes — the stop flag is only checked between runs — so no merge
        is ever torn mid-publish."""
        with self._merge_lock:
            thread = self._merge_thread
            if thread is None:
                return
            self._merge_stop.set()
            self._merge_wake.set()
            thread.join(timeout=timeout_s)
            self._merge_thread = None
            self._merge_wake.clear()
            self._merge_stop.clear()

    # -- auto-checkpoint ----------------------------------------------------

    def _maybe_autocheckpoint(self) -> None:
        """Persist the sealed pool — paired with the fitted ingest pipeline
        when the router holds one — every ``autocheckpoint_every``
        compactions, so a crash loses at most the current grow segment plus
        one checkpoint window. Runs OUTSIDE the service write lock (the
        snapshot is immutable once published; serialization is disk I/O the
        write path must not wait on) and serializes concurrent writers on
        its own lock."""
        cfg = self.config
        if cfg.autocheckpoint_every <= 0 or cfg.autocheckpoint_dir is None:
            return
        with self._ckpt_lock:
            done = self.stats.compactions
            if done - self._last_ckpt_compactions < cfg.autocheckpoint_every:
                return
            pool = self.pool
            if pool is None:
                return
            # local import: checkpoint.index_io imports serving-adjacent
            # modules at load time; importing it lazily keeps the router
            # importable in minimal environments
            from repro.checkpoint.index_io import save_pool

            save_pool(cfg.autocheckpoint_dir, pool, ingest=self._ingest)
            self._last_ckpt_compactions = done
            self.stats._autocheckpoints.inc()
