"""Grow-segment streaming router for the segmented serving layer.

The paper's index supports incremental insertion without reconstruction
(§4.1 "Updates"), and PR 2 gave every segment a device-resident
``insert()`` program — but a sharded ``SegmentedIndex`` had no service-level
way to absorb writes: any change to the stacked sealed segments would
change their shapes and evict every AOT-compiled search executable. This
module closes that gap with the classic vector-DB grow-segment scheme
(Milvus growing segments, GRAB-ANNS bucketed incremental indexing):

  * **growing** — streaming ``insert()`` batches land in one small mutable
    ``HybridIndex`` (the *grow segment*), built on first insert via
    ``build_index`` and extended by ``core.build_pipeline.insert`` (the
    pipelined per-segment insert program). Sealed segments are never
    touched, so their compiled executables stay warm; the read path merges
    sealed + grow per-row top-k in global-id space
    (``HybridSearchService._merge_grow``). The published grow segment is
    padded to power-of-two capacity by default (``RouterConfig.grow_pow2``)
    so the read path's ``search_padded`` retraces O(log growth) times
    between compactions instead of once per insert batch;
  * **sealed** — the immutable stacked segments served through
    ``make_distributed_search_padded``'s cached executable. Deletions
    resolve global ids to (segment, local row) tombstones
    (``core.distributed.mark_deleted_segmented``) — shape-preserving, so no
    recompiles;
  * **compacted** — when the grow segment's live docs cross
    ``RouterConfig.seal_threshold``, ``seal_and_compact`` rebuilds ALL
    surviving docs (sealed minus tombstones, plus live grow docs) into a
    fresh S-segment sealed index via ``build_index_sharded`` (or the
    sequential ``build_segmented_index`` off-mesh), preserving global ids,
    and atomically publishes it through ``HybridSearchService._publish``.
    S stays equal to the mesh's segment-device count — the
    one-segment-per-device contract of the sharded search — so the same
    distributed executable factory keeps serving; per-segment shapes do
    change here, which is the one (documented) point where sealed
    executables recompile.

Every mutation happens under the service's write lock and lands as one
atomic ``_Snapshot`` publish: readers either see (old sealed, old grow) or
(new sealed, new grow), never a half-updated pair. See DESIGN.md §6.

Knowledge-graph scope: give the router the triplets
(``SegmentRouter(..., kg_triplets=..., n_entities=...)``) and entity paths
survive compaction (logical edges are rebuilt over the surviving docs'
entities); a grow segment born from an entity-carrying insert gets its own
logical edges too, though docs from LATER inserts into the same grow
segment only gain logical edges at compaction. Constructing a router
without triplets over a KG-bearing sealed index fails fast unless
``RouterConfig.allow_kg_loss_on_compact`` is set.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.build_pipeline import build_index, insert as index_insert
from repro.core.distributed import (
    alive_docs,
    compact_segmented_index,
    mark_deleted_segmented,
    place_segmented_index,
    resolve_global_ids,
)
from repro.core.index import (
    BuildConfig,
    HybridIndex,
    mark_deleted as index_mark_deleted,
)
from repro.core.search import SearchParams
from repro.core.usms import PAD_IDX, FusedVectors, SparseVec
from repro.serving.batcher import _next_pow2
from repro.serving.hybrid_service import HybridSearchService


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    seal_threshold: int = 256  # live grow docs that trigger seal-and-compact
    auto_compact: bool = True  # compact from insert() when over threshold
    # optional override for the insert probe's search breadth (k and the
    # edge paths are forced by the build config; see build_pipeline.insert)
    insert_search: Optional[SearchParams] = None
    # opt-in acknowledgement that compacting a KG-bearing index WITHOUT
    # giving the router the triplets permanently drops the entity paths
    allow_kg_loss_on_compact: bool = False
    # shape-bucket the PUBLISHED grow segment: pad its capacity to the next
    # power of two so the read path's search_padded retraces O(log growth)
    # times between compactions instead of once per insert batch (pad rows
    # are dead — alive=False, PAD edges — and unreachable: no entry point or
    # edge ever references them)
    grow_pow2: bool = True


def _map_grow_rows(index: HybridIndex, fn) -> HybridIndex:
    """Apply ``fn(array, pad_fill)`` to every per-row (axis-0 == N) leaf of a
    grow-segment index; entity tables and entry points are N-independent."""
    return dataclasses.replace(
        index,
        corpus=FusedVectors(
            fn(index.corpus.dense, 0),
            SparseVec(
                fn(index.corpus.learned.idx, PAD_IDX),
                fn(index.corpus.learned.val, 0),
            ),
            SparseVec(
                fn(index.corpus.lexical.idx, PAD_IDX),
                fn(index.corpus.lexical.val, 0),
            ),
        ),
        semantic_edges=fn(index.semantic_edges, PAD_IDX),
        keyword_edges=fn(index.keyword_edges, PAD_IDX),
        logical_edges=fn(index.logical_edges, PAD_IDX),
        doc_entities=fn(index.doc_entities, PAD_IDX),
        alive=fn(index.alive, False),
        self_ip=fn(index.self_ip, 0.0),
    )


def pad_grow_to_capacity(index: HybridIndex, capacity: int) -> HybridIndex:
    """Pad a grow segment's per-row arrays with DEAD rows up to ``capacity``
    (shape-bucketing). Pad rows are unreachable by construction: entry
    points and edges only reference real rows, ``alive`` is False, and the
    grow-gid map never covers them."""
    n = index.n
    if capacity <= n:
        return index

    def pad(a, fill):
        return jnp.concatenate(
            [a, jnp.full((capacity - n,) + a.shape[1:], fill, a.dtype)]
        )

    return _map_grow_rows(index, pad)


def slice_grow_rows(index: HybridIndex, n: int) -> HybridIndex:
    """Drop a padded grow segment's dead tail (inverse of
    ``pad_grow_to_capacity`` — the raw index inserts extend)."""
    if index.n == n:
        return index
    return _map_grow_rows(index, lambda a, _fill: a[:n])


@dataclasses.dataclass
class RouterStats:
    inserts: int = 0  # insert() calls absorbed by the grow segment
    inserted_docs: int = 0
    deletes: int = 0  # delete() calls
    deleted_sealed: int = 0  # ids tombstoned in sealed segments
    deleted_grow: int = 0  # ids tombstoned in the grow segment
    unknown_deletes: int = 0  # ids found nowhere (already compacted away?)
    compactions: int = 0


class SegmentRouter:
    """Fronts a segmented ``HybridSearchService`` with a grow segment.

    Constructing a router attaches it to the service: ``service.insert`` /
    ``service.mark_deleted`` delegate here, and the service's read path
    starts merging the grow segment automatically once one exists."""

    def __init__(
        self,
        service: HybridSearchService,
        build_cfg: BuildConfig,
        config: Optional[RouterConfig] = None,
        *,
        kg_triplets: Optional[np.ndarray] = None,
        n_entities: int = 0,
    ):
        if not getattr(service, "_segmented", False):
            raise ValueError(
                "SegmentRouter fronts a SegmentedIndex service; a single "
                "HybridIndex already supports insert()/mark_deleted() directly"
            )
        self.service = service
        self.build_cfg = build_cfg
        self.config = config or RouterConfig()
        self.stats = RouterStats()
        self._kg_triplets = (
            None if kg_triplets is None else np.asarray(kg_triplets, np.int32)
        )
        self._n_entities = int(n_entities)
        # entity_adj is (1, 1) for a KG-less build (LogicalEdges.empty):
        # anything wider means the sealed index carries entity paths that a
        # triplet-less compaction would silently destroy — fail fast unless
        # the caller explicitly opted into that loss
        sealed_has_kg = service._snap.index.index.entity_adj.shape[-1] > 1
        if (
            sealed_has_kg
            and self._kg_triplets is None
            and not self.config.allow_kg_loss_on_compact
        ):
            raise ValueError(
                "the sealed index carries knowledge-graph data but the "
                "router has no kg_triplets: seal_and_compact would drop "
                "every entity path. Pass kg_triplets/n_entities, or set "
                "RouterConfig(allow_kg_loss_on_compact=True) to accept it."
            )
        gids = np.asarray(service._snap.index.global_ids)
        self._next_gid = int(gids.max()) + 1 if (gids >= 0).any() else 0
        self._grow_raw: Optional[HybridIndex] = None
        if service._snap.grow_gids is not None:
            # re-attaching over a live grow segment: its ids are allocated
            # past the sealed ones and must never be handed out again
            self._next_gid = max(
                self._next_gid, int(np.asarray(service._snap.grow_gids).max()) + 1
            )
            # recover the raw (unpadded) grow segment inserts extend — the
            # published one may carry a pow2 dead-row tail
            self._grow_raw = slice_grow_rows(
                service._snap.grow, int(service._snap.grow_gids.shape[0])
            )
        service._router = self

    # -- introspection ------------------------------------------------------

    @property
    def grow_size(self) -> int:
        """Real rows in the grow segment (including tombstoned ones,
        excluding pow2 shape-bucket padding)."""
        gids = self.service._snap.grow_gids
        return 0 if gids is None else int(gids.shape[0])

    @property
    def grow_capacity(self) -> int:
        """Published grow-segment capacity (= grow_size rounded up to a
        power of two when ``RouterConfig.grow_pow2`` is on)."""
        grow = self.service._snap.grow
        return 0 if grow is None else int(grow.n)

    @property
    def live_grow_size(self) -> int:
        """Non-tombstoned grow docs — the seal-threshold measure (pad rows
        are dead and never count)."""
        grow = self.service._snap.grow
        return 0 if grow is None else int(np.asarray(grow.alive).sum())

    # -- writes (all under the service write lock, atomic publishes) --------

    def insert(
        self,
        new_docs: FusedVectors,
        *,
        key: Optional[jax.Array] = None,
        new_doc_entities: Optional[np.ndarray] = None,
    ) -> int:
        """Absorb a batch of new docs into the grow segment; returns the new
        snapshot version. Never touches sealed segments (their executables
        stay cached). May trigger seal-and-compact when the grow segment
        crosses the threshold and ``auto_compact`` is on."""
        svc = self.service
        n_new = int(new_docs.n)
        if n_new == 0:
            return svc.snapshot_version
        if new_doc_entities is not None:
            if self._kg_triplets is None:
                raise ValueError(
                    "new_doc_entities given but the router has no knowledge "
                    "graph: pass kg_triplets/n_entities at construction"
                )
            new_doc_entities = np.asarray(new_doc_entities, np.int32)
            ent_width = int(svc._snap.index.index.doc_entities.shape[-1])
            if new_doc_entities.shape != (n_new, ent_width):
                raise ValueError(
                    f"new_doc_entities must be ({n_new}, {ent_width}) to "
                    "match the sealed index's entity width"
                )
        with svc._write_lock:
            snap = svc._snap
            if key is None:
                key = jax.random.fold_in(jax.random.key(17), snap.version)
            new_gids = np.arange(
                self._next_gid, self._next_gid + n_new, dtype=np.int32
            )
            if snap.grow is None:
                kg_kwargs = {}
                if self._kg_triplets is not None:
                    # a KG router ALWAYS births the grow segment with the
                    # sealed entity width (all-PAD rows when the batch has
                    # no entities), so later entity-carrying inserts never
                    # hit build_pipeline.insert's width check
                    ents = new_doc_entities
                    if ents is None:
                        width = int(snap.index.index.doc_entities.shape[-1])
                        ents = np.full((n_new, width), PAD_IDX, np.int32)
                    kg_kwargs = dict(
                        kg_triplets=self._kg_triplets,
                        doc_entities=ents,
                        n_entities=self._n_entities,
                    )
                grow = build_index(new_docs, self.build_cfg, key=key, **kg_kwargs)
                gids = jnp.asarray(new_gids)
            else:
                # inserts always extend the RAW grow segment; the published
                # one may carry a pow2 dead-row tail that must not become
                # real neighbors
                grow = index_insert(
                    self._grow_raw,
                    new_docs,
                    self.build_cfg,
                    key=key,
                    new_doc_entities=new_doc_entities,
                    search_params=self.config.insert_search,
                )
                gids = jnp.concatenate([snap.grow_gids, jnp.asarray(new_gids)])
            self._next_gid += n_new
            self._grow_raw = grow
            if self.config.grow_pow2:
                grow = pad_grow_to_capacity(grow, _next_pow2(grow.n))
            svc._publish(snap.index, grow=grow, grow_gids=gids)
            self.stats.inserts += 1
            self.stats.inserted_docs += n_new
            version = svc._snap.version
        if (
            self.config.auto_compact
            and self.live_grow_size >= self.config.seal_threshold
        ):
            return self.seal_and_compact()
        return version

    def delete(self, global_ids) -> int:
        """Tombstone docs by global id, wherever they live: sealed ids
        become (segment, local row) tombstones in the stacked alive mask,
        grow ids are mark-deleted in the grow segment. Both are
        shape-preserving — no executable is evicted. Returns the new
        snapshot version."""
        svc = self.service
        ids = np.atleast_1d(np.asarray(global_ids, np.int64))
        with svc._write_lock:
            snap = svc._snap
            seg, loc = resolve_global_ids(snap.index, ids)
            in_sealed = seg >= 0
            grow, grow_gids = snap.grow, snap.grow_gids
            in_grow = np.zeros(ids.shape, bool)
            if grow is not None:
                gmap = np.asarray(grow_gids)
                in_grow = np.isin(ids, gmap) & ~in_sealed
                if in_grow.any():
                    # grow gids are allocated monotonically, so the map is
                    # sorted and searchsorted resolves local rows directly
                    # (row indices are identical in the raw and the padded
                    # view — padding only appends a dead tail)
                    rows = jnp.asarray(
                        np.searchsorted(gmap, ids[in_grow]), jnp.int32
                    )
                    grow = index_mark_deleted(grow, rows)
                    self._grow_raw = index_mark_deleted(self._grow_raw, rows)
            sealed = snap.index
            if in_sealed.any():
                sealed = mark_deleted_segmented(
                    sealed, ids[in_sealed],
                    resolved=(seg[in_sealed], loc[in_sealed]),
                )
            svc._publish(sealed, grow=grow, grow_gids=grow_gids)
            self.stats.deletes += 1
            self.stats.deleted_sealed += int(in_sealed.sum())
            self.stats.deleted_grow += int(in_grow.sum())
            self.stats.unknown_deletes += int((~in_sealed & ~in_grow).sum())
            return svc._snap.version

    def seal_and_compact(self, *, key: Optional[jax.Array] = None) -> int:
        """Rebuild all surviving docs — sealed minus tombstones, plus live
        grow docs — into a fresh S-segment sealed index (S unchanged: the
        one-segment-per-device contract), remap the original global ids
        onto it, and publish atomically with the grow segment cleared.

        Physically drops every tombstoned id: this is the step that turns
        mark-deletion into reclaimed rows. Per-segment shapes change, so
        sealed executables recompile on the next read — the documented cost
        of compaction (DESIGN.md §6)."""
        svc = self.service
        with svc._write_lock:
            snap = svc._snap
            if snap.grow is None and not bool(
                (~np.asarray(snap.index.index.alive)
                 & (np.asarray(snap.index.global_ids) >= 0)).any()
            ):
                return snap.version  # nothing growing, nothing tombstoned
            sealed_corpus, sealed_gids, sealed_ents = alive_docs(snap.index)
            parts_corpus, parts_gids = [sealed_corpus], [sealed_gids]
            parts_ents = [sealed_ents]
            ent_width = sealed_ents.shape[-1]
            if snap.grow is not None:
                live = np.flatnonzero(np.asarray(snap.grow.alive))
                if live.size:
                    parts_corpus.append(
                        jax.tree.map(
                            lambda a: jnp.asarray(np.asarray(a)[live]),
                            snap.grow.corpus,
                        )
                    )
                    parts_gids.append(np.asarray(snap.grow_gids)[live])
                    # grow entity rows, padded/clipped to the sealed width
                    # (a KG-less grow segment has width-1 all-PAD rows)
                    g_ents = np.asarray(snap.grow.doc_entities)[live]
                    ents = np.full((live.size, ent_width), PAD_IDX, np.int32)
                    w = min(ent_width, g_ents.shape[-1])
                    ents[:, :w] = g_ents[:, :w]
                    parts_ents.append(ents)
            corpus = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *parts_corpus
            )
            gids = np.concatenate(parts_gids)
            if key is None:
                key = jax.random.fold_in(jax.random.key(23), snap.version)
            kg_kwargs = {}
            if self._kg_triplets is not None:
                kg_kwargs = dict(
                    kg_triplets=self._kg_triplets,
                    doc_entities=np.concatenate(parts_ents, axis=0),
                    n_entities=self._n_entities,
                )
            new_seg = compact_segmented_index(
                corpus,
                gids,
                snap.index.n_segments,
                self.build_cfg,
                mesh=svc._mesh,
                key=key,
                **kg_kwargs,
            )
            new_seg = place_segmented_index(new_seg, svc._mesh)
            svc._publish(new_seg, grow=None, grow_gids=None)
            self._grow_raw = None
            self.stats.compactions += 1
            return svc._snap.version
