"""Data-parallel replica tier: N ``HybridSearchService`` replicas behind a
thin router — the scale-out front-end of the ROADMAP's "millions of users"
item.

Each replica owns a ``SegmentPool`` placement (its shard of the corpus,
with its own grow segment, write lock, and — critically — its own AOT
compiled-executable cache: replicas share no mutable state, so the tier
maps 1:1 onto separate hosts). The router in front is deliberately thin:

  * **placement** — documents map to replicas by consistent hashing of the
    global doc id over a ring with virtual nodes (``virtual_nodes`` per
    replica, BLAKE2-hashed, so adding/removing a replica only remaps
    ~1/N of the id space — the exo-pt-style dynamic shard assignment).
    ``insert()`` allocates global ids, splits the batch by home replica,
    and forwards each slice to that replica's ``SegmentRouter`` with the
    ids pinned (``SegmentRouter.insert(global_ids=...)``), so an id's home
    is recomputable from the id alone; ``delete()`` routes the same way.
  * **reads** — ``search()`` scatter-gathers: every *up* replica searches
    the query batch over its shard, and the per-replica top-k blocks merge
    per row in global-id space via ``core.fusion.merge_fused_host``
    (shards are disjoint, so the merge is duplicate-free by construction).
    The merge honors the fusion contract (DESIGN.md §11): the router
    resolves ONE ``FusionSpec`` — normalization stats pooled tier-wide via
    ``PathStats.merge`` so normalized scores are comparable across shards —
    and RRF rows merge by re-summed rank contributions recomputed over the
    union from per-path scores, never by comparing local RRF score values.
    Replica passes run on a persistent per-replica thread pool and are
    dispatched in least-outstanding-requests order, so a slow replica
    backs up its own queue, not the whole tier.
  * **mirror mode** (``placement="mirror"``) — every replica holds the
    FULL corpus; a query is dispatched to exactly one replica, chosen by
    least outstanding requests (the classic replicated-serving balancer),
    and writes broadcast to all replicas to keep the copies identical.
  * **failure** — ``mark_down(i)`` removes a replica from the ring: writes
    rehash to the survivors, scatter reads skip its shard and the result
    is counted in ``stats.partial_searches`` (degraded, not failed; see
    DESIGN.md §9). ``mark_up`` restores it.

Equivalence contract (pinned by ``tests/test_replica_router.py``): with
saturating search parameters, scatter-gather over any replica partition
returns the same results as one service holding every document — up to
equal-score tie order — including tombstone exclusion and KG entity paths.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.fusion import (
    FusionSpec,
    PathStats,
    as_fusion_spec,
    merge_fused_host,
    stack_specs,
)
from repro.core.search import SearchResult
from repro.core.usms import FusedVectors, PathWeights
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import TraceContext, Tracer
from repro.serving.hybrid_service import HybridSearchService
from repro.serving.segment_router import SegmentRouter


def _hash64(data: bytes) -> int:
    # stable across processes/runs (unlike hash()): placement must be
    # recomputable from the id alone, anywhere, forever
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(), "big")


def build_ring(
    names: Sequence[str], virtual_nodes: int = 64
) -> list[tuple[int, int]]:
    """Sorted (hash, owner-index) consistent-hash ring with virtual nodes.
    Offline shard builders (``benchmarks/fig14_scale.py``) use this with
    ``ring_homes`` to pre-partition a corpus EXACTLY as the live tier
    routes it."""
    ring = [
        (_hash64(f"{name}#{v}".encode()), i)
        for i, name in enumerate(names)
        for v in range(virtual_nodes)
    ]
    return sorted(ring)


def ring_homes(ring: Sequence[tuple[int, int]], global_ids) -> np.ndarray:
    """Vectorized ring-successor lookup: owner index per doc id."""
    if not ring:
        raise RuntimeError("no replica is up")
    keys = np.asarray([k for k, _ in ring], np.uint64)
    owners = np.asarray([o for _, o in ring], np.int64)
    ids = np.atleast_1d(np.asarray(global_ids, np.int64))
    h = np.asarray(
        [_hash64(int(g).to_bytes(8, "big", signed=False)) for g in ids],
        np.uint64,
    )
    pos = np.searchsorted(keys, h, side="right") % len(keys)
    return owners[pos]


@dataclasses.dataclass(frozen=True)
class ReplicaTierConfig:
    # virtual ring nodes per replica: more nodes -> smoother shard balance
    # (64 keeps the max/min doc-count ratio under ~1.3 at 3+ replicas)
    virtual_nodes: int = 64
    # "hash": consistent-hash sharding, scatter-gather reads.
    # "mirror": full copy per replica, least-outstanding single dispatch.
    placement: str = "hash"
    # raise instead of returning shard-degraded results when replicas are down
    fail_on_partial: bool = False

    def __post_init__(self):
        if self.placement not in ("hash", "mirror"):
            raise ValueError("placement must be 'hash' or 'mirror'")
        if self.virtual_nodes < 1:
            raise ValueError("virtual_nodes must be >= 1")


class ReplicaTierStats:
    """Registry-backed view of the tier's counters (``allanpoe_replica_*``
    series in the router's metrics registry). Per-replica series are labeled
    with the replica NAME — stable across mark_down/mark_up — while the
    ``dispatched`` property re-exposes them as the positional list the
    original dataclass carried."""

    def __init__(self, metrics: MetricsRegistry, names: Sequence[str]):
        self._names = list(names)
        self._inserts = metrics.counter(
            "allanpoe_replica_inserts_total", "tier insert() batches"
        )
        self._inserted_docs = metrics.counter(
            "allanpoe_replica_inserted_docs_total",
            "documents routed to home replicas",
        )
        self._deletes = metrics.counter(
            "allanpoe_replica_deletes_total", "tier delete() calls"
        )
        self._searches = metrics.counter(
            "allanpoe_replica_searches_total", "tier search() calls"
        )
        self._partial = metrics.counter(
            "allanpoe_replica_partial_searches_total",
            "scatter reads served with >=1 replica down",
        )
        self._dispatched = metrics.counter(
            "allanpoe_replica_dispatched_total",
            "search dispatches per replica",
            labels=("replica",),
        )
        self._degraded = metrics.counter(
            "allanpoe_replica_degraded_reads_total",
            "reads that were missing this replica's shard (it was down)",
            labels=("replica",),
        )

    @property
    def inserts(self) -> int:
        return int(self._inserts.total())

    @property
    def inserted_docs(self) -> int:
        return int(self._inserted_docs.total())

    @property
    def deletes(self) -> int:
        return int(self._deletes.total())

    @property
    def searches(self) -> int:
        return int(self._searches.total())

    @property
    def partial_searches(self) -> int:
        return int(self._partial.total())

    @property
    def dispatched(self) -> list[int]:
        return [
            int(self._dispatched.value(replica=n)) for n in self._names
        ]

    def degraded_reads(self, name: str) -> int:
        """Reads served without this replica's shard while it was down."""
        return int(self._degraded.value(replica=name))

    def __repr__(self) -> str:
        return (
            f"ReplicaTierStats(inserts={self.inserts}, "
            f"inserted_docs={self.inserted_docs}, deletes={self.deletes}, "
            f"searches={self.searches}, "
            f"partial_searches={self.partial_searches}, "
            f"dispatched={self.dispatched})"
        )


class Replica:
    """One member of the tier: a service (its own executable cache and
    snapshot) plus, for writable tiers, the grow-segment router that owns
    its shard's streaming writes."""

    def __init__(
        self,
        service: HybridSearchService,
        router: Optional[SegmentRouter] = None,
        *,
        name: Optional[str] = None,
    ):
        self.service = service
        self.router = router
        self.name = name or f"replica{id(service):x}"
        self.up = True
        self.outstanding = 0  # in-flight search dispatches (LOR signal)


class ReplicaRouter:
    """Thin scatter/route layer over share-nothing service replicas."""

    def __init__(
        self,
        replicas: Sequence[Union[Replica, HybridSearchService]],
        config: Optional[ReplicaTierConfig] = None,
        *,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
    ):
        if not replicas:
            raise ValueError("a replica tier needs at least one replica")
        self.config = config or ReplicaTierConfig()
        self.replicas = [
            r if isinstance(r, Replica) else Replica(r, name=f"replica{i}")
            for i, r in enumerate(replicas)
        ]
        names = [r.name for r in self.replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique, got {names}")
        self.metrics = metrics or MetricsRegistry()
        self.tracer = tracer or Tracer()
        self.stats = ReplicaTierStats(self.metrics, names)
        self._lock = threading.Lock()  # ring + outstanding counters
        self._ring: list[tuple[int, int]] = []
        self._rebuild_ring()
        self._next_gid = 1 + max(
            (self._max_gid(r) for r in self.replicas), default=-1
        )
        self._pool = ThreadPoolExecutor(
            max_workers=len(self.replicas),
            thread_name_prefix="replica-scatter",
        )

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        """Join the scatter pool and every replica's pump/merge workers."""
        self._pool.shutdown(wait=True)
        for r in self.replicas:
            r.service.stop_pump()

    def __enter__(self) -> "ReplicaRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- consistent-hash placement ------------------------------------------

    _hash = staticmethod(_hash64)

    def _rebuild_ring(self) -> None:
        ring = []
        for i, r in enumerate(self.replicas):
            if not r.up:
                continue
            for v in range(self.config.virtual_nodes):
                ring.append((_hash64(f"{r.name}#{v}".encode()), i))
        self._ring = sorted(ring)

    def homes_of(self, global_ids) -> np.ndarray:
        """Home replica index per doc id (ring successor of each hash)."""
        with self._lock:
            ring = list(self._ring)
        return ring_homes(ring, global_ids)

    def replica_for(self, global_id: int) -> int:
        """Home replica index of a single doc id."""
        return int(self.homes_of([global_id])[0])

    def mark_down(self, i: int) -> None:
        """Take replica i out of rotation: writes rehash to survivors,
        scatter reads skip its shard (degraded results, counted)."""
        with self._lock:
            self.replicas[i].up = False
        self._rebuild_ring()

    def mark_up(self, i: int) -> None:
        with self._lock:
            self.replicas[i].up = True
        self._rebuild_ring()

    def _up(self) -> list[int]:
        return [i for i, r in enumerate(self.replicas) if r.up]

    @staticmethod
    def _max_gid(r: Replica) -> int:
        if r.router is not None:
            return r.router._next_gid - 1
        idx = r.service.index
        gids = getattr(idx, "global_ids", None)
        if gids is None:
            if hasattr(idx, "max_global_id"):
                return idx.max_global_id()
            return int(getattr(idx, "n", 0)) - 1
        arr = np.asarray(gids)
        return int(arr.max()) if (arr >= 0).any() else -1

    # -- writes -------------------------------------------------------------

    def insert(
        self,
        new_docs: FusedVectors,
        *,
        key: Optional[jax.Array] = None,
        new_doc_entities: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Allocate global ids, split the batch by home replica, forward
        each slice to that replica's grow segment. Returns the allocated
        ids (the caller's handle for later deletes). Mirror tiers broadcast
        the whole batch to every replica instead."""
        n = int(new_docs.n)
        if n == 0:
            return np.zeros((0,), np.int64)
        gids = np.arange(self._next_gid, self._next_gid + n, dtype=np.int64)
        self._next_gid += n
        mirror = self.config.placement == "mirror"
        targets: dict[int, np.ndarray] = (
            {i: np.arange(n) for i in self._up()}
            if mirror
            else {}
        )
        if not mirror:
            homes = self.homes_of(gids)
            for i in np.unique(homes):
                targets[int(i)] = np.flatnonzero(homes == i)
        for i, rows in targets.items():
            r = self.replicas[i]
            if r.router is None:
                raise ValueError(
                    f"replica {r.name} has no SegmentRouter: the tier "
                    "cannot route writes to it"
                )
            sub = jax.tree.map(lambda a: jnp.asarray(a)[rows], new_docs)
            ents = (
                None
                if new_doc_entities is None
                else np.asarray(new_doc_entities)[rows]
            )
            r.router.insert(
                sub, key=key, new_doc_entities=ents, global_ids=gids[rows]
            )
        self.stats._inserts.inc()
        self.stats._inserted_docs.inc(n)
        return gids

    def delete(self, global_ids) -> int:
        """Tombstone docs on their home replicas (every replica, for a
        mirror tier). Returns the number of ids routed."""
        ids = np.atleast_1d(np.asarray(global_ids, np.int64))
        if self.config.placement == "mirror":
            for i in self._up():
                self.replicas[i].router.delete(ids)
        else:
            homes = self.homes_of(ids)
            for i in np.unique(homes):
                self.replicas[int(i)].router.delete(ids[homes == i])
        self.stats._deletes.inc()
        return int(ids.size)

    # -- reads --------------------------------------------------------------

    def _dispatch_order(self, up: list[int]) -> list[int]:
        """Least-outstanding-requests first: the loaded replica's work is
        queued last (scatter) or avoided entirely (mirror)."""
        with self._lock:
            return sorted(up, key=lambda i: (self.replicas[i].outstanding, i))

    def _member_search(self, i: int, queries, fusion, kw, en, k, trace=None):
        r = self.replicas[i]
        with self._lock:
            r.outstanding += 1
        self.stats._dispatched.inc(replica=r.name)
        t0 = time.perf_counter()
        try:
            return r.service.search(
                queries, fusion, keywords=kw, entities=en, k=k, trace=trace
            )
        finally:
            with self._lock:
                r.outstanding -= 1
            if trace is not None:
                trace.add_span(
                    "replica_dispatch", t0, time.perf_counter(),
                    replica=r.name,
                )

    def path_stats(self) -> PathStats:
        """ONE tier-wide normalization-stats object: per-replica running
        stats pooled by live shard size (``PathStats.merge``). The shared
        stats make normalized fusion scores comparable across shards — the
        merge contract's precondition (DESIGN.md §11)."""
        up = self._up()
        sizes = self.shard_sizes()
        return PathStats.merge(
            [self.replicas[i].service.path_stats for i in up],
            [sizes[i] for i in up],
        )

    def _resolve_spec(self, fusion) -> FusionSpec:
        """Coerce the query-side fusion argument to ONE resolved spec for
        the whole tier: sequences stack to a batched spec, and unresolved
        (stats=None) specs pin to the tier-wide pooled stats so every
        member normalizes identically."""
        if isinstance(fusion, (FusionSpec, PathWeights)):
            spec = as_fusion_spec(fusion)
        else:
            spec = stack_specs([as_fusion_spec(f) for f in fusion])
        if spec.stats is not None:
            return spec
        stats = self.path_stats()
        if np.ndim(spec.mode) >= 1:  # batched spec needs (B, 3) stat leaves
            b = int(np.shape(spec.mode)[0])
            bs = lambda x: jnp.broadcast_to(
                jnp.asarray(x, jnp.float32), (b,) + jnp.shape(x)[-1:]
            )
            stats = PathStats(
                minv=bs(stats.minv), maxv=bs(stats.maxv),
                mean=bs(stats.mean), std=bs(stats.std),
            )
        return dataclasses.replace(spec, stats=stats)

    def search(
        self,
        queries: FusedVectors,
        fusion: Union[FusionSpec, PathWeights, Sequence, None] = None,
        *,
        weights: Union[PathWeights, Sequence[PathWeights], None] = None,
        keywords: Optional[np.ndarray] = None,
        entities: Optional[np.ndarray] = None,
        k: Optional[int] = None,
        trace: Optional[TraceContext] = None,
    ) -> SearchResult:
        """Batched read. Hash tiers scatter to every up replica and merge
        per-row top-k in global-id space; mirror tiers dispatch the batch
        to the single least-loaded replica. ``weights=`` is the deprecated
        ``PathWeights`` spelling.

        Degraded scatter reads (>=1 replica down) are recorded three ways:
        in the result (``SearchResult.down_replicas``), as the labeled
        counter ``allanpoe_replica_degraded_reads_total{replica}``, and as a
        ``down_replicas`` annotation on ``trace`` — all BEFORE the optional
        ``fail_on_partial`` raise, so the audit trail survives the error."""
        if fusion is not None and weights is not None:
            raise ValueError("pass fusion= or (deprecated) weights=, not both")
        if fusion is None:
            if weights is None:
                raise TypeError("search() requires fusion=FusionSpec(...)")
            fusion = weights  # deprecated form; as_fusion_spec warns
        spec = self._resolve_spec(fusion)
        up = self._dispatch_order(self._up())
        if not up:
            raise RuntimeError("no replica is up")
        self.stats._searches.inc()
        if self.config.placement == "mirror":
            return self._member_search(
                up[0], queries, spec, keywords, entities, k, trace
            )
        down = tuple(r.name for r in self.replicas if not r.up)
        if down:
            self.stats._partial.inc()
            for name in down:
                self.stats._degraded.inc(replica=name)
            if trace is not None:
                trace.annotate(down_replicas=list(down))
            if self.config.fail_on_partial:
                raise RuntimeError(
                    f"replicas down ({list(down)}) and fail_on_partial is set"
                )
        # a lone survivor still flows through the parts path below so
        # degraded reads carry the same span/merge metadata as full scatters
        t_sc = time.perf_counter()
        futures = [
            (
                i,
                self._pool.submit(
                    self._member_search, i, queries, spec,
                    keywords, entities, k, trace,
                ),
            )
            for i in up
        ]
        parts = [f.result() for _, f in futures]
        t_gather = time.perf_counter()
        if trace is not None:
            trace.add_span(
                "scatter_gather", t_sc, t_gather,
                replicas=len(up), down=list(down),
            )
        if len(parts) == 1:
            # identity merge: re-ranking a single shard's rows could reorder
            # ties, violating the one-replica == one-service equivalence
            m_ids = np.asarray(parts[0].ids)
            m_scores = np.asarray(parts[0].scores)
            m_ps = np.asarray(parts[0].path_scores)
        else:
            k_out = int(np.asarray(parts[0].ids).shape[1])
            m_ids, m_scores, m_ps = merge_fused_host(
                [np.asarray(p.ids) for p in parts],
                [np.asarray(p.scores) for p in parts],
                [np.asarray(p.path_scores) for p in parts],
                spec,
                k_out,
            )
        if trace is not None:
            trace.add_span(
                "fusion_rescore", t_gather, time.perf_counter(),
                parts=len(parts), site="replica_merge",
            )
        expanded = np.sum(
            [np.asarray(p.expanded) for p in parts], axis=0
        )
        return SearchResult(
            ids=jnp.asarray(m_ids),
            scores=jnp.asarray(m_scores),
            expanded=jnp.asarray(expanded, jnp.int32),
            path_scores=jnp.asarray(m_ps),
            down_replicas=down or None,
        )

    # -- introspection ------------------------------------------------------

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def shard_sizes(self) -> list[int]:
        """Live docs per replica (balance diagnostic)."""
        out = []
        for r in self.replicas:
            idx = r.service.index
            if hasattr(idx, "groups"):  # SegmentPool
                alive = sum(
                    int(np.asarray(g.index.alive).sum()) for g in idx.groups
                )
            elif hasattr(idx, "global_ids"):  # SegmentedIndex
                alive = int(np.asarray(idx.index.alive).sum())
            else:
                alive = int(np.asarray(idx.alive).sum())
            grow = r.service.grow_index
            if grow is not None:
                alive += int(np.asarray(grow.alive).sum())
            out.append(alive)
        return out
