"""Retrieve-then-generate: the Allan-Poe hybrid index as a first-class
feature of the serving path (DESIGN.md §3).

A RAG request carries the query's fused vectors (dense from the embedder,
sparse from SPLADE/BM25 analogues — here synthetic), optional required
keywords and entities. The pipeline is:

  1. hybrid search on the (optionally segment-sharded) index — either a
     direct ``search()`` call or, when a ``HybridSearchService`` is attached,
     through the micro-batched serving path so RAG traffic shares executables
     (and the snapshot-swapped index) with every other search client;
  2. retrieved doc ids -> context token prefixes (a real deployment detok-
     enizes documents; the synthetic corpus maps doc ids to token spans);
  3. batched generation conditioned on [context ; prompt].

With an attached ``ingest.IngestPipeline`` the request side starts from raw
text: ``retrieve_text``/``answer_text`` run the SAME analyzer the corpus was
ingested with — query dense + TF-IDF/BM25 SparseVec, double-quoted phrases
as required keywords, capitalized spans matched against the frozen entity
vocab as query entities — so "bring your own documents" deployments query
with strings, not hand-built FusedVectors.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fusion import (
    FusionSpec,
    adaptive_fusion,
    as_fusion_spec,
    query_nnz,
)
from repro.core.index import HybridIndex
from repro.core.search import SearchParams, SearchResult, resolve_params, search
from repro.core.usms import FusedVectors, PathWeights
from repro.obs.tracer import TraceContext
from repro.serving.engine import ServingEngine
from repro.serving.hybrid_service import HybridSearchService


@dataclasses.dataclass
class RagConfig:
    top_k: int = 4
    ctx_tokens_per_doc: int = 32
    # the query-side fusion object (DESIGN.md §11); stats resolve against
    # the attached service's running corpus stats (identity when direct)
    fusion: FusionSpec = dataclasses.field(
        default_factory=FusionSpec.three_path
    )
    # deprecated PathWeights spelling: converts to a weighted-sum FusionSpec
    # (with a DeprecationWarning) and overrides ``fusion`` when set
    weights: Optional[PathWeights] = None
    # pick mode + weights per query from its text-derived characteristics
    # (keyword count, lexical nnz, entity presence) on the text entry points
    adaptive: bool = False
    search: SearchParams = SearchParams(k=4, iters=32, pool_size=64)

    def __post_init__(self):
        if self.weights is not None:
            self.fusion = as_fusion_spec(self.weights)  # warns


class RagPipeline:
    def __init__(
        self,
        engine: ServingEngine,
        index: HybridIndex,
        doc_tokens: jax.Array,  # (N_docs, ctx_tokens_per_doc) int32
        cfg: RagConfig,
        *,
        service: Optional[HybridSearchService] = None,
        ingest=None,  # ingest.IngestPipeline (fitted) for text queries
    ):
        self.engine = engine
        self.index = index
        self.doc_tokens = doc_tokens
        self.cfg = cfg
        self.service = service
        self.ingest = ingest
        if ingest is not None and not getattr(ingest, "fitted", False):
            raise ValueError(
                "RagPipeline needs a FITTED IngestPipeline: the query-side "
                "analyzer must use the same frozen corpus stats the index "
                "was built from"
            )
        if service is not None:
            # retrieval runs with the service's SearchParams; refuse a config
            # that silently diverges from it (k may differ: the service caps
            # per-request k, cfg.top_k just has to fit under it)
            # compare backend-resolved params: the service pins use_kernel
            # (auto -> concrete) at construction for its executable-cache key
            resolved = resolve_params(dataclasses.replace(cfg.search, k=service.params.k))
            if resolved != service.params:
                raise ValueError(
                    "RagConfig.search and the attached service's SearchParams "
                    f"disagree: {cfg.search} vs {service.params}"
                )
            if cfg.top_k > service.params.k:
                raise ValueError(
                    f"top_k={cfg.top_k} exceeds the service cap k={service.params.k}"
                )

    def retrieve(
        self,
        queries: FusedVectors,
        *,
        keywords: Optional[jax.Array] = None,
        entities: Optional[jax.Array] = None,
        fusion: Optional[FusionSpec] = None,
        trace: Optional[TraceContext] = None,
    ) -> SearchResult:
        spec = self.cfg.fusion if fusion is None else as_fusion_spec(fusion)
        if self.service is not None:
            # mirror the direct path's semantics: keyword/entity operands are
            # inert when the params disable those paths, not request errors
            # (the trace context rides the SearchRequests, so the span tree
            # gains the service's admission/queue/dispatch phases)
            return self.service.search(
                queries, spec,
                keywords=keywords if self.service.params.use_keywords else None,
                entities=entities if self.service.params.use_kg else None,
                k=self.cfg.top_k,
                trace=trace,
            )
        params = dataclasses.replace(self.cfg.search, k=self.cfg.top_k)
        t0 = time.perf_counter()
        res = search(
            self.index, queries, spec, params,
            keywords=keywords, entities=entities,
        )
        if trace is not None:
            trace.add_span(
                "retrieval", t0, time.perf_counter(), path="direct"
            )
        return res

    def _adaptive_spec(self, enc) -> FusionSpec:
        """Per-query fusion selection from the analyzer's view of the query
        (the ingest/query-path hook): required-keyword count, lexical nnz,
        and entity presence pick mode + weights per row. Normalization
        stats pin to the attached service's running stats when available,
        else resolve downstream."""
        stats = self.service.path_stats if self.service is not None else None
        return adaptive_fusion(
            enc.keywords,
            enc.entities,
            query_nnz(enc.vectors),
            stats=stats,
        )

    def retrieve_text(
        self, texts, *, trace: Optional[TraceContext] = None
    ) -> SearchResult:
        """Raw query strings -> hybrid retrieval via the attached ingestion
        analyzer (query SparseVec + required keywords + query entities).
        With ``cfg.adaptive`` the fusion mode/weights are selected per query
        from the analyzer's signals."""
        if self.ingest is None:
            raise ValueError(
                "retrieve_text requires an IngestPipeline at construction"
            )
        t0 = time.perf_counter()
        enc = self.ingest.encode_queries(list(texts))
        if trace is not None:
            trace.add_span(
                "query_encode", t0, time.perf_counter(), queries=len(texts)
            )
        return self.retrieve(
            enc.vectors,
            keywords=jnp.asarray(enc.keywords),
            entities=jnp.asarray(enc.entities),
            fusion=self._adaptive_spec(enc) if self.cfg.adaptive else None,
            trace=trace,
        )

    def answer_text(
        self, texts, prompts: jax.Array, n_tokens: int,
        *, trace: Optional[TraceContext] = None,
    ) -> tuple[jax.Array, SearchResult]:
        """Text-query counterpart of ``answer`` (same retrieval-to-
        generation tail; only the query encoding differs)."""
        if self.ingest is None:
            raise ValueError(
                "answer_text requires an IngestPipeline at construction"
            )
        enc = self.ingest.encode_queries(list(texts))
        return self.answer(
            enc.vectors, prompts, n_tokens,
            keywords=jnp.asarray(enc.keywords),
            entities=jnp.asarray(enc.entities),
            fusion=self._adaptive_spec(enc) if self.cfg.adaptive else None,
            trace=trace,
        )

    def build_context(self, result: SearchResult) -> jax.Array:
        """Concatenate retrieved docs' token spans -> (B, top_k * ctx_len)."""
        ids = jnp.clip(result.ids[:, : self.cfg.top_k], 0, self.doc_tokens.shape[0] - 1)
        ctx = self.doc_tokens[ids]  # (B, k, ctx_len)
        b = ctx.shape[0]
        return ctx.reshape(b, -1)

    def answer(
        self,
        queries: FusedVectors,
        prompts: jax.Array,  # (B, Lp)
        n_tokens: int,
        *,
        keywords: Optional[jax.Array] = None,
        entities: Optional[jax.Array] = None,
        fusion: Optional[FusionSpec] = None,
        trace: Optional[TraceContext] = None,
    ) -> tuple[jax.Array, SearchResult]:
        res = self.retrieve(
            queries, keywords=keywords, entities=entities, fusion=fusion,
            trace=trace,
        )
        t0 = time.perf_counter()
        ctx = self.build_context(res)
        full_prompt = jnp.concatenate([ctx, prompts], axis=1)
        t1 = time.perf_counter()
        out = self.engine.generate(full_prompt, n_tokens)
        if trace is not None:
            trace.add_span("context_assembly", t0, t1, top_k=self.cfg.top_k)
            trace.add_span(
                "generation", t1, time.perf_counter(), n_tokens=n_tokens
            )
        return out, res
