"""Batched serving engine: continuous prefill + decode over a KV cache /
recurrent state, greedy or temperature sampling, with the production-mesh
shardings applied to params and cache."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024
    batch: int = 8
    temperature: float = 0.0  # 0 -> greedy
    eos_token: int = -1  # -1 -> never stop early


class ServingEngine:
    """Single-model engine; drives prefill once per request batch and then
    steps the decoder. Works on CPU (smoke) and any mesh (production)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        scfg: ServeConfig,
        mesh: Optional[Mesh] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
        self._prefill = jax.jit(tfm.make_prefill(cfg, scfg.max_len, mesh_axes))
        self._decode = jax.jit(tfm.make_decode_step(cfg, mesh_axes))

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(
        self,
        prompts: jax.Array,  # (B, Lp) int32
        n_tokens: int,
        *,
        frontend: Optional[jax.Array] = None,
        key: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Teacher-free generation. Returns (B, Lp + n_tokens)."""
        key = key if key is not None else jax.random.key(0)
        b, lp = prompts.shape
        assert lp + n_tokens <= self.scfg.max_len
        logits, cache = self._prefill(self.params, prompts, frontend)
        toks = [prompts]
        cur = self._sample(logits, key)
        for i in range(n_tokens):
            toks.append(cur[:, None])
            if i == n_tokens - 1:
                break
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                self.params, cur, cache, jnp.int32(lp + i)
            )
            cur = self._sample(logits, sub)
        return jnp.concatenate(toks, axis=1)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    from repro.models.layers import DATA, MODEL, POD

    dp = [mesh.shape[a] for a in (POD, DATA) if a in mesh.axis_names]
    specs = tfm.cache_specs(
        cfg,
        batch,
        max_len,
        dp_size=int(np_prod(dp)) if dp else 1,
        model_size=mesh.shape.get(MODEL, 1),
        multi_pod=POD in mesh.axis_names,
    )
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out
