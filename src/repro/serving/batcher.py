"""Micro-batching for the hybrid-search serving path.

The paper's throughput story (§5, 1.5x-186.4x) assumes the GPU sees *batches*
of queries, not one-at-a-time calls. This module turns a stream of
heterogeneous requests (any ``PathWeights``, optional keywords/entities, any
``k``) into fixed-shape batches:

  * the batch dimension is padded up to a power of two (``Bucket.batch``) so
    a handful of executables covers every arrival pattern;
  * keyword / entity widths are padded to power-of-two bucket caps, so a
    request with 3 keywords and one with none land in the same executable;
  * a bounded FIFO queue decouples arrival from execution, flushing when
    ``flush_size`` requests are pending (throughput mode) or when the oldest
    request has waited ``flush_deadline_s`` (latency bound).

The batcher is deliberately passive: it never runs a search itself. The
service (``hybrid_service.HybridSearchService``) drains ready batches and
owns the executable cache. Deadlines are evaluated on ``submit`` and on
explicit ``poll`` — a real deployment pumps ``poll`` from a timer thread
(ROADMAP open item), which keeps this module free of threading.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.fusion import FusionSpec, as_fusion_spec
from repro.core.usms import FusedVectors, PathWeights
from repro.obs.tracer import TraceContext


class QueueFullError(RuntimeError):
    """Raised when the bounded request queue rejects a submit (backpressure:
    the execution path is not draining fast enough; callers shed load or
    retry with backoff)."""


class AdmissionError(RuntimeError):
    """Raised when token-bucket admission control rejects a submit BEFORE it
    reaches the queue (rate policy, not backpressure — deliberately a
    distinct type from ``QueueFullError`` so callers and stats can tell
    "you are over quota" from "the service is saturated")."""


# ---------------------------------------------------------------------------
# Token-bucket admission control (per-tenant quotas + a global ceiling).
# Sits in FRONT of MicroBatcher.enqueue: the bounded queue remains the
# backpressure backstop, the buckets enforce rate policy.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuotaConfig:
    """One token bucket: sustained ``rate`` requests/s with ``burst`` depth."""

    rate: float
    burst: float

    def __post_init__(self):
        if self.rate < 0 or self.burst <= 0:
            raise ValueError("quota needs rate >= 0 and burst > 0")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """``global_quota`` caps the whole service; ``tenant_quotas`` pins named
    tenants; ``default_tenant_quota`` applies to any other named tenant.
    Requests with ``tenant=None`` only face the global bucket."""

    global_quota: Optional[QuotaConfig] = None
    default_tenant_quota: Optional[QuotaConfig] = None
    tenant_quotas: tuple[tuple[str, QuotaConfig], ...] = ()
    # cap on lazily-created tenant buckets: beyond it the oldest bucket is
    # evicted (it re-fills to a full burst if that tenant returns — a mild
    # over-admit, vs. unbounded growth under high-cardinality tenant ids)
    max_tenant_buckets: int = 4096


class TokenBucket:
    """Classic token bucket; time is injectable for deterministic tests."""

    __slots__ = ("rate", "burst", "_tokens", "_t")

    def __init__(self, quota: QuotaConfig, now: Optional[float] = None):
        self.rate = float(quota.rate)
        self.burst = float(quota.burst)
        self._tokens = self.burst  # start full: allow an initial burst
        self._t = time.monotonic() if now is None else now

    @property
    def tokens(self) -> float:
        return self._tokens

    def try_acquire(self, n: float = 1.0, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        if now > self._t:
            self._tokens = min(self.burst, self._tokens + (now - self._t) * self.rate)
            self._t = now
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    def refund(self, n: float = 1.0) -> None:
        self._tokens = min(self.burst, self._tokens + n)


class AdmissionController:
    """Tenant bucket first, then the global bucket (with refund on a global
    reject, so a saturated service never silently drains tenant quota).

    Not internally locked: the service calls ``try_admit`` under its queue
    lock, which also serializes lazy tenant-bucket creation."""

    def __init__(self, cfg: AdmissionConfig, now: Optional[float] = None):
        self.cfg = cfg
        self._quota_by_tenant = dict(cfg.tenant_quotas)
        self._global = (
            TokenBucket(cfg.global_quota, now) if cfg.global_quota else None
        )
        self._tenants: dict[str, TokenBucket] = {}

    def _tenant_bucket(self, tenant: Optional[str], now: float) -> Optional[TokenBucket]:
        if tenant is None:
            return None
        bucket = self._tenants.get(tenant)
        if bucket is None:
            quota = self._quota_by_tenant.get(tenant, self.cfg.default_tenant_quota)
            if quota is None:
                return None
            while len(self._tenants) >= self.cfg.max_tenant_buckets:
                self._tenants.pop(next(iter(self._tenants)))  # oldest first
            bucket = self._tenants[tenant] = TokenBucket(quota, now)
        return bucket

    def try_admit(self, tenant: Optional[str] = None, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        tb = self._tenant_bucket(tenant, now)
        if tb is not None and not tb.try_acquire(1.0, now):
            return False
        if self._global is not None and not self._global.try_acquire(1.0, now):
            if tb is not None:
                tb.refund(1.0)
            return False
        return True

    def refund(self, tenant: Optional[str] = None) -> None:
        """Return an admitted request's tokens (all buckets it consumed
        from). Called when a request passes admission but is then rejected
        downstream (queue full): backpressure must not drain rate quota."""
        tb = self._tenants.get(tenant) if tenant is not None else None
        if tb is not None:
            tb.refund(1.0)
        if self._global is not None:
            self._global.refund(1.0)


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_queue: int = 1024  # bounded FIFO capacity (admission control)
    flush_size: int = 32  # flush as soon as this many requests are pending
    flush_deadline_s: float = 0.01  # ... or the oldest request is this stale
    max_batch: int = 64  # largest bucket batch (power of two)
    kw_cap: int = 8  # largest keyword width bucket
    ent_cap: int = 4  # largest entity width bucket

    def __post_init__(self):
        if self.flush_size > self.max_batch:
            raise ValueError("flush_size must be <= max_batch")


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A fixed executable shape: (padded batch, keyword width, entity width).

    Hashable — it is the shape part of the executable-cache key."""

    batch: int
    kw_width: int
    ent_width: int


@dataclasses.dataclass
class SearchRequest:
    """One user query. ``query`` leaves are unbatched (dense (Dd,), sparse
    (P,)); ``fusion`` is a scalar-leaf ``FusionSpec`` (mode, weights, rrf_k,
    stats — stats=None defers to the service's running index stats);
    keywords/entities are 1-D id arrays (or None). ``weights`` is the
    deprecated ``PathWeights`` form: it converts to a weighted-sum spec on
    construction with a ``DeprecationWarning``."""

    query: FusedVectors
    fusion: Optional[FusionSpec] = None
    k: int = 10
    keywords: Optional[np.ndarray] = None
    entities: Optional[np.ndarray] = None
    tenant: Optional[str] = None  # admission-control quota key (None = global only)
    weights: Optional[PathWeights] = None  # deprecated: use fusion
    # optional span-tree context: every serving stage this request passes
    # through (admission, queue wait, batch phases, replica fan-out) appends
    # spans here — see repro.obs.tracer and DESIGN.md §12
    trace: Optional[TraceContext] = None

    def __post_init__(self):
        if self.fusion is not None and self.weights is not None:
            raise ValueError("pass fusion= or (deprecated) weights=, not both")
        if self.fusion is None:
            if self.weights is not None:
                self.fusion = as_fusion_spec(self.weights)  # warns
            # else: left unset; the service rejects it at submit time
        elif not isinstance(self.fusion, FusionSpec):
            self.fusion = as_fusion_spec(self.fusion)  # warns on PathWeights


class PendingResult:
    """Future-like handle filled when the request's batch executes."""

    __slots__ = (
        "_ids",
        "_scores",
        "_path_scores",
        "_expanded",
        "_error",
        "_event",
        "_service",
    )

    def __init__(self, service=None):
        self._ids = None
        self._scores = None
        self._path_scores = None
        self._expanded = 0
        self._error: Optional[BaseException] = None
        self._event = threading.Event()
        self._service = service

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def expanded(self) -> int:
        """Nodes the beam search expanded for this query (work measure)."""
        return self._expanded

    @property
    def path_scores(self) -> Optional[np.ndarray]:
        """(k, 3) raw per-path scores of the returned ids (dense / learned /
        lexical), or None before fulfillment. Required by cross-replica RRF
        merges, which re-rank from raw path scores rather than fused ones."""
        return self._path_scores

    def _fulfill(
        self,
        ids: np.ndarray,
        scores: np.ndarray,
        expanded: int,
        path_scores: Optional[np.ndarray] = None,
    ) -> None:
        self._ids, self._scores, self._expanded = ids, scores, expanded
        self._path_scores = path_scores
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def result(self, timeout: float = 600.0) -> tuple[np.ndarray, np.ndarray]:
        """(ids, scores) for this request, length == request.k. Forces a
        flush of the owning service if the request is still queued, then
        waits for delivery — the batch may be mid-execution on another
        thread (the timer-thread deployment mode)."""
        if not self.done and self._service is not None:
            try:
                self._service.flush()
            except Exception:
                # flush re-raises the drain's first batch error, which may
                # belong to a DIFFERENT request's batch; our own outcome —
                # result or error — arrives through _fulfill/_fail below
                pass
        if not self._event.wait(timeout):
            raise TimeoutError(f"search request not completed in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._ids, self._scores


@dataclasses.dataclass
class _Entry:
    request: SearchRequest
    pending: PendingResult
    arrival_s: float  # time.monotonic(): deadline clock (injectable in tests)
    # time.perf_counter() at enqueue: queue-wait attribution start. A
    # separate stamp because the tests inject `now` into the monotonic
    # deadline clock, and spans/histograms must stay on the real clock.
    arrival_perf: float = 0.0


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def bucket_for(entries: list[_Entry], cfg: BatcherConfig) -> Bucket:
    """Smallest power-of-two bucket covering a batch of requests."""
    b = min(_next_pow2(len(entries)), cfg.max_batch)
    kw = max(
        (len(e.request.keywords) for e in entries if e.request.keywords is not None),
        default=0,
    )
    en = max(
        (len(e.request.entities) for e in entries if e.request.entities is not None),
        default=0,
    )
    return Bucket(
        batch=b,
        kw_width=min(max(_next_pow2(kw), 1), cfg.kw_cap),
        ent_width=min(max(_next_pow2(en), 1), cfg.ent_cap),
    )


class MicroBatcher:
    """Bounded FIFO of pending requests with size/deadline flush triggers."""

    def __init__(self, cfg: BatcherConfig):
        self.cfg = cfg
        self._queue: deque[_Entry] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def enqueue(
        self, request: SearchRequest, pending: PendingResult, now: Optional[float] = None
    ) -> None:
        if len(self._queue) >= self.cfg.max_queue:
            raise QueueFullError(
                f"request queue full ({self.cfg.max_queue}); shed load or retry"
            )
        now = time.monotonic() if now is None else now
        self._queue.append(_Entry(request, pending, now, time.perf_counter()))

    def due(self, now: Optional[float] = None) -> bool:
        """True when a flush trigger has fired (size or deadline)."""
        if len(self._queue) >= self.cfg.flush_size:
            return True
        if not self._queue:
            return False
        now = time.monotonic() if now is None else now
        return now - self._queue[0].arrival_s >= self.cfg.flush_deadline_s

    def take_ready(
        self, now: Optional[float] = None, force: bool = False
    ) -> list[tuple[Bucket, list[_Entry]]]:
        """Pop batches whose trigger fired (all pending ones if ``force``),
        in FIFO order, each at most ``max_batch`` requests with its bucket."""
        out: list[tuple[Bucket, list[_Entry]]] = []
        while self._queue and (force or self.due(now)):
            entries = [
                self._queue.popleft()
                for _ in range(min(len(self._queue), self.cfg.max_batch))
            ]
            out.append((bucket_for(entries, self.cfg), entries))
        return out
