"""Micro-batching for the hybrid-search serving path.

The paper's throughput story (§5, 1.5x-186.4x) assumes the GPU sees *batches*
of queries, not one-at-a-time calls. This module turns a stream of
heterogeneous requests (any ``PathWeights``, optional keywords/entities, any
``k``) into fixed-shape batches:

  * the batch dimension is padded up to a power of two (``Bucket.batch``) so
    a handful of executables covers every arrival pattern;
  * keyword / entity widths are padded to power-of-two bucket caps, so a
    request with 3 keywords and one with none land in the same executable;
  * a bounded FIFO queue decouples arrival from execution, flushing when
    ``flush_size`` requests are pending (throughput mode) or when the oldest
    request has waited ``flush_deadline_s`` (latency bound).

The batcher is deliberately passive: it never runs a search itself. The
service (``hybrid_service.HybridSearchService``) drains ready batches and
owns the executable cache. Deadlines are evaluated on ``submit`` and on
explicit ``poll`` — a real deployment pumps ``poll`` from a timer thread
(ROADMAP open item), which keeps this module free of threading.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.usms import FusedVectors, PathWeights


class QueueFullError(RuntimeError):
    """Raised when the bounded request queue rejects a submit (the admission
    -control hook: callers shed load or retry with backoff)."""


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_queue: int = 1024  # bounded FIFO capacity (admission control)
    flush_size: int = 32  # flush as soon as this many requests are pending
    flush_deadline_s: float = 0.01  # ... or the oldest request is this stale
    max_batch: int = 64  # largest bucket batch (power of two)
    kw_cap: int = 8  # largest keyword width bucket
    ent_cap: int = 4  # largest entity width bucket

    def __post_init__(self):
        if self.flush_size > self.max_batch:
            raise ValueError("flush_size must be <= max_batch")


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A fixed executable shape: (padded batch, keyword width, entity width).

    Hashable — it is the shape part of the executable-cache key."""

    batch: int
    kw_width: int
    ent_width: int


@dataclasses.dataclass
class SearchRequest:
    """One user query. ``query`` leaves are unbatched (dense (Dd,), sparse
    (P,)); ``weights`` leaves are scalars; keywords/entities are 1-D id
    arrays (or None)."""

    query: FusedVectors
    weights: PathWeights
    k: int = 10
    keywords: Optional[np.ndarray] = None
    entities: Optional[np.ndarray] = None


class PendingResult:
    """Future-like handle filled when the request's batch executes."""

    __slots__ = ("_ids", "_scores", "_expanded", "_error", "_event", "_service")

    def __init__(self, service=None):
        self._ids = None
        self._scores = None
        self._expanded = 0
        self._error: Optional[BaseException] = None
        self._event = threading.Event()
        self._service = service

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def expanded(self) -> int:
        """Nodes the beam search expanded for this query (work measure)."""
        return self._expanded

    def _fulfill(self, ids: np.ndarray, scores: np.ndarray, expanded: int) -> None:
        self._ids, self._scores, self._expanded = ids, scores, expanded
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def result(self, timeout: float = 600.0) -> tuple[np.ndarray, np.ndarray]:
        """(ids, scores) for this request, length == request.k. Forces a
        flush of the owning service if the request is still queued, then
        waits for delivery — the batch may be mid-execution on another
        thread (the timer-thread deployment mode)."""
        if not self.done and self._service is not None:
            try:
                self._service.flush()
            except Exception:
                # flush re-raises the drain's first batch error, which may
                # belong to a DIFFERENT request's batch; our own outcome —
                # result or error — arrives through _fulfill/_fail below
                pass
        if not self._event.wait(timeout):
            raise TimeoutError(f"search request not completed in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._ids, self._scores


@dataclasses.dataclass
class _Entry:
    request: SearchRequest
    pending: PendingResult
    arrival_s: float


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def bucket_for(entries: list[_Entry], cfg: BatcherConfig) -> Bucket:
    """Smallest power-of-two bucket covering a batch of requests."""
    b = min(_next_pow2(len(entries)), cfg.max_batch)
    kw = max(
        (len(e.request.keywords) for e in entries if e.request.keywords is not None),
        default=0,
    )
    en = max(
        (len(e.request.entities) for e in entries if e.request.entities is not None),
        default=0,
    )
    return Bucket(
        batch=b,
        kw_width=min(max(_next_pow2(kw), 1), cfg.kw_cap),
        ent_width=min(max(_next_pow2(en), 1), cfg.ent_cap),
    )


class MicroBatcher:
    """Bounded FIFO of pending requests with size/deadline flush triggers."""

    def __init__(self, cfg: BatcherConfig):
        self.cfg = cfg
        self._queue: deque[_Entry] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def enqueue(
        self, request: SearchRequest, pending: PendingResult, now: Optional[float] = None
    ) -> None:
        if len(self._queue) >= self.cfg.max_queue:
            raise QueueFullError(
                f"request queue full ({self.cfg.max_queue}); shed load or retry"
            )
        now = time.monotonic() if now is None else now
        self._queue.append(_Entry(request, pending, now))

    def due(self, now: Optional[float] = None) -> bool:
        """True when a flush trigger has fired (size or deadline)."""
        if len(self._queue) >= self.cfg.flush_size:
            return True
        if not self._queue:
            return False
        now = time.monotonic() if now is None else now
        return now - self._queue[0].arrival_s >= self.cfg.flush_deadline_s

    def take_ready(
        self, now: Optional[float] = None, force: bool = False
    ) -> list[tuple[Bucket, list[_Entry]]]:
        """Pop batches whose trigger fired (all pending ones if ``force``),
        in FIFO order, each at most ``max_batch`` requests with its bucket."""
        out: list[tuple[Bucket, list[_Entry]]] = []
        while self._queue and (force or self.due(now)):
            entries = [
                self._queue.popleft()
                for _ in range(min(len(self._queue), self.cfg.max_batch))
            ]
            out.append((bucket_for(entries, self.cfg), entries))
        return out
