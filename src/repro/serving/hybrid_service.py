"""Batched hybrid-search serving engine with a compiled-executable cache.

``HybridSearchService`` is the online request path the paper's throughput
claims (§5) presume but the one-shot ``search()`` API does not provide:

  * heterogeneous requests (any ``PathWeights``, optional keywords/entities,
    any ``k <= params.k``) are micro-batched into fixed shape-buckets by
    ``serving.batcher`` — batch padded to a power of two, keyword/entity
    widths padded to bucket caps;
  * every bucket hits an AOT-compiled executable cached on
    ``(index shape, bucket shape, SearchParams)``. Path weights enter as
    (B,) traced arrays per Theorem 1, so one executable serves every weight
    combination with zero retrace — the whole point of the paper's dynamic
    fusion framework (§4.2);
  * streaming updates go through ``insert()``/``mark_deleted()`` behind a
    copy-on-write snapshot swap: writers build the next immutable index off
    to the side and publish it atomically, so in-flight searches never
    observe a half-updated index;
  * the same service fronts a single-device ``HybridIndex`` and a sharded
    ``SegmentedIndex`` (via ``make_distributed_search_padded``) — the
    request path is identical, only the executable factory differs;
  * a segmented snapshot may carry a *grow segment* (a small mutable
    ``HybridIndex`` absorbing streaming inserts, managed by
    ``serving.segment_router.SegmentRouter``): reads fan out to the sealed
    executable AND a ``search_padded`` pass over the grow segment, then
    merge per-row top-k in global-id space. The grow pass deliberately uses
    ``search_padded``'s own jit cache, NOT the AOT ``executable_cache``, so
    sealed-segment executables survive every insert (the grow segment
    changes shape per insert; the sealed one does not);
  * token-bucket admission control (``BatcherConfig``-level queue bound is
    backpressure; ``AdmissionConfig`` buckets are rate policy) runs in front
    of ``MicroBatcher.enqueue``, with per-tenant quotas keyed on
    ``SearchRequest.tenant``.

Deadlines are evaluated on ``submit``/``poll``; a background pump thread
(``start_pump``/``ServiceConfig.pump_interval_s``) drives ``poll`` so
flush-on-deadline no longer depends on the submit path.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distributed import (
    SegmentedIndex,
    make_distributed_search_padded,
    make_local_group_search,
    mesh_segment_count,
)
from repro.core.segment_pool import SegmentPool, group_shape_key
from repro.core.build_pipeline import insert as index_insert
from repro.core.index import BuildConfig, HybridIndex
from repro.core.index import mark_deleted as index_mark_deleted
from repro.core.fusion import (
    FUSION_MODE_NAMES,
    FusionSpec,
    PathStats,
    as_fusion_spec,
    merge_fused_host,
    stack_specs,
)
from repro.obs.export import write_metrics_snapshot
from repro.obs.metrics import GLOBAL as GLOBAL_METRICS
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import TraceContext, Tracer
from repro.core.search import (
    SearchParams,
    SearchResult,
    resolve_params,
    search_padded,
    search_padded_trace_count,
)
from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    PathWeights,
    QuantizedFusedVectors,
    SparseVec,
    corpus_nbytes_by_leaf,
)
from repro.serving.batcher import (
    AdmissionConfig,
    AdmissionController,
    AdmissionError,
    BatcherConfig,
    Bucket,
    MicroBatcher,
    PendingResult,
    QueueFullError,
    SearchRequest,
)


# process-wide storage-footprint gauges, ticked at every snapshot publish
# (and once at service construction — the initial snapshot never passes
# through _publish). Labels: leaf kind x storage dtype, so the quantized
# compression ratio is a scraped metric, not just a checkpoint-manifest
# field. With several services in one process the most recent publisher
# wins — the bench snapshot reads one serving index at a time.
_INDEX_BYTES = GLOBAL_METRICS.gauge(
    "allanpoe_index_bytes_total",
    "served index storage bytes by leaf kind and dtype",
    labels=("leaf", "dtype"),
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    batcher: BatcherConfig = BatcherConfig()
    keep_stale_executables: bool = False  # keep executables for old index shapes
    admission: Optional[AdmissionConfig] = None  # token buckets before enqueue
    pump_interval_s: Optional[float] = None  # auto-start a poll() pump thread
    # observability (DESIGN.md §12): share a registry/tracer across services
    # by passing them in; None gives the service its own private ones
    metrics: Optional[MetricsRegistry] = None
    tracer: Optional[Tracer] = None
    # periodic JSON snapshot flush from the pump thread (service registry +
    # the process-global one); None disables
    metrics_dump_path: Optional[str] = None
    metrics_dump_interval_s: float = 10.0


def _bucket_label(bucket: Bucket) -> str:
    return f"{bucket.batch}x{bucket.kw_width}x{bucket.ent_width}"


def _fusion_mode_label(spec) -> str:
    """Host-side fusion-mode label of a request spec ("batched" for (B,)
    leaf specs — per-row modes are traced data the host never unpacks)."""
    try:
        mode = spec.mode
        if np.ndim(mode) >= 1:
            return "batched"
        return FUSION_MODE_NAMES.get(int(mode), str(int(mode)))
    except Exception:
        return "unknown"


class ServiceStats:
    """Thread-safe service counters, backed by the metrics registry: every
    increment goes through the registry's single lock (previously these
    were bare ``+=`` from multiple submitter threads), and the legacy field
    names read the live series. Labeled dimensions (fusion mode on
    requests, bucket shape on batches, reject reason) are visible through
    ``HybridSearchService.metrics``; the properties here report totals."""

    def __init__(self, metrics: MetricsRegistry):
        self._requests = metrics.counter(
            "allanpoe_serving_requests_total",
            "requests admitted and enqueued (rejects counted separately)",
            labels=("mode",),
        )
        self._batches = metrics.counter(
            "allanpoe_serving_batches_total",
            "batches executed",
            labels=("bucket",),
        )
        self._compiles = metrics.counter(
            "allanpoe_serving_compiles_total",
            "AOT executable compiles (cache misses that won the publish race)",
        )
        self._padded_slots = metrics.counter(
            "allanpoe_serving_padded_slots_total",
            "wasted batch slots (padding overhead measure)",
        )
        self._rejected = metrics.counter(
            "allanpoe_serving_rejected_total",
            "rejected submits by reason (admission = rate policy, "
            "queue_full = backpressure)",
            labels=("reason",),
        )

    @property
    def requests(self) -> int:
        return int(self._requests.total())

    @property
    def batches(self) -> int:
        return int(self._batches.total())

    @property
    def compiles(self) -> int:
        return int(self._compiles.total())

    @property
    def padded_slots(self) -> int:
        return int(self._padded_slots.total())

    @property
    def rejected_queue_full(self) -> int:
        return int(self._rejected.value(reason="queue_full"))

    @property
    def rejected_admission(self) -> int:
        return int(self._rejected.value(reason="admission"))

    @property
    def rejected(self) -> int:
        return self.rejected_queue_full + self.rejected_admission

    def __repr__(self) -> str:
        return (
            f"ServiceStats(requests={self.requests}, batches={self.batches}, "
            f"compiles={self.compiles}, padded_slots={self.padded_slots}, "
            f"rejected_queue_full={self.rejected_queue_full}, "
            f"rejected_admission={self.rejected_admission})"
        )


@dataclasses.dataclass(frozen=True)
class _Snapshot:
    """An immutable, fully-materialized index the read path can hold across
    a whole batch — the copy-on-write unit. ``grow``/``grow_gids`` are the
    optional grow segment of a segmented deployment: a small mutable-by-
    replacement HybridIndex plus its local-row -> global-id map."""

    index: Union[HybridIndex, SegmentedIndex, SegmentPool]
    version: int
    grow: Optional[HybridIndex] = None
    grow_gids: Optional[jax.Array] = None  # (n_grow,) int32


class HybridSearchService:
    """Micro-batched serving front-end over a hybrid index snapshot."""

    def __init__(
        self,
        index: Union[HybridIndex, SegmentedIndex, SegmentPool],
        params: SearchParams,
        config: Optional[ServiceConfig] = None,
        *,
        mesh=None,
        build_cfg: Optional[BuildConfig] = None,
    ):
        # pin backend-auto fields (use_kernel=None) to concrete values up
        # front: self.params is a component of every AOT executable-cache
        # key, so kernel mode must be resolved — not deferred to the op
        # layer — or a backend/flag change could alias a stale executable
        self.params = resolve_params(params)
        # declared storage mode must match what the index actually holds:
        # serving quantized segments under corpus_dtype="float32" would hand
        # the executables a pytree the cache key does not describe. The
        # reverse — "int8" over (still-)fp32 segments — is allowed: during a
        # migration old fp32 seals coexist with new int8 ones, and the
        # per-group dispatch handles each by its own treedef.
        if self.params.corpus_dtype == "float32":
            quantized = [
                type(c).__name__
                for c, _ in self._norm_parts(_Snapshot(index, version=0))
                if isinstance(c, QuantizedFusedVectors)
            ]
            if quantized:
                raise ValueError(
                    "index holds quantized corpus storage but "
                    'SearchParams.corpus_dtype is "float32"; construct the '
                    'service with corpus_dtype="int8"'
                )
        self.config = config or ServiceConfig()
        self.metrics = self.config.metrics or MetricsRegistry()
        self.tracer = self.config.tracer or Tracer()
        self.stats = ServiceStats(self.metrics)
        # instruments beyond the legacy counters (naming: DESIGN.md §12)
        self._m_exec_cache = self.metrics.counter(
            "allanpoe_serving_executable_cache_total",
            "AOT executable-cache lookups by outcome",
            labels=("outcome",),
        )
        self._m_group_dispatch = self.metrics.counter(
            "allanpoe_serving_group_dispatches_total",
            "pool-read dispatches per segment shape group",
            labels=("group",),
        )
        self._m_queue_depth = self.metrics.gauge(
            "allanpoe_serving_queue_depth", "pending requests in the batcher"
        )
        self._m_queue_wait = self.metrics.histogram(
            "allanpoe_serving_queue_wait_seconds",
            "enqueue -> batch start per request",
        )
        self._m_latency = self.metrics.histogram(
            "allanpoe_serving_request_latency_seconds",
            "enqueue -> result delivery per request (the bench p50/p99 source)",
        )
        self._m_batch_exec = self.metrics.histogram(
            "allanpoe_serving_batch_exec_seconds",
            "assemble -> deliver per batch",
            labels=("bucket",),
        )
        self._snap = _Snapshot(index, version=0)
        self._index_bytes_keys: set = set()
        self._tick_index_bytes(self._snap)
        self._write_lock = threading.Lock()  # serializes snapshot writers
        # queue lock: enqueue/take_ready only, never held across a batch run,
        # so a timer thread pumping poll() can coexist with request threads
        # without submit() stalling behind a compile or device execution
        self._queue_lock = threading.Lock()
        # cache lock: every _exec_cache read/write/prune plus batch stats
        self._cache_lock = threading.Lock()
        self._batcher = MicroBatcher(self.config.batcher)
        self._exec_cache: dict = {}
        self._pool = isinstance(index, SegmentPool)
        self._segmented = isinstance(index, SegmentedIndex) or self._pool
        self._mesh = mesh
        self._dist_fn = None
        if isinstance(index, SegmentedIndex):
            # a plain stacked index is served through the sharded executable
            if mesh is None:
                raise ValueError("a SegmentedIndex service requires a mesh")
        if self._segmented and mesh is not None:
            self._dist_fn = make_distributed_search_padded(mesh, self.params)
        # pool groups off the mesh's segment axes (or the whole pool of an
        # off-mesh deployment) are served by the collective-free local pass;
        # any segmented service can become pool-fronted after an incremental
        # compaction, so the local factory is always on hand
        self._local_fn = (
            make_local_group_search(self.params) if self._segmented else None
        )
        self._build_cfg = build_cfg
        self._router = None  # set by serving.segment_router.SegmentRouter
        # running per-path normalization stats: recomputed lazily when the
        # snapshot version moves, EMA-blended across publishes so normalized
        # fusion scores stay stable under streaming churn (DESIGN.md §11)
        self._stats_cache: Optional[PathStats] = None
        self._stats_version = -1
        self._admission = (
            AdmissionController(self.config.admission)
            if self.config.admission is not None
            else None
        )
        self._pump_lock = threading.Lock()  # guards pump start/stop
        self._pump_thread: Optional[threading.Thread] = None
        self._pump_stop = threading.Event()
        if self.config.pump_interval_s is not None:
            self.start_pump()

    # -- background pump (flush-on-deadline without a submit) ---------------

    def start_pump(self, interval_s: Optional[float] = None) -> None:
        """Start the daemon thread that drives ``poll()`` every
        ``interval_s`` (default: ``config.pump_interval_s``), so deadline
        flushes happen even when no new submit arrives. Idempotent."""
        interval = (
            self.config.pump_interval_s if interval_s is None else interval_s
        )
        if interval is None:
            raise ValueError("pump interval required (arg or config)")
        with self._pump_lock:  # check-then-start must be atomic: exactly
            # one pump thread, and _pump_stop always refers to ITS event
            if self._pump_thread is not None and self._pump_thread.is_alive():
                return
            self._pump_stop = threading.Event()
            stop = self._pump_stop

            def loop():
                last_dump = time.monotonic()
                while not stop.wait(interval):
                    try:
                        self.poll()
                    except Exception:
                        # the failing batch already failed its own waiters
                        # (_run_batch); the pump must keep pumping for the rest
                        pass
                    # periodic exposition flush rides the pump thread: the
                    # snapshot is the same registry the benches read
                    if (
                        self.config.metrics_dump_path is not None
                        and time.monotonic() - last_dump
                        >= self.config.metrics_dump_interval_s
                    ):
                        last_dump = time.monotonic()
                        try:
                            self.dump_metrics()
                        except Exception:
                            pass  # a full disk must not kill the pump

            self._pump_thread = threading.Thread(
                target=loop, name="hybrid-service-pump", daemon=True
            )
            self._pump_thread.start()

    def dump_metrics(self, path=None) -> dict:
        """Write the merged (service + process-global) metrics snapshot to
        ``path`` (default ``config.metrics_dump_path``); returns the dict."""
        path = self.config.metrics_dump_path if path is None else path
        if path is None:
            raise ValueError("no metrics dump path (arg or config)")
        return write_metrics_snapshot(path, self.metrics, GLOBAL_METRICS)

    def stop_pump(self, timeout_s: float = 5.0) -> None:
        with self._pump_lock:
            thread = self._pump_thread
            if thread is not None:
                self._pump_stop.set()
                thread.join(timeout=timeout_s)
                self._pump_thread = None
                if self.config.metrics_dump_path is not None:
                    try:
                        self.dump_metrics()  # final flush on clean shutdown
                    except Exception:
                        pass
        # clean shutdown extends to the attached router's background merge
        # worker: an in-flight merge finishes its atomic publish, then the
        # worker exits before this returns
        router = getattr(self, "_router", None)
        if router is not None and hasattr(router, "stop_merge_worker"):
            router.stop_merge_worker()

    def __enter__(self) -> "HybridSearchService":
        return self

    def __exit__(self, *exc) -> None:
        self.stop_pump()

    # -- snapshot management (copy-on-write swap) ---------------------------

    @property
    def snapshot_version(self) -> int:
        return self._snap.version

    @property
    def index(self) -> Union[HybridIndex, SegmentedIndex]:
        return self._snap.index

    @property
    def grow_index(self) -> Optional[HybridIndex]:
        """The current grow segment (None when sealed-only)."""
        return self._snap.grow

    # EMA weight of FRESH stats at each snapshot publish; extremes still
    # widen monotonically (PathStats.ema), so minmax stays in-range for
    # every row both snapshots contained
    _STATS_EMA = 0.3

    @staticmethod
    def _norm_parts(snap: _Snapshot):
        """(corpus, alive) pairs covering every live row of a snapshot —
        the input to ``PathStats.from_corpus_parts``."""
        idx = snap.index
        if isinstance(idx, SegmentPool):
            parts = [(g.index.corpus, g.index.alive) for g in idx.groups]
        elif isinstance(idx, SegmentedIndex):
            parts = [(idx.index.corpus, idx.index.alive)]
        else:
            parts = [(idx.corpus, idx.alive)]
        if snap.grow is not None:
            parts.append((snap.grow.corpus, snap.grow.alive))
        return parts

    @property
    def path_stats(self) -> PathStats:
        """Running per-path normalization stats of the served corpus
        ((3,) leaves). Lazily refreshed when the snapshot version moves;
        successive publishes EMA-blend rather than jump."""
        snap = self._snap
        if self._stats_cache is None or self._stats_version != snap.version:
            fresh = PathStats.from_corpus_parts(self._norm_parts(snap))
            stats = (
                fresh
                if self._stats_cache is None
                else PathStats.ema(self._stats_cache, fresh, self._STATS_EMA)
            )
            self._stats_cache, self._stats_version = stats, snap.version
        return self._stats_cache

    def _resolve_spec(self, spec: FusionSpec) -> FusionSpec:
        """Pin unresolved (stats=None) specs to the service's running
        stats — the downstream resolution the ``FusionSpec`` contract
        promises. Already-resolved specs pass through untouched."""
        if spec.stats is not None:
            return spec
        return dataclasses.replace(spec, stats=self.path_stats)

    def _tick_index_bytes(self, snap: _Snapshot) -> None:
        """Set the ``allanpoe_index_bytes_total{leaf,dtype}`` gauges to this
        snapshot's storage footprint. Corpus leaves are broken out by kind
        (dense / dense_scale / sparse_idx / sparse_val); everything else —
        edges, entry points, liveness, entity tables — rolls up as "graph"
        by dtype. Label pairs that vanished (e.g. float32 dense after a
        fully quantized compaction) are zeroed, not left stale."""
        totals: dict = {}

        def add(leaf: str, arr) -> None:
            key = (leaf, str(arr.dtype))
            totals[key] = totals.get(key, 0) + arr.size * arr.dtype.itemsize

        def add_index(hidx) -> None:
            for key, v in corpus_nbytes_by_leaf(hidx.corpus).items():
                totals[key] = totals.get(key, 0) + v
            corpus_ids = {id(l) for l in jax.tree.leaves(hidx.corpus)}
            for leaf in jax.tree.leaves(hidx):
                if id(leaf) not in corpus_ids:
                    add("graph", leaf)

        idx = snap.index
        if isinstance(idx, SegmentPool):
            for g in idx.groups:
                add_index(g.index)
                add("graph", g.global_ids)
        elif isinstance(idx, SegmentedIndex):
            add_index(idx.index)
            add("graph", idx.global_ids)
        else:
            add_index(idx)
        if snap.grow is not None:
            add_index(snap.grow)
        for leaf, dtype in self._index_bytes_keys - set(totals):
            _INDEX_BYTES.set(0, leaf=leaf, dtype=dtype)
        for (leaf, dtype), v in totals.items():
            _INDEX_BYTES.set(v, leaf=leaf, dtype=dtype)
        self._index_bytes_keys = set(totals)

    def _publish(self, new_index, *, grow=None, grow_gids=None) -> None:
        # materialize before publishing so readers never block on (or fail
        # inside) a half-computed donor buffer
        leaves = jax.tree.leaves(new_index)
        if grow is not None:
            if grow_gids is None:
                raise ValueError("a grow segment needs its global-id map")
            grow_gids = jnp.asarray(grow_gids, jnp.int32)
            leaves = leaves + jax.tree.leaves(grow) + [grow_gids]
        jax.block_until_ready(leaves)
        self._snap = _Snapshot(
            new_index, self._snap.version + 1, grow=grow, grow_gids=grow_gids
        )
        self._tick_index_bytes(self._snap)
        if not self.config.keep_stale_executables:
            # prune on the SEALED index keys only: the grow segment is read
            # through search_padded's own jit cache, so grow churn neither
            # adds nor evicts AOT entries — sealed executables stay warm
            # across every streaming insert (the cache-key invariant the
            # grow-segment scheme exists to provide; DESIGN.md §6). A pool
            # publish keeps every executable whose shape group SURVIVED the
            # mutation: compacting into one group never evicts the others
            # (the cache-survival guarantee, DESIGN.md §8)
            valid = self._valid_index_keys(new_index)
            with self._cache_lock:
                self._exec_cache = {
                    k: v for k, v in self._exec_cache.items() if k[0] in valid
                }

    def insert(
        self,
        new_docs: FusedVectors,
        *,
        key: Optional[jax.Array] = None,
        new_doc_entities: Optional[np.ndarray] = None,
    ) -> int:
        """Absorb streaming inserts; returns the new snapshot version.
        In-flight searches keep the snapshot they started with. A segmented
        service routes inserts to its grow segment via the attached
        ``SegmentRouter``."""
        if self._segmented:
            if self._router is None:
                raise NotImplementedError(
                    "streaming insert into a SegmentedIndex needs a grow "
                    "segment: attach a serving.segment_router.SegmentRouter"
                )
            return self._router.insert(
                new_docs, key=key, new_doc_entities=new_doc_entities
            )
        if self._build_cfg is None:
            raise ValueError("insert requires build_cfg at service construction")
        with self._write_lock:
            new_index = index_insert(
                self._snap.index,
                new_docs,
                self._build_cfg,
                key=key,
                new_doc_entities=new_doc_entities,
            )
            self._publish(new_index)
            return self._snap.version  # read under the lock: OUR version

    def mark_deleted(self, ids) -> int:
        """Mark-delete docs; returns the new snapshot version. The index
        shape is unchanged, so cached executables keep serving. A segmented
        service resolves global ids to (segment, local row) tombstones via
        the attached ``SegmentRouter``."""
        if self._segmented:
            if self._router is None:
                raise NotImplementedError(
                    "deletion on a SegmentedIndex needs global->segment id "
                    "routing: attach a serving.segment_router.SegmentRouter"
                )
            return self._router.delete(ids)
        with self._write_lock:
            new_index = index_mark_deleted(
                self._snap.index, jnp.asarray(ids, jnp.int32)
            )
            self._publish(new_index)
            return self._snap.version  # read under the lock: OUR version

    # -- executable cache ---------------------------------------------------

    @staticmethod
    def _index_key(index) -> tuple:
        if isinstance(index, SegmentedIndex):
            # the full shape signature: a stacked index serving as a pool
            # group keeps the SAME key either way, so wrapping it into a
            # SegmentPool never invalidates its cached executable
            return group_shape_key(index)
        return ("single", index.n)

    def _valid_index_keys(self, index) -> set:
        """Executable-cache keys the given snapshot index can serve."""
        if isinstance(index, SegmentPool):
            return {group_shape_key(g) for g in index.groups}
        return {self._index_key(index)}

    @property
    def executable_cache(self) -> dict:
        """(index/group key, Bucket, SearchParams) -> AOT executable."""
        return self._exec_cache

    def _compile_cached(self, key: tuple, lower):
        """(executable, cache_hit) for a cache key, compiling on miss. Every
        lookup lands in the ``executable_cache_total{outcome}`` counter — the
        hit-rate series the CI obs gate tracks."""
        with self._cache_lock:
            exe = self._exec_cache.get(key)
        if exe is not None:
            self._m_exec_cache.inc(outcome="hit")
            return exe, True
        self._m_exec_cache.inc(outcome="miss")
        # compile outside the lock: a cold bucket must not stall warm-bucket
        # batches or snapshot publishes behind a multi-second XLA compile
        exe = lower().compile()
        with self._cache_lock:
            winner = self._exec_cache.get(key)
            if winner is not None:
                return winner, False  # another thread compiled the bucket first
            # a writer may have swapped the snapshot while we compiled;
            # don't re-add an executable its prune already evicted
            if (
                self.config.keep_stale_executables
                or key[0] in self._valid_index_keys(self._snap.index)
            ):
                self._exec_cache[key] = exe
        self.stats._compiles.inc()
        return exe, False

    def _get_executable(self, snap: _Snapshot, bucket: Bucket, args):
        key = (self._index_key(snap.index), bucket, self.params)
        if self._segmented:
            lower = lambda: self._dist_fn.lower(snap.index, *args)
        else:
            lower = lambda: search_padded.lower(snap.index, *args, self.params)
        return self._compile_cached(key, lower)

    def _group_runner(self, group: SegmentedIndex):
        """Pick the executable factory for one pool group per the placement
        map: the sharded pass when the group divides over the mesh's segment
        devices, else the collective-free local pass."""
        if self._dist_fn is not None and self._mesh is not None:
            msc = mesh_segment_count(self._mesh)
            if msc > 1 and group.n_segments % msc == 0:
                return self._dist_fn
        return self._local_fn

    def _get_group_executable(self, group: SegmentedIndex, bucket: Bucket, args):
        """(executable, cache_hit) for one pool shape group."""
        key = (group_shape_key(group), bucket, self.params)
        fn = self._group_runner(group)
        return self._compile_cached(key, lambda: fn.lower(group, *args))

    # -- request path -------------------------------------------------------

    def _validate(self, request: SearchRequest) -> None:
        bcfg = self.config.batcher
        if request.fusion is None:
            raise ValueError(
                "SearchRequest needs fusion=FusionSpec(...) "
                "(or the deprecated weights=PathWeights form)"
            )
        if request.k > self.params.k:
            raise ValueError(
                f"request.k={request.k} exceeds the service cap params.k={self.params.k}"
            )
        if request.keywords is not None:
            if not self.params.use_keywords:
                raise ValueError("service params have use_keywords=False")
            if len(request.keywords) > bcfg.kw_cap:
                raise ValueError(
                    f"{len(request.keywords)} keywords exceed kw_cap={bcfg.kw_cap}"
                )
        if request.entities is not None:
            if not self.params.use_kg:
                raise ValueError("service params have use_kg=False")
            if len(request.entities) > bcfg.ent_cap:
                raise ValueError(
                    f"{len(request.entities)} entities exceed ent_cap={bcfg.ent_cap}"
                )

    def submit(self, request: SearchRequest) -> PendingResult:
        """Enqueue one request; runs any batch whose flush trigger fired.

        Raises ``AdmissionError`` on a token-bucket reject (rate policy) and
        ``QueueFullError`` on a bounded-queue reject (backpressure) — the
        two are counted separately in ``stats``."""
        self._validate(request)
        ctx = request.trace
        t_sub = time.perf_counter()
        pending = PendingResult(service=self)
        with self._queue_lock:
            if self._admission is not None and not self._admission.try_admit(
                request.tenant
            ):
                self.stats._rejected.inc(reason="admission")
                if ctx is not None:
                    ctx.add_span(
                        "admission", t_sub, time.perf_counter(),
                        outcome="rejected_admission", tenant=request.tenant,
                    )
                raise AdmissionError(
                    f"token-bucket admission rejected request "
                    f"(tenant={request.tenant!r}); shed load or retry later"
                )
            try:
                self._batcher.enqueue(request, pending)
            except QueueFullError:
                # the request was admitted but never served: hand the
                # tokens back so backpressure rejects don't drain quota
                if self._admission is not None:
                    self._admission.refund(request.tenant)
                self.stats._rejected.inc(reason="queue_full")
                if ctx is not None:
                    ctx.add_span(
                        "admission", t_sub, time.perf_counter(),
                        outcome="rejected_queue_full", tenant=request.tenant,
                    )
                raise
            self.stats._requests.inc(mode=_fusion_mode_label(request.fusion))
            self._m_queue_depth.set(len(self._batcher))
        if ctx is not None:
            ctx.add_span(
                "admission", t_sub, time.perf_counter(),
                outcome="admitted", tenant=request.tenant,
            )
        try:
            self._drain()
        except Exception:
            # a failing batch (ours or a sibling's) has already failed its
            # own waiters; the returned handle is the error channel here —
            # raising would discard it while the request may still be queued
            pass
        return pending

    def poll(self) -> int:
        """Run deadline-due batches (call from a timer loop); returns the
        number of batches executed. A failing batch raises here after its
        waiters have been failed — timer loops should catch and keep
        pumping; every affected result() re-raises the real error."""
        return self._drain()

    def flush(self) -> int:
        """Force-run every pending batch; returns the number executed."""
        return self._drain(force=True)

    def _drain(self, force: bool = False) -> int:
        with self._queue_lock:
            ready = self._batcher.take_ready(force=force)
            self._m_queue_depth.set(len(self._batcher))
        # entries are dequeued: run each batch outside the queue lock so
        # concurrent submits only wait for the enqueue, not the execution.
        # Every dequeued batch must resolve its waiters even if an earlier
        # sibling batch failed, so run them all before re-raising.
        first_err: Optional[BaseException] = None
        for bucket, entries in ready:
            try:
                self._run_batch(bucket, entries)
            except Exception as err:  # waiters already failed by _run_batch
                first_err = first_err or err
        if first_err is not None:
            raise first_err
        return len(ready)

    # large-negative fill for merged pad slots (matches distributed NEG_FILL)
    _NEG_FILL = np.float32(-1e30)

    @staticmethod
    def _merge_host(ids_parts, score_parts, k, path_parts=None, spec=None):
        """Per-row top-k merge of several result blocks in global-id space
        (every global id lives in exactly one segment, so merged rows are
        duplicate-free). Fusion-aware: non-RRF rows merge by score, RRF rows
        recompute ranks over the union from ``path_parts`` — merging local
        RRF scores by value is a contract violation (DESIGN.md §11) and
        raises inside ``merge_fused_host``."""
        return merge_fused_host(ids_parts, score_parts, path_parts, spec, k)

    def _merge_grow(
        self, snap: _Snapshot, args, ids, scores, ps, expanded, phases=None
    ):
        """Phase two of a segmented read: search the grow segment and merge
        per-row top-k with the sealed results in global-id space.

        The grow pass goes through ``search_padded`` directly — its jit cache
        retraces as the grow segment changes shape, while the AOT
        ``executable_cache`` (sealed segments) is never touched. Tombstones
        need no extra filtering here: both phases already filter on their
        own ``alive`` masks."""
        t0 = time.perf_counter()
        traces0 = search_padded_trace_count()
        gres = search_padded(snap.grow, *args, self.params)
        g_local = np.asarray(gres.ids)
        gids_map = np.asarray(snap.grow_gids)
        g_ids = np.where(
            g_local >= 0,
            gids_map[np.clip(g_local, 0, gids_map.shape[0] - 1)],
            PAD_IDX,
        )
        g_scores = np.where(g_local >= 0, np.asarray(gres.scores), -np.inf)
        g_ps = np.where(
            (g_local >= 0)[:, :, None], np.asarray(gres.path_scores), 0.0
        )
        m_ids, m_scores, m_ps = self._merge_host(
            [ids, g_ids],
            [scores, g_scores],
            ids.shape[1],
            path_parts=[ps, g_ps],
            spec=args[1],
        )
        if phases is not None:
            phases.append((
                "grow_merge", t0, time.perf_counter(),
                {"grow_rows": int(snap.grow.n),
                 "retraced": search_padded_trace_count() > traces0},
            ))
        return m_ids, m_scores, m_ps, expanded + np.asarray(gres.expanded)

    def _run_pool(self, pool: SegmentPool, bucket: Bucket, args, phases=None):
        """Pool read: one cached executable per shape group, merged per-row
        in global-id space. Groups untouched by a compaction keep hitting
        their existing executables."""
        t0 = time.perf_counter()
        pairs = [
            self._get_group_executable(group, bucket, args)
            for group in pool.groups
        ]
        t1 = time.perf_counter()
        # dispatch EVERY group before blocking on any result: jax executes
        # asynchronously, so the groups' device work overlaps instead of
        # paying the sum of per-group latencies
        results = []
        for gi, (group, (exe, _)) in enumerate(zip(pool.groups, pairs)):
            self._m_group_dispatch.inc(group=gi)
            results.append(exe(group, *args))
        ids_parts, score_parts, ps_parts = [], [], []
        expanded = np.int64(0)
        for res in results:
            ids_parts.append(np.asarray(res.ids))
            score_parts.append(np.asarray(res.scores))
            ps_parts.append(np.asarray(res.path_scores))
            expanded = expanded + np.asarray(res.expanded)
        t2 = time.perf_counter()
        if phases is not None:
            phases.append((
                "executable_lookup", t0, t1,
                {"hit": all(h for _, h in pairs), "groups": len(pairs)},
            ))
            phases.append(
                ("device_dispatch", t1, t2, {"groups": len(pairs)})
            )
        if len(ids_parts) == 1:
            return ids_parts[0], score_parts[0], ps_parts[0], expanded
        k = ids_parts[0].shape[1]
        m_ids, m_scores, m_ps = self._merge_host(
            ids_parts, score_parts, k, path_parts=ps_parts, spec=args[1]
        )
        if phases is not None:
            phases.append((
                "fusion_rescore", t2, time.perf_counter(),
                {"parts": len(ids_parts), "site": "pool_merge"},
            ))
        return m_ids, m_scores, m_ps, expanded

    def _run_batch(self, bucket: Bucket, entries) -> None:
        # batch phases are timed once and attributed to every query in the
        # batch: (name, t0, t1, attrs) tuples become spans on each request's
        # TraceContext (DESIGN.md §12 span taxonomy)
        t_batch0 = time.perf_counter()
        blabel = _bucket_label(bucket)
        phases: list[tuple[str, float, float, dict]] = []
        try:
            snap = self._snap  # one snapshot for the whole batch
            t0 = time.perf_counter()
            args = self._assemble(bucket, entries)
            phases.append((
                "batch_assembly", t0, time.perf_counter(),
                {"bucket": blabel, "requests": len(entries)},
            ))
            if isinstance(snap.index, SegmentPool):
                ids, scores, ps, expanded = self._run_pool(
                    snap.index, bucket, args, phases
                )
            else:
                t0 = time.perf_counter()
                exe, hit = self._get_executable(snap, bucket, args)
                t1 = time.perf_counter()
                phases.append(("executable_lookup", t0, t1, {"hit": hit}))
                res = exe(snap.index, *args)
                ids = np.asarray(res.ids)
                scores = np.asarray(res.scores)
                ps = np.asarray(res.path_scores)
                expanded = np.asarray(res.expanded)
                phases.append(
                    ("device_dispatch", t1, time.perf_counter(), {})
                )
            if snap.grow is not None:
                ids, scores, ps, expanded = self._merge_grow(
                    snap, args, ids, scores, ps, expanded, phases
                )
        except Exception as err:
            # entries are already dequeued: propagate to every waiter so no
            # result() blocks forever, then surface to the driving thread
            for e in entries:
                e.pending._fail(err)
            raise
        for i, e in enumerate(entries):
            e.pending._fulfill(
                ids[i, : e.request.k],
                scores[i, : e.request.k],
                int(expanded[i]),
                path_scores=ps[i, : e.request.k],
            )
        t_done = time.perf_counter()
        for e in entries:
            self._m_queue_wait.observe(t_batch0 - e.arrival_perf)
            self._m_latency.observe(t_done - e.arrival_perf)
            ctx = e.request.trace
            if ctx is not None:
                ctx.add_span(
                    "queue_wait", e.arrival_perf, t_batch0, bucket=blabel
                )
                for name, p0, p1, attrs in phases:
                    ctx.add_span(name, p0, p1, **attrs)
        self._m_batch_exec.observe(t_done - t_batch0, bucket=blabel)
        self.stats._batches.inc(bucket=blabel)
        self.stats._padded_slots.inc(bucket.batch - len(entries))

    def _assemble(self, bucket: Bucket, entries):
        """Pack requests into the bucket's fixed shapes. Pad rows carry the
        all-zero fusion spec and PAD ids; their results are discarded on
        delivery. Every request spec is resolved against the service's
        running stats here, so the stacked spec has a FIXED pytree
        structure — fusion mode/weights/stats remain traced data, never part
        of the executable-cache key."""
        m = len(entries)
        b = bucket.batch
        queries = jax.tree.map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
            *[e.request.query for e in entries],
        )
        if m < b:
            padn = b - m
            grow = lambda a, fill: jnp.concatenate(
                [a, jnp.full((padn,) + a.shape[1:], fill, a.dtype)]
            )
            queries = FusedVectors(
                grow(queries.dense, 0),
                SparseVec(grow(queries.learned.idx, PAD_IDX), grow(queries.learned.val, 0)),
                SparseVec(grow(queries.lexical.idx, PAD_IDX), grow(queries.lexical.val, 0)),
            )
        pad_spec = self._resolve_spec(FusionSpec.zero())
        fusion = stack_specs(
            [self._resolve_spec(e.request.fusion) for e in entries]
            + [pad_spec] * (b - m)
        )
        kw = np.full((b, bucket.kw_width), PAD_IDX, np.int32)
        en = np.full((b, bucket.ent_width), PAD_IDX, np.int32)
        for i, e in enumerate(entries):
            if e.request.keywords is not None and len(e.request.keywords):
                kws = np.asarray(e.request.keywords, np.int32)
                kw[i, : len(kws)] = kws
            if e.request.entities is not None and len(e.request.entities):
                ens = np.asarray(e.request.entities, np.int32)
                en[i, : len(ens)] = ens
        return queries, fusion, jnp.asarray(kw), jnp.asarray(en)

    # -- synchronous convenience -------------------------------------------

    def search(
        self,
        queries: FusedVectors,
        fusion: Union[FusionSpec, PathWeights, Sequence, None] = None,
        *,
        weights: Union[PathWeights, Sequence[PathWeights], None] = None,
        keywords: Optional[np.ndarray] = None,
        entities: Optional[np.ndarray] = None,
        k: Optional[int] = None,
        trace: Optional[TraceContext] = None,
    ) -> SearchResult:
        """Submit a whole batch and flush: per-row requests (row i of
        ``queries`` with fusion[i] if a sequence / batched-leaf spec was
        given), results reassembled into a SearchResult. Mirrors
        core.search.search but runs through the batched request path.
        ``weights=`` is the deprecated ``PathWeights`` spelling (converts to
        a weighted-sum spec with a warning). 2-D keyword/entity arrays may
        be PAD_IDX padded (the core search() convention); pad slots are
        stripped per row before the requests are formed."""

        def row_ids(arr, i):
            if arr is None:
                return None
            row = np.asarray(arr)[i]
            row = row[row >= 0]
            return row if len(row) else None

        if fusion is not None and weights is not None:
            raise ValueError("pass fusion= or (deprecated) weights=, not both")
        if fusion is None:
            if weights is None:
                raise TypeError("search() requires fusion=FusionSpec(...)")
            fusion = weights  # deprecated form; as_fusion_spec warns below
        b = queries.dense.shape[0]
        k = self.params.k if k is None else k
        if isinstance(fusion, (FusionSpec, PathWeights)):
            spec = as_fusion_spec(fusion)
            if np.ndim(spec.mode) >= 1:  # batched (B,)-leaf form
                get_f = lambda i: jax.tree.map(lambda x: x[i], spec)
            else:
                get_f = lambda i: spec
        else:  # per-row sequence of FusionSpec / deprecated PathWeights
            rows = [as_fusion_spec(f) for f in fusion]
            get_f = lambda i: rows[i]
        reqs = [
            SearchRequest(
                query=queries[i],
                fusion=get_f(i),
                k=k,
                keywords=row_ids(keywords, i),
                entities=row_ids(entities, i),
                trace=trace,
            )
            for i in range(b)
        ]
        # validate the whole batch before enqueuing anything: one bad row
        # must not strand its predecessors as orphaned queue entries
        for req in reqs:
            self._validate(req)
        pendings = []
        for req in reqs:
            try:
                pendings.append(self.submit(req))
            except QueueFullError:
                # drain to make room rather than stranding the rows already
                # queued; force-flush empties the bounded queue entirely
                self.flush()
                pendings.append(self.submit(req))
        try:
            self.flush()
        except Exception:
            pass  # per-row errors surface from each result() below
        ids = np.stack([p.result()[0] for p in pendings])
        scores = np.stack([p.result()[1] for p in pendings])
        ps = np.stack([p.path_scores for p in pendings])
        return SearchResult(
            ids=jnp.asarray(ids),
            scores=jnp.asarray(scores),
            expanded=jnp.asarray([p.expanded for p in pendings], jnp.int32),
            path_scores=jnp.asarray(ps),
        )
