"""Architecture registry: one module per assigned architecture.

Each module exports ``CONFIG`` (the exact published configuration from the
assignment) and ``smoke_config()`` (a reduced same-family config for CPU
smoke tests). Select with ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "rwkv6-7b",
    "llama3.2-1b",
    "starcoder2-15b",
    "qwen2-1.5b",
    "deepseek-7b",
    "llama-3.2-vision-90b",
    "zamba2-1.2b",
    "kimi-k2-1t-a32b",
    "deepseek-v3-671b",
    "whisper-large-v3",
]

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke_config()


def list_archs() -> list[str]:
    return list(ARCHS)
