"""deepseek-7b — llama-architecture dense [arXiv:2401.02954].

30L, d_model=4096, 32H (kv=32, MHA), d_ff=11008, vocab=102400.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    rope_theta=10_000.0,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, head_dim=32, fsdp=False, remat="none",
    )
