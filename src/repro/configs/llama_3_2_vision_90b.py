"""llama-3.2-vision-90b — cross-attention image layers
[hf:meta-llama/Llama-3.2-90B-Vision].

100L total = 80 self-attention + 20 cross-attention (every 5th layer),
d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256. The vision tower is a
STUB: input_specs() provides precomputed patch embeddings (B, 1600, 8192).
Pure full attention -> long_500k cell skipped (DESIGN.md).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,  # counted as 80 self + 20 cross via cross_attn_every=5
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_frontend_tokens=1600,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=10, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, cross_attn_every=5, n_frontend_tokens=16,
        fsdp=False, remat="none",
    )
