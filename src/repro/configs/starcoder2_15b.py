"""starcoder2-15b — GQA + RoPE code model [arXiv:2402.19173].

40L, d_model=6144, 48H (GQA kv=4), d_ff=24576, vocab=49152.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    rope_theta=1_000_000.0,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, fsdp=False, remat="none",
    )
