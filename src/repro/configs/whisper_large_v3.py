"""whisper-large-v3 — encoder-decoder speech model [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280, 20H (kv=20), d_ff=5120,
vocab=51866. The conv mel frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, 1280). Positional scheme adapted to
RoPE for the synthetic 32k decode cells (backbone-only per the assignment).
20 heads do not divide the 16-way model axis -> attention replicated under
TP. Pure full attention -> long_500k cell skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    n_frontend_tokens=1500,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab=512, head_dim=32,
        n_frontend_tokens=12, remat="none",
    )
