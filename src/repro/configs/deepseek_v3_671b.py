"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 + MTP [arXiv:2412.19437].

61L, d_model=7168, 128 MLA heads (q_lora=1536, kv_lora=512, rope_dim=64),
vocab=129280; experts d_ff=2048; first 3 layers dense (d_ff=18432); MTP head.
The assignment line's "GQA kv=128" is superseded by its own MLA annotation —
we implement MLA as published, with compressed-latent decode (DESIGN.md).
Pure full attention -> long_500k cell skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # leading dense layers
    vocab=129280,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    mtp=True,
    rope_theta=10_000.0,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=512, n_experts=8, experts_per_token=2,
        n_shared_experts=1, moe_d_ff=64, first_dense_layers=1,
        q_lora_rank=48, kv_lora_rank=32, rope_head_dim=16,
        fsdp=False, remat="none",
    )
