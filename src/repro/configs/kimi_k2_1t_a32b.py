"""kimi-k2-1t-a32b — trillion-parameter MoE [arXiv:2501 Kimi K2 paper table].

61L, d_model=7168, 64H (GQA kv=8), vocab=163840; MoE: 384 routed experts
(top-8, expert d_ff=2048) + 1 shared expert; first layer dense (d_ff=18432).
Pure full attention -> long_500k cell skipped.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,  # d_model / n_heads
    d_ff=18432,  # the single leading dense layer
    vocab=163840,
    n_experts=384,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=1,
    rope_theta=50_000.0,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, n_experts=8, experts_per_token=2,
        n_shared_experts=1, moe_d_ff=64, first_dense_layers=1,
        fsdp=False, remat="none",
    )
