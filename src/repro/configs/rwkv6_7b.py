"""rwkv6-7b — Finch: attention-free, data-dependent decay [arXiv:2404.05892].

32L, d_model=4096, d_ff=14336 (= 3.5*d channel-mix hidden), vocab=65536.
Sub-quadratic: runs the long_500k cell (O(1)-state decode).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads = d_model / ssm_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    ssm_head_dim=64,
    ssm_chunk=64,
    wkv_lora=64,
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=448,
        vocab=512, ssm_head_dim=32, wkv_lora=8, ssm_chunk=16,
        head_dim=32, fsdp=False, remat="none",
    )
