"""qwen2-1.5b — GQA with QKV bias [arXiv:2407.10671].

28L, d_model=1536, 12H (GQA kv=2), d_ff=8960, vocab=151936.
Note: 12 heads do not divide the 16-way model axis -> attention weights are
replicated under TP (only the MLP shards); see DESIGN.md.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_ff=192,
        vocab=512, head_dim=32, remat="none",
    )
