"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242].

38L mamba2 (ssm_state=64) with one weight-shared attention+MLP block applied
after every 6 mamba layers; d_model=2048, 32H (kv=32), d_ff=8192, vocab=32000.
Hybrid -> runs the long_500k cell (O(1)-state decode).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=64,
    attn_every=6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
        attn_every=3, remat="none",
    )
