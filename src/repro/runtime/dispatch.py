"""Host->device dispatch accounting for the build path.

A "dispatch" here is one invocation of a jitted executable from Python at an
instrumented call site — the unit the device-resident build pipeline
collapses (a Python chunk loop issues one dispatch per chunk per round; the
fused pipeline issues one for the whole build). Eager jnp ops between jitted
calls dispatch op-by-op and are NOT counted, so legacy-path numbers are a
*lower bound* and the pipeline/legacy ratio reported in BENCH_build.json is
conservative.

The counters are series in the process-wide metrics registry
(``repro.obs.metrics.GLOBAL``), so the serving exposition and the benches
read the same numbers this module's accessors report.

Usage:
    with dispatch.track() as t:
        build_index(...)
    t.count  # dispatches issued inside the block
"""

from __future__ import annotations

import contextlib

from repro.obs.metrics import GLOBAL as _OBS

_DISPATCHES = _OBS.counter(
    "allanpoe_runtime_dispatches_total",
    "jitted-executable launches at instrumented build-path call sites",
)
_BUILD_ROWS = _OBS.counter(
    "allanpoe_runtime_build_rows_total",
    "corpus rows fed through graph (re)construction",
)


def tick(n: int = 1) -> None:
    """Record ``n`` jitted-executable launches (called at instrumented sites)."""
    _DISPATCHES.inc(n)


def count() -> int:
    return int(_DISPATCHES.total())


def build_rows_tick(n: int) -> None:
    """Record ``n`` corpus rows entering a graph (re)build — the work measure
    incremental compaction is gated on: ``compact_incremental`` must grow
    this by O(grow segment), a full ``seal_and_compact`` by O(corpus)."""
    _BUILD_ROWS.inc(int(n))


def build_rows() -> int:
    """Total corpus rows fed through graph construction so far."""
    return int(_BUILD_ROWS.total())


def reset() -> None:
    _DISPATCHES.reset()
    _BUILD_ROWS.reset()


class _Tracker:
    def __init__(self, start: int):
        self._start = start
        self._stop: int | None = None

    def freeze(self, stop: int) -> None:
        self._stop = stop

    @property
    def count(self) -> int:
        return (count() if self._stop is None else self._stop) - self._start


@contextlib.contextmanager
def track():
    """Context manager counting dispatches issued inside the block."""
    t = _Tracker(count())
    try:
        yield t
    finally:
        t.freeze(count())
