"""Fault tolerance for 1000+-node runs: straggler detection, elastic mesh
recovery, and a supervised step-driver with checkpoint/restart.

On a real cluster the failure signals come from collective timeouts and the
coordinator's heartbeat service; in this container they are injected by
tests (`FailureInjector`). The recovery *logic* — detect, shrink the mesh,
reshard from the last committed checkpoint, deterministically skip data — is
identical and fully exercised.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np



# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------


class StragglerMonitor:
    """Moving-percentile step-time detector.

    At scale, per-host step times are all-gathered each K steps (a tiny
    collective); any host slower than `threshold` x p50 over the window is
    flagged for preemptive replacement — the standard mitigation for fail-slow
    HBM/ICI degradation."""

    def __init__(self, window: int = 32, threshold: float = 1.8):
        self.window = window
        self.threshold = threshold
        self._times: dict[int, deque] = {}

    def record(self, host_id: int, step_time_s: float):
        self._times.setdefault(host_id, deque(maxlen=self.window)).append(step_time_s)

    def p50(self) -> float:
        all_t = [t for d in self._times.values() for t in d]
        return float(np.median(all_t)) if all_t else 0.0

    def stragglers(self) -> list[int]:
        p50 = self.p50()
        if p50 <= 0:
            return []
        out = []
        for host, d in self._times.items():
            if len(d) >= max(4, self.window // 4) and float(np.median(d)) > self.threshold * p50:
                out.append(host)
        return out


# ---------------------------------------------------------------------------
# elastic mesh
# ---------------------------------------------------------------------------


def elastic_mesh_shape(
    n_devices: int, model_parallel: int, *, pod_size: Optional[int] = None
) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest valid production mesh for the surviving device count.

    Keeps the model axis intact (TP cannot shrink without resharding the
    layer math) and gives the rest to (pod, data)."""
    assert n_devices % model_parallel == 0, "surviving devices must cover TP"
    rest = n_devices // model_parallel
    if pod_size and rest % pod_size == 0 and rest // pod_size > 1:
        return ((rest // pod_size, pod_size, model_parallel), ("pod", "data", "model"))
    return ((rest, model_parallel), ("data", "model"))


# ---------------------------------------------------------------------------
# supervised training driver
# ---------------------------------------------------------------------------


class FailureInjector:
    """Test hook: raise at chosen steps to simulate node loss."""

    def __init__(self, fail_at: Optional[set[int]] = None):
        self.fail_at = fail_at or set()
        self.failed: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    losses: list
    straggler_events: int


def run_supervised(
    *,
    n_steps: int,
    make_state: Callable[[], dict],
    train_step: Callable,
    batch_fn: Callable[[int], dict],
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    injector: Optional[FailureInjector] = None,
    monitor: Optional[StragglerMonitor] = None,
) -> RunReport:
    """Checkpoint/restart driver: crashes roll back to the last committed
    checkpoint and resume with deterministic data skip."""
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    restarts = 0
    losses: list = []
    straggler_events = 0

    while True:
        state = make_state()
        start = latest_step(ckpt_dir)
        if start is not None:
            state = restore_checkpoint(ckpt_dir, start, state)
            step = start
        else:
            step = 0
        try:
            while step < n_steps:
                t0 = time.perf_counter()
                if injector is not None:
                    injector.maybe_fail(step)
                state, metrics = train_step(state, batch_fn(step))
                dt = time.perf_counter() - t0
                if monitor is not None:
                    monitor.record(0, dt)
                    if monitor.stragglers():
                        straggler_events += 1
                losses.append(float(metrics["loss"]))
                step += 1
                if step % ckpt_every == 0 or step == n_steps:
                    save_checkpoint(ckpt_dir, step, state)
            return RunReport(step, restarts, losses, straggler_events)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            continue
