"""Pallas TPU kernel fusing the hybrid distance with top-k selection.

The unfused hot path (kernels/hybrid_distance.py + a separate
``jax.lax.top_k``) ships the full ``(B, C)`` score matrix back to HBM between
the distance kernel and the selection — the candidate round-trip the paper's
warp-level kernel avoids by selecting in registers. This kernel keeps a
running per-query top-k *inside* the grid row:

  * grid = (B, C // C_TILE), candidate-tile axis innermost, so all of one
    query's tiles run back-to-back;
  * the distance tile is computed exactly as in ``_hybrid_distance_kernel``
    (MXU matvec for the dense path, nnz-major vectorized ELL intersection
    for the two sparse paths), then biased and validity-masked in place;
  * the ``(1, K_PAD)`` output blocks are pinned per grid row (their index
    map ignores the tile coordinate), so Mosaic keeps them VMEM-resident
    across a row's tiles — they double as the top-k accumulator: initialized
    at tile 0, merged with each tile's scores, written back to HBM only
    once per row. Nothing of size C ever leaves the kernel;
  * K is padded to ``K_PAD`` (a multiple of the 128-lane tile) so the
    accumulator is lane-aligned; only the first ``k`` slots are live, the
    rest stay at (NEG, PAD_IDX) and are sliced off by the wrapper;
  * selection payloads are candidate *positions* (j * C_TILE + lane), not
    ids: the caller holds the id list plus any per-candidate metadata
    (entity/hop state in the beam search) and gathers everything from the
    ``(B, k)`` position output — the kernel stays metadata-free;
  * multi-node batching falls out of the layout: the caller stacks an
    entire expansion round (all ``expand`` nodes' neighbor lists) into one
    candidate axis, so the pinned query block amortizes over every node's
    tiles in a single kernel invocation.

The merge itself is ``k`` unrolled max-extraction steps over the
``(1, K_PAD + C_TILE)`` concatenation of the accumulator and the current
tile: each step takes the max, records (value, position-payload) into lane
``t`` via a masked select, and retires the winning lane. That is k * O(few)
VPU ops per tile — noise next to the MXU matvec — and needs no sort network
or data-dependent control flow. Ties resolve to the lowest position (the
same preference as ``lax.top_k``), so fused and oracle agree up to the order
of equal scores.

Padding contract (shared with hybrid_distance.py): ELL slots with
idx == PAD_IDX carry val == 0; candidate slots with id PAD_IDX (and any
wrapper-added C padding) score exactly NEG and can never be selected while a
live candidate remains.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hybrid_distance import DEFAULT_C_TILE

NEG = -1e30  # matches core.search.NEG: "no candidate" score sentinel
PAD_IDX = -1  # matches core.usms.PAD_IDX (not imported: kernels stay leaf)
K_LANE = 128  # TPU lane tile: the accumulator width granularity


def k_pad(k: int) -> int:
    """K rounded up to the 128-lane tile (the accumulator lane rule)."""
    if k <= 0:
        raise ValueError(f"top-k needs k >= 1, got {k}")
    return -(-k // K_LANE) * K_LANE


def _distance_tile(qd_ref, qsi_ref, qsv_ref, qfi_ref, qfv_ref,
                   cd_ref, csi_ref, csv_ref, cfi_ref, cfv_ref,
                   cscale_ref=None):
    """One (1, C_TILE) hybrid-distance tile — identical math to
    ``hybrid_distance._hybrid_distance_kernel``. A non-None ``cscale_ref``
    dequantizes int8 dense rows by the per-candidate scale after the MXU
    matvec (one VPU multiply per candidate)."""
    f32 = jnp.float32
    qd = qd_ref[...].astype(f32)  # (1, Dd)
    cd = cd_ref[0].astype(f32)  # (C_TILE, Dd)
    acc = jax.lax.dot_general(
        qd, cd, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )  # (1, C_TILE)
    if cscale_ref is not None:
        acc = acc * cscale_ref[...].astype(f32)  # dequant-in-tile

    def sparse_accumulate(acc, qi_ref, qv_ref, ci_ref, cv_ref):
        qi = qi_ref[...]  # (1, P) int32
        qv = qv_ref[...].astype(f32)  # (1, P)
        ci = ci_ref[0]  # (P, C_TILE) int32
        cv = cv_ref[0].astype(f32)  # (P, C_TILE)
        for j in range(qi.shape[-1]):  # static unroll over query nnz slots
            match = ci == qi[0, j]
            contrib = jnp.where(match, cv, 0.0)
            acc = acc + jnp.sum(contrib, axis=0, keepdims=True) * qv[0, j]
        return acc

    acc = sparse_accumulate(acc, qsi_ref, qsv_ref, csi_ref, csv_ref)
    return sparse_accumulate(acc, qfi_ref, qfv_ref, cfi_ref, cfv_ref)


def _merge_topk_lanes(acc_s, acc_i, tile_s, tile_i, k: int):
    """Merge a (1, K_PAD) running top-k with a (1, C_TILE) tile: k unrolled
    max-extraction steps over the lane-axis concatenation. Returns the new
    (1, K_PAD) accumulator (slots >= k stay at NEG / PAD_IDX)."""
    kp = acc_s.shape[-1]
    comb_s = jnp.concatenate([acc_s, tile_s], axis=-1)  # (1, K_PAD + C_TILE)
    comb_i = jnp.concatenate([acc_i, tile_i], axis=-1)
    m_total = comb_s.shape[-1]
    miota = jax.lax.broadcasted_iota(jnp.int32, comb_s.shape, 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, acc_s.shape, 1)
    res_s = jnp.full(acc_s.shape, NEG, jnp.float32)
    res_i = jnp.full(acc_i.shape, PAD_IDX, jnp.int32)
    for t in range(min(k, kp)):
        m = jnp.max(comb_s, axis=-1, keepdims=True)  # (1, 1)
        # lowest position achieving the max: lax.top_k's tie preference
        hit = (comb_s == m) & (comb_s > NEG)
        pos = jnp.min(jnp.where(hit, miota, m_total), axis=-1, keepdims=True)
        win = miota == pos  # at most one lane
        payload = jnp.sum(
            jnp.where(win, comb_i, 0), axis=-1, keepdims=True
        )
        res_s = jnp.where(lane == t, m, res_s)
        res_i = jnp.where((lane == t) & (m > NEG), payload, res_i)
        comb_s = jnp.where(win, NEG, comb_s)  # retire the winner
    return res_s, res_i


def _make_fused_topk_kernel(k: int, c_tile: int, has_bias: bool,
                            has_scale: bool = False):
    def kernel(*refs):
        refs = list(refs)
        qd, qsi, qsv, qfi, qfv, cd, csi, csv, cfi, cfv, cid_ref = refs[:11]
        rest = refs[11:]
        bias_ref = rest.pop(0) if has_bias else None
        cscale_ref = rest.pop(0) if has_scale else None
        out_s_ref, out_i_ref = rest
        j = pl.program_id(1)

        # the output blocks are this row's accumulator (index map pins them
        # per grid row): seed them on the row's first tile
        @pl.when(j == 0)
        def _init():
            out_s_ref[...] = jnp.full(out_s_ref.shape, NEG, jnp.float32)
            out_i_ref[...] = jnp.full(out_i_ref.shape, PAD_IDX, jnp.int32)

        scores = _distance_tile(qd, qsi, qsv, qfi, qfv, cd, csi, csv,
                                cfi, cfv, cscale_ref)
        if bias_ref is not None:
            scores = scores + bias_ref[...].astype(jnp.float32)
        cids = cid_ref[...]  # (1, C_TILE) candidate ids (validity only)
        scores = jnp.where(cids >= 0, scores, NEG)
        tile_pos = j * c_tile + jax.lax.broadcasted_iota(
            jnp.int32, scores.shape, 1
        )
        new_s, new_i = _merge_topk_lanes(
            out_s_ref[...], out_i_ref[...], scores, tile_pos, k
        )
        out_s_ref[...] = new_s
        out_i_ref[...] = new_i

    return kernel


def fused_topk_pallas(
    qd: jax.Array,  # (B, Dd)
    qsi: jax.Array,  # (B, Ps) int32
    qsv: jax.Array,  # (B, Ps)
    qfi: jax.Array,  # (B, Pf) int32
    qfv: jax.Array,  # (B, Pf)
    cd: jax.Array,  # (B, C, Dd)
    csi: jax.Array,  # (B, Ps, C)  nnz-major
    csv: jax.Array,  # (B, Ps, C)
    cfi: jax.Array,  # (B, Pf, C)
    cfv: jax.Array,  # (B, Pf, C)
    cid: jax.Array,  # (B, C) int32 candidate ids (PAD_IDX = invalid slot)
    bias: jax.Array | None,  # (B, C) f32 per-candidate score bias, or None
    cscale: jax.Array | None = None,  # (B, C) f32 per-candidate dense scale
    *,
    k: int,
    c_tile: int = DEFAULT_C_TILE,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call wrapper. C must be a multiple of c_tile (callers pad).

    When ``cscale`` is given, ``cd`` holds int8 rows and the dense matvec is
    dequantized in-tile by the per-candidate scale.

    Returns ``(scores, positions)`` of shape (B, K_PAD): per query the top-k
    candidate scores (descending) and their positions along the C axis.
    Slots beyond k — and slots with no live candidate — hold (NEG, PAD_IDX).
    """
    b, dd = qd.shape
    _, ps = qsi.shape
    _, pf = qfi.shape
    c = cd.shape[1]
    assert c % c_tile == 0, f"C={c} not a multiple of c_tile={c_tile}"
    kp = k_pad(k)
    grid = (b, c // c_tile)

    q_row = lambda i, j: (i, 0)
    cand3 = lambda i, j: (i, 0, j)
    dense3 = lambda i, j: (i, j, 0)
    crow = lambda i, j: (i, j)

    in_specs = [
        pl.BlockSpec((1, dd), q_row),
        pl.BlockSpec((1, ps), q_row),
        pl.BlockSpec((1, ps), q_row),
        pl.BlockSpec((1, pf), q_row),
        pl.BlockSpec((1, pf), q_row),
        pl.BlockSpec((1, c_tile, dd), dense3),
        pl.BlockSpec((1, ps, c_tile), cand3),
        pl.BlockSpec((1, ps, c_tile), cand3),
        pl.BlockSpec((1, pf, c_tile), cand3),
        pl.BlockSpec((1, pf, c_tile), cand3),
        pl.BlockSpec((1, c_tile), crow),
    ]
    args = [qd, qsi, qsv, qfi, qfv, cd, csi, csv, cfi, cfv, cid]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, c_tile), crow))
        args.append(bias)
    if cscale is not None:
        in_specs.append(pl.BlockSpec((1, c_tile), crow))
        args.append(cscale)

    return pl.pallas_call(
        _make_fused_topk_kernel(k, c_tile, bias is not None,
                                cscale is not None),
        grid=grid,
        in_specs=in_specs,
        # both outputs pinned per grid row -> VMEM-resident accumulators
        out_specs=[
            pl.BlockSpec((1, kp), q_row),
            pl.BlockSpec((1, kp), q_row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
