"""Jitted public wrappers around the hybrid distance kernels.

``hybrid_scores``           — (B queries) x (B, C candidate rows) -> (B, C)
``hybrid_scores_vs_ids``    — gather candidate ids from a corpus, score, mask
``fused_topk`` / ``_vs_ids``— distance + in-kernel top-k selection: (B, k)
                              scores + candidate positions, no (B, C) output
``pairwise_scores_chunked`` — brute-force (N x M) scoring in memory-bounded
                              chunks (ground truth / rerank)

Every wrapper takes ``use_kernel: bool | None``. ``None`` (the default at
the config layer) resolves by backend: Pallas on TPU, the jnp oracle on CPU
— the same call sites serve both. An explicit ``True`` on CPU runs the
kernel in interpret mode (tests use this for kernel/oracle equality);
explicit ``False`` forces the oracle anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    QuantizedFusedVectors,
    SparseVec,
)
from repro.kernels import ref
from repro.kernels.fused_topk import NEG as NEG  # re-export: callers mask on it
from repro.kernels.fused_topk import fused_topk_pallas
from repro.kernels.hybrid_distance import DEFAULT_C_TILE, hybrid_distance_pallas
from repro.kernels.pairwise_tile import pairwise_tile_pallas
from repro.runtime import dispatch


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_use_kernel(use_kernel: bool | None) -> bool:
    """Backend-auto kernel dispatch: ``None`` -> Pallas iff not on CPU.

    Config dataclasses (``SearchParams``, ``KnnConfig``, ``PruneConfig``)
    default ``use_kernel`` to ``None``; resolving to a concrete bool happens
    once, at construction/entry time, so jit cache keys and the serving AOT
    executable-cache key always see a pinned kernel mode.
    """
    if use_kernel is None:
        return not _on_cpu()
    return bool(use_kernel)


def _pad_candidates(cands: FusedVectors, c_tile: int) -> tuple[FusedVectors, int]:
    c = cands.dense.shape[1]
    c_pad = (-c) % c_tile
    if c_pad == 0:
        return cands, c
    pad3 = lambda a: jnp.pad(a, ((0, 0), (0, c_pad), (0, 0)))
    padi = lambda a: jnp.pad(a, ((0, 0), (0, c_pad), (0, 0)), constant_values=PAD_IDX)
    return (
        FusedVectors(
            pad3(cands.dense),
            SparseVec(padi(cands.learned.idx), pad3(cands.learned.val)),
            SparseVec(padi(cands.lexical.idx), pad3(cands.lexical.val)),
        ),
        c,
    )


def _pad_candidates_q(
    cands: QuantizedFusedVectors, c_tile: int
) -> tuple[QuantizedFusedVectors, int]:
    """Quantized twin of ``_pad_candidates``: int8 padding rows are 0 with
    scale 0.0, so padded dense scores are exactly 0 before masking."""
    c = cands.dense_q.shape[1]
    c_pad = (-c) % c_tile
    if c_pad == 0:
        return cands, c
    pad3 = lambda a: jnp.pad(a, ((0, 0), (0, c_pad), (0, 0)))
    pad2 = lambda a: jnp.pad(a, ((0, 0), (0, c_pad)))
    padi = lambda a: jnp.pad(a, ((0, 0), (0, c_pad), (0, 0)), constant_values=PAD_IDX)
    return (
        QuantizedFusedVectors(
            pad3(cands.dense_q),
            pad2(cands.dense_scale),
            SparseVec(padi(cands.learned.idx), pad3(cands.learned.val)),
            SparseVec(padi(cands.lexical.idx), pad3(cands.lexical.val)),
        ),
        c,
    )


@functools.partial(jax.jit, static_argnames=("c_tile", "use_kernel", "interpret"))
def hybrid_scores(
    q: FusedVectors,
    cands: FusedVectors,
    *,
    c_tile: int = DEFAULT_C_TILE,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Score B queries against their (B, C, ...) candidate rows -> (B, C) f32.

    Weights must already be folded into ``q`` (usms.weighted_query).
    ``cands`` may be quantized storage (``QuantizedFusedVectors``) — the
    corpus dtype is a trace-time pytree property, never traced data.
    """
    quantized = isinstance(cands, QuantizedFusedVectors)
    if not resolve_use_kernel(use_kernel):
        if quantized:
            return ref.hybrid_scores_quant_ref(q, cands)
        return ref.hybrid_scores_ref(q, cands)
    if interpret is None:
        interpret = _on_cpu()
    if quantized:
        cands, c_orig = _pad_candidates_q(cands, c_tile)
        cd, cscale = cands.dense_q, cands.dense_scale
    else:
        cands, c_orig = _pad_candidates(cands, c_tile)
        cd, cscale = cands.dense, None
    # nnz-major candidate layout for the kernel (see hybrid_distance.py).
    csi = jnp.swapaxes(cands.learned.idx, 1, 2)
    csv = jnp.swapaxes(cands.learned.val, 1, 2)
    cfi = jnp.swapaxes(cands.lexical.idx, 1, 2)
    cfv = jnp.swapaxes(cands.lexical.val, 1, 2)
    out = hybrid_distance_pallas(
        q.dense,
        q.learned.idx,
        q.learned.val,
        q.lexical.idx,
        q.lexical.val,
        cd,
        csi,
        csv,
        cfi,
        cfv,
        cscale,
        c_tile=c_tile,
        interpret=interpret,
    )
    return out[:, :c_orig]


@functools.partial(jax.jit, static_argnames=("c_tile", "use_kernel"))
def hybrid_scores_vs_ids(
    q: FusedVectors,
    corpus: FusedVectors,
    ids: jax.Array,  # (B, C) int32, PAD_IDX entries masked to -inf
    *,
    c_tile: int = DEFAULT_C_TILE,
    use_kernel: bool | None = None,
) -> jax.Array:
    flat = ids.reshape(-1)
    rows = corpus.take(flat)
    cands = jax.tree.map(
        lambda a: a.reshape(ids.shape + a.shape[1:]), rows
    )
    scores = hybrid_scores(q, cands, c_tile=c_tile, use_kernel=use_kernel)
    return jnp.where(ids >= 0, scores, -jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("k", "c_tile", "use_kernel", "interpret")
)
def fused_topk(
    q: FusedVectors,
    cands: FusedVectors,
    cid: jax.Array,  # (B, C) int32 candidate ids; PAD_IDX slots invalid
    k: int,
    *,
    bias: jax.Array | None = None,  # (B, C) f32 pre-selection score bias
    c_tile: int = DEFAULT_C_TILE,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused distance + top-k: score candidate rows, select in-kernel.

    Returns ``(scores, positions)`` of shape (B, k) — the per-query top-k
    biased hybrid scores (descending) and the positions along the C axis
    they came from. The ``(B, C)`` score matrix never reaches HBM on the
    kernel path. Invalid slots (PAD candidates, k beyond the live count)
    hold ``(NEG, PAD_IDX)``; ``bias`` must be finite (mask via PAD ids, not
    via bias). Tie order matches ``lax.top_k`` (lowest position wins).
    """
    quantized = isinstance(cands, QuantizedFusedVectors)
    if not resolve_use_kernel(use_kernel):
        if quantized:
            return ref.fused_topk_quant_ref(q, cands, cid, bias, k)
        return ref.fused_topk_ref(q, cands, cid, bias, k)
    if interpret is None:
        interpret = _on_cpu()
    if quantized:
        cands, c_orig = _pad_candidates_q(cands, c_tile)
        cd, cscale = cands.dense_q, cands.dense_scale
    else:
        cands, c_orig = _pad_candidates(cands, c_tile)
        cd, cscale = cands.dense, None
    c_padded = cd.shape[1]
    if c_padded != c_orig:
        grow = ((0, 0), (0, c_padded - c_orig))
        cid = jnp.pad(cid, grow, constant_values=PAD_IDX)
        if bias is not None:
            bias = jnp.pad(bias, grow)
    csi = jnp.swapaxes(cands.learned.idx, 1, 2)
    csv = jnp.swapaxes(cands.learned.val, 1, 2)
    cfi = jnp.swapaxes(cands.lexical.idx, 1, 2)
    cfv = jnp.swapaxes(cands.lexical.val, 1, 2)
    out_s, out_i = fused_topk_pallas(
        q.dense,
        q.learned.idx,
        q.learned.val,
        q.lexical.idx,
        q.lexical.val,
        cd,
        csi,
        csv,
        cfi,
        cfv,
        cid.astype(jnp.int32),
        None if bias is None else bias.astype(jnp.float32),
        cscale,
        k=k,
        c_tile=c_tile,
        interpret=interpret,
    )
    return out_s[:, :k], out_i[:, :k]


@functools.partial(
    jax.jit, static_argnames=("k", "c_tile", "use_kernel", "interpret")
)
def fused_topk_vs_ids(
    q: FusedVectors,
    corpus: FusedVectors,
    ids: jax.Array,  # (B, C) int32 candidate ids into the corpus
    k: int,
    *,
    bias: jax.Array | None = None,
    c_tile: int = DEFAULT_C_TILE,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Gather candidate rows by id, then fused distance + top-k selection.

    The round's expansion consumer: callers stack a whole round's neighbor
    lists into the C axis (multi-node batching) and gather ids plus any
    per-candidate metadata from the returned positions via ``take_topk``.
    """
    flat = ids.reshape(-1)
    rows = corpus.take(flat)
    cands = jax.tree.map(lambda a: a.reshape(ids.shape + a.shape[1:]), rows)
    return fused_topk(
        q, cands, ids, k,
        bias=bias, c_tile=c_tile, use_kernel=use_kernel, interpret=interpret,
    )


def take_topk(values: jax.Array, pos: jax.Array, fill) -> jax.Array:
    """Gather per-candidate values at fused-top-k positions (PAD -> fill).

    ``values``: (..., C) aligned with the candidate axis the positions were
    selected over; ``pos``: (..., k) from ``fused_topk*``.
    """
    got = jnp.take_along_axis(
        values, jnp.clip(pos, 0, values.shape[-1] - 1), axis=-1
    )
    return jnp.where(pos >= 0, got, fill)


def take_topk_ids(ids: jax.Array, pos: jax.Array) -> jax.Array:
    """Resolve fused-top-k positions back to candidate ids (PAD -> PAD_IDX)."""
    return take_topk(ids, pos, PAD_IDX)


def pairwise_tile_scores(
    tile: FusedVectors,  # (C, K, ...) gathered candidate rows
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """All-pairs hybrid scores within each node's candidate tile -> (C, K, K).

    out[c, i, j] = score(tile[c, i], tile[c, j]). Rows are gathered once by
    the caller (no per-pair re-gather); invalid-candidate masking stays with
    the caller, which holds the id list.
    """
    if not resolve_use_kernel(use_kernel):
        return ref.pairwise_tile_ref(tile)
    if interpret is None:
        interpret = _on_cpu()
    return pairwise_tile_pallas(
        tile.dense,
        tile.learned.idx,
        tile.learned.val,
        tile.lexical.idx,
        tile.lexical.val,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def _pairwise_scores_mapped(
    queries: FusedVectors, corpus: FusedVectors, chunk: int
) -> jax.Array:
    """In-trace corpus-chunked brute force: lax.map over corpus blocks, so
    ground-truth / rerank scoring is one dispatch regardless of corpus size
    while peak memory stays bounded by one (Nq, chunk) block."""
    n = corpus.dense.shape[0]
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        corpus = jax.tree.map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]
            ),
            corpus,
        )
    blocks = jax.tree.map(
        lambda a: a.reshape((-1, chunk) + a.shape[1:]), corpus
    )
    outs = jax.lax.map(
        lambda blk: ref.pairwise_hybrid_scores_ref(queries, blk), blocks
    )  # (n_blocks, Nq, chunk)
    out = jnp.moveaxis(outs, 0, 1).reshape(queries.dense.shape[0], -1)
    return out[:, :n]


def pairwise_scores_chunked(
    queries: FusedVectors,
    corpus: FusedVectors,
    *,
    chunk: int = 4096,
) -> jax.Array:
    """Brute-force (Nq, Ncorpus) hybrid scores, chunked over the corpus.

    Oracle path (jnp); used for ground truth and exact rerank. The chunk
    loop runs in-trace (lax.map), so this is a single dispatch.
    """
    dispatch.tick()
    return _pairwise_scores_mapped(queries, corpus, chunk)


def topk_hybrid(
    queries: FusedVectors,
    corpus: FusedVectors,
    k: int,
    *,
    chunk: int = 4096,
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k by brute force (ground truth). Returns (scores, ids)."""
    scores = pairwise_scores_chunked(queries, corpus, chunk=chunk)
    top, idx = jax.lax.top_k(scores, k)
    return top, idx.astype(jnp.int32)
