"""Pallas TPU flash attention (fwd + bwd) — beyond-paper optimization for
the serving/training stack (DESIGN.md §Perf).

The naive attention materializes the (L, S) score matrix in HBM — the
dominant roofline memory term for every attention arch at seq 4k-32k. This
kernel streams K/V tiles through VMEM with the online-softmax recurrence, so
HBM traffic drops from O(L·S) to O(L·d + S·d) per head.

Supports GQA (kv-head index derived in the BlockSpec index_map — no K/V
repetition in HBM), causal or full masking, and distinct K/V head dims (for
MLA's 192/128 split). Backward = two kernels (dq; dkv) recomputing P from
the saved (out, lse) — the standard FlashAttention-2 structure.

Validated in interpret mode against ``ref_attention`` (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# reference oracle
# ---------------------------------------------------------------------------


def ref_attention(q, k, v, *, causal: bool, sm_scale: float | None = None):
    """q: (B, H, L, dk); k: (B, KV, S, dk); v: (B, KV, S, dv)."""
    b, h, l, dk = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    scale = sm_scale if sm_scale is not None else dk**-0.5
    qg = q.reshape(b, kvh, g, l, dk).astype(jnp.float32)
    scores = jnp.einsum("bkgld,bksd->bkgls", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((l, s), bool), k=s - l)
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgls,bksd->bkgld", w, v.astype(jnp.float32))
    return out.reshape(b, h, l, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_s, l_s, acc_s,
                *, sm_scale, causal, block_q, block_k, n_k):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dk)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dk)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_s[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0].astype(jnp.float32)  # (bk, dv)
        pv = jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_s[...] = acc_s[...] * alpha + pv
        m_s[...] = m_new
        l_s[...] = l_new

    if causal:
        # skip fully-masked tiles (kv block strictly above the diagonal)
        @pl.when(ik * block_k <= iq * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == n_k - 1)
    def _finish():
        l_fin = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / l_fin).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_s[...] + jnp.log(l_fin))[:, 0]


def _flash_fwd(q, k, v, *, causal, sm_scale, block_q, block_k, interpret):
    b, h, l, dk = q.shape
    kvh, s_len, dv = k.shape[1], k.shape[2], v.shape[3]
    g = h // kvh
    block_q = min(block_q, l)
    block_k = min(block_k, s_len)
    n_q = pl.cdiv(l, block_q)
    n_k = pl.cdiv(s_len, block_k)
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm_scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k,
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dk), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dk), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, dv), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, dv), q.dtype),
            jax.ShapeDtypeStruct((b, h, l), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_s, *, sm_scale, causal, block_q, block_k, n_k):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_s[...] = jnp.zeros_like(dq_s)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)  # (bq, dv)
        lse = lse_ref[0, 0]  # (bq,)
        delta = delta_ref[0, 0]  # (bq,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_s[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    if causal:
        @pl.when(ik * block_k <= iq * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ik == n_k - 1)
    def _finish():
        dq_ref[0, 0] = dq_s[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_s, dv_s,
                    *, sm_scale, causal, block_q, block_k, n_inner, g):
    inner = pl.program_id(3)  # enumerates (group_head, q_block)
    ik = pl.program_id(2)
    n_q = n_inner // g

    @pl.when(inner == 0)
    def _init():
        dk_s[...] = jnp.zeros_like(dk_s)
        dv_s[...] = jnp.zeros_like(dv_s)

    iq = inner % n_q

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dk)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dk)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv_s[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bk, dv)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_s[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bk, dk)

    if causal:
        @pl.when(ik * block_k <= iq * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(inner == n_inner - 1)
    def _finish():
        dk_ref[0, 0] = dk_s[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_s[...].astype(dv_ref.dtype)


def _flash_bwd(res, dout, *, causal, sm_scale, block_q, block_k, interpret):
    q, k, v, out, lse = res
    b, h, l, dk = q.shape
    kvh, s_len, dv = k.shape[1], k.shape[2], v.shape[3]
    g = h // kvh
    block_q = min(block_q, l)
    block_k = min(block_k, s_len)
    n_q = pl.cdiv(l, block_q)
    n_k = pl.cdiv(s_len, block_k)

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, n_k=n_k,
        ),
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dk), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dk), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_q, dv), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, block_q), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dk), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, dk), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    n_inner = g * n_q
    dkv_spec_q = pl.BlockSpec(
        (1, 1, block_q, dk),
        lambda ib, ikv, ik, inner, n_q=n_q, g=g: (ib, ikv * g + inner // n_q, inner % n_q, 0),
    )
    dkv_spec_dv = pl.BlockSpec(
        (1, 1, block_q, dv),
        lambda ib, ikv, ik, inner, n_q=n_q, g=g: (ib, ikv * g + inner // n_q, inner % n_q, 0),
    )
    dkv_spec_lse = pl.BlockSpec(
        (1, 1, block_q),
        lambda ib, ikv, ik, inner, n_q=n_q, g=g: (ib, ikv * g + inner // n_q, inner % n_q),
    )
    dk_out, dv_out = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel, sm_scale=sm_scale, causal=causal,
            block_q=block_q, block_k=block_k, n_inner=n_inner, g=g,
        ),
        grid=(b, kvh, n_k, n_inner),
        in_specs=[
            dkv_spec_q,
            pl.BlockSpec((1, 1, block_k, dk), lambda ib, ikv, ik, inner: (ib, ikv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda ib, ikv, ik, inner: (ib, ikv, ik, 0)),
            dkv_spec_dv,
            dkv_spec_lse,
            dkv_spec_lse,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, dk), lambda ib, ikv, ik, inner: (ib, ikv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda ib, ikv, ik, inner: (ib, ikv, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, dk), jnp.float32),
            pltpu.VMEM((block_k, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk_out, dv_out


# ---------------------------------------------------------------------------
# public API with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(
    q, k, v, causal=True, sm_scale=None,
    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K, interpret=False,
):
    """q: (B, H, L, dk); k: (B, KV, S, dk); v: (B, KV, S, dv) -> (B, H, L, dv)."""
    scale = sm_scale if sm_scale is not None else q.shape[-1] ** -0.5
    out, _ = _flash_fwd(
        q, k, v, causal=causal, sm_scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out


def _vjp_fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    scale = sm_scale if sm_scale is not None else q.shape[-1] ** -0.5
    out, lse = _flash_fwd(
        q, k, v, causal=causal, sm_scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, sm_scale, block_q, block_k, interpret, res, dout):
    q = res[0]
    scale = sm_scale if sm_scale is not None else q.shape[-1] ** -0.5
    dq, dk, dv = _flash_bwd(
        res, dout, causal=causal, sm_scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
