"""Pallas TPU kernel for the hybrid distance computation (paper §4.1 Step 1).

The paper assigns one GPU *warp* per (query, candidate) distance: threads do
vectorized float4 loads for the dense part and per-thread binary search over
CSR for the sparse intersection, with warp-shuffle reductions.

TPU has no warps, no shuffles, and hates data-dependent scalar loads, so the
kernel is re-derived for the MXU/VPU + VMEM hierarchy:

  * one grid cell = (one query) x (one C_TILE-wide tile of its candidates);
  * dense part: a (1, Dd) x (C_TILE, Dd) MXU matvec -> (1, C_TILE);
  * sparse part: fixed-nnz ELL vectors; the candidate tile is stored
    **nnz-major** (P, C_TILE) so every per-query-term step is a vectorized
    (P, C_TILE) equality-compare + masked multiply-accumulate whose reduction
    lands on the sublane axis — no transposes, no gathers, no branches;
  * the query block (dense + sparse idx/val) is VMEM-resident across all of
    its candidate tiles (BlockSpec index_map pins it per grid row) — the TPU
    analogue of the paper's shared-memory caching of the explored node.

Padding contract: ELL slots with idx == PAD_IDX carry val == 0, so padded
slots contribute exactly 0 without validity masks (query-side -1 can only
match candidate-side -1, whose value is 0).

Path weights are folded into the query beforehand (Theorem 1), making the
kernel weight-free and therefore reusable for any path combination.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_C_TILE = 128


def _make_hybrid_distance_kernel(has_scale: bool):
    """Build the distance kernel, optionally with a per-candidate dense
    dequantization scale (int8 corpus storage). The int8 rows ride the MXU
    as-is; the fp32 scale multiplies the (1, C_TILE) matvec *output*, so
    dequantization costs one VPU multiply per candidate instead of Dd."""

    def kernel(
        qd_ref,  # (1, Dd)            query dense
        qsi_ref,  # (1, Ps) int32      query learned-sparse indices
        qsv_ref,  # (1, Ps)            query learned-sparse values
        qfi_ref,  # (1, Pf) int32      query lexical-sparse indices
        qfv_ref,  # (1, Pf)            query lexical-sparse values
        cd_ref,  # (1, C_TILE, Dd)    candidate dense tile
        csi_ref,  # (1, Ps, C_TILE)    candidate learned idx (nnz-major)
        csv_ref,  # (1, Ps, C_TILE)
        cfi_ref,  # (1, Pf, C_TILE)    candidate lexical idx (nnz-major)
        cfv_ref,  # (1, Pf, C_TILE)
        *rest,  # [cscale_ref (1, C_TILE) f32 if has_scale], out_ref (1, C_TILE)
    ):
        if has_scale:
            cscale_ref, out_ref = rest
        else:
            (out_ref,) = rest
        f32 = jnp.float32

        # --- dense path: MXU matvec (1, Dd) x (C_TILE, Dd)^T -> (1, C_TILE) ---
        qd = qd_ref[...].astype(f32)  # (1, Dd)
        cd = cd_ref[0].astype(f32)  # (C_TILE, Dd)
        acc = jax.lax.dot_general(
            qd, cd, (((1,), (1,)), ((), ())), preferred_element_type=f32
        )  # (1, C_TILE)
        if has_scale:
            acc = acc * cscale_ref[...].astype(f32)  # dequant-in-tile

        # --- sparse paths: per-query-term vectorized intersection ---
        def sparse_accumulate(acc, qi_ref, qv_ref, ci_ref, cv_ref):
            qi = qi_ref[...]  # (1, P) int32
            qv = qv_ref[...].astype(f32)  # (1, P)
            ci = ci_ref[0]  # (P, C_TILE) int32
            cv = cv_ref[0].astype(f32)  # (P, C_TILE)
            n_terms = qi.shape[-1]
            for j in range(n_terms):  # static unroll over the query's nnz slots
                match = ci == qi[0, j]  # (P, C_TILE)
                contrib = jnp.where(match, cv, 0.0)  # padded slots have val 0
                acc = acc + jnp.sum(contrib, axis=0, keepdims=True) * qv[0, j]
            return acc

        acc = sparse_accumulate(acc, qsi_ref, qsv_ref, csi_ref, csv_ref)
        acc = sparse_accumulate(acc, qfi_ref, qfv_ref, cfi_ref, cfv_ref)
        out_ref[...] = acc

    return kernel


_hybrid_distance_kernel = _make_hybrid_distance_kernel(has_scale=False)


def hybrid_distance_pallas(
    qd: jax.Array,  # (B, Dd)
    qsi: jax.Array,  # (B, Ps) int32
    qsv: jax.Array,  # (B, Ps)
    qfi: jax.Array,  # (B, Pf) int32
    qfv: jax.Array,  # (B, Pf)
    cd: jax.Array,  # (B, C, Dd)
    csi: jax.Array,  # (B, Ps, C)  nnz-major
    csv: jax.Array,  # (B, Ps, C)
    cfi: jax.Array,  # (B, Pf, C)
    cfv: jax.Array,  # (B, Pf, C)
    cscale: jax.Array | None = None,  # (B, C) f32 per-candidate dense scale
    *,
    c_tile: int = DEFAULT_C_TILE,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call wrapper. C must be a multiple of c_tile (callers pad).

    When ``cscale`` is given, ``cd`` holds int8 rows and the dense matvec is
    dequantized in-tile by the per-candidate scale.

    Returns (B, C) float32 hybrid scores (higher = more similar).
    """
    b, dd = qd.shape
    _, ps = qsi.shape
    _, pf = qfi.shape
    c = cd.shape[1]
    assert c % c_tile == 0, f"C={c} not a multiple of c_tile={c_tile}"
    grid = (b, c // c_tile)

    # Query blocks are pinned per grid row (index_map ignores the candidate
    # tile coordinate) -> VMEM-resident across candidate tiles.
    q_row = lambda i, j: (i, 0)
    cand3 = lambda i, j: (i, 0, j)  # (1, P, C_TILE) tiles along last dim
    dense3 = lambda i, j: (i, j, 0)  # (1, C_TILE, Dd) tiles along middle dim
    crow = lambda i, j: (i, j)

    has_scale = cscale is not None
    in_specs = [
        pl.BlockSpec((1, dd), q_row),
        pl.BlockSpec((1, ps), q_row),
        pl.BlockSpec((1, ps), q_row),
        pl.BlockSpec((1, pf), q_row),
        pl.BlockSpec((1, pf), q_row),
        pl.BlockSpec((1, c_tile, dd), dense3),
        pl.BlockSpec((1, ps, c_tile), cand3),
        pl.BlockSpec((1, ps, c_tile), cand3),
        pl.BlockSpec((1, pf, c_tile), cand3),
        pl.BlockSpec((1, pf, c_tile), cand3),
    ]
    operands = [qd, qsi, qsv, qfi, qfv, cd, csi, csv, cfi, cfv]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, c_tile), crow))
        operands.append(cscale)

    return pl.pallas_call(
        _make_hybrid_distance_kernel(has_scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c_tile), crow),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(*operands)
