"""Pure-jnp oracle for the hybrid distance kernel (paper §4.1 Step 1).

Semantics contract (shared by the Pallas kernel and this oracle):

  score(q, c) = <q.dense, c.dense> + sp_ip(q.learned, c.learned)
                                   + sp_ip(q.lexical, c.lexical)

where ``sp_ip`` is the sparse inner product over fixed-nnz ELL vectors and
padded slots (idx == PAD_IDX) never match. Path weights are applied to the
query beforehand via ``usms.weighted_query`` (Theorem 1), so the kernel itself
is weight-free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.usms import PAD_IDX, FusedVectors, QuantizedFusedVectors
from repro.kernels.fused_topk import NEG


def sparse_ip_ref(
    q_idx: jax.Array, q_val: jax.Array, c_idx: jax.Array, c_val: jax.Array
) -> jax.Array:
    """Sparse inner product via all-pairs index matching.

    q_idx/q_val: (B, Pq); c_idx/c_val: (B, C, Pc)  ->  (B, C) float32.
    """
    q_idx = q_idx[:, None, None, :]  # (B, 1, 1, Pq)
    q_val = q_val[:, None, None, :]
    c_idxe = c_idx[..., :, None]  # (B, C, Pc, 1)
    c_vale = c_val[..., :, None]
    match = (c_idxe == q_idx) & (c_idxe >= 0) & (q_idx >= 0)
    contrib = jnp.where(match, c_vale.astype(jnp.float32) * q_val.astype(jnp.float32), 0.0)
    return contrib.sum(axis=(-1, -2))


def hybrid_scores_ref(q: FusedVectors, cands: FusedVectors) -> jax.Array:
    """q: batch of B queries; cands: (B, C, ...) candidate rows -> (B, C)."""
    dense = jnp.einsum(
        "bd,bcd->bc",
        q.dense.astype(jnp.float32),
        cands.dense.astype(jnp.float32),
    )
    sp = sparse_ip_ref(q.learned.idx, q.learned.val, cands.learned.idx, cands.learned.val)
    fp = sparse_ip_ref(q.lexical.idx, q.lexical.val, cands.lexical.idx, cands.lexical.val)
    return dense + sp + fp


def hybrid_scores_quant_ref(
    q: FusedVectors, cands: QuantizedFusedVectors
) -> jax.Array:
    """Quantized-storage oracle: ``scale_c * <q, int8_c>`` — the scale
    multiplies the dense *dot product* (not the rows), matching the kernel's
    dequant-in-tile op order so oracle and kernel differ only by summation
    order, like the fp32 paths."""
    dense = jnp.einsum(
        "bd,bcd->bc",
        q.dense.astype(jnp.float32),
        cands.dense_q.astype(jnp.float32),
    ) * cands.dense_scale.astype(jnp.float32)
    sp = sparse_ip_ref(q.learned.idx, q.learned.val, cands.learned.idx, cands.learned.val)
    fp = sparse_ip_ref(q.lexical.idx, q.lexical.val, cands.lexical.idx, cands.lexical.val)
    return dense + sp + fp


def fused_topk_ref(
    q: FusedVectors,
    cands: FusedVectors,
    cid: jax.Array,  # (B, C) int32 candidate ids; PAD_IDX slots are invalid
    bias: jax.Array | None,  # (B, C) f32 pre-selection score bias, or None
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """jnp oracle for the fused distance+top-k kernel.

    Returns ``(scores, positions)`` of shape (B, k): the top-k biased hybrid
    scores per query (descending, ``lax.top_k`` tie order) and the candidate
    positions along the C axis they came from. Invalid slots — PAD candidates,
    or k exceeding the number of live candidates — hold (NEG, PAD_IDX).
    """
    scores = hybrid_scores_ref(q, cands)
    return _select_topk_ref(scores, cid, bias, k)


def fused_topk_quant_ref(
    q: FusedVectors,
    cands: QuantizedFusedVectors,
    cid: jax.Array,
    bias: jax.Array | None,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """``fused_topk_ref`` over quantized candidate storage (same contract)."""
    scores = hybrid_scores_quant_ref(q, cands)
    return _select_topk_ref(scores, cid, bias, k)


def _select_topk_ref(
    scores: jax.Array, cid: jax.Array, bias: jax.Array | None, k: int
) -> tuple[jax.Array, jax.Array]:
    if bias is not None:
        scores = scores + bias.astype(jnp.float32)
    scores = jnp.where(cid >= 0, scores, NEG)
    b, c = scores.shape
    k_eff = min(k, c)
    top, pos = jax.lax.top_k(scores, k_eff)
    pos = pos.astype(jnp.int32)
    if k_eff < k:
        top = jnp.pad(top, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        pos = jnp.pad(pos, ((0, 0), (0, k - k_eff)), constant_values=PAD_IDX)
    pos = jnp.where(top > NEG, pos, PAD_IDX)
    return top, pos


def pairwise_tile_ref(tile: FusedVectors) -> jax.Array:
    """All-pairs hybrid scores within each candidate tile (jnp oracle).

    tile: (C, K, ...) gathered candidate rows -> (C, K, K) float32 with
    out[c, i, j] = score(tile[c, i], tile[c, j]). Shares the ELL padding
    contract (PAD slots carry val 0); no per-id validity masking here.
    """
    dense = jnp.einsum(
        "cid,cjd->cij",
        tile.dense.astype(jnp.float32),
        tile.dense.astype(jnp.float32),
    )

    def sp_tile(idx, val):
        # (C, K, P) x itself -> (C, K, K)
        m = (idx[:, :, None, :, None] == idx[:, None, :, None, :]) & (
            idx[:, :, None, :, None] >= 0
        )
        c = jnp.where(
            m,
            val[:, :, None, :, None].astype(jnp.float32)
            * val[:, None, :, None, :].astype(jnp.float32),
            0.0,
        )
        return c.sum(axis=(-1, -2))

    sp = sp_tile(tile.learned.idx, tile.learned.val)
    fp = sp_tile(tile.lexical.idx, tile.lexical.val)
    return dense + sp + fp


def pairwise_hybrid_scores_ref(a: FusedVectors, b: FusedVectors) -> jax.Array:
    """All-pairs scores between two flat sets: a (N, ...) x b (M, ...) -> (N, M).

    Brute-force oracle used for ground truth in recall tests/benchmarks.
    """
    dense = a.dense.astype(jnp.float32) @ b.dense.astype(jnp.float32).T

    def sp_all(aidx, aval, bidx, bval):
        # (N, Pa) x (M, Pb) -> (N, M)
        m = (aidx[:, None, :, None] == bidx[None, :, None, :]) & (
            aidx[:, None, :, None] >= 0
        ) & (bidx[None, :, None, :] >= 0)
        c = jnp.where(
            m,
            aval[:, None, :, None].astype(jnp.float32)
            * bval[None, :, None, :].astype(jnp.float32),
            0.0,
        )
        return c.sum(axis=(-1, -2))

    sp = sp_all(a.learned.idx, a.learned.val, b.learned.idx, b.learned.val)
    fp = sp_all(a.lexical.idx, a.lexical.val, b.lexical.idx, b.lexical.val)
    return dense + sp + fp
