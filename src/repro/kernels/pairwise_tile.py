"""Pallas TPU kernel for candidate-pairwise score tiles (paper §4.1 Step 2).

RNG-IP joint pruning needs, for every node u, the full (K, K) hybrid-score
matrix among u's K candidates: detour counting reads sim(v_i, v_j) for every
pair and the IP keep-scan reads IP(w, v) against already-kept candidates.
The GPU paper evaluates those pairs with one warp per (v_i, v_j); the naive
TPU port materialized the candidate rows K times — `corpus.take` over a
(C*K, K) id matrix gathers C*K*K fused rows per chunk.

This kernel removes the re-gather: the caller gathers each node's K candidate
rows ONCE, and every grid cell computes one node's (K, K) tile from a single
VMEM-resident copy of those rows:

  * grid = (C,), one cell per node in the chunk;
  * dense part: a (K, Dd) x (K, Dd)^T MXU matmul -> (K, K);
  * sparse parts: candidate rows are passed twice — row-major (K, P) as the
    "query side" and nnz-major (P, K) as the "candidate side" (the same
    layout trick as hybrid_distance.py). A static unroll over the P query
    slots does a vectorized (K, P, K) equality-compare + masked
    multiply-accumulate per slot, so the pair intersection needs no gathers
    and no branches;
  * the padding contract is inherited from the ELL layout: idx == PAD_IDX
    slots carry val == 0, so padded slots contribute exactly 0. Masking of
    *invalid candidates* (cand_ids < 0) stays in the caller, which knows the
    id list; the kernel only ever sees gathered rows.

Symmetry note: scores are computed for all (i, j) pairs, not just i < j —
the IP keep rule needs the full matrix, and the MXU produces it for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_tile_kernel(
    d_ref,  # (1, K, Dd)         candidate dense rows
    si_ref,  # (1, K, Ps) int32   learned-sparse idx (row-major)
    sv_ref,  # (1, K, Ps)         learned-sparse val (row-major)
    fi_ref,  # (1, K, Pf) int32   lexical idx (row-major)
    fv_ref,  # (1, K, Pf)         lexical val (row-major)
    tsi_ref,  # (1, Ps, K) int32   learned idx (nnz-major)
    tsv_ref,  # (1, Ps, K)
    tfi_ref,  # (1, Pf, K) int32   lexical idx (nnz-major)
    tfv_ref,  # (1, Pf, K)
    out_ref,  # (1, K, K) f32
):
    f32 = jnp.float32

    # --- dense path: (K, Dd) x (K, Dd)^T on the MXU -> (K, K) ---
    d = d_ref[0].astype(f32)
    acc = jax.lax.dot_general(
        d, d, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )

    # --- sparse paths: per-slot vectorized intersection over the tile ---
    def sparse_accumulate(acc, qi_ref, qv_ref, ci_ref, cv_ref):
        qi = qi_ref[0]  # (K, P) int32  "query side" rows
        qv = qv_ref[0].astype(f32)  # (K, P)
        ci = ci_ref[0]  # (P, K) int32  same rows, nnz-major
        cv = cv_ref[0].astype(f32)  # (P, K)
        n_slots = qi.shape[-1]
        for p in range(n_slots):  # static unroll over nnz slots
            qip = qi[:, p]  # (K,)
            match = ci[None, :, :] == qip[:, None, None]  # (K, P, K)
            contrib = jnp.where(match, cv[None, :, :], 0.0)
            acc = acc + contrib.sum(axis=1) * qv[:, p][:, None]
        return acc

    acc = sparse_accumulate(acc, si_ref, sv_ref, tsi_ref, tsv_ref)
    acc = sparse_accumulate(acc, fi_ref, fv_ref, tfi_ref, tfv_ref)
    out_ref[0] = acc


def pairwise_tile_pallas(
    d: jax.Array,  # (C, K, Dd)
    si: jax.Array,  # (C, K, Ps) int32
    sv: jax.Array,  # (C, K, Ps)
    fi: jax.Array,  # (C, K, Pf) int32
    fv: jax.Array,  # (C, K, Pf)
    *,
    interpret: bool = False,
) -> jax.Array:
    """All-pairs hybrid scores within each node's candidate tile.

    Returns (C, K, K) float32 with out[c, i, j] = score(row i, row j) of
    node c's gathered candidate rows. No validity masking — callers mask.
    """
    c, k, dd = d.shape
    ps = si.shape[-1]
    pf = fi.shape[-1]
    tsi = jnp.swapaxes(si, 1, 2)  # (C, Ps, K) nnz-major views
    tsv = jnp.swapaxes(sv, 1, 2)
    tfi = jnp.swapaxes(fi, 1, 2)
    tfv = jnp.swapaxes(fv, 1, 2)

    cell = lambda i: (i, 0, 0)
    return pl.pallas_call(
        _pairwise_tile_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, k, dd), cell),
            pl.BlockSpec((1, k, ps), cell),
            pl.BlockSpec((1, k, ps), cell),
            pl.BlockSpec((1, k, pf), cell),
            pl.BlockSpec((1, k, pf), cell),
            pl.BlockSpec((1, ps, k), cell),
            pl.BlockSpec((1, ps, k), cell),
            pl.BlockSpec((1, pf, k), cell),
            pl.BlockSpec((1, pf, k), cell),
        ],
        out_specs=pl.BlockSpec((1, k, k), cell),
        out_shape=jax.ShapeDtypeStruct((c, k, k), jnp.float32),
        interpret=interpret,
    )(d, si, sv, fi, fv, tsi, tsv, tfi, tfv)
