import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh with ShapeDtypeStruct inputs (no
allocation), print memory/cost analysis, and record collective traffic for
the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST precede any other import (jax locks the device
count on first init). Run modes:

    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all --out results/dryrun   # orchestrator
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh, mesh_dp_size, mesh_model_size
from repro.models import transformer as tfm
from repro.models.config import SHAPES, ModelConfig
from repro.models.layers import DATA, POD, ShardCtx, dtype_of
from repro.training import optimizer as opt
from repro.training.train_loop import _accumulate_grads

RETRIEVAL_ARCH = "allanpoe-retrieval"  # extra dry-run target: the paper's index


def batch_size_spec(batch: int, mesh) -> P:
    dp = tuple(a for a in (POD, DATA) if a in mesh.axis_names)
    dp_total = mesh_dp_size(mesh)
    if batch % dp_total == 0 and batch >= dp_total:
        return P(dp if len(dp) > 1 else dp[0])
    return P()


def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    shape = SHAPES[shape_name]
    b, l = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)
    out = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
        if cfg.family in ("vlm", "audio"):
            out["frontend"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), dt
            )
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        out["cache"] = tfm.cache_shape(cfg, b, l)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def _shardings_for(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell_program(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (jitted_fn, arg_structs) ready to .lower(*arg_structs)."""
    shape = SHAPES[shape_name]
    tp = mesh_model_size(mesh)
    dp = mesh_dp_size(mesh)
    ctx = ShardCtx(model_size=tp, fsdp=cfg.fsdp)
    pspecs = tfm.param_specs(cfg, ctx)
    p_shard = _shardings_for(pspecs, mesh)
    param_structs = jax.eval_shape(lambda: tfm.init_params(jax.random.key(0), cfg))
    mesh_axes = tuple(mesh.axis_names)
    ins = input_specs(cfg, shape_name, mesh)
    bspec = batch_size_spec(shape.global_batch, mesh)
    b_shard = NamedSharding(mesh, bspec)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        big = cfg.n_params > 100e9
        ocfg = opt.OptConfig(moment_dtype="bfloat16" if big else "float32")
        loss_fn = tfm.make_loss_fn(cfg, mesh_axes)
        opt_structs = jax.eval_shape(lambda p: opt.init_opt_state(p, ocfg), param_structs)
        o_shard = {"m": p_shard, "v": p_shard, "step": repl}

        def step(state, batch):
            loss, grads = _accumulate_grads(loss_fn, state["params"], batch, 1)
            new_p, new_o, metrics = opt.adamw_update(
                grads, state["opt"], state["params"], ocfg
            )
            return {"params": new_p, "opt": new_o}, metrics

        state_structs = {"params": param_structs, "opt": opt_structs}
        state_shard = {"params": p_shard, "opt": o_shard}
        batch_structs = {"tokens": ins["tokens"]}
        batch_shard = {"tokens": b_shard}
        if "frontend" in ins:
            batch_structs["frontend"] = ins["frontend"]
            batch_shard["frontend"] = b_shard
        fn = jax.jit(
            step,
            in_shardings=(state_shard, batch_shard),
            out_shardings=(state_shard, None),
        )
        return fn, (state_structs, batch_structs)

    if shape.kind == "prefill":
        prefill = tfm.make_prefill(cfg, shape.seq_len, mesh_axes)
        cache_specs = tfm.cache_specs(
            cfg, shape.global_batch, shape.seq_len,
            dp_size=dp, model_size=tp, multi_pod=POD in mesh_axes,
        )
        args = [param_structs, ins["tokens"]]
        in_sh = [p_shard, b_shard]
        if "frontend" in ins:
            args.append(ins["frontend"])
            in_sh.append(b_shard)
        fn = jax.jit(
            prefill,
            in_shardings=tuple(in_sh),
            out_shardings=(None, _shardings_for(cache_specs, mesh)),
        )
        return fn, tuple(args)

    # decode
    decode = tfm.make_decode_step(cfg, mesh_axes)
    cache_specs = tfm.cache_specs(
        cfg, shape.global_batch, shape.seq_len,
        dp_size=dp, model_size=tp, multi_pod=POD in mesh_axes,
    )
    c_shard = _shardings_for(cache_specs, mesh)
    fn = jax.jit(
        decode,
        in_shardings=(p_shard, b_shard, c_shard, repl),
        out_shardings=(None, c_shard),
    )
    return fn, (param_structs, ins["token"], ins["cache"], ins["pos"])


def build_retrieval_program(mesh, overrides: dict | None = None):
    """The paper's own workload as a dry-run cell: distributed hybrid search
    over a segment-sharded 1M-doc corpus (shapes from paper Table 1).

    overrides: {"use_kernel": bool, "iters": int, "pool_size": int, ...}."""
    from repro.core.distributed import (
        SegmentedIndex,
        make_distributed_search,
    )
    from repro.core.index import HybridIndex
    from repro.core.search import SearchParams
    from repro.core.usms import FusedVectors, PathWeights, SparseVec

    ov = overrides or {}
    n_total = 1_048_576
    n_seg = mesh_dp_size(mesh)
    n_loc = n_total // n_seg
    d, ps, pf = 1024, 64, 32
    deg, dk, lcap, ed = 32, 8, 4, 4
    n_q = int(ov.get("n_queries", 1024))
    tp = mesh_model_size(mesh)

    f32 = jnp.bfloat16 if ov.get("bf16") else jnp.float32
    i32 = jnp.int32

    def fused(n):
        return FusedVectors(
            dense=jax.ShapeDtypeStruct((n, d), f32),
            learned=SparseVec(
                jax.ShapeDtypeStruct((n, ps), i32), jax.ShapeDtypeStruct((n, ps), f32)
            ),
            lexical=SparseVec(
                jax.ShapeDtypeStruct((n, pf), i32), jax.ShapeDtypeStruct((n, pf), f32)
            ),
        )

    def seg(x):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_seg,) + s.shape, s.dtype), x
        )

    index_structs = HybridIndex(
        corpus=fused(n_loc),
        semantic_edges=jax.ShapeDtypeStruct((n_loc, deg), i32),
        keyword_edges=jax.ShapeDtypeStruct((n_loc, dk), i32),
        logical_edges=jax.ShapeDtypeStruct((n_loc, lcap, 4), i32),
        doc_entities=jax.ShapeDtypeStruct((n_loc, ed), i32),
        entity_to_docs=jax.ShapeDtypeStruct((64, 4), i32),
        entity_adj=jax.ShapeDtypeStruct((64, 64), jnp.bool_),
        entry_points=jax.ShapeDtypeStruct((16,), i32),
        alive=jax.ShapeDtypeStruct((n_loc,), jnp.bool_),
        self_ip=jax.ShapeDtypeStruct((n_loc,), f32),
    )
    seg_structs = SegmentedIndex(index=seg(index_structs), global_ids=jax.ShapeDtypeStruct((n_seg, n_loc), i32))
    q_structs = fused(n_q)
    ov = overrides or {}
    params = SearchParams(
        k=int(ov.get("k", 10)),
        iters=int(ov.get("iters", 48)),
        pool_size=int(ov.get("pool_size", 64)),
        expand=int(ov.get("expand", 1)),
        use_kernel=bool(ov.get("use_kernel", False)),
    )
    run = make_distributed_search(mesh, PathWeights.three_path(), params)
    return run, (seg_structs, q_structs)


def _parse_overrides(spec: str | None) -> dict:
    """--set a=1,b=flash,c=true -> config overrides (perf iterations)."""
    out = {}
    if not spec:
        return out
    for kv in spec.split(","):
        k, v = kv.split("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides: str | None = None) -> dict:
    import dataclasses

    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "overrides": overrides or "",
    }
    t0 = time.time()
    if arch == RETRIEVAL_ARCH:
        fn, args = build_retrieval_program(mesh, _parse_overrides(overrides))
        cfg = None
    else:
        cfg = get_config(arch)
        if overrides:
            cfg = dataclasses.replace(cfg, **_parse_overrides(overrides))
        shape = SHAPES[shape_name]
        if shape_name == "long_500k" and not cfg.supports_long_context:
            record["status"] = "SKIP(full-attn)"
            return record
        fn, args = build_cell_program(cfg, shape_name, mesh)

    with jax.set_mesh(mesh):
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        print("memory_analysis:", record["memory"])
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["cost"] = {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
        }
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            record["cost"]["flops"], record["cost"]["bytes_accessed"]))
    except Exception as e:  # pragma: no cover
        record["cost"] = {"error": str(e)}

    # loop-aware per-device accounting (scan bodies x trip counts)
    hlo_text = compiled.as_text()
    if os.environ.get("REPRO_SAVE_HLO"):
        import gzip

        path = pathlib.Path(os.environ["REPRO_SAVE_HLO"])
        path.parent.mkdir(parents=True, exist_ok=True)
        with gzip.open(path, "wt") as f:
            f.write(hlo_text)
    hlo = analyze_hlo(hlo_text)
    record["hlo"] = hlo
    print(
        "loop-aware/device: dot_flops=%.3e hbm_bytes=%.3e coll_bytes=%.3e %s"
        % (
            hlo["dot_flops"],
            hlo["hbm_bytes"],
            hlo["collective_bytes"],
            hlo["collective_counts"],
        )
    )

    if cfg is not None:
        shape = SHAPES[shape_name]
        n_tokens = shape.global_batch * (
            shape.seq_len if shape.kind in ("train", "prefill") else 1
        )
        mult = 6 if shape.kind == "train" else 2
        record["model_flops"] = float(mult * cfg.n_active_params * n_tokens)
        record["model_flops_per_device"] = record["model_flops"] / record["n_devices"]
        record["n_params"] = float(cfg.n_params)
        record["n_active_params"] = float(cfg.n_active_params)
        if hlo["dot_flops"] > 0:
            record["useful_flops_ratio"] = (
                record["model_flops_per_device"] / hlo["dot_flops"]
            )

    record["roofline"] = roofline_terms(
        hlo_flops=hlo["dot_flops"],
        hlo_bytes=hlo["hbm_bytes"],
        coll_bytes_per_device=hlo["collective_bytes"],
        n_chips=record["n_devices"],
    )
    print("roofline:", {k: (round(v, 4) if isinstance(v, float) else v)
                        for k, v in record["roofline"].items()})
    record["status"] = "OK"
    return record


def orchestrate(out_dir: str, jobs: int, meshes: list[str], archs: list[str], shapes: list[str]):
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    cells = []
    for mesh in meshes:
        for arch in archs:
            if arch == RETRIEVAL_ARCH:
                cells.append((arch, "search_1m", mesh))
                continue
            for shape in shapes:
                cells.append((arch, shape, mesh))
    procs: list[tuple] = []
    results = []

    def drain(block=False):
        for i, (p, cell, path, log) in enumerate(list(procs)):
            if p.poll() is None and not block:
                continue
            p.wait()
            procs.remove((p, cell, path, log))
            if path.exists():
                results.append(json.loads(path.read_text()))
                r = results[-1]
                print(f"[{len(results)}/{len(cells)}] {r['arch']} {r['shape']} "
                      f"{r['mesh']}: {r.get('status')} ({r.get('compile_s', '-')}s)",
                      flush=True)
            else:
                print(f"FAILED: {cell}; see {log}", flush=True)
                results.append({"arch": cell[0], "shape": cell[1],
                                "mesh": cell[2], "status": "COMPILE_FAIL",
                                "log": str(log)})

    for cell in cells:
        arch, shape, mesh = cell
        path = out / f"{arch}__{shape}__{mesh}.json"
        if path.exists():
            results.append(json.loads(path.read_text()))
            continue
        log = out / f"{arch}__{shape}__{mesh}.log"
        cmd = [
            "timeout", "3000",
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
            "--json-out", str(path),
        ]
        env = dict(os.environ)
        env["REPRO_SAVE_HLO"] = str(path.with_suffix(".hlo.gz"))
        with open(log, "w") as lf:
            procs.append((subprocess.Popen(cmd, stdout=lf, stderr=lf, env=env), cell, path, log))
        while len(procs) >= jobs:
            drain()
            time.sleep(2)
    while procs:
        drain()
        time.sleep(2)
    (out / "summary.json").write_text(json.dumps(results, indent=1))
    n_ok = sum(1 for r in results if r.get("status") == "OK")
    n_skip = sum(1 for r in results if str(r.get("status", "")).startswith("SKIP"))
    print(f"\n{n_ok} OK, {n_skip} skipped, {len(results) - n_ok - n_skip} failed "
          f"of {len(results)} cells")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--set", dest="overrides", default=None,
                    help="config overrides, e.g. attn_impl=flash,seq_shard=true")
    ap.add_argument("--archs", default=None, help="comma list (with --all)")
    ap.add_argument("--shapes", default=None, help="comma list (with --all)")
    args = ap.parse_args()

    if args.all:
        archs = args.archs.split(",") if args.archs else list_archs() + [RETRIEVAL_ARCH]
        shapes = args.shapes.split(",") if args.shapes else list(SHAPES)
        orchestrate(args.out, args.jobs, ["single", "multi"], archs, shapes)
        return

    record = run_cell(args.arch, args.shape, args.mesh == "multi", args.overrides)
    print(json.dumps({k: v for k, v in record.items() if k != "hlo"}, indent=1))
    if args.json_out:
        pathlib.Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.json_out).write_text(json.dumps(record, indent=1))
    if record.get("status") not in ("OK",) and not str(record.get("status", "")).startswith("SKIP"):
        sys.exit(1)


if __name__ == "__main__":
    main()
