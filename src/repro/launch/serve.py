"""Production serving launcher: batched generation with optional Allan-Poe
retrieval augmentation.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --requests 16 --prompt-len 16 --gen 32 [--rag]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as tfm
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = tfm.init_params(jax.random.key(args.seed), cfg)
    max_len = args.prompt_len + args.gen + (64 if args.rag else 0)
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_len=max_len, batch=args.requests,
                    temperature=args.temperature),
    )
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.requests, args.prompt_len)), jnp.int32
    )
    frontend = None
    if cfg.family in ("vlm", "audio"):
        frontend = jnp.asarray(
            rng.normal(0, 0.02, size=(args.requests, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
        )

    if args.rag:
        from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
        from repro.data.corpus import CorpusConfig, make_corpus
        from repro.serving.rag import RagConfig, RagPipeline

        corpus = make_corpus(
            CorpusConfig(n_docs=2048, n_queries=args.requests, d_dense=64, seed=args.seed)
        )
        index = build_index(
            corpus.docs,
            BuildConfig(knn=KnnConfig(k=16, iters=4), prune=PruneConfig(degree=16)),
        )
        doc_tokens = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(2048, 16)), jnp.int32
        )
        rag = RagPipeline(eng, index, doc_tokens, RagConfig(top_k=2, ctx_tokens_per_doc=16))
        t0 = time.perf_counter()
        out, res = rag.answer(corpus.queries, prompts, args.gen)
        dt = time.perf_counter() - t0
        print(f"RAG: retrieved top-{res.ids.shape[1]} per request; "
              f"{args.requests} requests in {dt:.2f}s")
        print("sample retrieved ids:", np.asarray(res.ids[0]).tolist())
    else:
        t0 = time.perf_counter()
        out = eng.generate(prompts, args.gen, frontend=frontend)
        dt = time.perf_counter() - t0

    tok = args.requests * args.gen
    print(f"generated {tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s)")
    print("sample output:", np.asarray(out[0, -16:]).tolist())


if __name__ == "__main__":
    main()
