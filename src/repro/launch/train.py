"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
        --steps 200 --batch 8 --seq 512 --ckpt-dir /tmp/run1 [--smoke]

Single-host CPU runs use the smoke config; on a TPU fleet the same driver
runs the full config on the production mesh (it auto-detects device count
and builds the largest valid mesh via elastic_mesh_shape). Fault tolerance:
checkpoint every --ckpt-every steps, automatic restart from the last commit,
deterministic data skip, straggler monitoring.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import StragglerMonitor, elastic_mesh_shape, run_supervised
from repro.training import optimizer as opt
from repro.training.train_loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        tp = 16 if n_dev % 16 == 0 else 1
        shape, axes = elastic_mesh_shape(n_dev, tp, pod_size=16)
        mesh = jax.make_mesh(shape, axes)
        print(f"mesh: {dict(zip(axes, shape))}")

    tcfg = TrainConfig(
        opt=opt.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    pipe = TokenPipeline(
        DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            seed=args.seed,
            frontend_tokens=cfg.n_frontend_tokens if cfg.family in ("vlm", "audio") else 0,
            d_model=cfg.d_model,
        )
    )
    step_fn = make_train_step(cfg, tcfg, mesh, None)

    def make_state():
        params = tfm.init_params(jax.random.key(args.seed), cfg)
        return {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}

    n_params = cfg.n_params if not args.smoke else sum(
        int(x.size) for x in jax.tree.leaves(make_state()["params"])
    )
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    if args.ckpt_dir:
        monitor = StragglerMonitor()
        report = run_supervised(
            n_steps=args.steps, make_state=make_state, train_step=step_fn,
            batch_fn=pipe.batch, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, monitor=monitor,
        )
        print(f"done: {report.steps_done} steps, {report.restarts} restarts, "
              f"final loss {report.losses[-1]:.4f}")
        return

    state = make_state()
    t0 = time.perf_counter()
    for s in range(args.steps):
        state, metrics = step_fn(state, pipe.batch(s))
        if s % args.log_every == 0 or s == args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = args.batch * args.seq * (s + 1) / dt
            print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                  f"tok/s {tok_s:,.0f}", flush=True)


if __name__ == "__main__":
    main()
