"""Production mesh definitions.

Never touches jax device state at import time: ``make_production_mesh`` is a
function (the dry-run sets XLA_FLAGS for 512 host devices BEFORE calling it;
smoke tests never call it)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e pod); multi-pod adds the pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_dp_size(mesh) -> int:
    out = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            out *= mesh.shape[a]
    return out


def mesh_model_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
