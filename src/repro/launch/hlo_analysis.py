"""Post-SPMD HLO analysis for the roofline.

XLA's ``cost_analysis()`` (and any naive text scan) counts while-loop bodies
ONCE, but every layer stack here is a lax.scan — so flops/bytes/collectives
would be undercounted by ~n_layers. This module parses the optimized HLO
into computations, extracts each while op's ``known_trip_count`` from its
backend_config, walks the call graph with multiplicities, and accumulates
per-device:

  * dot_flops        — 2*M*N*K per dot, the MXU work (elementwise flops are
                       <2% for these models and are reported separately via
                       cost_analysis for reference);
  * hbm_bytes        — Σ over surviving (post-fusion) instructions of
                       operand+result bytes, the same definition
                       HloCostAnalysis uses;
  * collective bytes — operand sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_info(shape_text: str) -> tuple[int, list[list[int]]]:
    """(total bytes, list of dims lists) over every dtype[dims] occurrence."""
    total = 0
    dims_all = []
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in DTYPE_BYTES:
            continue
        dd = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dd:
            n *= d
        total += n * DTYPE_BYTES[dtype]
        dims_all.append(dd)
    return total, dims_all


@dataclass
class Computation:
    name: str
    bytes_accessed: int = 0
    bytes_fused: int = 0
    dot_flops: int = 0
    coll_bytes: dict = field(default_factory=lambda: defaultdict(int))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    # (callee, multiplicity) edges: while bodies get their trip count
    calls: list = field(default_factory=list)


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLSITE = re.compile(
    r"(?:body=|condition=|to_apply=|calls=|branch_computations=\{)\s*%?([\w\.\-]+)"
)
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")
_OPC = re.compile(r"^\s*((?:\([^)]*\))|(?:[\w\[\],{}\s]+?))\s+([\w\-]+)(?:\.\d+)?\(")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
}

# Ops that genuinely stream HBM on TPU even under aggressive fusion. CPU XLA
# fuses far less than TPU, so counting EVERY instruction's operands+results
# ("raw") wildly overstates TPU HBM traffic from elementwise chains; the
# "fused" estimate counts only these anchor ops (their operands/results are
# the fusion boundaries: weights, activations entering/leaving matmuls,
# caches, gathers/scatters, big reductions, data movement between loop
# iterations).
_HBM_ANCHOR_OPS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "select-and-scatter", "cholesky",
    "triangular-solve", "fft", "custom-call", "pad", "concatenate",
    "slice", "reverse", "transpose", "broadcast-to",
}


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    shapes: dict[str, str] = {}
    pending: list[tuple] = []

    for raw in text.splitlines():
        m = _COMP_HEADER.match(raw.strip()) if not raw.startswith(" ") else None
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        mi = _INSTR.match(raw)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        # result shape + opcode
        mo = _OPC.match(rhs)
        if not mo:
            continue
        shape_text, opcode = mo.group(1).strip(), mo.group(2)
        shapes[name] = shape_text
        pending.append((cur.name, name, shape_text, opcode, rhs))

    # second pass with the full shape table
    for comp_name, name, shape_text, opcode, rhs in pending:
        comp = comps[comp_name]
        result_bytes, result_dims = _shape_info(shape_text)

        # call edges
        if opcode in ("while",):
            trip = 1
            mt = _TRIP.search(rhs)
            if mt:
                trip = int(mt.group(1))
            for callee in _CALLSITE.findall(rhs):
                comp.calls.append((callee, trip))
        elif opcode in ("call", "fusion", "conditional", "custom-call", "reduce",
                        "map", "sort", "scatter", "select-and-scatter",
                        "reduce-window", "async-start"):
            for callee in _CALLSITE.findall(rhs):
                comp.calls.append((callee, 1))

        # operand bytes
        paren = rhs[rhs.index("(") :] if "(" in rhs else "()"
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args_text = paren[1:end]
        operand_bytes = 0
        for ref in re.findall(r"%([\w\.\-]+)", args_text):
            if ref in shapes:
                operand_bytes += _shape_info(shapes[ref])[0]

        # slicing/indexing ops touch only the sliced region, not the full
        # operand buffer (dynamic-update-slice writes in place: the update
        # region, not the carry buffer)
        if opcode in ("dynamic-slice", "slice", "gather"):
            touched = 2 * result_bytes
        elif opcode in ("dynamic-update-slice", "scatter"):
            # in-place update: read update (+indices) and write that region
            touched = 2 * max(operand_bytes - result_bytes, 0)
        else:
            touched = result_bytes + operand_bytes
        if opcode not in _SKIP_BYTES_OPS and opcode != "while":
            comp.bytes_accessed += touched
            if opcode in _HBM_ANCHOR_OPS:
                comp.bytes_fused += touched

        if opcode == "dot":
            # contraction sizes from lhs shape + contracting dims
            md = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            refs = re.findall(r"%([\w\.\-]+)", args_text)
            if md and refs and refs[0] in shapes:
                _, lhs_dims_list = _shape_info(shapes[refs[0]])
                if lhs_dims_list:
                    lhs_dims = lhs_dims_list[0]
                    k = 1
                    for ci in md.group(1).split(","):
                        if ci:
                            k *= lhs_dims[int(ci)]
                    out_elems = 1
                    for dd in result_dims:
                        for d in dd:
                            out_elems *= d
                    comp.dot_flops += 2 * out_elems * k

        for coll in COLLECTIVES:
            if opcode.startswith(coll):
                comp.coll_bytes[coll] += operand_bytes or result_bytes
                comp.coll_counts[coll] += 1
                break

    return comps, entry or next(iter(comps), "")


def _accumulate(comps: dict[str, Computation], entry: str) -> dict:
    """DFS with loop multiplicities (memoized per (comp))."""
    totals = {"bytes": 0, "bytes_fused": 0, "dot_flops": 0,
              "coll": defaultdict(int), "coll_counts": defaultdict(int)}

    import sys
    sys.setrecursionlimit(10000)

    cache: dict[str, dict] = {}

    def visit(name: str) -> dict:
        if name in cache:
            return cache[name]
        comp = comps.get(name)
        if comp is None:
            return {"bytes": 0, "bytes_fused": 0, "dot_flops": 0, "coll": {},
                    "coll_counts": {}}
        out = {
            "bytes": comp.bytes_accessed,
            "bytes_fused": comp.bytes_fused,
            "dot_flops": comp.dot_flops,
            "coll": dict(comp.coll_bytes),
            "coll_counts": dict(comp.coll_counts),
        }
        for callee, mult in comp.calls:
            sub = visit(callee)
            out["bytes"] += mult * sub["bytes"]
            out["bytes_fused"] += mult * sub["bytes_fused"]
            out["dot_flops"] += mult * sub["dot_flops"]
            for k, v in sub["coll"].items():
                out["coll"][k] = out["coll"].get(k, 0) + mult * v
            for k, v in sub["coll_counts"].items():
                out["coll_counts"][k] = out["coll_counts"].get(k, 0) + mult * v
        cache[name] = out
        return out

    return visit(entry)


def analyze_hlo(text: str) -> dict:
    """Loop-aware per-device totals from optimized HLO text."""
    comps, entry = parse_hlo(text)
    tot = _accumulate(comps, entry)
    coll_total = sum(tot["coll"].values())
    return {
        "dot_flops": int(tot["dot_flops"]),
        "hbm_bytes": int(tot["bytes_fused"]),
        "hbm_bytes_raw": int(tot["bytes"]),
        "collective_bytes": int(coll_total),
        "collectives": {k: int(v) for k, v in tot["coll"].items()},
        "collective_counts": {k: int(v) for k, v in tot["coll_counts"].items()},
        "n_computations": len(comps),
    }


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat wrapper: loop-aware collective accounting."""
    a = analyze_hlo(hlo_text)
    out = dict(a["collectives"])
    out["total"] = a["collective_bytes"]
    out["counts"] = a["collective_counts"]
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    coll_bytes_per_device: float,
    n_chips: int,
) -> dict:
    """Three-term roofline over PER-DEVICE quantities (the SPMD-partitioned
    module is the per-device program, so chips appear via the partitioned
    shapes, not an extra division)."""
    compute_s = hlo_flops / PEAK_FLOPS_BF16
    memory_s = hlo_bytes / HBM_BW
    collective_s = coll_bytes_per_device / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms
