"""Logical edge augmentation from a knowledge graph (paper §3.4, §4.1 Step 4).

The KG is entity-level; the index is document-level. For each index node X
the logical edges are the triplets {(s, r, t) | s ∈ V(X), t ∈ V \\ V(X)}
materialized as fixed-width per-node tables

    logical_edges[X]  : (L, 4) int32 rows  (dst_doc, src_entity, rel, dst_entity)

plus two search-side structures:

    entity_to_docs    : (E, M) int32  — entry-point selection for entity queries
    entity_adjacency  : (E, E) bool   — "related?" test during traversal
                        (paper line 19-20 of Algorithm 2)

KG construction itself happens offline (the paper uses Qwen3/LLMs); this
module only *maps* a given KG onto the index, so it is host-side numpy — the
paper applies KG augmentation to small high-value shards, and the resulting
tables are device arrays consumed by the jitted search.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.usms import PAD_IDX


@dataclasses.dataclass
class LogicalEdges:
    edges: np.ndarray  # (N, L, 4) int32: dst_doc, src_ent, rel, dst_ent
    entity_to_docs: np.ndarray  # (E, M) int32
    entity_adj: np.ndarray  # (E, E) bool
    doc_entities: np.ndarray  # (N, Ed) int32

    @classmethod
    def empty(cls, n_docs: int, l_cap: int = 1, n_entities: int = 1, m_cap: int = 1):
        return cls(
            np.full((n_docs, l_cap, 4), PAD_IDX, np.int32),
            np.full((n_entities, m_cap), PAD_IDX, np.int32),
            np.zeros((n_entities, n_entities), bool),
            np.full((n_docs, 1), PAD_IDX, np.int32),
        )


def build_logical_edges(
    triplets: np.ndarray,  # (T, 3) (src_ent, rel, dst_ent)
    doc_entities: np.ndarray,  # (N, Ed) int32 PAD-padded
    n_entities: int,
    l_cap: int = 16,
    m_cap: int = 8,
) -> LogicalEdges:
    n_docs = doc_entities.shape[0]
    triplets = np.asarray(triplets, np.int32).reshape(-1, 3)

    # entity -> docs
    ent_docs: list[list[int]] = [[] for _ in range(n_entities)]
    for d in range(n_docs):
        for e in doc_entities[d]:
            if e >= 0 and len(ent_docs[e]) < m_cap:
                ent_docs[e].append(d)
    entity_to_docs = np.full((n_entities, m_cap), PAD_IDX, np.int32)
    for e, ds in enumerate(ent_docs):
        entity_to_docs[e, : len(ds)] = ds

    # symmetric adjacency (relations are traversable both ways for retrieval)
    adj = np.zeros((n_entities, n_entities), bool)
    if len(triplets):
        adj[triplets[:, 0], triplets[:, 2]] = True
        adj[triplets[:, 2], triplets[:, 0]] = True

    # per-doc logical edge tables
    doc_ent_sets = [set(int(e) for e in row if e >= 0) for row in doc_entities]
    edges = np.full((n_docs, l_cap, 4), PAD_IDX, np.int32)
    fill = np.zeros(n_docs, np.int32)
    for s, r, t in triplets:
        for src_e, dst_e in ((s, t), (t, s)):  # both directions
            src_docs = ent_docs[src_e] if src_e < n_entities else []
            dst_docs = ent_docs[dst_e] if dst_e < n_entities else []
            for X in src_docs:
                for Y in dst_docs:
                    if Y == X or dst_e in doc_ent_sets[X]:
                        continue
                    if fill[X] < l_cap:
                        edges[X, fill[X]] = (Y, src_e, r, dst_e)
                        fill[X] += 1
    return LogicalEdges(edges, entity_to_docs, adj, np.asarray(doc_entities, np.int32))
