"""Approximate k-NN graph construction with NN-Descent (paper §4.1 Step 1,
Algorithm 1 lines 1-4).

NN-Descent principle: "a neighbor's neighbors are likely neighbors" — each
round explores every node's 2-hop neighborhood, scores the candidates with
the hybrid distance kernel, and keeps the top-k. The GPU paper runs one warp
per distance; here each round is a fixed-shape batched tensor program:
gather (N, K*K) 2-hop candidate ids -> dedup by id-sort -> hybrid-score ->
merge with current neighbors -> top-k. Everything is jittable and chunkable
over nodes so 1M-document segments stream through device memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.usms import PAD_IDX, FusedVectors
from repro.kernels import ops
from repro.runtime import dispatch


@dataclasses.dataclass(frozen=True)
class KnnConfig:
    k: int = 32  # neighbors kept per node during descent
    iters: int = 6
    extra_random: int = 8  # random candidates injected per round (escape lows)
    node_chunk: int = 2048  # nodes processed per jit call (memory bound)
    use_kernel: bool | None = None  # None -> backend auto (Pallas off-CPU)


def dedup_mask(ids: jax.Array) -> jax.Array:
    """Boolean mask marking the first occurrence of each id in a 1-D array
    (PAD_IDX entries are always masked out). O(L log L), fixed shape."""
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    mask_sorted = first & (sorted_ids != PAD_IDX)
    # scatter back to original positions
    mask = jnp.zeros_like(mask_sorted).at[order].set(mask_sorted)
    return mask


def _merge_topk(
    ids_a, scores_a, ids_b, scores_b, k: int
) -> tuple[jax.Array, jax.Array]:
    """Merge two (.., L) candidate lists into top-k by score with id dedup."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    scores = jnp.concatenate([scores_a, scores_b], axis=-1)
    keep = jax.vmap(dedup_mask)(ids)
    scores = jnp.where(keep, scores, -jnp.inf)
    top, pos = jax.lax.top_k(scores, k)
    out_ids = jnp.take_along_axis(ids, pos, axis=-1)
    out_ids = jnp.where(jnp.isfinite(top), out_ids, PAD_IDX)
    return out_ids, top


def _descent_round_chunk(
    corpus: FusedVectors,
    nbr_ids: jax.Array,  # (N, K) current graph (global)
    chunk_queries: FusedVectors,  # (C, ...) fused vectors of this node chunk
    chunk_node_ids: jax.Array,  # (C,)
    chunk_nbrs: jax.Array,  # (C, K)
    chunk_scores: jax.Array,  # (C, K)
    rand_ids: jax.Array,  # (C, R) random candidate injection
    cfg: KnnConfig,
):
    k = cfg.k
    # 2-hop candidates: neighbors of my neighbors (K*K) + random restarts
    safe = jnp.where(chunk_nbrs >= 0, chunk_nbrs, 0)
    two_hop = jnp.take(nbr_ids, safe, axis=0).reshape(chunk_nbrs.shape[0], k * k)
    two_hop = jnp.where(
        (chunk_nbrs >= 0).repeat(k, axis=-1), two_hop, PAD_IDX
    )
    cand = jnp.concatenate([two_hop, rand_ids], axis=-1)
    # never propose the node itself or ids already in the neighbor list
    cand = jnp.where(cand == chunk_node_ids[:, None], PAD_IDX, cand)
    already = (cand[:, :, None] == chunk_nbrs[:, None, :]).any(-1)
    cand = jnp.where(already, PAD_IDX, cand)
    keep = jax.vmap(dedup_mask)(cand)
    cand = jnp.where(keep, cand, PAD_IDX)
    # fused distance + per-row top-k: the (C, K*K+R) candidate score matrix
    # never materializes outside the kernel. Pre-selecting the candidates'
    # top-k before the merge is exact — cand is internally deduped and
    # disjoint from chunk_nbrs (the ``already`` mask above), so the merge
    # can keep at most k of them anyway.
    sel_scores, sel_pos = ops.fused_topk_vs_ids(
        chunk_queries, corpus, cand, k, use_kernel=cfg.use_kernel
    )
    sel_ids = ops.take_topk_ids(cand, sel_pos)
    return _merge_topk(chunk_nbrs, chunk_scores, sel_ids, sel_scores, k)


# jitted wrapper for the legacy host-driven chunk loop; the device-resident
# pipeline (core/build_pipeline.py) traces the plain body inside lax.map
_descent_round_chunk_jit = jax.jit(
    _descent_round_chunk, static_argnames=("cfg",)
)


def _init_graph(n: int, k: int, key: jax.Array) -> jax.Array:
    """Random initial neighbors, self-loops remapped."""
    ids = jax.random.randint(key, (n, k), 0, n, dtype=jnp.int32)
    return jnp.where(ids == jnp.arange(n, dtype=jnp.int32)[:, None], (ids + 1) % n, ids)


def build_knn_graph(
    corpus: FusedVectors,
    cfg: KnnConfig,
    key: jax.Array,
    *,
    queries: FusedVectors | None = None,
    init_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """NN-Descent over the fused corpus. Returns (nbr_ids (N,K), scores (N,K))
    sorted by hybrid score descending per row.

    queries: optional weight-scaled view of the corpus (Theorem 1) — used for
        the per-path refinement rounds that feed the single-path neighbor
        slots of the pruned edge lists (paper Step 2 tail).
    init_ids: optional (N, >=K) warm-start graph (e.g. the fused k-NN graph).
    """
    n = corpus.n
    k = cfg.k
    queries = corpus if queries is None else queries
    key, k0 = jax.random.split(key)
    if init_ids is None:
        nbr_ids = _init_graph(n, k, k0)
    else:
        nbr_ids = init_ids[:, :k]
        if nbr_ids.shape[1] < k:
            extra = _init_graph(n, k - nbr_ids.shape[1], k0)
            nbr_ids = jnp.concatenate([nbr_ids, extra], axis=1)
    node_ids = jnp.arange(n, dtype=jnp.int32)
    dispatch.tick()
    # fused score + full sort of the initial rows (k == row width, so the
    # fused top-k IS the sort); mirrored operation-for-operation by the
    # pipeline prologue (build_pipeline._descent_init) so both build paths
    # stay bitwise-identical
    top, pos = ops.fused_topk_vs_ids(
        queries, corpus, nbr_ids, k, use_kernel=cfg.use_kernel
    )
    nbr_ids = ops.take_topk_ids(nbr_ids, pos)
    scores = jnp.where(nbr_ids >= 0, top, -jnp.inf)

    for it in range(cfg.iters):
        key, kr = jax.random.split(key)
        rand_ids = jax.random.randint(kr, (n, cfg.extra_random), 0, n, dtype=jnp.int32)
        new_ids = []
        new_scores = []
        for s in range(0, n, cfg.node_chunk):
            e = min(s + cfg.node_chunk, n)
            dispatch.tick()
            ids_c, sc_c = _descent_round_chunk_jit(
                corpus,
                nbr_ids,
                queries[slice(s, e)],
                node_ids[s:e],
                nbr_ids[s:e],
                scores[s:e],
                rand_ids[s:e],
                cfg,
            )
            new_ids.append(ids_c)
            new_scores.append(sc_c)
        nbr_ids = jnp.concatenate(new_ids, axis=0)
        scores = jnp.concatenate(new_scores, axis=0)
    return nbr_ids, scores


def reverse_neighbors(nbr_ids: jax.Array, cap: int) -> jax.Array:
    """Fixed-width reverse adjacency: rev[v] lists up to ``cap`` nodes u with
    v in N(u). Built via id-sort + per-group position (fixed shapes)."""
    n, k = nbr_ids.shape
    dst = nbr_ids.reshape(-1)
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    valid = dst >= 0
    dst_s = jnp.where(valid, dst, n)  # push invalid to the end
    order = jnp.argsort(dst_s)
    dst_sorted = dst_s[order]
    src_sorted = src[order]
    group_start = jnp.searchsorted(dst_sorted, dst_sorted, side="left")
    pos = jnp.arange(n * k) - group_start
    pos = jnp.where((dst_sorted < n) & (pos < cap), pos, cap)  # cap -> dropped
    rev = jnp.full((n, cap), PAD_IDX, jnp.int32)
    rev = rev.at[jnp.clip(dst_sorted, 0, n - 1), pos].set(src_sorted, mode="drop")
    return rev


def new_node_reverse(
    merged_ids: jax.Array, n_old: int, cap: int
) -> jax.Array:
    """Reverse adjacency among the NEW nodes of an insert batch.

    merged_ids: (n_new, K) candidate lists holding GLOBAL ids — old-corpus
    ids are < n_old, new-node ids are >= n_old. Only new-node targets have
    rows in the returned (n_new, cap) table; old-corpus targets are dropped
    (their back-links are handled by the insert back-link pass). Returned
    source ids are GLOBAL (>= n_old).

    This exists because feeding global ids straight into
    ``reverse_neighbors`` treats old-corpus ids < n_new as new-node-local
    row indices, scattering old-corpus targets into wrong rows.
    """
    local = jnp.where(merged_ids >= n_old, merged_ids - n_old, PAD_IDX)
    rev = reverse_neighbors(local, cap)
    return jnp.where(rev >= 0, rev + n_old, PAD_IDX)


def knn_recall(nbr_ids: jax.Array, truth_ids: jax.Array) -> float:
    """Fraction of true k-NN recovered (diagnostic for NN-Descent quality)."""
    import numpy as np

    nbr = np.asarray(nbr_ids)
    truth = np.asarray(truth_ids)
    hits = sum(
        len(set(a.tolist()) & set(b.tolist())) for a, b in zip(nbr, truth)
    )
    return hits / truth.size
