"""Distributed retrieval: segment-sharded hybrid index over the production
mesh (paper §5.9 scaling story, Milvus/Starling-style data segments).

Sharding layout on a mesh with axes ("pod", "data", "model") — or any prefix:

  * the corpus is split into S = |pod|x|data| *segments*; every segment owns
    a full standalone hybrid index over its documents (graphs never cross
    segments, exactly like vector-DB data segments, so construction and
    updates stay embarrassingly parallel);
  * the "model" axis shards the *query batch* within each segment group —
    with 2x16 pods x 16-way model that is 512-way parallelism for a batched
    search;
  * each device runs the full beam search on its (segment, query-shard)
    block; results are merged with one all_gather over "model" (reassemble
    the batch) + one all_gather over ("pod", "data") (merge segment top-k) +
    a local top-k — the only collectives in the query path.

The per-device compute (gather + hybrid-distance kernel) is identical to the
single-device path, so the Pallas kernel is reused unchanged.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.build_pipeline import GraphArrays, _build_graph_program, build_index
from repro.core.fusion import (
    FusionSpec,
    PathStats,
    broadcast_spec,
    merge_rows_fused,
)
from repro.core.index import BuildConfig, HybridIndex
from repro.core.logical_edges import LogicalEdges, build_logical_edges
from repro.core.search import SearchParams, SearchResult, search_padded
from repro.core import usms
from repro.core.usms import PAD_IDX, FusedVectors, PathWeights
from repro.runtime import dispatch

SEGMENT_AXES = ("pod", "data")  # axes that shard segments (present subset used)
QUERY_AXIS = "model"  # axis that shards the query batch


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map moved out of jax.experimental around 0.5, and its
    replication-check kwarg was renamed check_rep -> check_vma along the way;
    support every combination (the container pins an older jax than the TPU
    fleet)."""
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, **kw, check_vma=False)
        except TypeError:  # public jax.shard_map, pre-rename kwarg
            return jax.shard_map(f, **kw, check_rep=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, **kw, check_rep=False)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["index", "global_ids"],
    meta_fields=[],
)
@dataclasses.dataclass
class SegmentedIndex:
    """Per-segment hybrid indexes stacked on a leading segment axis.

    index: HybridIndex pytree whose leaves have shape (S, ...).
    global_ids: (S, n_seg) int32 mapping local row -> original doc id.
    """

    index: HybridIndex
    global_ids: jax.Array

    @property
    def n_segments(self) -> int:
        return self.global_ids.shape[0]


def segment_slices(n: int, n_segments: int) -> list[tuple[int, int]]:
    """Contiguous per-segment (lo, hi) slices; trailing segments may be
    EMPTY (lo == hi) when n < n_segments * ceil(n/n_segments) — e.g. after
    a compaction shrank the corpus below the segment layout."""
    per = -(-n // n_segments)  # ceil
    return [
        (min(s * per, n), min((s + 1) * per, n)) for s in range(n_segments)
    ]


def shard_corpus(
    corpus: FusedVectors, n_segments: int
) -> tuple[list[FusedVectors], np.ndarray]:
    """Split a corpus into equal segments (last one zero-padded).
    Returns per-segment corpora and the (S, n_seg) global id map."""
    n = corpus.n
    per = -(-n // n_segments)
    gids = np.full((n_segments, per), PAD_IDX, np.int32)
    parts = []
    for s, (lo, hi) in enumerate(segment_slices(n, n_segments)):
        gids[s, : hi - lo] = np.arange(lo, hi)
        part = corpus[slice(lo, hi)]
        pad = per - (hi - lo)
        if pad:
            part = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]
                ),
                part,
            )
        parts.append(part)
    return parts, gids


def build_segmented_index(
    corpus: FusedVectors,
    n_segments: int,
    cfg: BuildConfig = BuildConfig(),
    *,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
) -> SegmentedIndex:
    """Build every segment's index independently (the distributed-construction
    model: on real hardware each host builds its own segments; here the loop
    is sequential but each build is the same jitted program)."""
    key = key if key is not None else jax.random.key(0)
    parts, gids = shard_corpus(corpus, n_segments)
    indexes = []
    for s, part in enumerate(parts):
        kg_kwargs = {}
        if kg_triplets is not None and doc_entities is not None:
            lo, hi = segment_slices(corpus.n, n_segments)[s]
            ents = np.full((part.n, doc_entities.shape[1]), PAD_IDX, np.int32)
            ents[: hi - lo] = doc_entities[lo:hi]
            kg_kwargs = dict(
                kg_triplets=kg_triplets, doc_entities=ents, n_entities=n_entities
            )
        idx = build_index(part, cfg, key=jax.random.fold_in(key, s), **kg_kwargs)
        # padded rows must never be returned
        valid = jnp.asarray(gids[s] >= 0)
        idx = dataclasses.replace(idx, alive=idx.alive & valid)
        indexes.append(idx)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *indexes)
    return SegmentedIndex(index=stacked, global_ids=jnp.asarray(gids))


def _present_axes(mesh: Mesh, axes: Sequence[str]) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.axis_names)


def mesh_segment_count(mesh: Mesh) -> int:
    """Number of devices on the segment axes. Sharded builds and searches
    require the stacked segment count S to be a MULTIPLE of this (each
    device owns S / mesh_segment_count segments — the segment-pool
    generalization of the old one-segment-per-device contract)."""
    seg_axes = _present_axes(mesh, SEGMENT_AXES)
    return int(np.prod([mesh.shape[a] for a in seg_axes])) if seg_axes else 1


# ---------------------------------------------------------------------------
# Global-id routing (serving-layer grow-segment scheme): deletion and
# compaction need to resolve original doc ids back to (segment, local row).
# ---------------------------------------------------------------------------


def resolve_global_ids(
    seg_index: SegmentedIndex, ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side routing: global doc id -> (segment, local row).

    Ids not present in ``global_ids`` (never indexed here, or compacted away)
    resolve to (-1, -1). Compaction leaves gaps in the id space, so the
    lookup is a searchsorted over the sorted valid ids, not an arange."""
    gids = np.asarray(seg_index.global_ids)
    per = gids.shape[1]
    flat = gids.reshape(-1)
    valid_pos = np.flatnonzero(flat >= 0)
    ids = np.atleast_1d(np.asarray(ids, np.int64))
    if valid_pos.size == 0:
        none = np.full(ids.shape, -1, np.int32)
        return none, none.copy()
    order = np.argsort(flat[valid_pos], kind="stable")
    sorted_g = flat[valid_pos][order]
    pos = valid_pos[order]
    j = np.clip(np.searchsorted(sorted_g, ids), 0, sorted_g.size - 1)
    found = (sorted_g[j] == ids) & (ids >= 0)
    p = np.where(found, pos[j], -1)
    seg = np.where(found, p // per, -1).astype(np.int32)
    loc = np.where(found, p % per, -1).astype(np.int32)
    return seg, loc


def mark_deleted_segmented(
    seg_index: SegmentedIndex,
    global_ids: np.ndarray,
    *,
    resolved: Optional[tuple[np.ndarray, np.ndarray]] = None,
) -> SegmentedIndex:
    """Tombstone docs by GLOBAL id: resolve to (segment, local row) and clear
    the per-segment alive mask. Shape-preserving, so cached search
    executables for this index keep serving. Unresolved ids are ignored.
    Pass ``resolved=(seg, loc)`` when the caller already routed the ids —
    skips a second full global_ids materialization + sort."""
    seg, loc = (
        resolved if resolved is not None
        else resolve_global_ids(seg_index, global_ids)
    )
    alive = seg_index.index.alive
    n_seg = alive.shape[0]
    seg_j = jnp.asarray(np.where(seg >= 0, seg, n_seg), jnp.int32)
    loc_j = jnp.asarray(np.where(loc >= 0, loc, 0), jnp.int32)
    alive = alive.at[seg_j, loc_j].set(False, mode="drop")
    return SegmentedIndex(
        index=dataclasses.replace(seg_index.index, alive=alive),
        global_ids=seg_index.global_ids,
    )


def alive_docs(
    seg_index: SegmentedIndex,
) -> tuple[FusedVectors, np.ndarray, np.ndarray]:
    """Gather the live (non-pad, non-tombstoned) docs of every segment on
    the host. Returns (corpus rows, their global ids, their doc-entity
    rows) — the compaction input. The entity rows are all-PAD width-1 for
    an index built without a knowledge graph. Quantized storage is
    dequantized here: every rebuild / merge input is fp32 (builds never see
    int8; re-quantization happens when the rebuilt segment seals)."""
    gids = np.asarray(seg_index.global_ids).reshape(-1)
    alive = np.asarray(seg_index.index.alive).reshape(-1)
    rows = np.flatnonzero((gids >= 0) & alive)
    corpus = jax.tree.map(
        lambda a: jnp.asarray(
            np.asarray(a).reshape((-1,) + a.shape[2:])[rows]
        ),
        seg_index.index.corpus,
    )
    if isinstance(corpus, usms.QuantizedFusedVectors):
        corpus = usms.dequantize_corpus(corpus)
    ents = np.asarray(seg_index.index.doc_entities)
    ents = ents.reshape((-1, ents.shape[-1]))[rows]
    return corpus, gids[rows].astype(np.int32), ents


def compact_segmented_index(
    corpus: FusedVectors,
    global_ids: np.ndarray,
    n_segments: int,
    cfg: BuildConfig = BuildConfig(),
    *,
    mesh: Optional[Mesh] = None,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
) -> SegmentedIndex:
    """Rebuild a corpus of surviving docs into a fresh S-segment sealed
    index, PRESERVING the caller's global ids (positions change, identities
    don't — results keep referring to the original doc ids). Pass the
    knowledge graph (triplets + per-row doc entities) to rebuild the
    logical edges too — without it a KG-bearing index would lose its
    entity paths on compaction.

    Uses the parallel ``build_index_sharded`` when ``n_segments`` is a
    multiple of the mesh's segment-axis device count (the segment-pool
    placement contract), else the sequential ``build_segmented_index``."""
    global_ids = np.asarray(global_ids, np.int32)
    if corpus.n == 0:
        raise ValueError("cannot compact an empty corpus (all docs deleted)")
    if global_ids.shape[0] != corpus.n:
        raise ValueError("global_ids must map every corpus row")
    kg_kwargs = dict(
        kg_triplets=kg_triplets, doc_entities=doc_entities,
        n_entities=n_entities,
    )
    if mesh is not None and n_segments % mesh_segment_count(mesh) == 0:
        seg = build_index_sharded(
            corpus, n_segments, cfg, mesh=mesh, key=key, **kg_kwargs
        )
    else:
        seg = build_segmented_index(corpus, n_segments, cfg, key=key, **kg_kwargs)
    # the build assigned positional ids; remap to the surviving originals
    per = seg.global_ids.shape[1]
    new_g = np.full((n_segments, per), PAD_IDX, np.int32)
    for s, (lo, hi) in enumerate(segment_slices(corpus.n, n_segments)):
        new_g[s, : hi - lo] = global_ids[lo:hi]
    return SegmentedIndex(index=seg.index, global_ids=jnp.asarray(new_g))


def _segment_spec(mesh: Mesh) -> P:
    seg_axes = _present_axes(mesh, SEGMENT_AXES)
    return P(seg_axes if len(seg_axes) > 1 else (seg_axes[0] if seg_axes else None))


# ---------------------------------------------------------------------------
# Segment-parallel construction (paper §4.1 at scale): every device builds
# its segment's graph with the SAME device-resident program the single-device
# path uses (core/build_pipeline.py). Graphs never cross segments, so the
# build has zero collectives and scales linearly with devices.
# ---------------------------------------------------------------------------


_sharded_builder_cache: dict = {}


def make_sharded_graph_builder(mesh: Mesh, cfg: BuildConfig):
    """shard_map wrapper around the fused graph-build program.

    Returns fn(stacked_corpus, seg_key_data) -> GraphArrays with leaves
    (S, ...). Each device owns S / mesh_segment_count segments and streams
    its local block through ``lax.map`` (sequential per local segment, so
    the per-device memory high-water stays one build); keys travel as
    uint32 key data so they shard like ordinary arrays. Builders are cached
    on (mesh, cfg) so repeated sharded builds (periodic segment rebuilds)
    reuse the compiled program."""
    cache_key = (mesh, cfg)
    cached = _sharded_builder_cache.get(cache_key)
    if cached is not None:
        return cached
    spec = _segment_spec(mesh)

    def local_build(corpus_blk: FusedVectors, key_blk: jax.Array) -> GraphArrays:
        def one(args):
            corpus, key_data = args
            return _build_graph_program(
                corpus, jax.random.wrap_key_data(key_data), cfg
            )

        return jax.lax.map(one, (corpus_blk, key_blk))

    graph_specs = GraphArrays(
        knn_ids=spec,
        knn_scores=spec,
        semantic_edges=spec,
        keyword_edges=spec,
        entry_points=spec,
        self_ip=spec,
    )
    builder = jax.jit(
        _shard_map(
            local_build,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: spec, _queries_struct()), spec),
            out_specs=graph_specs,
        )
    )
    _sharded_builder_cache[cache_key] = builder
    return builder


def build_index_sharded(
    corpus: FusedVectors,
    n_segments: int,
    cfg: BuildConfig = BuildConfig(),
    *,
    mesh: Mesh,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
) -> SegmentedIndex:
    """Build every segment's graph IN PARALLEL across the mesh (one
    shard_map dispatch for all device-side stages), then assemble the
    SegmentedIndex on the host (logical edges are host-side numpy).

    Per-segment results match ``build_segmented_index`` (which runs the same
    program sequentially): segment s is built from ``fold_in(key, s)``."""
    key = key if key is not None else jax.random.key(0)
    n_mesh_segs = mesh_segment_count(mesh)
    if n_segments % n_mesh_segs != 0:
        raise ValueError(
            f"n_segments={n_segments} must be a multiple of the segment-axes "
            f"device count {n_mesh_segs} (each device builds "
            f"n_segments / {n_mesh_segs} segments)"
        )
    dispatch.build_rows_tick(corpus.n)
    parts, gids = shard_corpus(corpus, n_segments)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *parts)
    seg_keys = jnp.stack(
        [
            jax.random.key_data(jax.random.fold_in(key, s))
            for s in range(n_segments)
        ]
    )
    sharding = NamedSharding(mesh, _segment_spec(mesh))
    stacked = jax.tree.map(lambda a: jax.device_put(a, sharding), stacked)
    seg_keys = jax.device_put(seg_keys, sharding)

    builder = make_sharded_graph_builder(mesh, cfg)
    dispatch.tick()
    g = builder(stacked, seg_keys)  # GraphArrays, leaves (S, ...)

    # host-side assembly: logical edges + alive masks per segment
    per = gids.shape[1]
    slices = segment_slices(corpus.n, n_segments)
    logs = []
    for s in range(n_segments):
        if kg_triplets is not None and doc_entities is not None and n_entities > 0:
            lo, hi = slices[s]
            ents = np.full((per, doc_entities.shape[1]), PAD_IDX, np.int32)
            ents[: hi - lo] = doc_entities[lo:hi]
            logs.append(
                build_logical_edges(
                    kg_triplets,
                    ents,
                    n_entities,
                    l_cap=cfg.logical_cap,
                    m_cap=cfg.entity_doc_cap,
                )
            )
        else:
            logs.append(LogicalEdges.empty(per))
    stack_log = lambda get: jnp.stack([jnp.asarray(get(l)) for l in logs], axis=0)
    alive = jnp.asarray(gids >= 0)

    index = HybridIndex(
        corpus=stacked,
        semantic_edges=g.semantic_edges,
        keyword_edges=g.keyword_edges,
        logical_edges=stack_log(lambda l: l.edges),
        doc_entities=stack_log(lambda l: l.doc_entities),
        entity_to_docs=stack_log(lambda l: l.entity_to_docs),
        entity_adj=stack_log(lambda l: l.entity_adj),
        entry_points=g.entry_points,
        alive=alive,
        self_ip=g.self_ip,
    )
    return SegmentedIndex(index=index, global_ids=jnp.asarray(gids))


def _segment_to_global(
    idx: HybridIndex,
    gids: jax.Array,
    queries: FusedVectors,
    fusion: FusionSpec,
    keywords: jax.Array,
    entities: jax.Array,
    params: SearchParams,
):
    """One segment's search with local row ids mapped to GLOBAL doc ids
    (-inf scores on pad slots) — the unit every segment/pool merge
    composes. Per-path scores ride along so fusion-aware merges can
    recompute RRF ranks over the union (the merge contract, §11)."""
    res = search_padded(idx, queries, fusion, keywords, entities, params)
    g = jnp.where(
        res.ids >= 0, gids[jnp.clip(res.ids, 0, gids.shape[0] - 1)], PAD_IDX
    )
    scores = jnp.where(g >= 0, res.scores, -jnp.inf)
    ps = jnp.where((g >= 0)[:, :, None], res.path_scores, 0.0)
    return g, scores, ps, res.expanded


def _merge_rows_topk(g_all: jax.Array, s_all: jax.Array, k: int):
    """Per-row top-k over stacked (S, B, k) global-id results; returns
    (top scores, ids) with PAD ids on non-finite slots. Raw-score merge:
    correct for weighted/normalized fusion only — RRF results go through
    ``fusion.merge_rows_fused`` instead."""
    b = g_all.shape[1]
    g_flat = jnp.moveaxis(g_all, 0, 1).reshape(b, -1)
    s_flat = jnp.moveaxis(s_all, 0, 1).reshape(b, -1)
    top, pos = jax.lax.top_k(s_flat, k)
    ids = jnp.where(
        jnp.isfinite(top), jnp.take_along_axis(g_flat, pos, axis=-1), PAD_IDX
    )
    return top, ids


def make_distributed_search_padded(
    mesh: Mesh,
    params: SearchParams,
):
    """Build the jitted shard_map search for a given mesh, shape-stable form.

    Returns fn(seg_index, queries, fusion, keywords, entities) ->
    SearchResult with globally-merged ids. Fusion/keywords/entities travel
    as traced data per call (fusion leaves must be (B,)/(B, 3) arrays so
    they shard with the query batch), so one executable serves every path
    combination AND every fusion mode — this is the entry point the serving
    layer fronts sharded indexes with. Queries are sharded over the "model"
    axis (if present); the segmented index is sharded over ("pod", "data").
    S may be any MULTIPLE of the segment-axes device count: a device owning
    several segments searches them in one vmapped pass; all S segments'
    top-k then merge in ONE fusion-aware pass after the segment-axes gather
    (RRF rows re-rank over the union — merging local RRF scores by value
    across segments would be meaningless, §11).
    """
    seg_axes = _present_axes(mesh, SEGMENT_AXES)
    q_axes = _present_axes(mesh, (QUERY_AXIS,))
    seg_spec = _segment_spec(mesh)
    q_spec = P(q_axes[0]) if q_axes else P()
    NEG_FILL = jnp.float32(-1e30)

    def local_search(
        seg_index: SegmentedIndex,
        queries: FusedVectors,
        fusion: FusionSpec,
        keywords: jax.Array,
        entities: jax.Array,
    ):
        # shard_map gives each device a (segments_per_device, ...) block
        spd = seg_index.global_ids.shape[0]
        if spd == 1:
            g, scores, ps, exp = _segment_to_global(
                jax.tree.map(lambda a: a[0], seg_index.index),
                seg_index.global_ids[0],
                queries, fusion, keywords, entities, params,
            )
            g, scores, ps = g[None], scores[None], ps[None]
        else:
            # several same-device segments: one vmapped batched pass
            g, scores, ps, exp = jax.vmap(
                lambda idx, gids: _segment_to_global(
                    idx, gids, queries, fusion, keywords, entities, params
                )
            )(seg_index.index, seg_index.global_ids)  # (spd, B, k)
        expanded_local = exp.sum()

        # gather the OTHER devices' segment results FIRST, while rows are
        # still aligned with this device's local query shard (the fusion
        # spec rows are local), and fuse-merge all S segments in one pass
        if seg_axes:
            g = jax.lax.all_gather(g, seg_axes, axis=0, tiled=True)
            scores = jax.lax.all_gather(scores, seg_axes, axis=0, tiled=True)
            ps = jax.lax.all_gather(ps, seg_axes, axis=0, tiled=True)
        ids, top, ps_m = merge_rows_fused(g, scores, ps, fusion, params.k)

        # reassemble the query batch across the model axis
        if q_axes:
            ids = jax.lax.all_gather(ids, q_axes[0], axis=0, tiled=True)
            top = jax.lax.all_gather(top, q_axes[0], axis=0, tiled=True)
            ps_m = jax.lax.all_gather(ps_m, q_axes[0], axis=0, tiled=True)
        expanded = expanded_local
        all_axes = tuple(seg_axes) + tuple(q_axes)
        if all_axes:
            expanded = jax.lax.psum(expanded, all_axes)
        return (
            ids,
            jnp.where(jnp.isfinite(top), top, NEG_FILL),
            ps_m,
            expanded,
        )

    shard_fn = _shard_map(
        local_search,
        mesh=mesh,
        in_specs=(
            # a single prefix spec for the whole SegmentedIndex: every leaf
            # shards over the segment axes regardless of whether the corpus
            # subtree is FusedVectors or QuantizedFusedVectors (§13)
            seg_spec,
            jax.tree.map(lambda _: q_spec, _queries_struct()),
            jax.tree.map(lambda _: q_spec, _fusion_struct()),
            q_spec,
            q_spec,
        ),
        out_specs=(P(), P(), P(), P()),
    )

    @jax.jit
    def run(
        seg_index: SegmentedIndex,
        queries: FusedVectors,
        fusion: Union[FusionSpec, PathWeights],
        keywords: jax.Array,
        entities: jax.Array,
    ) -> SearchResult:
        if isinstance(fusion, PathWeights):
            fusion = FusionSpec.from_weights(fusion)
        spec = broadcast_spec(fusion, queries.dense.shape[0])
        ids, scores, ps, expanded = shard_fn(
            seg_index, queries, spec, keywords, entities
        )
        return SearchResult(
            ids, scores, jnp.broadcast_to(expanded, (ids.shape[0],)), ps
        )

    return run


_local_group_search_cache: dict = {}


def make_local_group_search(params: SearchParams):
    """Single-host counterpart of ``make_distributed_search_padded``: search
    a stacked ``SegmentedIndex`` (a segment-pool group) with one vmapped
    ``search_padded`` pass over the leading segment axis and merge the
    per-segment top-k in global-id space — no mesh, no collectives. This is
    the executable the serving layer AOT-caches per pool shape-group when a
    group is not placed on (or not divisible over) the mesh's segment axes.
    Cached on ``params`` so every caller shares one jit cache."""
    cached = _local_group_search_cache.get(params)
    if cached is not None:
        return cached
    NEG_FILL = jnp.float32(-1e30)

    @jax.jit
    def run(
        seg_index: SegmentedIndex,
        queries: FusedVectors,
        fusion: Union[FusionSpec, PathWeights],
        keywords: jax.Array,
        entities: jax.Array,
    ) -> SearchResult:
        if isinstance(fusion, PathWeights):
            fusion = FusionSpec.from_weights(fusion)
        spec = broadcast_spec(fusion, queries.dense.shape[0])
        g_all, s_all, ps_all, exp = jax.vmap(
            lambda idx, gids: _segment_to_global(
                idx, gids, queries, spec, keywords, entities, params
            )
        )(seg_index.index, seg_index.global_ids)  # (S, B, k)
        ids, top, ps = merge_rows_fused(g_all, s_all, ps_all, spec, params.k)
        scores = jnp.where(jnp.isfinite(top), top, NEG_FILL)
        # whole-batch total broadcast per row — the same convention as the
        # sharded executable, so pool reads can sum the two coherently
        expanded = jnp.broadcast_to(exp.sum(), (ids.shape[0],))
        return SearchResult(ids, scores, expanded, ps)

    _local_group_search_cache[params] = run
    return run


def make_distributed_search(
    mesh: Mesh,
    fusion: Union[FusionSpec, PathWeights],
    params: SearchParams,
):
    """Fixed-fusion convenience wrapper over the shape-stable form (accepts
    a ``FusionSpec`` or bare ``PathWeights`` = weighted-sum).

    Returns fn(seg_index, queries) -> SearchResult with globally-merged ids.
    """
    run = make_distributed_search_padded(mesh, params)

    def fn(seg_index: SegmentedIndex, queries: FusedVectors) -> SearchResult:
        b = queries.dense.shape[0]
        pad = jnp.full((b, 1), PAD_IDX, jnp.int32)
        return run(seg_index, queries, fusion, pad, pad)

    return fn


def _index_struct():
    """A HybridIndex-shaped pytree of placeholders for building spec trees."""
    z = 0
    return HybridIndex(
        corpus=_queries_struct(),
        semantic_edges=z,
        keyword_edges=z,
        logical_edges=z,
        doc_entities=z,
        entity_to_docs=z,
        entity_adj=z,
        entry_points=z,
        alive=z,
        self_ip=z,
    )


def _queries_struct():
    from repro.core.usms import SparseVec

    z = 0
    return FusedVectors(dense=z, learned=SparseVec(z, z), lexical=SparseVec(z, z))


def _weights_struct():
    z = 0
    return PathWeights(dense=z, sparse=z, full=z, kg=z)


def _fusion_struct():
    """A FusionSpec-shaped pytree of placeholders (stats RESOLVED: the
    sharded entry point broadcasts specs before crossing into shard_map, so
    the in-spec tree always carries concrete stats leaves)."""
    z = 0
    return FusionSpec(
        mode=z,
        weights=_weights_struct(),
        rrf_k=z,
        stats=PathStats(minv=z, maxv=z, mean=z, std=z),
    )


def place_segmented_index(
    seg_index: SegmentedIndex, mesh: Mesh
) -> SegmentedIndex:
    """Device_put the segmented index with segments over ("pod", "data")."""
    sharding = NamedSharding(mesh, _segment_spec(mesh))
    return jax.tree.map(
        lambda a: jax.device_put(a, sharding) if hasattr(a, "shape") else a, seg_index
    )


# ---------------------------------------------------------------------------
# Distributed construction round (for the construction dry-run at scale):
# each segment runs one NN-Descent round locally under shard_map.
# ---------------------------------------------------------------------------


def make_distributed_descent_round(mesh: Mesh, cfg):
    """One lock-step NN-Descent round across all segments (shard_map). The
    graph of each segment is private, so no cross-device collectives appear in
    the construction path — the build scales linearly with devices."""
    from repro.core.knn_graph import _descent_round_chunk

    spec = _segment_spec(mesh)

    def local_round(corpus, nbr_ids, scores, rand_ids):
        corpus = jax.tree.map(lambda a: a[0], corpus)
        nbr_ids, scores, rand_ids = nbr_ids[0], scores[0], rand_ids[0]
        n = nbr_ids.shape[0]
        node_ids = jnp.arange(n, dtype=jnp.int32)
        ids, sc = _descent_round_chunk(
            corpus, nbr_ids, corpus, node_ids, nbr_ids, scores, rand_ids, cfg
        )
        return ids[None], sc[None]

    return jax.jit(
        _shard_map(
            local_round,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: spec, _queries_struct()),
                spec,
                spec,
                spec,
            ),
            out_specs=(spec, spec),
        )
    )
