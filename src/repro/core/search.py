"""Flexible query processing on the hybrid index (paper §4.2, Algorithm 2).

Decouples computation from storage: path weights live in the *query* (Theorem
1), keyword edges load dynamically only at nodes sharing a query keyword
(§4.2.2), and logical edges load only within ``kg_max_hops`` of the query
entities (§4.2.3) — so one index serves every path combination with zero
reconstruction.

GPU -> TPU: the CUDA best-first loop with hash-table visited sets becomes a
fixed-iteration batched beam search — bounded candidate pool as sorted
arrays, ``lax.top_k`` merges, id-matching dedup against pool + visited ring —
vmapped over the query batch under ``lax.fori_loop``. The hybrid distances of
each expansion go through the same Pallas kernel as construction.

Twin candidate pool (§4.2.2): keyword-satisfying nodes that fall out of the
primary pool are retained in a secondary pool; final results merge both and
filter for required keywords.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.fusion import (
    FusionSpec,
    as_fusion_spec,
    broadcast_spec,
    fuse_candidates,
)
from repro.core.index import HybridIndex
from repro.core.knn_graph import dedup_mask
from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    PathWeights,
    SparseVec,
    has_keyword_overlap,
    weighted_query,
)
from repro.kernels import ops
from repro.obs.metrics import GLOBAL as _OBS

NEG = -1e30
INF_HOP = jnp.int32(10**6)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    k: int = 10
    iters: int = 48  # expansion rounds (search breadth ~ iters * expand)
    pool_size: int = 64  # primary candidate pool
    kw_pool_size: int = 16  # twin pool for keyword-satisfying overflow
    expand: int = 1  # nodes expanded per round (CAGRA-style multi-expansion;
    # >1 cuts the sequential merge/top_k rounds ~expand-fold — §Perf)
    use_kernel: bool | None = None  # None -> backend auto: Pallas off-CPU,
    # jnp oracle on CPU (ops.resolve_use_kernel); pin via resolve_params()
    # before using params as a jit/AOT cache key
    use_keywords: bool = False  # enable keyword edge loading + filtering
    use_kg: bool = False  # enable logical edge traversal
    kg_max_hops: int = 3  # x: max entity hops for logical expansion
    corpus_dtype: str = "float32"  # sealed-corpus storage: "float32" or
    # "int8" (symmetric per-row int8 dense + fp16 sparse vals, quantized at
    # seal/compact time; traversal scores on quantized storage, the final
    # pool re-scores at full precision). A build/cache-key property — it
    # selects the corpus pytree the index carries, never traced data.


CORPUS_DTYPES = ("float32", "int8")


def resolve_params(params: SearchParams) -> SearchParams:
    """Pin backend-auto fields to concrete values.

    ``use_kernel=None`` resolves to the backend default (Pallas off-CPU).
    Callers that use ``SearchParams`` as a cache key — the serving AOT
    executable cache above all — must key on the *resolved* params so a
    kernel-mode change can never alias a stale executable.
    """
    if params.corpus_dtype not in CORPUS_DTYPES:
        raise ValueError(
            f"corpus_dtype must be one of {CORPUS_DTYPES}, "
            f"got {params.corpus_dtype!r}"
        )
    if params.use_kernel is None:
        return dataclasses.replace(
            params, use_kernel=ops.resolve_use_kernel(None)
        )
    return params


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["ids", "scores", "expanded", "path_scores"],
    meta_fields=["down_replicas"],
)
@dataclasses.dataclass
class SearchResult:
    ids: jax.Array  # (B, k) int32
    scores: jax.Array  # (B, k) f32 fused scores (mode-dependent scale)
    expanded: jax.Array  # (B,) int32 number of expanded nodes (work measure)
    # per-path raw scores of the winners, (B, k, 3) f32 [dense, learned,
    # lexical], zero on PAD slots — every downstream merge recomputes RRF
    # ranks from these (the cross-segment/replica merge contract, §11)
    path_scores: Optional[jax.Array] = None
    # replica names whose shards this result is missing (degraded scatter
    # read, DESIGN.md §9); None for single-index results and healthy tiers.
    # meta field: a hashable tuple, so tracing never specializes on it
    down_replicas: Optional[tuple] = None


def _entry_state(index: HybridIndex, q_entities: jax.Array, p: SearchParams):
    """Entry points: nodes containing user-specified entities when querying
    with the KG, else the precomputed large-norm nodes (Algorithm 2 l.2-8)."""
    n = index.n
    base = index.entry_points  # (n_entry,)
    base_ent = jnp.full(base.shape, PAD_IDX, jnp.int32)
    if p.use_kg:
        ent_safe = jnp.clip(q_entities, 0, index.entity_to_docs.shape[0] - 1)
        ent_docs = index.entity_to_docs[ent_safe]  # (Eq, M)
        valid_e = (q_entities >= 0)[:, None] & (ent_docs >= 0)
        ent_ids = jnp.where(valid_e, ent_docs, PAD_IDX).reshape(-1)
        ent_of = jnp.where(
            valid_e, q_entities[:, None], PAD_IDX
        ).reshape(-1)
        ids = jnp.concatenate([ent_ids, base])
        ents = jnp.concatenate([ent_of, base_ent])
    else:
        ids, ents = base, base_ent
    keep = dedup_mask(ids)
    ids = jnp.where(keep, ids, PAD_IDX)
    hops = jnp.where(ents >= 0, 0, INF_HOP)
    return ids, ents, hops


def _search_one(
    index: HybridIndex,
    qw: FusedVectors,  # weight-scaled query (single, no batch dim)
    q_raw: FusedVectors,  # UNWEIGHTED query (per-path re-scoring, modes 1-3)
    q_keywords: jax.Array,  # (Kw,) required keyword ids (PAD padded)
    q_entities: jax.Array,  # (Eq,) query entity ids (PAD padded)
    spec: FusionSpec,  # scalar-leaf spec row (mode/weights/rrf_k/stats)
    p: SearchParams,
):
    n = index.n
    P = p.pool_size
    w_kg = spec.weights.kg  # logical-path traversal bias weight
    q_b = jax.tree.map(lambda a: a[None], qw)  # add batch dim for the kernel

    def score_ids(ids):
        return ops.hybrid_scores_vs_ids(
            q_b, index.corpus, ids[None], use_kernel=p.use_kernel
        )[0]

    # ---- init pool --------------------------------------------------------
    e_ids, e_ents, e_hops = _entry_state(index, q_entities, p)
    ne = e_ids.shape[0]
    assert ne <= P, "pool_size must cover the entry set"
    e_scores = jnp.where(e_ids >= 0, score_ids(e_ids), NEG)
    if p.use_kg:
        # entity-matched entry points get the full hop-0 logical reward so the
        # traversal actually explores them (deviation from Algorithm 2 line 9,
        # which would leave chain heads with near-zero semantic score
        # unexpanded; see DESIGN.md §2)
        e_scores = jnp.where(
            (e_ents >= 0) & (e_ids >= 0), e_scores + w_kg, e_scores
        )
    pad = lambda a, fill: jnp.concatenate(
        [a, jnp.full((P - ne,) + a.shape[1:], fill, a.dtype)]
    )
    E = p.expand
    pool_ids = pad(e_ids, PAD_IDX)
    pool_scores = pad(e_scores, NEG)
    pool_visited = pad(jnp.zeros((ne,), bool), True)
    pool_ents = pad(e_ents, PAD_IDX)
    pool_hops = pad(e_hops, INF_HOP)
    ring = jnp.full((p.iters * E,), PAD_IDX, jnp.int32)
    kw_ids = jnp.full((p.kw_pool_size,), PAD_IDX, jnp.int32)
    kw_scores = jnp.full((p.kw_pool_size,), NEG, jnp.float32)
    n_expanded = jnp.int32(0)

    def body(i, state):
        (pool_ids, pool_scores, pool_visited, pool_ents, pool_hops, ring,
         kw_ids, kw_scores, n_expanded) = state

        # ---- pick the E best unvisited candidates (Algorithm 2 l.11;
        # multi-expansion per round cuts sequential merge cost — §Perf) ----
        sel = jnp.where(~pool_visited & (pool_ids >= 0), pool_scores, NEG)
        sel_top, js = jax.lax.top_k(sel, E)  # (E,)
        active = sel_top > NEG
        u = jnp.where(active, pool_ids[js], PAD_IDX)  # (E,)
        u_safe = jnp.clip(u, 0, n - 1)
        u_ent = pool_ents[js]
        u_hop = pool_hops[js]
        pool_visited = pool_visited.at[js].set(True)
        ring = jax.lax.dynamic_update_slice_in_dim(ring, u, i * E, axis=0)
        n_expanded = n_expanded + active.sum().astype(jnp.int32)

        # ---- gather neighbor lists (l.13-17, dynamic edge loading) ----
        parts_ids = [index.semantic_edges[u_safe]]  # (E, d)
        parts_ents = [jnp.full((E, index.degree), PAD_IDX, jnp.int32)]
        if p.use_keywords:
            shares = has_keyword_overlap(
                index.corpus.lexical.idx[u_safe], q_keywords[None, :]
            )  # (E,)
            kwe = jnp.where(
                shares[:, None], index.keyword_edges[u_safe], PAD_IDX
            )
            parts_ids.append(kwe)
            parts_ents.append(jnp.full(kwe.shape, PAD_IDX, jnp.int32))
        if p.use_kg:
            loge = index.logical_edges[u_safe]  # (E, L, 4)
            ok = (
                (u_ent[:, None] >= 0)
                & (u_hop[:, None] < p.kg_max_hops)
                & (loge[:, :, 1] == u_ent[:, None])
                & (loge[:, :, 0] >= 0)
            )
            parts_ids.append(jnp.where(ok, loge[:, :, 0], PAD_IDX))
            parts_ents.append(jnp.where(ok, loge[:, :, 3], PAD_IDX))
        nbr_ids2 = jnp.concatenate(parts_ids, axis=1)  # (E, W)
        nbr_log_ents2 = jnp.concatenate(parts_ents, axis=1)
        nbr_ids2 = jnp.where(active[:, None], nbr_ids2, PAD_IDX)
        src_hop2 = jnp.broadcast_to(u_hop[:, None], nbr_ids2.shape)
        src_ent2 = jnp.broadcast_to(u_ent[:, None], nbr_ids2.shape)
        nbr_ids = nbr_ids2.reshape(-1)
        nbr_log_ents = nbr_log_ents2.reshape(-1)
        src_hop = src_hop2.reshape(-1)
        src_ent = src_ent2.reshape(-1)

        # ---- dedup vs pool, visited ring, and within the list ----
        dup = (nbr_ids[:, None] == pool_ids[None, :]).any(-1)
        dup |= (nbr_ids[:, None] == ring[None, :]).any(-1)
        nbr_ids = jnp.where(dup | ~dedup_mask(nbr_ids), PAD_IDX, nbr_ids)

        # ---- entity matching for semantic expansions (l.19-20) ----
        if p.use_kg:
            cand_ents = index.doc_entities[jnp.clip(nbr_ids, 0, n - 1)]  # (W, Ed)
            src_ent_safe = jnp.clip(src_ent, 0, index.entity_adj.shape[0] - 1)
            rel = (
                index.entity_adj[
                    src_ent_safe[:, None], jnp.clip(cand_ents, 0, index.entity_adj.shape[0] - 1)
                ]
                & (cand_ents >= 0)
                & (src_ent[:, None] >= 0)
            )  # (W, Ed)
            first = jnp.argmax(rel, axis=-1)
            sem_match = jnp.where(
                rel.any(-1), jnp.take_along_axis(cand_ents, first[:, None], -1)[:, 0], PAD_IDX
            )
            o_ents = jnp.where(nbr_log_ents >= 0, nbr_log_ents, sem_match)
            o_hops = jnp.where(
                (o_ents >= 0) & (nbr_ids >= 0),
                jnp.minimum(src_hop + 1, INF_HOP),
                INF_HOP,
            )
            reward = jnp.where(
                o_hops < INF_HOP, w_kg / jnp.maximum(o_hops, 1).astype(jnp.float32), 0.0
            )
        else:
            o_ents = jnp.full(nbr_ids.shape, PAD_IDX, jnp.int32)
            o_hops = jnp.full(nbr_ids.shape, INF_HOP)
            reward = jnp.zeros(nbr_ids.shape, jnp.float32)

        # ---- fused hybrid distance + top-k over the round (l.21-25) ----
        # All E expanded nodes' neighbor lists ride the candidate axis of ONE
        # fused kernel invocation (multi-node batching: the pinned query
        # block amortizes over every node's tiles), the kg reward enters as
        # the pre-selection bias, and only the round's top-kr survivors come
        # back — the (W,) score vector never round-trips through HBM on the
        # kernel path. Pre-selecting the round is exact:
        # top_P(pool ∪ round) == top_P(pool ∪ top_kr(round)) for kr >= min(P, W),
        # and tie order is preserved (fused selection prefers low positions,
        # matching the concat order lax.top_k would have seen).
        W = nbr_ids.shape[0]
        kr = min(P, W)
        sel_scores, sel_pos = ops.fused_topk_vs_ids(
            q_b, index.corpus, nbr_ids[None], kr,
            bias=reward[None], use_kernel=p.use_kernel,
        )
        sel_scores, sel_pos = sel_scores[0], sel_pos[0]
        sel_ids = ops.take_topk_ids(nbr_ids, sel_pos)
        sel_ents = ops.take_topk(o_ents, sel_pos, PAD_IDX)
        sel_hops = ops.take_topk(o_hops, sel_pos, INF_HOP)

        all_ids = jnp.concatenate([pool_ids, sel_ids])
        all_scores = jnp.concatenate([pool_scores, sel_scores])
        all_visited = jnp.concatenate([pool_visited, jnp.zeros(sel_ids.shape, bool)])
        all_ents = jnp.concatenate([pool_ents, sel_ents])
        all_hops = jnp.concatenate([pool_hops, sel_hops])
        top, pos = jax.lax.top_k(all_scores, P)
        pool_ids = jnp.where(top > NEG, all_ids[pos], PAD_IDX)
        pool_scores = top
        pool_visited = all_visited[pos] | (top <= NEG)
        pool_ents = all_ents[pos]
        pool_hops = all_hops[pos]

        # ---- twin pool: keyword-satisfying candidates (l.26-28) ----
        # Same fused selection over the keyword-matching subset. Candidates
        # already resident in the twin pool are PAD'd out *before* selection
        # (the pre-selection dedup that makes top_kk exact), so
        # top_kwP(kw ∪ matched) == top_kwP(kw ∪ top_kk(matched \ kw)).
        if p.use_keywords:
            cand_kw = index.corpus.lexical.idx[jnp.clip(nbr_ids, 0, n - 1)]
            matches = has_keyword_overlap(cand_kw, q_keywords) & (nbr_ids >= 0)
            in_kw = (nbr_ids[:, None] == kw_ids[None, :]).any(-1)
            kw_cand = jnp.where(matches & ~in_kw, nbr_ids, PAD_IDX)
            kk = min(p.kw_pool_size, W)
            kwsel_scores, kwsel_pos = ops.fused_topk_vs_ids(
                q_b, index.corpus, kw_cand[None], kk,
                bias=reward[None], use_kernel=p.use_kernel,
            )
            kwsel_ids = ops.take_topk_ids(kw_cand, kwsel_pos[0])
            m_ids = jnp.concatenate([kw_ids, kwsel_ids])
            m_scores = jnp.concatenate([kw_scores, kwsel_scores[0]])
            kw_top, kw_pos = jax.lax.top_k(m_scores, p.kw_pool_size)
            kw_ids = jnp.where(kw_top > NEG, m_ids[kw_pos], PAD_IDX)
            kw_scores = kw_top

        return (pool_ids, pool_scores, pool_visited, pool_ents, pool_hops,
                ring, kw_ids, kw_scores, n_expanded)

    state = (pool_ids, pool_scores, pool_visited, pool_ents, pool_hops,
             ring, kw_ids, kw_scores, n_expanded)
    state = jax.lax.fori_loop(0, p.iters, body, state)
    (pool_ids, pool_scores, _, _, _, _, kw_ids, kw_scores, n_expanded) = state

    # ---- final results (l.29-30): merge pools, keyword filter, alive filter
    res_ids = jnp.concatenate([pool_ids, kw_ids])
    res_scores = jnp.concatenate([pool_scores, kw_scores])
    keep = dedup_mask(res_ids)
    alive = index.alive[jnp.clip(res_ids, 0, n - 1)] & (res_ids >= 0)
    valid = keep & alive
    res_scores = jnp.where(valid, res_scores, NEG)
    if p.use_keywords:
        has_req = (q_keywords >= 0).any()
        match = has_keyword_overlap(
            index.corpus.lexical.idx[jnp.clip(res_ids, 0, n - 1)], q_keywords
        )
        valid = valid & ~(has_req & ~match)
        res_scores = jnp.where(has_req & ~match, NEG, res_scores)

    # ---- dynamic fusion (§11): re-score the final candidate pool ----------
    # Traversal always navigated with the weighted-sum score (qw); the
    # fusion mode only re-scores the merged pool. Per-path raw scores come
    # from the UNWEIGHTED query via three single-path-masked passes through
    # the same scoring op — the shape-stable analogue of keeping separate
    # per-path result lists. In weighted_sum mode the fused scores are
    # exactly ``res_scores`` (bit-compatible default). The KG logical reward
    # is a traversal bias in every mode but enters FINAL scores only through
    # the weighted-sum branch (ranks/normalized sums are score-path-only).
    zeros_like_val = lambda s: SparseVec(s.idx, jnp.zeros_like(s.val))

    def path_score(q_single):
        return ops.hybrid_scores_vs_ids(
            jax.tree.map(lambda a: a[None], q_single),
            index.corpus,
            res_ids[None],
            use_kernel=p.use_kernel,
        )[0]

    q_dense = FusedVectors(
        q_raw.dense, zeros_like_val(q_raw.learned), zeros_like_val(q_raw.lexical)
    )
    q_learned = FusedVectors(
        jnp.zeros_like(q_raw.dense), q_raw.learned, zeros_like_val(q_raw.lexical)
    )
    q_lexical = FusedVectors(
        jnp.zeros_like(q_raw.dense), zeros_like_val(q_raw.learned), q_raw.lexical
    )
    ps = jnp.stack(
        [path_score(q_dense), path_score(q_learned), path_score(q_lexical)],
        axis=-1,
    )  # (M, 3); -inf on PAD slots -> sanitize before any arithmetic
    ps = jnp.where(valid[:, None], ps, 0.0)
    fused = fuse_candidates(res_scores, ps, valid, spec, NEG)

    top, pos = jax.lax.top_k(fused, p.k)
    ok = top > NEG
    out_ids = jnp.where(ok, res_ids[pos], PAD_IDX)
    out_ps = jnp.where(ok[:, None], ps[pos], 0.0)
    return out_ids, top, out_ps, n_expanded


# incremented once per trace of search_padded (the Python body only runs
# when jit misses its cache) — the observable the shape-bucketing tests
# and the CI obs gate assert on: retraces == compiles for this entry point.
# Lives in the process-wide metrics registry so benches and the serving
# exposition read the same series (obs naming convention, DESIGN.md §12).
_TRACE_COUNTER = _OBS.counter(
    "allanpoe_core_search_padded_traces_total",
    "search_padded (re)traces: jit cache misses for the padded entry point",
)


def search_padded_trace_count() -> int:
    """Process-wide number of ``search_padded`` (re)traces so far."""
    return int(_TRACE_COUNTER.total())


@partial(jax.jit, static_argnames=("params",))
def search_padded(
    index: HybridIndex,
    queries: FusedVectors,
    fusion: Union[FusionSpec, PathWeights],
    keywords: jax.Array,  # (B, Kw) required keywords, PAD_IDX padded
    entities: jax.Array,  # (B, Eq) query entities, PAD_IDX padded
    params: SearchParams,
) -> SearchResult:
    """Shape-stable batched search: every operand is a concrete array with a
    static pad cap and no data-dependent Python branching, so one traced
    executable serves every request mix of a given shape bucket.

    ``fusion`` is a ``FusionSpec`` whose leaves may be scalars (whole-batch)
    or (B,)/(B, 3) arrays (per-query fusion, as micro-batched serving
    requires): mode, weights, rrf_k and stats all enter as traced data, so
    switching ANY of them never recompiles (Theorem 1 extended to the
    dynamic fusion framework, §11). A bare ``PathWeights`` still works
    (silently: jitted code is no place for a once-per-trace warning) and
    means weighted-sum. This is the entry point the serving layer
    AOT-compiles per (bucket shape, SearchParams); ``search()`` is the
    convenience wrapper that fabricates the pad arrays.
    """
    _TRACE_COUNTER.inc()
    if isinstance(fusion, PathWeights):
        fusion = FusionSpec.from_weights(fusion)
    b = queries.dense.shape[0]
    spec = broadcast_spec(fusion, b)
    qw = weighted_query(queries, spec.weights)
    ids, scores, ps, expanded = jax.vmap(
        lambda q, qr, kw, en, sp: _search_one(index, q, qr, kw, en, sp, params)
    )(qw, queries, keywords, entities, spec)
    return SearchResult(ids, scores, expanded, ps)


# retained name for callers of the private batched entry point
_search_batch = search_padded


def search(
    index: HybridIndex,
    queries: FusedVectors,
    fusion: Union[FusionSpec, PathWeights],
    params: SearchParams,
    *,
    keywords: Optional[jax.Array] = None,  # (B, Kw) required keywords
    entities: Optional[jax.Array] = None,  # (B, Eq) query entities
) -> SearchResult:
    """Batched hybrid search with any path combination and fusion mode
    (public API). ``fusion`` is a ``FusionSpec``; passing ``PathWeights``
    still works via the deprecated weighted-sum shim (DeprecationWarning)."""
    spec = as_fusion_spec(fusion)
    b = queries.dense.shape[0]

    def as_padded(a):  # fabricate the PAD array only when absent/empty
        a = None if a is None else jnp.asarray(a, jnp.int32)
        if a is None or a.shape[1] == 0:
            return jnp.full((b, 1), PAD_IDX, jnp.int32)
        return a

    return search_padded(
        index, queries, spec, as_padded(keywords), as_padded(entities), params
    )
