"""Dynamic fusion framework — the query-side half of the paper's "any
combination of retrieval paths and weights without index reconstruction"
claim, extended beyond weighted-sum (DESIGN.md §11).

A ``FusionSpec`` is the single query-side fusion object: it carries the
fusion *mode*, the per-path weights, the RRF constant, and the per-path
normalization stats — all as traced data. Four modes share one compiled
executable per shape bucket:

  * ``weighted_sum`` (mode 0) — today's behavior, bit-compatible: the fused
    score IS the traversal score (Theorem 1's single inner product).
  * ``minmax`` (mode 1) — per-path scores affinely rescaled by the corpus
    min/max stats, then weighted-summed.
  * ``zscore`` (mode 2) — per-path scores standardized by the corpus
    mean/std stats, then weighted-summed.
  * ``rrf`` (mode 3) — Reciprocal Rank Fusion over the per-path ranks of
    the final candidate pool: fused(i) = sum_p w_p / (k_rrf + 1 + rank_p(i)).

Shape stability: the mode is an int32 *array* selected with ``jnp.select``
(the per-query-batched form of ``lax.switch`` — under ``vmap`` a switch on a
traced (B,) operand lowers to a select anyway), so switching mode, weights,
or rrf_k NEVER retraces or recompiles ``search_padded``. Traversal always
navigates with the weighted-sum score (the USMS inner product); modes 1-3
re-score the final candidate pool from per-path raw scores.

Merge contract (cross-segment / cross-replica): raw weighted-sum scores are
globally comparable, normalized scores are comparable ONLY under shared
stats (a router must resolve ONE stats object for all members), and local
RRF scores are NOT comparable at all — every merge level must recompute
ranks over the union from the per-path raw scores that ride along as
``SearchResult.path_scores``. ``merge_fused_host`` enforces this and raises
if asked to merge RRF rows without per-path scores.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.usms import PAD_IDX, FusedVectors, PathWeights

# fusion mode ids (traced int32 data, never part of a cache key)
WEIGHTED_SUM = 0
MINMAX = 1
ZSCORE = 2
RRF = 3

FUSION_MODES = {
    "weighted_sum": WEIGHTED_SUM,
    "minmax": MINMAX,
    "zscore": ZSCORE,
    "rrf": RRF,
}
FUSION_MODE_NAMES = {v: k for k, v in FUSION_MODES.items()}

DEFAULT_RRF_K = 60.0  # the classic RRF constant (Cormack et al.)
N_SCORE_PATHS = 3  # dense / learned-sparse / lexical (kg is a traversal bias)
_EPS = 1e-6
_NEG_FILL = np.float32(-1e30)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["minv", "maxv", "mean", "std"],
    meta_fields=[],
)
@dataclasses.dataclass
class PathStats:
    """Per-path running normalization stats, (3,) or (B, 3) f32 leaves in
    [dense, learned, lexical] order. ``minmax`` normalizes with
    (minv, maxv - minv); ``zscore`` with (mean, std). The identity stats
    make both transforms the identity map."""

    minv: jax.Array
    maxv: jax.Array
    mean: jax.Array
    std: jax.Array

    @classmethod
    def identity(cls) -> "PathStats":
        z = jnp.zeros((N_SCORE_PATHS,), jnp.float32)
        o = jnp.ones((N_SCORE_PATHS,), jnp.float32)
        return cls(minv=z, maxv=o, mean=z, std=o)

    @classmethod
    def from_corpus_parts(cls, parts) -> "PathStats":
        """Stats over one or more (corpus: FusedVectors, alive mask | None)
        pairs — per-path L2 norms of the live rows proxy the per-path score
        scale (scores are inner products against ~unit-scale queries).
        Leaves may carry extra leading axes (stacked segments); they are
        flattened. Host-side numpy: stats refresh is a publish-time event,
        never traced."""
        norms = [[] for _ in range(N_SCORE_PATHS)]
        for corpus, alive in parts:
            if hasattr(corpus, "dense_scale"):  # quantized sealed segment:
                # ||scale * int8 row|| = scale * ||int8 row|| — no need to
                # densify the stored rows back to fp32
                dq = np.asarray(corpus.dense_q, np.float32)
                dq = dq.reshape(-1, dq.shape[-1])
                dense = dq * np.asarray(
                    corpus.dense_scale, np.float32
                ).reshape(-1, 1)
            else:
                dense = np.asarray(corpus.dense)
                dense = dense.reshape(-1, dense.shape[-1])
            lv = np.asarray(corpus.learned.val)
            lv = lv.reshape(-1, lv.shape[-1])
            fv = np.asarray(corpus.lexical.val)
            fv = fv.reshape(-1, fv.shape[-1])
            mask = (
                np.ones(dense.shape[0], bool)
                if alive is None
                else np.asarray(alive).reshape(-1)
            )
            if not mask.any():
                continue
            norms[0].append(np.linalg.norm(dense[mask], axis=-1))
            norms[1].append(np.linalg.norm(lv[mask], axis=-1))
            norms[2].append(np.linalg.norm(fv[mask], axis=-1))
        if not norms[0]:
            return cls.identity()
        f = lambda fn: jnp.asarray(
            [fn(np.concatenate(n)) for n in norms], jnp.float32
        )
        return cls(
            minv=f(np.min), maxv=f(np.max), mean=f(np.mean), std=f(np.std)
        )

    @classmethod
    def from_corpus(cls, corpus: FusedVectors, alive=None) -> "PathStats":
        return cls.from_corpus_parts([(corpus, alive)])

    @classmethod
    def ema(cls, old: "PathStats", new: "PathStats", alpha: float) -> "PathStats":
        """Running blend across snapshot publishes: alpha weights the FRESH
        stats (alpha=1 forgets history). Extremes widen monotonically under
        the blend's min/max so normalized scores never overflow [0, 1] for
        rows both snapshots contained."""
        mix = lambda o, n: (1.0 - alpha) * o + alpha * n
        return cls(
            minv=jnp.minimum(old.minv, new.minv),
            maxv=jnp.maximum(old.maxv, new.maxv),
            mean=mix(old.mean, new.mean),
            std=mix(old.std, new.std),
        )

    @classmethod
    def merge(
        cls, parts: Sequence["PathStats"], counts: Sequence[int]
    ) -> "PathStats":
        """Combine per-shard stats into ONE tier-wide stats object (the
        shared-stats half of the merge contract): count-weighted moment
        pooling for mean/std, extreme-of-extremes for min/max."""
        if not parts:
            return cls.identity()
        c = np.maximum(np.asarray(counts, np.float64), 1.0)
        w = c / c.sum()
        means = np.stack([np.asarray(p.mean, np.float64) for p in parts])
        varis = np.stack([np.asarray(p.std, np.float64) ** 2 for p in parts])
        mean = (w[:, None] * means).sum(0)
        var = (w[:, None] * (varis + means**2)).sum(0) - mean**2
        return cls(
            minv=jnp.asarray(
                np.min([np.asarray(p.minv) for p in parts], axis=0), jnp.float32
            ),
            maxv=jnp.asarray(
                np.max([np.asarray(p.maxv) for p in parts], axis=0), jnp.float32
            ),
            mean=jnp.asarray(mean, jnp.float32),
            std=jnp.asarray(np.sqrt(np.maximum(var, 0.0)), jnp.float32),
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["mode", "weights", "rrf_k", "stats"],
    meta_fields=[],
)
@dataclasses.dataclass
class FusionSpec:
    """The single query-side fusion object: every field is traced data, so
    one compiled executable serves every (mode, weights, rrf_k, stats) mix.

    ``stats=None`` means "resolve downstream": the serving layer injects its
    running corpus stats (``HybridSearchService.path_stats``); the direct
    ``core.search`` path falls back to the identity stats. A batched spec
    has (B,) mode/weight/rrf_k leaves and (B, 3) stats leaves."""

    mode: jax.Array  # int32, scalar or (B,)
    weights: PathWeights
    rrf_k: jax.Array  # f32, scalar or (B,)
    stats: Optional[PathStats] = None

    @classmethod
    def make(
        cls,
        mode="weighted_sum",
        dense=1.0,
        sparse=0.0,
        full=0.0,
        kg=0.0,
        *,
        rrf_k: float = DEFAULT_RRF_K,
        stats: Optional[PathStats] = None,
    ) -> "FusionSpec":
        mode_id = FUSION_MODES[mode] if isinstance(mode, str) else int(mode)
        return cls(
            mode=jnp.asarray(mode_id, jnp.int32),
            weights=PathWeights.make(dense, sparse, full, kg),
            rrf_k=jnp.asarray(rrf_k, jnp.float32),
            stats=stats,
        )

    @classmethod
    def weighted(cls, dense=1.0, sparse=0.0, full=0.0, kg=0.0) -> "FusionSpec":
        return cls.make("weighted_sum", dense, sparse, full, kg)

    @classmethod
    def three_path(cls) -> "FusionSpec":
        return cls.weighted(1.0, 1.0, 1.0, 0.0)

    @classmethod
    def rrf(
        cls, dense=1.0, sparse=1.0, full=1.0, *, rrf_k: float = DEFAULT_RRF_K
    ) -> "FusionSpec":
        return cls.make("rrf", dense, sparse, full, rrf_k=rrf_k)

    @classmethod
    def minmax(
        cls, dense=1.0, sparse=1.0, full=1.0, stats: Optional[PathStats] = None
    ) -> "FusionSpec":
        return cls.make("minmax", dense, sparse, full, stats=stats)

    @classmethod
    def zscore(
        cls, dense=1.0, sparse=1.0, full=1.0, stats: Optional[PathStats] = None
    ) -> "FusionSpec":
        return cls.make("zscore", dense, sparse, full, stats=stats)

    @classmethod
    def zero(cls) -> "FusionSpec":
        """All-zero weighted-sum spec for batch pad rows."""
        return cls.weighted(0.0, 0.0, 0.0, 0.0)

    @classmethod
    def from_weights(cls, w: PathWeights) -> "FusionSpec":
        """PathWeights -> weighted-sum spec (no deprecation warning: the
        silent form for internal/traced call sites)."""
        b = jnp.broadcast_shapes(
            jnp.shape(w.dense), jnp.shape(w.sparse), jnp.shape(w.full)
        )
        return cls(
            mode=jnp.broadcast_to(jnp.asarray(WEIGHTED_SUM, jnp.int32), b),
            weights=w,
            rrf_k=jnp.broadcast_to(jnp.asarray(DEFAULT_RRF_K, jnp.float32), b),
            stats=None,
        )

    def score_weights(self) -> jax.Array:
        """The 3 score-path weights stacked on a trailing axis: (3,)/(B, 3)."""
        return jnp.stack(
            [
                jnp.asarray(self.weights.dense, jnp.float32),
                jnp.asarray(self.weights.sparse, jnp.float32),
                jnp.asarray(self.weights.full, jnp.float32),
            ],
            axis=-1,
        )


def as_fusion_spec(x, *, warn: bool = True) -> FusionSpec:
    """Coerce the query-side fusion argument: ``FusionSpec`` passes through;
    ``PathWeights`` converts to a weighted-sum spec — the deprecated shim
    (the emitted ``DeprecationWarning`` is the migration nudge; the paper's
    dynamic-fusion surface is ``FusionSpec``)."""
    if isinstance(x, FusionSpec):
        return x
    if isinstance(x, PathWeights):
        if warn:
            warnings.warn(
                "passing PathWeights as the query-side fusion argument is "
                "deprecated: use FusionSpec (PathWeights converts to "
                "FusionSpec(mode=weighted_sum); see README migration note)",
                DeprecationWarning,
                stacklevel=3,
            )
        return FusionSpec.from_weights(x)
    raise TypeError(
        f"expected FusionSpec or (deprecated) PathWeights, got {type(x)!r}"
    )


def stack_specs(specs: Sequence[FusionSpec]) -> FusionSpec:
    """Stack per-request specs into one batched spec ((B,) / (B, 3) leaves),
    preserving leaf dtypes (mode stays int32 — ``usms.stack_weights`` casts
    to f32, which would corrupt the mode). Specs with unresolved
    (``None``) stats must be resolved first — mixing would change the
    pytree structure mid-stack."""
    resolved = [s.stats is not None for s in specs]
    if any(resolved) and not all(resolved):
        raise ValueError(
            "cannot stack FusionSpecs with mixed stats resolution: resolve "
            "stats=None against the index stats (or identity) first"
        )
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *specs
    )


def broadcast_spec(spec: FusionSpec, b: int) -> FusionSpec:
    """Broadcast a scalar-leaf (or already-batched) spec to the (B,)-leaf
    form ``search_padded`` vmaps over; ``stats=None`` resolves to identity
    here (the direct-search fallback)."""
    stats = spec.stats if spec.stats is not None else PathStats.identity()
    v = lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (b,))
    s = lambda x: jnp.broadcast_to(
        jnp.asarray(x, jnp.float32), (b, N_SCORE_PATHS)
    )
    return FusionSpec(
        mode=jnp.broadcast_to(jnp.asarray(spec.mode, jnp.int32), (b,)),
        weights=PathWeights(
            dense=v(spec.weights.dense),
            sparse=v(spec.weights.sparse),
            full=v(spec.weights.full),
            kg=v(spec.weights.kg),
        ),
        rrf_k=v(spec.rrf_k),
        stats=PathStats(
            minv=s(stats.minv),
            maxv=s(stats.maxv),
            mean=s(stats.mean),
            std=s(stats.std),
        ),
    )


# ---------------------------------------------------------------------------
# In-trace fused scoring (consumed by core.search / core.distributed).
# ---------------------------------------------------------------------------


def ranks_desc(ps: jax.Array, valid: jax.Array) -> jax.Array:
    """Per-path descending ranks (0 = best) over a candidate list.

    ps: (M, 3) per-path scores; valid: (M,) mask. rank_p(i) counts the valid
    j with a strictly higher score, ties broken by position (stable — the
    order a stable sort would produce). Invalid rows get arbitrary ranks;
    callers mask them. O(M^2) compare matrices: M is the final-pool size
    (~80) or a merged top-k union, small by construction."""
    pos = jnp.arange(ps.shape[0])
    gt = ps[None, :, :] > ps[:, None, :]  # [i, j, p]: j strictly beats i
    tie = (ps[None, :, :] == ps[:, None, :]) & (
        pos[None, :, None] < pos[:, None, None]
    )
    beats = (gt | tie) & valid[None, :, None]
    return beats.sum(axis=1).astype(jnp.float32)  # (M, 3)


def fuse_candidates(
    base: jax.Array,  # (M,) traversal weighted-sum scores, NEG on invalid
    ps: jax.Array,  # (M, 3) per-path raw scores (sanitized: 0 on invalid)
    valid: jax.Array,  # (M,) candidate mask
    spec: FusionSpec,  # scalar-leaf row ((3,) stats)
    neg: float,
) -> jax.Array:
    """Mode-selected fused score of the final candidate pool. Mode 0 returns
    ``base`` elementwise (bit-compatible with the pre-fusion pipeline); all
    four branches are computed and selected arithmetically, keeping the
    program shape-stable for every traced mode value."""
    w3 = spec.score_weights()  # (3,)
    st = spec.stats
    mm_scale = jnp.maximum(st.maxv - st.minv, _EPS)
    z_scale = jnp.maximum(st.std, _EPS)
    minmax = (((ps - st.minv) / mm_scale) * w3).sum(-1)
    zscore = (((ps - st.mean) / z_scale) * w3).sum(-1)
    ranks = ranks_desc(ps, valid)
    rrf = (w3 / (spec.rrf_k + 1.0 + ranks)).sum(-1)
    fused = jnp.select(
        [spec.mode == WEIGHTED_SUM, spec.mode == MINMAX, spec.mode == ZSCORE],
        [base, minmax, zscore],
        rrf,
    )
    return jnp.where(valid, fused, neg)


def merge_rows_fused(
    g_all: jax.Array,  # (S, B, k) global ids, PAD on empty slots
    s_all: jax.Array,  # (S, B, k) fused scores, -inf on empty slots
    ps_all: jax.Array,  # (S, B, k, 3) per-path raw scores of the winners
    spec: FusionSpec,  # batched (B,)-leaf spec
    k: int,
):
    """In-trace fusion-aware merge of stacked per-segment results. Non-RRF
    rows merge by score (raw weighted sums are globally comparable;
    normalized sums are comparable under the shared stats the batched spec
    carries). RRF rows RE-RANK: per-path ranks are recomputed over the
    merged union from ``ps_all`` and the rank contributions re-summed —
    merging local RRF scores by value would compare ranks from different
    local pools (the bug the merge contract exists to prevent)."""
    b = g_all.shape[1]
    g = jnp.moveaxis(g_all, 0, 1).reshape(b, -1)
    s = jnp.moveaxis(s_all, 0, 1).reshape(b, -1)
    ps = jnp.moveaxis(ps_all, 0, 1).reshape(b, -1, N_SCORE_PATHS)

    def one(g_r, s_r, ps_r, mode, w3, rrf_k):
        valid = (g_r >= 0) & jnp.isfinite(s_r)
        ps_r = jnp.where(valid[:, None], ps_r, 0.0)
        ranks = ranks_desc(ps_r, valid)
        rrf = (w3 / (rrf_k + 1.0 + ranks)).sum(-1)
        eff = jnp.where(
            mode == RRF, jnp.where(valid, rrf, -jnp.inf), s_r
        )
        top, pos = jax.lax.top_k(eff, k)
        ok = jnp.isfinite(top)
        return (
            jnp.where(ok, g_r[pos], PAD_IDX),
            jnp.where(ok, top, -jnp.inf),
            jnp.where(ok[:, None], ps_r[pos], 0.0),
        )

    return jax.vmap(one)(
        g, s, ps, spec.mode, spec.score_weights(), spec.rrf_k
    )


# ---------------------------------------------------------------------------
# Host-side fusion-aware merge (serving scatter-gather: pool groups, grow
# segment, replica tier).
# ---------------------------------------------------------------------------


def merge_fused_host(
    ids_parts: Sequence[np.ndarray],  # each (B, k_i) global ids
    score_parts: Sequence[np.ndarray],  # each (B, k_i) fused scores
    path_parts,  # each (B, k_i, 3) per-path raw scores, or None
    spec: Optional[FusionSpec],
    k: int,
):
    """Numpy counterpart of ``merge_rows_fused`` for host-side scatter-
    gather merges. Enforces the merge contract: merging rows in RRF mode
    without per-path scores raises (silently falling back to raw-score
    comparison is exactly the corruption this replaces)."""
    all_ids = np.concatenate([np.asarray(p) for p in ids_parts], axis=1)
    all_scores = np.concatenate(
        [
            np.where(np.asarray(i) >= 0, np.asarray(s, np.float32), -np.inf)
            for i, s in zip(ids_parts, score_parts)
        ],
        axis=1,
    )
    b, m = all_ids.shape
    if spec is None:
        mode = np.full((b,), WEIGHTED_SUM, np.int32)
        w3 = np.ones((b, N_SCORE_PATHS), np.float32)
        rrf_k = np.full((b,), DEFAULT_RRF_K, np.float32)
    else:
        mode = np.broadcast_to(
            np.asarray(spec.mode, np.int32).reshape(-1), (b,)
        )
        w3 = np.broadcast_to(
            np.asarray(spec.score_weights(), np.float32).reshape(
                -1, N_SCORE_PATHS
            ),
            (b, N_SCORE_PATHS),
        )
        rrf_k = np.broadcast_to(
            np.asarray(spec.rrf_k, np.float32).reshape(-1), (b,)
        )
    rrf_rows = mode == RRF
    if rrf_rows.any():
        if path_parts is None or any(p is None for p in path_parts):
            raise ValueError(
                "merge contract violation: RRF results cannot be merged by "
                "raw score — per-path scores (SearchResult.path_scores) are "
                "required to recompute ranks over the union (DESIGN.md §11)"
            )
    if path_parts is None or any(p is None for p in path_parts):
        all_ps = np.zeros((b, m, N_SCORE_PATHS), np.float32)
    else:
        all_ps = np.concatenate(
            [np.asarray(p, np.float32) for p in path_parts], axis=1
        )
    valid = (all_ids >= 0) & np.isfinite(all_scores)
    all_ps = np.where(valid[:, :, None], all_ps, 0.0)
    if rrf_rows.any():
        pos = np.arange(m)
        gt = all_ps[:, None, :, :] > all_ps[:, :, None, :]  # [b, i, j, p]
        tie = (all_ps[:, None, :, :] == all_ps[:, :, None, :]) & (
            pos[None, None, :, None] < pos[None, :, None, None]
        )
        beats = (gt | tie) & valid[:, None, :, None]
        ranks = beats.sum(axis=2).astype(np.float32)  # (b, m, 3)
        rrf_scores = (w3[:, None, :] / (rrf_k[:, None, None] + 1.0 + ranks)).sum(
            -1
        )
        rrf_scores = np.where(valid, rrf_scores, -np.inf)
        eff = np.where(rrf_rows[:, None], rrf_scores, all_scores)
    else:
        eff = all_scores
    order = np.argsort(-eff, axis=1, kind="stable")[:, :k]
    m_ids = np.take_along_axis(all_ids, order, axis=1)
    m_scores = np.take_along_axis(eff, order, axis=1)
    m_ps = np.take_along_axis(all_ps, order[:, :, None], axis=1)
    ok = np.isfinite(m_scores)
    return (
        np.where(ok, m_ids, PAD_IDX).astype(np.int32),
        np.where(ok, m_scores, _NEG_FILL).astype(np.float32),
        np.where(ok[:, :, None], m_ps, 0.0).astype(np.float32),
    )


# ---------------------------------------------------------------------------
# Per-query adaptive selector (the ingest/query path hook).
# ---------------------------------------------------------------------------


def query_nnz(vectors: FusedVectors) -> np.ndarray:
    """Live lexical terms per query row — the query-specificity signal the
    adaptive selector keys on."""
    return np.asarray((np.asarray(vectors.lexical.idx) >= 0).sum(axis=-1))


def adaptive_fusion(
    keywords,
    entities,
    nnz,
    *,
    stats: Optional[PathStats] = None,
    rrf_k: float = DEFAULT_RRF_K,
) -> FusionSpec:
    """Per-query fusion-mode selector from query characteristics (the
    adaptive policy both SNIPPETS exemplars ship, host-side and cheap):

      * entity-bearing queries -> weighted_sum with the KG path on (entity
        waypoints steer traversal; rank fusion would dilute the logical
        reward, which only the weighted mode folds into final scores);
      * >= 2 required keywords -> RRF (precision-shaped query: rank fusion
        is robust to the paths' incomparable score scales);
      * lexically rich queries (nnz >= 8) -> zscore-normalized weighted sum
        (many live terms make the lexical magnitude dominate raw sums);
      * else -> dense-leaning weighted sum (today's default shape).

    Returns a batched (B,)-leaf FusionSpec; pass ``stats`` (e.g. a
    service's running stats) to pin normalization, else it resolves
    downstream."""
    kw = np.asarray(keywords) if keywords is not None else None
    en = np.asarray(entities) if entities is not None else None
    nnz = np.asarray(nnz)
    b = nnz.shape[0]
    kw_count = (
        (kw >= 0).sum(axis=-1) if kw is not None and kw.size else np.zeros(b)
    )
    has_ent = (
        (en >= 0).any(axis=-1)
        if en is not None and en.size
        else np.zeros(b, bool)
    )
    mode = np.full(b, WEIGHTED_SUM, np.int32)
    wd = np.ones(b, np.float32)
    ws = np.full(b, 0.5, np.float32)
    wf = np.full(b, 0.5, np.float32)
    wk = np.zeros(b, np.float32)

    lex_rich = nnz >= 8
    mode[lex_rich] = ZSCORE
    ws[lex_rich] = 1.0
    wf[lex_rich] = 1.0

    kw_rich = kw_count >= 2
    mode[kw_rich] = RRF
    ws[kw_rich] = 1.0
    wf[kw_rich] = 1.0

    mode[has_ent] = WEIGHTED_SUM
    wd[has_ent] = 1.0
    ws[has_ent] = 1.0
    wf[has_ent] = 1.0
    wk[has_ent] = 1.0

    batched_stats = None
    if stats is not None:
        s = lambda x: jnp.broadcast_to(
            jnp.asarray(x, jnp.float32), (b, N_SCORE_PATHS)
        )
        batched_stats = PathStats(
            minv=s(stats.minv), maxv=s(stats.maxv),
            mean=s(stats.mean), std=s(stats.std),
        )
    return FusionSpec(
        mode=jnp.asarray(mode),
        weights=PathWeights(
            dense=jnp.asarray(wd), sparse=jnp.asarray(ws),
            full=jnp.asarray(wf), kg=jnp.asarray(wk),
        ),
        rrf_k=jnp.full((b,), rrf_k, jnp.float32),
        stats=batched_stats,
    )
