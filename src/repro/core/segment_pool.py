"""Segment pool: a variable-length collection of sealed segments decoupled
from the device layout (the GRAB-ANNS-style logical/physical split).

``SegmentedIndex`` stacks same-shape segments on a leading axis — the unit
one vmapped/sharded search pass consumes. A ``SegmentPool`` holds MANY such
stacks ("shape groups"): segments of the same per-row capacity live in one
group and are searched together; segments of different capacities live in
different groups and are searched by different cached executables. That
turns the old hard "S segments == S mesh devices" coupling into a placement
decision:

  * any group whose segment count divides over the mesh's segment axes is
    served by the sharded ``make_distributed_search_padded`` executable
    (several same-device segments per device, one vmapped pass each);
  * every other group (including all groups of an off-mesh deployment) is
    served by ``make_local_group_search`` — same math, no collectives;
  * group results merge per-row in GLOBAL-id space, so a pool search is
    exactly a segment search with more segments.

Because segment capacities are quantized (the serving layer seals grow
segments at power-of-two capacity), the number of distinct groups — and
therefore of cached executables — is O(log corpus), and compacting a grow
segment into the pool touches at most ONE group: every other group's
executable survives byte-identical (the cache-survival guarantee DESIGN.md
§8 documents and ``tests/test_segment_pool.py`` pins).

All functions here are host-side orchestration; the device work happens in
the search/build programs this module composes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build_pipeline import build_index, pad_index_rows
from repro.core.distributed import (
    SegmentedIndex,
    _segment_spec,
    alive_docs,
    mark_deleted_segmented,
    mesh_segment_count,
    resolve_global_ids,
)
from repro.core.index import BuildConfig
from repro.core.usms import PAD_IDX, FusedVectors, quantize_corpus


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["groups"],
    meta_fields=[],
)
@dataclasses.dataclass
class SegmentPool:
    """A list of shape groups, each a stacked ``SegmentedIndex``.

    Group g holds ``groups[g].n_segments`` segments of identical per-row
    capacity ``groups[g].global_ids.shape[1]``; different groups may have
    different capacities (the heterogeneity the pool exists for)."""

    groups: list[SegmentedIndex]

    @classmethod
    def from_segmented(cls, seg: SegmentedIndex) -> "SegmentPool":
        """Wrap an existing stacked index as a single-group pool. The group
        is the SAME pytree (no copy), so shape-keyed executables compiled
        for it keep serving after the wrap."""
        return cls(groups=[seg])

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_segments(self) -> int:
        return sum(g.n_segments for g in self.groups)

    @property
    def capacities(self) -> tuple[int, ...]:
        """Per-group per-segment row capacity."""
        return tuple(int(g.global_ids.shape[1]) for g in self.groups)

    @property
    def entity_width(self) -> int:
        """Widest doc-entity row across groups (grow segments are born at
        this width so entity-carrying inserts never hit a width check)."""
        return max(int(g.index.doc_entities.shape[-1]) for g in self.groups)

    @property
    def has_kg(self) -> bool:
        """True when any group carries knowledge-graph entity paths."""
        return any(g.index.entity_adj.shape[-1] > 1 for g in self.groups)

    def max_global_id(self) -> int:
        """Largest global doc id present, or -1 for an all-pad pool."""
        out = -1
        for g in self.groups:
            gids = np.asarray(g.global_ids)
            if (gids >= 0).any():
                out = max(out, int(gids.max()))
        return out

    def segments(self) -> list[tuple[int, int]]:
        """Flat (group, local segment) enumeration of every pooled segment."""
        return [(g, s) for g, grp in enumerate(self.groups)
                for s in range(grp.n_segments)]


def group_shape_key(group: SegmentedIndex) -> tuple:
    """Exact shape signature of a group — the executable-cache key material.
    Two groups with equal keys are served by the same compiled program."""
    return ("seg",) + tuple(
        tuple(leaf.shape) for leaf in jax.tree.leaves(group)
    )


# ---------------------------------------------------------------------------
# Global-id routing over a pool (deletion, compaction, introspection)
# ---------------------------------------------------------------------------


def resolve_global_ids_pool(
    pool: SegmentPool, ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Global doc id -> (group, segment-in-group, local row); all -1 when
    the id lives nowhere in the pool."""
    ids = np.atleast_1d(np.asarray(ids, np.int64))
    grp = np.full(ids.shape, -1, np.int32)
    seg = np.full(ids.shape, -1, np.int32)
    loc = np.full(ids.shape, -1, np.int32)
    for g, group in enumerate(pool.groups):
        todo = grp < 0
        if not todo.any():
            break
        s, l = resolve_global_ids(group, ids[todo])
        hit = s >= 0
        idx = np.flatnonzero(todo)[hit]
        grp[idx] = g
        seg[idx] = s[hit]
        loc[idx] = l[hit]
    return grp, seg, loc


def mark_deleted_pool(
    pool: SegmentPool,
    ids: np.ndarray,
    *,
    resolved: Optional[tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> SegmentPool:
    """Tombstone docs by global id wherever they live. Shape-preserving in
    every group, so no executable is evicted. Unknown ids are ignored.
    Pass ``resolved=(grp, seg, loc)`` when the caller already routed the
    ids — skips a second full per-group resolve."""
    grp, seg, loc = (
        resolved if resolved is not None else resolve_global_ids_pool(pool, ids)
    )
    groups = list(pool.groups)
    for g in range(len(groups)):
        mine = grp == g
        if mine.any():
            groups[g] = mark_deleted_segmented(
                groups[g], None, resolved=(seg[mine], loc[mine])
            )
    return SegmentPool(groups=groups)


def widen_entities(ents: np.ndarray, width: int) -> np.ndarray:
    """Pad (or clip) doc-entity rows to ``width`` columns with PAD_IDX —
    the one place segment/grow entity widths are reconciled."""
    ents = np.asarray(ents, np.int32)
    if ents.shape[-1] == width:
        return ents
    out = np.full((ents.shape[0], width), PAD_IDX, np.int32)
    w = min(width, ents.shape[-1])
    out[:, :w] = ents[:, :w]
    return out


def alive_docs_pool(
    pool: SegmentPool,
) -> tuple[FusedVectors, np.ndarray, np.ndarray]:
    """Every live (non-pad, non-tombstoned) doc in the pool: (corpus rows,
    global ids, doc-entity rows padded to the pool's widest entity row) —
    the full-rebuild compaction input."""
    width = pool.entity_width
    parts, gid_parts, ent_parts = [], [], []
    for group in pool.groups:
        corpus, gids, ents = alive_docs(group)
        parts.append(corpus)
        gid_parts.append(gids)
        ent_parts.append(widen_entities(ents, width))
    corpus = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    return corpus, np.concatenate(gid_parts), np.concatenate(ent_parts, axis=0)


def live_counts(pool: SegmentPool) -> list[tuple[int, int, int, int]]:
    """Per pooled segment: (group, segment-in-group, capacity, live docs) —
    the merge policy's working set."""
    out = []
    for g, group in enumerate(pool.groups):
        alive = np.asarray(group.index.alive)
        cap = int(group.global_ids.shape[1])
        for s in range(group.n_segments):
            out.append((g, s, cap, int(alive[s].sum())))
    return out


# ---------------------------------------------------------------------------
# Pool surgery: build one segment, append it, remove segments
# ---------------------------------------------------------------------------


def build_pool_segment(
    corpus: FusedVectors,
    global_ids: np.ndarray,
    cfg: BuildConfig = BuildConfig(),
    *,
    capacity: Optional[int] = None,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
    corpus_dtype: str = "float32",
) -> SegmentedIndex:
    """Build ONE sealed segment of arbitrary size — O(rows given), never
    re-entering the full sharded build. Returns a single-segment stacked
    index (leaves (1, ...)) padded to ``capacity`` with dead rows (shape
    bucketing: quantized capacities keep the pool's group count low),
    carrying the caller's global ids.

    ``corpus_dtype="int8"`` quantizes the segment's corpus storage after the
    (always-fp32) build — the seal-time contract: graph construction sees
    exact vectors, sealed storage is compressed."""
    global_ids = np.asarray(global_ids, np.int32)
    n = corpus.n
    if n == 0:
        raise ValueError("a pool segment needs at least one row")
    if global_ids.shape[0] != n:
        raise ValueError("global_ids must map every corpus row")
    capacity = n if capacity is None else int(capacity)
    if capacity < n:
        raise ValueError(f"capacity {capacity} below row count {n}")
    kg_kwargs = {}
    if kg_triplets is not None and doc_entities is not None and n_entities > 0:
        kg_kwargs = dict(
            kg_triplets=kg_triplets,
            doc_entities=doc_entities,
            n_entities=n_entities,
        )
    idx = build_index(corpus, cfg, key=key, **kg_kwargs)
    idx = pad_index_rows(idx, capacity)
    # entry_points is built at min(cfg.n_entry, n) — normalize it to the
    # CAPACITY-determined length (cycling real entries; duplicates are
    # harmless, the search pool dedups) so two segments of equal capacity
    # always share every leaf shape and stack into one group
    n_entry = min(cfg.n_entry, capacity)
    ep = idx.entry_points
    if ep.shape[0] < n_entry:
        reps = -(-n_entry // ep.shape[0])
        idx = dataclasses.replace(
            idx, entry_points=jnp.tile(ep, reps)[:n_entry]
        )
    if corpus_dtype == "int8":
        idx = dataclasses.replace(idx, corpus=quantize_corpus(idx.corpus))
    elif corpus_dtype != "float32":
        raise ValueError(f"unknown corpus_dtype {corpus_dtype!r}")
    gids = np.full((capacity,), PAD_IDX, np.int32)
    gids[:n] = global_ids
    stacked = jax.tree.map(lambda a: jnp.asarray(a)[None], idx)
    return SegmentedIndex(index=stacked, global_ids=jnp.asarray(gids)[None])


def append_segment(
    pool: SegmentPool, segment: SegmentedIndex
) -> tuple[SegmentPool, int]:
    """Add sealed segments to the pool. Segments whose leaf shapes match an
    existing group's per-segment shapes stack INTO that group (that group's
    executable recompiles on next read — the documented cost of joining a
    shape bucket); otherwise they form a new group. Every other group is
    reused by reference, so its executables survive untouched. Returns
    (new pool, index of the touched group)."""
    seg_shapes = tuple(
        tuple(leaf.shape[1:]) for leaf in jax.tree.leaves(segment)
    )
    groups = list(pool.groups)
    for g, group in enumerate(groups):
        if seg_shapes == tuple(
            tuple(leaf.shape[1:]) for leaf in jax.tree.leaves(group)
        ):
            groups[g] = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), group, segment
            )
            return SegmentPool(groups=groups), g
    groups.append(segment)
    return SegmentPool(groups=groups), len(groups) - 1


def remove_segments(
    pool: SegmentPool, picks: Sequence[tuple[int, int]]
) -> SegmentPool:
    """Drop the (group, segment-in-group) picks. Groups losing segments
    shrink (their executables recompile); groups losing ALL segments
    disappear; untouched groups are reused by reference."""
    by_group: dict[int, set[int]] = {}
    for g, s in picks:
        by_group.setdefault(g, set()).add(s)
    groups = []
    for g, group in enumerate(pool.groups):
        drop = by_group.get(g)
        if not drop:
            groups.append(group)
            continue
        keep = [s for s in range(group.n_segments) if s not in drop]
        if keep:
            keep_idx = jnp.asarray(keep, jnp.int32)
            groups.append(
                jax.tree.map(lambda a: jnp.take(a, keep_idx, axis=0), group)
            )
    return SegmentPool(groups=groups)


def extract_segment_docs(
    pool: SegmentPool, g: int, s: int
) -> tuple[FusedVectors, np.ndarray, np.ndarray]:
    """Live docs of one pooled segment (corpus rows, global ids, entity
    rows) — the merge input."""
    group = pool.groups[g]
    one = jax.tree.map(lambda a: a[s : s + 1], group)
    return alive_docs(one)


# ---------------------------------------------------------------------------
# Placement: logical segments -> physical devices (many per device)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupPlacement:
    """Where one shape group's segments live.

    ``sharded=True``: the group's leading axis is split over the mesh's
    segment axes — ``devices[s]`` is the segment-axis device index serving
    segment s (each device owns a contiguous block of
    ``n_segments / mesh_segment_count`` segments, searched in one vmapped
    pass). ``sharded=False``: the group is replicated/host-local and served
    by the collective-free local group search."""

    group: int
    n_segments: int
    capacity: int
    sharded: bool
    devices: tuple[int, ...]


def pool_placement(pool: SegmentPool, mesh=None) -> list[GroupPlacement]:
    """The placement map: which device serves which pooled segment. A group
    shards iff its segment count divides the mesh's segment-axes device
    count product; everything else is replicated (served locally)."""
    msc = mesh_segment_count(mesh) if mesh is not None else 1
    out = []
    for g, group in enumerate(pool.groups):
        n_seg = group.n_segments
        sharded = mesh is not None and msc > 1 and n_seg % msc == 0
        if sharded:
            per = n_seg // msc
            devices = tuple(s // per for s in range(n_seg))
        else:
            devices = (0,) * n_seg
        out.append(
            GroupPlacement(
                group=g,
                n_segments=n_seg,
                capacity=int(group.global_ids.shape[1]),
                sharded=sharded,
                devices=devices,
            )
        )
    return out


def place_pool(pool: SegmentPool, mesh=None) -> SegmentPool:
    """Device_put each group per the placement map: sharded groups over the
    mesh segment axes, the rest replicated. Off-mesh, a no-op."""
    if mesh is None:
        return pool
    from jax.sharding import NamedSharding, PartitionSpec as P

    placements = pool_placement(pool, mesh)
    seg_sharding = NamedSharding(mesh, _segment_spec(mesh))
    rep_sharding = NamedSharding(mesh, P())
    groups = []
    for group, pl in zip(pool.groups, placements):
        sharding = seg_sharding if pl.sharded else rep_sharding
        groups.append(
            jax.tree.map(
                lambda a: jax.device_put(a, sharding)
                if hasattr(a, "shape")
                else a,
                group,
            )
        )
    return SegmentPool(groups=groups)
