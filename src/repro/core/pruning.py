"""RNG-IP joint edge pruning + keyword-aware neighbor recycling
(paper §4.1 Steps 2-3, §3.3, Algorithm 1 lines 5-17).

Phase 1 (RNG, CAGRA-style): for node u with candidates sorted by hybrid
similarity, the edge u->v_j is *detourable* via v_i when
sim(u, v_i) > sim(u, v_j) and sim(v_i, v_j) > sim(u, v_j); candidates are
re-ranked by detourable-route count (fewest first).

Phase 2 (IP pruning, Tan et al. rule): walking the re-ranked list, candidate
v joins the kept set only if IP(w, v) < IP(v, v) for every already-kept w —
this removes small-norm vectors that can never win a MIPS comparison.

Keyword recycling (dual assessment): a candidate v that phase 2 prunes is
recycled as a *keyword edge* iff it contributes a keyword k in K(u) ∩ K(v)
that no kept neighbor covers — keeping keyword navigation reachable after
vector fusion. The flags are computed from the same intersection pass that
the pruning distances already need (the paper fuses this into the warp
kernel; here it is a fused batched mask computation over the same gathered
tiles).

Final edge list (paper Step 2 tail): d/4 IP-kept + d/4 reverse neighbors +
d/2 single-path neighbors (per-path re-ranking of the fused candidate pool —
the Pareto-frontier approximation that keeps any-weight queries robust).

GPU->TPU: one warp per neighbor pair becomes vmapped (K, K) score tiles; the
sequential keep-scan is a lax.scan; everything is fixed-shape and chunked.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.knn_graph import reverse_neighbors
from repro.core.usms import PAD_IDX, FusedVectors, PathWeights, weighted_query
from repro.kernels import ops, ref  # noqa: F401  (ref re-exported for tests)
from repro.runtime import dispatch

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    degree: int = 16  # final semantic degree d
    keyword_degree: int = 8  # keyword-edge slots per node
    node_chunk: int = 1024
    use_kernel: bool | None = None  # None -> backend auto (Pallas off-CPU)
    mode: str = "joint"  # joint | rng (no IP rule) | ip (no detour ordering)


def detour_counts(cand_scores: jax.Array, pair_scores: jax.Array) -> jax.Array:
    """cand_scores: (K,) sim(u, v_j) sorted desc; pair_scores: (K, K) sim(v_i, v_j).
    Returns (K,) number of detourable routes per candidate."""
    k = cand_scores.shape[0]
    i_lt_j = jnp.tril(jnp.ones((k, k), bool), k=-1).T  # [i, j] True iff i < j
    detour = i_lt_j & (pair_scores > cand_scores[None, :])
    return detour.sum(axis=0).astype(jnp.int32)


def ip_keep_scan(
    order: jax.Array,  # (K,) candidate positions in keep-priority order
    pair_scores: jax.Array,  # (K, K) sim(v_i, v_j)
    self_scores: jax.Array,  # (K,) IP(v, v)
    valid: jax.Array,  # (K,) candidate validity
    cap: int,
) -> jax.Array:
    """Sequential IP-pruning keep rule -> bool (K,) kept mask (in original
    candidate positions)."""
    k = order.shape[0]

    def body(carry, j):
        kept, n_kept = carry  # kept: (K,) bool in original positions
        v = order[j]
        ips_vs_kept = jnp.where(kept, pair_scores[:, v], NEG)  # IP(w, v)
        ok = jnp.all(ips_vs_kept < self_scores[v]) & (n_kept < cap) & valid[v]
        kept = kept.at[v].set(ok)
        return (kept, n_kept + ok.astype(jnp.int32)), ok

    (kept, _), _ = jax.lax.scan(
        body, (jnp.zeros((k,), bool), jnp.int32(0)), jnp.arange(k)
    )
    return kept


def keyword_flags(
    u_kw: jax.Array,  # (Pf,) keyword ids of node u (PAD padded)
    cand_kw: jax.Array,  # (K, Pf) keyword ids of candidates
    kept: jax.Array,  # (K,) kept mask
) -> jax.Array:
    """Dual-assessment recycle flags: candidate v (not kept) is flagged iff
    some keyword in K(u) ∩ K(v) is absent from every kept neighbor."""
    # in_u[v, p]: cand_kw[v, p] ∈ K(u)
    in_u = (cand_kw[:, :, None] == u_kw[None, None, :]).any(-1) & (cand_kw >= 0)
    # covered[v, p]: cand_kw[v, p] present in some *kept* candidate's keyword set
    eq = cand_kw[:, :, None, None] == cand_kw[None, None, :, :]  # (K, Pf, K, Pf)
    covered_by = eq.any(-1) & kept[None, None, :]  # (K, Pf, K)
    covered = covered_by.any(-1)
    return ((in_u & ~covered).any(-1)) & ~kept


def unique_take(ids: jax.Array, scores: jax.Array, width: int) -> jax.Array:
    """Stable first-occurrence unique over a priority-ordered id list, padded
    to ``width`` with PAD_IDX. O(L^2) fixed-shape."""
    l = ids.shape[0]
    earlier_same = (ids[:, None] == ids[None, :]) & (
        jnp.arange(l)[None, :] < jnp.arange(l)[:, None]
    )
    is_dup = earlier_same.any(-1) | (ids == PAD_IDX) | ~jnp.isfinite(scores)
    rank = jnp.where(is_dup, l + jnp.arange(l), jnp.arange(l))
    order = jnp.argsort(rank)
    out = jnp.where(jnp.sort(rank) < l, ids[order], PAD_IDX)
    return out[:width]


def _prune_node(
    u_query: FusedVectors,  # fused vec of node u (no batch dim handled by caller)
    u_id: jax.Array,  # () node id (self-edges masked)
    cand_ids: jax.Array,  # (K,) candidate ids sorted by fused score desc
    cand_scores: jax.Array,  # (K,) sim(u, v)
    pair_scores: jax.Array,  # (K, K)
    cand_self: jax.Array,  # (K,) IP(v, v)
    path_picks: jax.Array,  # (3, pk) single-path neighbor ids (dense/sparse/full)
    u_kw: jax.Array,  # (Pf,)
    cand_kw: jax.Array,  # (K, Pf)
    rev_ids: jax.Array,  # (R,) reverse-neighbor ids
    rev_scores: jax.Array,  # (R,)
    cfg: PruneConfig,
):
    d = cfg.degree
    d4 = max(d // 4, 1)
    cand_ids = jnp.where(cand_ids == u_id, PAD_IDX, cand_ids)
    path_picks = jnp.where(path_picks == u_id, PAD_IDX, path_picks)
    valid = cand_ids >= 0

    # --- phase 1: RNG ordering by detourable routes ---
    if cfg.mode == "ip":
        # ablation: no detour ordering, keep fused-score order
        order = jnp.argsort(jnp.where(valid, -cand_scores, jnp.inf))
    else:
        routes = detour_counts(cand_scores, pair_scores)
        routes = jnp.where(valid, routes, jnp.iinfo(jnp.int32).max)
        # stable: tie-break by original rank (already score-sorted)
        order = jnp.argsort(routes * cand_ids.shape[0] + jnp.arange(cand_ids.shape[0]))

    # --- phase 2: IP keep rule ---
    if cfg.mode == "rng":
        # ablation: accept the first d/4 candidates in detour order
        kept = jnp.zeros(cand_ids.shape, bool).at[order[:d4]].set(True) & valid
    else:
        kept = ip_keep_scan(order, pair_scores, cand_self, valid, d4)

    # --- keyword recycling flags (dual assessment) ---
    flags = keyword_flags(u_kw, cand_kw, kept) & valid

    # --- assemble final semantic edges ---
    kept_rank = jnp.where(kept, -cand_scores, jnp.inf)  # kept first, best first
    kept_order = jnp.argsort(kept_rank)
    kept_ids = jnp.where(
        jnp.sort(kept_rank) < jnp.inf, cand_ids[kept_order], PAD_IDX
    )[:d4]

    rev_top = rev_ids[:d4]

    d_rem = d - 2 * d4
    per_path = max(d_rem // 3, 1)
    # interleave per-path picks (dense, sparse, full, dense, ...) so the
    # d/2 single-path budget is shared evenly (Pareto-frontier approximation)
    picks = jnp.swapaxes(path_picks[:, :per_path], 0, 1).reshape(-1)
    picks = jnp.where(picks == jnp.int32(-2), PAD_IDX, picks)
    # priority list: IP-kept, reverse, per-path picks, then remaining by score
    priority = jnp.concatenate([kept_ids, rev_top, picks, cand_ids])
    pr_scores = jnp.zeros_like(priority, jnp.float32)  # order already encodes priority
    sem = unique_take(priority, pr_scores, d)

    # --- keyword edges from flagged pruned candidates ---
    kw_rank = jnp.where(flags, -cand_scores, jnp.inf)
    kw_order = jnp.argsort(kw_rank)
    kw = jnp.where(jnp.sort(kw_rank) < jnp.inf, cand_ids[kw_order], PAD_IDX)[
        : cfg.keyword_degree
    ]
    return sem, kw, flags


_prune_nodes_batch = jax.vmap(
    _prune_node, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, None)
)


def _prune_chunk(
    corpus: FusedVectors,
    chunk_queries: FusedVectors,
    node_ids: jax.Array,  # (C,) ids of the nodes being pruned
    cand_ids: jax.Array,  # (C, K)
    cand_scores: jax.Array,  # (C, K)
    corpus_self: jax.Array,  # (N,) IP(v,v) for all nodes
    rev_ids: jax.Array,  # (C, R)
    path_ids: jax.Array | None,  # (C, 3, pk) per-path neighbor ids or None
    cfg: PruneConfig,
):
    c, k = cand_ids.shape
    # pairwise scores among candidates: gather each node's K rows ONCE and
    # compute the (K, K) tile in place (kernels/pairwise_tile.py) — the old
    # path re-gathered the rows K times via a (C*K, K) id matrix
    cand_rows = corpus.take(cand_ids.reshape(-1))  # (C*K, ...)
    tile = jax.tree.map(
        lambda a: a.reshape((c, k) + a.shape[1:]), cand_rows
    )
    pair = ops.pairwise_tile_scores(tile, use_kernel=cfg.use_kernel)
    # invalid candidates j score -inf, matching hybrid_scores_vs_ids masking
    pair = jnp.where(cand_ids[:, None, :] >= 0, pair, -jnp.inf)
    cand_self = jnp.where(
        cand_ids >= 0, corpus_self[jnp.clip(cand_ids, 0, corpus.n - 1)], NEG
    )
    if path_ids is None:
        # fallback (insertion path): rerank the fused candidate pool per path
        pk = max((cfg.degree - 2 * max(cfg.degree // 4, 1)) // 3, 1)
        paths = []
        for w in (
            PathWeights.make(1.0, 0.0, 0.0),
            PathWeights.make(0.0, 1.0, 0.0),
            PathWeights.make(0.0, 0.0, 1.0),
        ):
            qw = weighted_query(chunk_queries, w)
            # fused per-path top-pk: selection happens in-kernel, the (C, K)
            # per-path score matrix never leaves it
            _, pos = ops.fused_topk_vs_ids(
                qw, corpus, cand_ids, pk, use_kernel=cfg.use_kernel
            )
            paths.append(ops.take_topk_ids(cand_ids, pos))
        path_ids = jnp.stack(paths, axis=1)  # (C, 3, pk)
    u_kw = chunk_queries.lexical.idx
    cand_kw = corpus.lexical.idx[jnp.clip(cand_ids, 0, corpus.n - 1)]
    cand_kw = jnp.where(cand_ids[..., None] >= 0, cand_kw, PAD_IDX)
    rev_scores = jnp.zeros(rev_ids.shape, jnp.float32)
    return _prune_nodes_batch(
        chunk_queries,
        node_ids,
        cand_ids,
        cand_scores,
        pair,
        cand_self,
        path_ids,
        u_kw,
        cand_kw,
        rev_ids,
        rev_scores,
        cfg,
    )


# jitted wrapper for the legacy host-driven chunk loop; the device-resident
# pipeline (core/build_pipeline.py) traces the plain body inside lax.map
_prune_chunk_jit = jax.jit(_prune_chunk, static_argnames=("cfg",))


def self_scores(corpus: FusedVectors, use_kernel: bool | None = None) -> jax.Array:
    """IP(v, v) — fused self-similarity (squared fused norm)."""
    cands = jax.tree.map(lambda a: a[:, None], corpus)
    return ops.hybrid_scores(corpus, cands, use_kernel=use_kernel)[:, 0]


def rng_ip_prune(
    corpus: FusedVectors,
    knn_ids: jax.Array,  # (N, K) NN-Descent output, score-sorted desc
    knn_scores: jax.Array,  # (N, K)
    cfg: PruneConfig,
    *,
    path_ids: jax.Array | None = None,  # (N, 3, pk) per-path neighbors
) -> tuple[jax.Array, jax.Array]:
    """Full pruning pass. Returns (semantic_edges (N, d), keyword_edges (N, dk))."""
    n = corpus.n
    rev = reverse_neighbors(knn_ids, max(cfg.degree // 4, 1))
    dispatch.tick()
    cself = self_scores(corpus, use_kernel=cfg.use_kernel)
    node_ids = jnp.arange(n, dtype=jnp.int32)
    sems, kws = [], []
    for s in range(0, n, cfg.node_chunk):
        e = min(s + cfg.node_chunk, n)
        dispatch.tick()
        sem, kw, _ = _prune_chunk_jit(
            corpus,
            corpus[slice(s, e)],
            node_ids[s:e],
            knn_ids[s:e],
            knn_scores[s:e],
            cself,
            rev[s:e],
            None if path_ids is None else path_ids[s:e],
            cfg,
        )
        sems.append(sem)
        kws.append(kw)
    return jnp.concatenate(sems, 0), jnp.concatenate(kws, 0)
