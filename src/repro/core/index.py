"""The all-in-one hybrid index: structure, build pipeline, and updates
(paper §3, §4.1, Algorithm 1).

Isolated heterogeneous edge storage (paper §3.1): semantic edges, keyword
edges and logical edges live in separate fixed-width tables so any path
combination can be toggled at query time with zero reconstruction — the
"pluggable" property the paper's flexibility principle requires.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn_graph, pruning
from repro.core.knn_graph import KnnConfig, build_knn_graph
from repro.core.logical_edges import LogicalEdges, build_logical_edges
from repro.core.pruning import PruneConfig, rng_ip_prune, self_scores
from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    PathWeights,
    SparseVec,
    weighted_query,
)
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    knn: KnnConfig = KnnConfig()
    prune: PruneConfig = PruneConfig()
    n_entry: int = 16  # large-norm entry points (paper §4.2.1)
    path_refine_iters: int = 2  # per-path NN-Descent rounds (single-path slots)
    logical_cap: int = 16
    entity_doc_cap: int = 8


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "corpus",
        "semantic_edges",
        "keyword_edges",
        "logical_edges",
        "doc_entities",
        "entity_to_docs",
        "entity_adj",
        "entry_points",
        "alive",
        "self_ip",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class HybridIndex:
    corpus: FusedVectors  # (N, ...)
    semantic_edges: jax.Array  # (N, d) int32
    keyword_edges: jax.Array  # (N, dk) int32
    logical_edges: jax.Array  # (N, L, 4) int32
    doc_entities: jax.Array  # (N, Ed) int32
    entity_to_docs: jax.Array  # (E, M) int32
    entity_adj: jax.Array  # (E, E) bool
    entry_points: jax.Array  # (n_entry,) int32
    alive: jax.Array  # (N,) bool — mark-deletion
    self_ip: jax.Array  # (N,) IP(v, v)

    @property
    def n(self) -> int:
        return self.semantic_edges.shape[0]

    @property
    def degree(self) -> int:
        return self.semantic_edges.shape[1]

    def nbytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self)
        )

    def edge_nbytes(self) -> dict:
        """Index-only storage (excludes raw vectors) — paper Table 2 metric."""
        return {
            "semantic": self.semantic_edges.nbytes,
            "keyword": self.keyword_edges.nbytes,
            "logical": self.logical_edges.nbytes,
            "entity_maps": self.entity_to_docs.nbytes + self.entity_adj.nbytes,
            "vectors": sum(a.nbytes for a in jax.tree.leaves(self.corpus)),
        }


def build_index(
    corpus: FusedVectors,
    cfg: BuildConfig = BuildConfig(),
    *,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
) -> HybridIndex:
    """Full construction pipeline (Algorithm 1)."""
    key = key if key is not None else jax.random.key(0)
    n = corpus.n

    # Step 1: NN-Descent k-NN graph over fused vectors
    knn_ids, knn_scores = build_knn_graph(corpus, cfg.knn, key)

    # Step 1b: per-path refinement — short NN-Descent under each single-path
    # weighting, warm-started from the fused graph, to feed the d/2
    # single-path slots (paper Step 2 "Pareto frontier" tail)
    path_ids = None
    if cfg.path_refine_iters > 0:
        d = cfg.prune.degree
        pk = max((d - 2 * max(d // 4, 1)) // 3 + 1, 2)
        pcfg = dataclasses.replace(
            cfg.knn, iters=cfg.path_refine_iters, k=max(pk, 12)
        )
        per_path = []
        for i, w in enumerate(
            (
                PathWeights.make(1.0, 0.0, 0.0),
                PathWeights.make(0.0, 1.0, 0.0),
                PathWeights.make(0.0, 0.0, 1.0),
            )
        ):
            pids, _ = build_knn_graph(
                corpus,
                pcfg,
                jax.random.fold_in(key, i + 1),
                queries=weighted_query(corpus, w),
                init_ids=knn_ids,
            )
            per_path.append(pids[:, :pk])
        path_ids = jnp.stack(per_path, axis=1)  # (N, 3, pk)

    # Steps 2-3: RNG-IP joint pruning + keyword recycling
    sem, kw = rng_ip_prune(corpus, knn_ids, knn_scores, cfg.prune, path_ids=path_ids)

    # Step 4: logical edges
    if kg_triplets is not None and doc_entities is not None and n_entities > 0:
        log = build_logical_edges(
            kg_triplets,
            doc_entities,
            n_entities,
            l_cap=cfg.logical_cap,
            m_cap=cfg.entity_doc_cap,
        )
    else:
        log = LogicalEdges.empty(n)

    # entry points: largest vector norms (paper §4.2.1). Because weights are
    # dynamic, we take the union of the top-norm nodes under the fused metric
    # AND under each single path, so entry quality holds for any weights.
    sip = self_scores(corpus, use_kernel=cfg.prune.use_kernel)
    n_entry = min(cfg.n_entry, n)
    per = max(n_entry // 4, 1)
    entry_parts = [jax.lax.top_k(sip, per)[1]]
    for w in (
        PathWeights.make(1.0, 0.0, 0.0),
        PathWeights.make(0.0, 1.0, 0.0),
        PathWeights.make(0.0, 0.0, 1.0),
    ):
        qw = weighted_query(corpus, w)
        cands = jax.tree.map(lambda a: a[:, None], qw)
        norms = ops.hybrid_scores(qw, cands, use_kernel=cfg.prune.use_kernel)[:, 0]
        entry_parts.append(jax.lax.top_k(norms, per)[1])
    cat = jnp.concatenate(entry_parts).astype(jnp.int32)
    entries = pruning.unique_take(
        cat, jnp.zeros(cat.shape, jnp.float32), n_entry
    )
    # backfill duplicates with the next-best fused-norm nodes
    fill = jax.lax.top_k(sip, n_entry)[1].astype(jnp.int32)
    entries = jnp.where(entries >= 0, entries, fill)

    return HybridIndex(
        corpus=corpus,
        semantic_edges=sem,
        keyword_edges=kw,
        logical_edges=jnp.asarray(log.edges),
        doc_entities=jnp.asarray(log.doc_entities),
        entity_to_docs=jnp.asarray(log.entity_to_docs),
        entity_adj=jnp.asarray(log.entity_adj),
        entry_points=entries.astype(jnp.int32),
        alive=jnp.ones((n,), bool),
        self_ip=sip,
    )


# ---------------------------------------------------------------------------
# Updates (paper §4.1 "Updates of the Hybrid Index")
# ---------------------------------------------------------------------------


def mark_deleted(index: HybridIndex, ids: jax.Array) -> HybridIndex:
    """Mark-deletion: nodes stay traversable, filtered from results."""
    return dataclasses.replace(index, alive=index.alive.at[ids].set(False))


def insert(
    index: HybridIndex,
    new_docs: FusedVectors,
    cfg: BuildConfig,
    *,
    key: Optional[jax.Array] = None,
    new_doc_entities: Optional[np.ndarray] = None,
) -> HybridIndex:
    """Insert new nodes: their k-NN = merge of (a) search of the existing
    index and (b) NN-Descent among the new nodes; then the standard pruning.
    Existing nodes acquire reverse edges to the new nodes (slot-replacement of
    their weakest edge) so the new region stays reachable."""
    from repro.core.search import SearchParams, search  # local import (cycle)

    key = key if key is not None else jax.random.key(1)
    n_old = index.n
    n_new = new_docs.n
    k = cfg.knn.k

    # (a) k-NN from the existing index via its own search
    params = SearchParams(k=k, iters=max(24, 2 * k), use_kernel=cfg.knn.use_kernel)
    from repro.core.usms import PathWeights

    res = search(index, new_docs, PathWeights.three_path(), params)
    old_ids, old_scores = res.ids, res.scores

    # (b) NN-Descent among the new nodes only
    new_ids_local, new_scores = build_knn_graph(new_docs, cfg.knn, key)
    new_ids_global = jnp.where(
        new_ids_local >= 0, new_ids_local + n_old, PAD_IDX
    )

    # merged candidate lists for the new nodes
    merged_ids, merged_scores = knn_graph._merge_topk(
        old_ids, old_scores, new_ids_global, new_scores, k
    )

    # concatenated corpus
    corpus = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=0), index.corpus, new_docs
    )

    # prune the new nodes against the merged candidates
    prune_cfg = cfg.prune
    cself = jnp.concatenate(
        [index.self_ip, self_scores(new_docs, use_kernel=prune_cfg.use_kernel)]
    )
    rev = knn_graph.reverse_neighbors(merged_ids, max(prune_cfg.degree // 4, 1))
    # reverse ids here index into new-node rows; they are new-node ids
    rev = jnp.where(rev >= 0, rev + n_old, PAD_IDX)
    sem_new, kw_new, _ = pruning._prune_chunk(
        corpus,
        new_docs,
        jnp.arange(n_new, dtype=jnp.int32) + n_old,
        merged_ids,
        merged_scores,
        cself,
        rev,
        None,
        prune_cfg,
    )

    # back-link: replace the weakest semantic edge of each strong old neighbor
    sem_old = index.semantic_edges
    top_back = min(4, k)
    for j in range(top_back):
        tgt = merged_ids[:, j]  # (n_new,) target node (old or new)
        ok = (tgt >= 0) & (tgt < n_old)
        tgt_safe = jnp.clip(tgt, 0, n_old - 1)
        new_id = jnp.arange(n_new, dtype=jnp.int32) + n_old
        # weakest slot = last column (edge lists are priority-ordered)
        col = sem_old.shape[1] - 1 - (j % 2)
        sem_old = sem_old.at[tgt_safe, col].set(
            jnp.where(ok, new_id, sem_old[tgt_safe, col]), mode="drop"
        )

    pad_rows = lambda a, rows: jnp.concatenate(
        [a, jnp.full((rows,) + a.shape[1:], PAD_IDX, a.dtype)], axis=0
    )
    if new_doc_entities is not None:
        new_ents = jnp.asarray(new_doc_entities, jnp.int32)
        if new_ents.shape[1] != index.doc_entities.shape[1]:
            raise ValueError("entity width mismatch")
        doc_entities = jnp.concatenate([index.doc_entities, new_ents], 0)
    else:
        doc_entities = pad_rows(index.doc_entities, n_new)

    return HybridIndex(
        corpus=corpus,
        semantic_edges=jnp.concatenate([sem_old, sem_new], 0),
        keyword_edges=jnp.concatenate([index.keyword_edges, kw_new], 0),
        logical_edges=pad_rows(index.logical_edges, n_new),
        doc_entities=doc_entities,
        entity_to_docs=index.entity_to_docs,
        entity_adj=index.entity_adj,
        entry_points=index.entry_points,
        alive=jnp.concatenate([index.alive, jnp.ones((n_new,), bool)]),
        self_ip=cself,
    )
