"""The all-in-one hybrid index: structure and in-place updates
(paper §3, §4.1).

Isolated heterogeneous edge storage (paper §3.1): semantic edges, keyword
edges and logical edges live in separate fixed-width tables so any path
combination can be toggled at query time with zero reconstruction — the
"pluggable" property the paper's flexibility principle requires.

Layering: this module holds only the index *structure* (plus the shape-
preserving ``mark_deleted``). Construction — ``build_index``, ``insert``,
the device-resident fused programs — lives in ``core/build_pipeline.py``,
which imports this module and ``core/search.py`` from above; nothing here
imports the search or build layers, which is what keeps the old
index <-> search import cycle broken.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.knn_graph import KnnConfig
from repro.core.pruning import PruneConfig
from repro.core.usms import FusedVectors


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    knn: KnnConfig = KnnConfig()
    prune: PruneConfig = PruneConfig()
    n_entry: int = 16  # large-norm entry points (paper §4.2.1)
    path_refine_iters: int = 2  # per-path NN-Descent rounds (single-path slots)
    logical_cap: int = 16
    entity_doc_cap: int = 8


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "corpus",
        "semantic_edges",
        "keyword_edges",
        "logical_edges",
        "doc_entities",
        "entity_to_docs",
        "entity_adj",
        "entry_points",
        "alive",
        "self_ip",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class HybridIndex:
    corpus: FusedVectors  # (N, ...)
    semantic_edges: jax.Array  # (N, d) int32
    keyword_edges: jax.Array  # (N, dk) int32
    logical_edges: jax.Array  # (N, L, 4) int32
    doc_entities: jax.Array  # (N, Ed) int32
    entity_to_docs: jax.Array  # (E, M) int32
    entity_adj: jax.Array  # (E, E) bool
    entry_points: jax.Array  # (n_entry,) int32
    alive: jax.Array  # (N,) bool — mark-deletion
    self_ip: jax.Array  # (N,) IP(v, v)

    @property
    def n(self) -> int:
        return self.semantic_edges.shape[0]

    @property
    def degree(self) -> int:
        return self.semantic_edges.shape[1]

    def nbytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(self)
        )

    def edge_nbytes(self) -> dict:
        """Index-only storage (excludes raw vectors) — paper Table 2 metric."""
        return {
            "semantic": self.semantic_edges.nbytes,
            "keyword": self.keyword_edges.nbytes,
            "logical": self.logical_edges.nbytes,
            "entity_maps": self.entity_to_docs.nbytes + self.entity_adj.nbytes,
            "vectors": sum(a.nbytes for a in jax.tree.leaves(self.corpus)),
        }


def mark_deleted(index: HybridIndex, ids: jax.Array) -> HybridIndex:
    """Mark-deletion: nodes stay traversable, filtered from results
    (paper §4.1 "Updates of the Hybrid Index").

    Negative ids (``PAD_IDX`` slots from padded routing tables) are ignored:
    a raw ``.at[ids]`` would wrap them numpy-style and silently tombstone the
    *last* row, so they are remapped out of bounds and dropped."""
    ids = jnp.asarray(ids, jnp.int32)
    n = index.alive.shape[0]
    safe = jnp.where(ids >= 0, ids, n)  # PAD -> out-of-bounds, dropped below
    return dataclasses.replace(
        index, alive=index.alive.at[safe].set(False, mode="drop")
    )
