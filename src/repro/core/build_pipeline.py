"""Device-resident construction pipeline (paper §4.1, Algorithm 1; Table 2).

The paper's headline build numbers come from keeping the whole pipeline —
NN-Descent, RNG-IP joint pruning, keyword recycling — resident on the
accelerator. The seed reproduction drove every stage from Python chunk loops
(one jit dispatch per chunk per round, three *sequential* single-path
refinement descents, host-side concatenation of every round's (N, K)
tables). This module replaces that with a single jitted program:

  * ``BuildState`` — a pytree (neighbor ids/scores, RNG key) advanced by
    ``lax.fori_loop`` over descent rounds; node chunks stream through
    ``lax.map`` *inside* the trace, so per-round intermediates stay bounded
    by one chunk while the whole build is one host->device dispatch;
  * the three per-path refinement descents run as ONE batched descent over
    stacked single-path weight views (weights are traced data, Theorem 1):
    ``vmap`` over a leading path axis of the same round body. Note this
    trades memory for dispatch latency: the refinement stage holds the 3
    weighted corpus views and 3 (N, K) tables live at once (the legacy path
    held one at a time) — budget ~3x the fused-corpus footprint in HBM;
  * pruning chunks likewise run under ``lax.map`` in the same trace, using
    the candidate-pairwise tile kernel (kernels/pairwise_tile.py) instead of
    re-gathering candidate rows through a (C*K, K) id matrix;
  * ``insert()`` routes through the same stages (descent program + one
    fused merge/reverse/prune/back-link program).

Layering (this breaks the old index.py <-> search.py import cycle): graph
stages (knn_graph, pruning, this module's programs) sit below; assembly
(``build_index``/``insert``, which need HybridIndex and — for insert — the
search entry point) sits here at the top. ``core/index.py`` now holds only
the index structure and ``mark_deleted`` and imports neither.

Donation contract: the standalone ``nn_descent`` entry point donates the
init state buffers into the loop program (``_descent_rounds_jit``), so the
(N, K) tables are updated in place across the host boundary on accelerators
(donation is a no-op on CPU and disabled there to avoid warnings). Inside
the single-trace programs XLA reuses the fori_loop carry buffers without
any host round trip. See DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn_graph, pruning
from repro.core.index import BuildConfig, HybridIndex
from repro.core.knn_graph import KnnConfig, _merge_topk, new_node_reverse
from repro.core.logical_edges import LogicalEdges, build_logical_edges
from repro.core.pruning import self_scores
from repro.core.search import SearchParams, search
from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    PathWeights,
    stack_weights,
    weighted_query,
)
from repro.kernels import ops
from repro.runtime import dispatch

# the donated loop program is built lazily at first use: donation is only
# honored on accelerator backends (on CPU it just triggers "donated buffers
# were not usable" warnings), and querying the backend at import time would
# initialize it before callers can set XLA_FLAGS / distributed topology
_descent_rounds_jit_cache = None


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["nbr_ids", "nbr_scores", "key"],
    meta_fields=[],
)
@dataclasses.dataclass
class BuildState:
    """Carry of the descent loop: the evolving k-NN tables + RNG key.

    Leaves may carry a leading path axis (3, N, K) during the batched
    per-path refinement."""

    nbr_ids: jax.Array  # (N, K) int32
    nbr_scores: jax.Array  # (N, K) f32
    key: jax.Array  # RNG key


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "knn_ids",
        "knn_scores",
        "semantic_edges",
        "keyword_edges",
        "entry_points",
        "self_ip",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class GraphArrays:
    """Device outputs of the graph stages (everything but logical edges,
    which are host-side numpy)."""

    knn_ids: jax.Array  # (N, K)
    knn_scores: jax.Array  # (N, K)
    semantic_edges: jax.Array  # (N, d)
    keyword_edges: jax.Array  # (N, dk)
    entry_points: jax.Array  # (n_entry,)
    self_ip: jax.Array  # (N,)


def _pad_rows(a: jax.Array, pad: int, fill) -> jax.Array:
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)])


def _chunked(a: jax.Array, chunk: int, fill) -> jax.Array:
    """Pad rows to a multiple of ``chunk`` and reshape to (n_chunks, chunk, ...)."""
    pad = (-a.shape[0]) % chunk
    a = _pad_rows(a, pad, fill)
    return a.reshape((-1, chunk) + a.shape[1:])


def _chunked_tree(t, chunk: int, fill):
    return jax.tree.map(lambda a: _chunked(a, chunk, fill), t)


# ---------------------------------------------------------------------------
# Stage 1: NN-Descent, fully in-trace
# ---------------------------------------------------------------------------


def _descent_init(
    corpus: FusedVectors,
    queries: FusedVectors,
    key: jax.Array,
    init_ids: jax.Array | None,
    cfg: KnnConfig,
):
    """Initial graph + score-sorted rows (mirrors the legacy loop's prologue
    operation-for-operation so pipeline and legacy builds agree bitwise)."""
    n = corpus.n
    k = cfg.k
    key, k0 = jax.random.split(key)
    if init_ids is None:
        nbr_ids = knn_graph._init_graph(n, k, k0)
    else:
        nbr_ids = init_ids[:, :k]
        if nbr_ids.shape[1] < k:
            extra = knn_graph._init_graph(n, k - nbr_ids.shape[1], k0)
            nbr_ids = jnp.concatenate([nbr_ids, extra], axis=1)
    # fused score + full sort (k == row width) — operation-for-operation the
    # same as knn_graph.build_knn_graph's prologue, so both paths agree bitwise
    top, pos = ops.fused_topk_vs_ids(
        queries, corpus, nbr_ids, k, use_kernel=cfg.use_kernel
    )
    nbr_ids = ops.take_topk_ids(nbr_ids, pos)
    scores = jnp.where(nbr_ids >= 0, top, -jnp.inf)
    return BuildState(nbr_ids=nbr_ids, nbr_scores=scores, key=key)


def _descent_rounds(
    corpus: FusedVectors,
    queries: FusedVectors,
    state: BuildState,
    cfg: KnnConfig,
    iters: int,
) -> BuildState:
    """``iters`` NN-Descent rounds as one fori_loop; each round streams node
    chunks through lax.map against the round-start neighbor table."""
    n, k = state.nbr_ids.shape
    chunk = min(cfg.node_chunk, n)
    node_ids = jnp.arange(n, dtype=jnp.int32)
    # static per-trace chunk views (node id pad value n never matches a
    # candidate id, so pad rows stay inert)
    q_chunks = _chunked_tree(queries, chunk, 0)
    node_chunks = _chunked(node_ids, chunk, n)

    def one_round(_, st: BuildState) -> BuildState:
        key, kr = jax.random.split(st.key)
        rand_ids = jax.random.randint(
            kr, (n, cfg.extra_random), 0, n, dtype=jnp.int32
        )

        def chunk_fn(x):
            qs, nid, nbrs, scs, rnd = x
            return knn_graph._descent_round_chunk(
                corpus, st.nbr_ids, qs, nid, nbrs, scs, rnd, cfg
            )

        ids_c, sc_c = jax.lax.map(
            chunk_fn,
            (
                q_chunks,
                node_chunks,
                _chunked(st.nbr_ids, chunk, PAD_IDX),
                _chunked(st.nbr_scores, chunk, -jnp.inf),
                _chunked(rand_ids, chunk, PAD_IDX),
            ),
        )
        return BuildState(
            nbr_ids=ids_c.reshape(-1, k)[:n],
            nbr_scores=sc_c.reshape(-1, k)[:n],
            key=key,
        )

    return jax.lax.fori_loop(0, iters, one_round, state)


_descent_init_jit = jax.jit(_descent_init, static_argnames=("cfg",))


def _descent_rounds_flat(corpus, queries, nbr_ids, nbr_scores, key, cfg, iters):
    state = BuildState(nbr_ids=nbr_ids, nbr_scores=nbr_scores, key=key)
    out = _descent_rounds(corpus, queries, state, cfg, iters)
    return out.nbr_ids, out.nbr_scores


def _descent_rounds_jit(*args, **kw):
    global _descent_rounds_jit_cache
    if _descent_rounds_jit_cache is None:
        donate = (2, 3) if jax.default_backend() != "cpu" else ()
        _descent_rounds_jit_cache = jax.jit(
            _descent_rounds_flat,
            static_argnames=("cfg", "iters"),
            donate_argnums=donate,
        )
    return _descent_rounds_jit_cache(*args, **kw)


def nn_descent(
    corpus: FusedVectors,
    cfg: KnnConfig,
    key: jax.Array,
    *,
    queries: FusedVectors | None = None,
    init_ids: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Device-resident NN-Descent: two dispatches total (init + donated loop
    program) instead of the legacy iters x n_chunks. Drop-in replacement for
    ``knn_graph.build_knn_graph`` — same (cfg, key) gives the same graph."""
    queries = corpus if queries is None else queries
    dispatch.tick()
    state = _descent_init_jit(corpus, queries, key, init_ids, cfg)
    dispatch.tick()
    return _descent_rounds_jit(
        corpus, queries, state.nbr_ids, state.nbr_scores, state.key, cfg, cfg.iters
    )


# ---------------------------------------------------------------------------
# Stage 1b: batched per-path refinement (one descent over stacked views)
# ---------------------------------------------------------------------------


def _single_path_views(corpus: FusedVectors) -> FusedVectors:
    """Stack the three single-path weight views of the corpus on a leading
    path axis — weights enter as traced data (Theorem 1), so one program
    refines all paths at once."""
    ws = stack_weights(
        [
            PathWeights.make(1.0, 0.0, 0.0),
            PathWeights.make(0.0, 1.0, 0.0),
            PathWeights.make(0.0, 0.0, 1.0),
        ]
    )
    return jax.vmap(lambda w: weighted_query(corpus, w))(ws)


def _path_refinement(
    corpus: FusedVectors,
    knn_ids: jax.Array,
    key: jax.Array,
    cfg: BuildConfig,
    pk: int,
) -> jax.Array:
    """The d/2 single-path neighbor slots: one *batched* descent over the
    stacked path views (vs the legacy three sequential descents). Returns
    (N, 3, pk) per-path neighbor ids."""
    pcfg = dataclasses.replace(
        cfg.knn, iters=cfg.path_refine_iters, k=max(pk, 12)
    )
    qviews = _single_path_views(corpus)  # leaves (3, N, ...)
    pkeys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(1, 4))

    def one_path(qv: FusedVectors, pkey: jax.Array) -> jax.Array:
        st = _descent_init(corpus, qv, pkey, knn_ids, pcfg)
        st = _descent_rounds(corpus, qv, st, pcfg, pcfg.iters)
        return st.nbr_ids[:, :pk]

    per_path = jax.vmap(one_path)(qviews, pkeys)  # (3, N, pk)
    return jnp.swapaxes(per_path, 0, 1)  # (N, 3, pk)


# ---------------------------------------------------------------------------
# Stages 2-3: pruning + keyword recycling, chunked in-trace
# ---------------------------------------------------------------------------


def _prune_all(
    corpus: FusedVectors,
    knn_ids: jax.Array,
    knn_scores: jax.Array,
    cself: jax.Array,
    path_ids: jax.Array | None,
    cfg,
) -> tuple[jax.Array, jax.Array]:
    """rng_ip_prune with the chunk loop inside the trace (lax.map)."""
    n = corpus.n
    chunk = min(cfg.node_chunk, n)
    rev = knn_graph.reverse_neighbors(knn_ids, max(cfg.degree // 4, 1))
    node_ids = jnp.arange(n, dtype=jnp.int32)

    xs = (
        _chunked_tree(corpus, chunk, 0),
        _chunked(node_ids, chunk, n),
        _chunked(knn_ids, chunk, PAD_IDX),
        _chunked(knn_scores, chunk, -jnp.inf),
        _chunked(rev, chunk, PAD_IDX),
    )
    if path_ids is not None:
        xs = xs + (_chunked(path_ids, chunk, PAD_IDX),)

    def chunk_fn(x):
        qs, nid, cids, cscs, rv = x[:5]
        pids = x[5] if len(x) > 5 else None
        return pruning._prune_chunk(
            corpus, qs, nid, cids, cscs, cself, rv, pids, cfg
        )

    sem, kw, _ = jax.lax.map(chunk_fn, xs)
    d = sem.shape[-1]
    dk = kw.shape[-1]
    return sem.reshape(-1, d)[:n], kw.reshape(-1, dk)[:n]


# ---------------------------------------------------------------------------
# Entry points (paper §4.2.1) — shared by pipeline (in-trace) and legacy
# ---------------------------------------------------------------------------


def _entry_points(
    corpus: FusedVectors, sip: jax.Array, n_entry: int, use_kernel: bool | None
) -> jax.Array:
    """Union of top-norm nodes under the fused metric AND each single path,
    so entry quality holds for any query weights."""
    # ceil: the 4-part union must never be narrower than n_entry (a tiny
    # segment's n_entry = n may not divide by 4, and unique_take can only
    # return what it was given)
    per = max(-(-n_entry // 4), 1)
    entry_parts = [jax.lax.top_k(sip, per)[1]]
    for w in (
        PathWeights.make(1.0, 0.0, 0.0),
        PathWeights.make(0.0, 1.0, 0.0),
        PathWeights.make(0.0, 0.0, 1.0),
    ):
        qw = weighted_query(corpus, w)
        cands = jax.tree.map(lambda a: a[:, None], qw)
        norms = ops.hybrid_scores(qw, cands, use_kernel=use_kernel)[:, 0]
        entry_parts.append(jax.lax.top_k(norms, per)[1])
    cat = jnp.concatenate(entry_parts).astype(jnp.int32)
    entries = pruning.unique_take(
        cat, jnp.zeros(cat.shape, jnp.float32), n_entry
    )
    # backfill duplicates with the next-best fused-norm nodes
    fill = jax.lax.top_k(sip, n_entry)[1].astype(jnp.int32)
    return jnp.where(entries >= 0, entries, fill).astype(jnp.int32)


# ---------------------------------------------------------------------------
# The fused graph-build program: ONE dispatch for steps 1-3 + entry points
# ---------------------------------------------------------------------------


def _graph_pk(cfg: BuildConfig) -> int:
    d = cfg.prune.degree
    return max((d - 2 * max(d // 4, 1)) // 3 + 1, 2)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _build_graph_program(
    corpus: FusedVectors, key: jax.Array, cfg: BuildConfig
) -> GraphArrays:
    # Step 1: fused NN-Descent
    st = _descent_init(corpus, corpus, key, None, cfg.knn)
    st = _descent_rounds(corpus, corpus, st, cfg.knn, cfg.knn.iters)
    knn_ids, knn_scores = st.nbr_ids, st.nbr_scores

    # Step 1b: batched per-path refinement
    path_ids = None
    if cfg.path_refine_iters > 0:
        path_ids = _path_refinement(corpus, knn_ids, key, cfg, _graph_pk(cfg))

    # Steps 2-3: RNG-IP joint pruning + keyword recycling
    cself = self_scores(corpus, use_kernel=cfg.prune.use_kernel)
    sem, kw = _prune_all(corpus, knn_ids, knn_scores, cself, path_ids, cfg.prune)

    # entry points (§4.2.1)
    n_entry = min(cfg.n_entry, corpus.n)
    entries = _entry_points(corpus, cself, n_entry, cfg.prune.use_kernel)
    return GraphArrays(
        knn_ids=knn_ids,
        knn_scores=knn_scores,
        semantic_edges=sem,
        keyword_edges=kw,
        entry_points=entries,
        self_ip=cself,
    )


def build_graph(
    corpus: FusedVectors, cfg: BuildConfig, key: jax.Array
) -> GraphArrays:
    """All device-side graph stages as a single dispatch. This is the unit
    ``build_index_sharded`` replicates per segment under shard_map."""
    dispatch.tick()
    dispatch.build_rows_tick(corpus.n)
    return _build_graph_program(corpus, key, cfg)


def _build_graph_host(
    corpus: FusedVectors, cfg: BuildConfig, key: jax.Array
) -> GraphArrays:
    """Legacy host-driven path (Python chunk loops, sequential per-path
    descents). Kept for A/B benchmarking (BENCH_build.json) and as the
    reference the pipeline is validated against."""
    dispatch.build_rows_tick(corpus.n)
    knn_ids, knn_scores = knn_graph.build_knn_graph(corpus, cfg.knn, key)
    path_ids = None
    if cfg.path_refine_iters > 0:
        pk = _graph_pk(cfg)
        pcfg = dataclasses.replace(
            cfg.knn, iters=cfg.path_refine_iters, k=max(pk, 12)
        )
        per_path = []
        for i, w in enumerate(
            (
                PathWeights.make(1.0, 0.0, 0.0),
                PathWeights.make(0.0, 1.0, 0.0),
                PathWeights.make(0.0, 0.0, 1.0),
            )
        ):
            pids, _ = knn_graph.build_knn_graph(
                corpus,
                pcfg,
                jax.random.fold_in(key, i + 1),
                queries=weighted_query(corpus, w),
                init_ids=knn_ids,
            )
            per_path.append(pids[:, :pk])
        path_ids = jnp.stack(per_path, axis=1)  # (N, 3, pk)
    sem, kw = pruning.rng_ip_prune(
        corpus, knn_ids, knn_scores, cfg.prune, path_ids=path_ids
    )
    dispatch.tick()
    sip = self_scores(corpus, use_kernel=cfg.prune.use_kernel)
    dispatch.tick(3)  # the three per-path top-norm scoring passes below
    entries = _entry_points(corpus, sip, min(cfg.n_entry, corpus.n), cfg.prune.use_kernel)
    return GraphArrays(
        knn_ids=knn_ids,
        knn_scores=knn_scores,
        semantic_edges=sem,
        keyword_edges=kw,
        entry_points=entries,
        self_ip=sip,
    )


# ---------------------------------------------------------------------------
# Assembly: build_index (Algorithm 1) and insert (paper §4.1 Updates)
# ---------------------------------------------------------------------------


def build_index(
    corpus: FusedVectors,
    cfg: BuildConfig = BuildConfig(),
    *,
    key: Optional[jax.Array] = None,
    kg_triplets: Optional[np.ndarray] = None,
    doc_entities: Optional[np.ndarray] = None,
    n_entities: int = 0,
    pipeline: bool = True,
) -> HybridIndex:
    """Full construction pipeline (Algorithm 1). ``pipeline=True`` runs the
    device-resident fused program (one dispatch for all graph stages);
    ``pipeline=False`` keeps the legacy host-driven chunk loops."""
    key = key if key is not None else jax.random.key(0)
    n = corpus.n

    g = build_graph(corpus, cfg, key) if pipeline else _build_graph_host(corpus, cfg, key)

    # Step 4: logical edges (host-side numpy; no device work)
    if kg_triplets is not None and doc_entities is not None and n_entities > 0:
        log = build_logical_edges(
            kg_triplets,
            doc_entities,
            n_entities,
            l_cap=cfg.logical_cap,
            m_cap=cfg.entity_doc_cap,
        )
    else:
        log = LogicalEdges.empty(n)

    return HybridIndex(
        corpus=corpus,
        semantic_edges=g.semantic_edges,
        keyword_edges=g.keyword_edges,
        logical_edges=jnp.asarray(log.edges),
        doc_entities=jnp.asarray(log.doc_entities),
        entity_to_docs=jnp.asarray(log.entity_to_docs),
        entity_adj=jnp.asarray(log.entity_adj),
        entry_points=g.entry_points,
        alive=jnp.ones((n,), bool),
        self_ip=g.self_ip,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def _insert_program(
    corpus_cat: FusedVectors,  # (n_old + n_new, ...) concatenated corpus
    new_docs: FusedVectors,  # (n_new, ...)
    old_self_ip: jax.Array,  # (n_old,)
    sem_old: jax.Array,  # (n_old, d)
    old_ids: jax.Array,  # (n_new, k) search results vs the existing index
    old_scores: jax.Array,  # (n_new, k)
    new_ids_local: jax.Array,  # (n_new, k) NN-Descent among the new nodes
    new_scores: jax.Array,  # (n_new, k)
    cfg: BuildConfig,
):
    """Fused merge + reverse + prune + back-link for an insert batch: one
    dispatch where the legacy path issued one per stage."""
    n_old = sem_old.shape[0]
    n_new = new_docs.n
    k = cfg.knn.k
    prune_cfg = cfg.prune

    new_ids_global = jnp.where(
        new_ids_local >= 0, new_ids_local + n_old, PAD_IDX
    )
    merged_ids, merged_scores = _merge_topk(
        old_ids, old_scores, new_ids_global, new_scores, k
    )

    cself = jnp.concatenate(
        [old_self_ip, self_scores(new_docs, use_kernel=prune_cfg.use_kernel)]
    )
    # reverse edges among the new nodes only — merged_ids holds GLOBAL ids,
    # so old-corpus targets must not be mistaken for new-node rows
    rev = new_node_reverse(merged_ids, n_old, max(prune_cfg.degree // 4, 1))
    sem_new, kw_new, _ = pruning._prune_chunk(
        corpus_cat,
        new_docs,
        jnp.arange(n_new, dtype=jnp.int32) + n_old,
        merged_ids,
        merged_scores,
        cself,
        rev,
        None,
        prune_cfg,
    )

    # back-link: replace the weakest semantic edge of each strong old neighbor
    top_back = min(4, k)
    for j in range(top_back):
        tgt = merged_ids[:, j]  # (n_new,) target node (old or new)
        ok = (tgt >= 0) & (tgt < n_old)
        tgt_safe = jnp.clip(tgt, 0, n_old - 1)
        new_id = jnp.arange(n_new, dtype=jnp.int32) + n_old
        # weakest slot = last column (edge lists are priority-ordered)
        col = sem_old.shape[1] - 1 - (j % 2)
        sem_old = sem_old.at[tgt_safe, col].set(
            jnp.where(ok, new_id, sem_old[tgt_safe, col]), mode="drop"
        )
    return sem_old, sem_new, kw_new, cself


def insert(
    index: HybridIndex,
    new_docs: FusedVectors,
    cfg: BuildConfig,
    *,
    key: Optional[jax.Array] = None,
    new_doc_entities: Optional[np.ndarray] = None,
    search_params: Optional[SearchParams] = None,
) -> HybridIndex:
    """Insert new nodes: their k-NN = merge of (a) search of the existing
    index and (b) device-resident NN-Descent among the new nodes; then the
    standard pruning, all through the same pipeline stages as build_graph.
    Existing nodes acquire reverse edges to the new nodes (slot-replacement
    of their weakest edge) so the new region stays reachable.

    ``search_params`` bounds the step-(a) probe (the serving-layer grow
    segment trades probe breadth for insert latency); ``k`` and the edge
    paths are forced to the build's values so the candidate merge widths
    stay fixed regardless of the caller's serving params."""
    key = key if key is not None else jax.random.key(1)
    n_old = index.n
    n_new = new_docs.n
    k = cfg.knn.k
    dispatch.build_rows_tick(n_new)

    # (a) k-NN from the existing index via its own search
    if search_params is None:
        params = SearchParams(k=k, iters=max(24, 2 * k), use_kernel=cfg.knn.use_kernel)
    else:
        params = dataclasses.replace(
            search_params, k=k, use_keywords=False, use_kg=False,
            use_kernel=cfg.knn.use_kernel,
            # forcing k up must drag the pool along, or top_k(pool, k)
            # dies at trace time with an opaque XLA error
            pool_size=max(search_params.pool_size, 2 * k),
        )
    dispatch.tick()
    res = search(index, new_docs, PathWeights.three_path(), params)

    # (b) NN-Descent among the new nodes only (device-resident program)
    new_ids_local, new_scores = nn_descent(new_docs, cfg.knn, key)

    # concatenated corpus
    corpus = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b], axis=0), index.corpus, new_docs
    )

    dispatch.tick()
    sem_old, sem_new, kw_new, cself = _insert_program(
        corpus,
        new_docs,
        index.self_ip,
        index.semantic_edges,
        res.ids,
        res.scores,
        new_ids_local,
        new_scores,
        cfg,
    )

    pad_rows = lambda a, rows: jnp.concatenate(
        [a, jnp.full((rows,) + a.shape[1:], PAD_IDX, a.dtype)], axis=0
    )
    if new_doc_entities is not None:
        new_ents = jnp.asarray(new_doc_entities, jnp.int32)
        if new_ents.shape[1] != index.doc_entities.shape[1]:
            raise ValueError("entity width mismatch")
        doc_entities = jnp.concatenate([index.doc_entities, new_ents], 0)
    else:
        doc_entities = pad_rows(index.doc_entities, n_new)

    return HybridIndex(
        corpus=corpus,
        semantic_edges=jnp.concatenate([sem_old, sem_new], 0),
        keyword_edges=jnp.concatenate([index.keyword_edges, kw_new], 0),
        logical_edges=pad_rows(index.logical_edges, n_new),
        doc_entities=doc_entities,
        entity_to_docs=index.entity_to_docs,
        entity_adj=index.entity_adj,
        entry_points=index.entry_points,
        alive=jnp.concatenate([index.alive, jnp.ones((n_new,), bool)]),
        self_ip=cself,
    )


# ---------------------------------------------------------------------------
# Row-axis reshaping of a built index (shape-bucketing support): shared by
# the serving grow segment and the segment pool's pow2-capacity segments.
# ---------------------------------------------------------------------------


def map_index_rows(index: HybridIndex, fn) -> HybridIndex:
    """Apply ``fn(array, pad_fill)`` to every per-row (axis-0 == N) leaf of a
    single-segment index; entity tables and entry points are N-independent."""
    from repro.core.usms import SparseVec

    return dataclasses.replace(
        index,
        corpus=FusedVectors(
            fn(index.corpus.dense, 0),
            SparseVec(
                fn(index.corpus.learned.idx, PAD_IDX),
                fn(index.corpus.learned.val, 0),
            ),
            SparseVec(
                fn(index.corpus.lexical.idx, PAD_IDX),
                fn(index.corpus.lexical.val, 0),
            ),
        ),
        semantic_edges=fn(index.semantic_edges, PAD_IDX),
        keyword_edges=fn(index.keyword_edges, PAD_IDX),
        logical_edges=fn(index.logical_edges, PAD_IDX),
        doc_entities=fn(index.doc_entities, PAD_IDX),
        alive=fn(index.alive, False),
        self_ip=fn(index.self_ip, 0.0),
    )


def pad_index_rows(index: HybridIndex, capacity: int) -> HybridIndex:
    """Pad an index's per-row arrays with DEAD rows up to ``capacity``
    (shape-bucketing). Pad rows are unreachable by construction: entry
    points and edges only reference real rows, ``alive`` is False, and no
    global-id map ever covers them."""
    n = index.n
    if capacity <= n:
        return index

    def pad(a, fill):
        return jnp.concatenate(
            [a, jnp.full((capacity - n,) + a.shape[1:], fill, a.dtype)]
        )

    return map_index_rows(index, pad)


def slice_index_rows(index: HybridIndex, n: int) -> HybridIndex:
    """Drop a padded index's dead tail (inverse of ``pad_index_rows``)."""
    if index.n == n:
        return index
    return map_index_rows(index, lambda a, _fill: a[:n])
