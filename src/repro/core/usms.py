"""Unified Semantic Metric Space (USMS) — paper §3.1/§3.2.

A USMS fuses the heterogeneous retrieval paths (dense vector, learned sparse
vector, lexical/full-text sparse vector, knowledge-graph entities) into a
single metric space where weighted hybrid search is *exactly* Maximum Inner
Product Search (Theorem 1 of the paper):

    M_w(q, d) = w_d·<qd, dd> + w_s·<qs, ds> + w_f·<qf, df>
              = <[w_d·qd, w_s·qs, w_f·qf], [dd, ds, df]>

so weights are applied to the QUERY only and one index serves any weight
vector without reconstruction.

TPU adaptation: sparse vectors use a fixed-nnz ELL layout ``(idx, val)`` with
``PAD_IDX`` padding instead of CSR — fixed shapes are mandatory for XLA and
turn the GPU per-thread binary-search intersection into vectorized
equality-compare tiles (see ``kernels/hybrid_distance.py``).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

PAD_IDX = -1  # sentinel for unused sparse slots / entity slots


@partial(jax.tree_util.register_dataclass, data_fields=["idx", "val"], meta_fields=[])
@dataclasses.dataclass
class SparseVec:
    """Fixed-nnz (ELL) sparse vectors.

    idx: (..., P) int32, PAD_IDX-padded, indices unique per row.
    val: (..., P) float, 0 in padded slots.
    """

    idx: jax.Array
    val: jax.Array

    @property
    def nnz_cap(self) -> int:
        return self.idx.shape[-1]

    def __getitem__(self, key) -> "SparseVec":
        return SparseVec(self.idx[key], self.val[key])


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["dense", "learned", "lexical"],
    meta_fields=[],
)
@dataclasses.dataclass
class FusedVectors:
    """A batch of documents or queries in the USMS.

    dense:   (..., Dd) float — semantic embedding (e.g. BGE-M3).
    learned: SparseVec (..., Ps) — learned sparse (e.g. SPLADE).
    lexical: SparseVec (..., Pf) — full-text/BM25 term weights. The lexical
             ``idx`` doubles as the keyword set K(·) used by keyword edges.
    """

    dense: jax.Array
    learned: SparseVec
    lexical: SparseVec

    @property
    def n(self) -> int:
        return self.dense.shape[0]

    def __getitem__(self, key) -> "FusedVectors":
        return FusedVectors(self.dense[key], self.learned[key], self.lexical[key])

    def take(self, ids: jax.Array) -> "FusedVectors":
        """Gather rows by id along axis 0. ids may contain PAD_IDX (clipped;
        callers must mask the resulting scores)."""
        safe = jnp.clip(ids, 0, self.dense.shape[0] - 1)
        take = lambda a: jnp.take(a, safe, axis=0)
        return FusedVectors(
            take(self.dense),
            SparseVec(take(self.learned.idx), take(self.learned.val)),
            SparseVec(take(self.lexical.idx), take(self.lexical.val)),
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["dense_q", "dense_scale", "learned", "lexical"],
    meta_fields=[],
)
@dataclasses.dataclass
class QuantizedFusedVectors:
    """A sealed corpus in compressed storage: per-row symmetric int8 dense
    vectors with fp32 scales, fp16 ELL sparse values (ids stay int32).

    dense_q:     (..., Dd) int8 — round(dense / scale), clipped to ±127.
    dense_scale: (...,) float32 — per-row scale; 1.0 for all-zero rows.
    learned:     SparseVec (..., Ps) with float16 vals.
    lexical:     SparseVec (..., Pf) with float16 vals.

    Deliberately has no ``.dense`` property: reconstructing fp32 rows must be
    an explicit ``dequantize_corpus`` call, never a silent densification.
    """

    dense_q: jax.Array
    dense_scale: jax.Array
    learned: SparseVec
    lexical: SparseVec

    @property
    def n(self) -> int:
        return self.dense_q.shape[0]

    def __getitem__(self, key) -> "QuantizedFusedVectors":
        return QuantizedFusedVectors(
            self.dense_q[key],
            self.dense_scale[key],
            self.learned[key],
            self.lexical[key],
        )

    def take(self, ids: jax.Array) -> "QuantizedFusedVectors":
        """Gather rows by id along axis 0. ids may contain PAD_IDX (clipped;
        callers must mask the resulting scores)."""
        safe = jnp.clip(ids, 0, self.dense_q.shape[0] - 1)
        take = lambda a: jnp.take(a, safe, axis=0)
        return QuantizedFusedVectors(
            take(self.dense_q),
            take(self.dense_scale),
            SparseVec(take(self.learned.idx), take(self.learned.val)),
            SparseVec(take(self.lexical.idx), take(self.lexical.val)),
        )


def quantize_corpus(f: FusedVectors) -> QuantizedFusedVectors:
    """Seal-time compression of a built corpus (paper: reduced storage).

    Dense rows use symmetric per-row int8: scale = max|row| / 127 (1.0 for
    all-zero rows so dequantization is exact there), giving a per-element
    dequantization error of at most scale / 2. Sparse ELL values drop to
    fp16 — padded slots stay exactly 0, so the kernel padding contract
    (query PAD only matches candidate PAD whose val is 0) is preserved.
    """
    amax = jnp.max(jnp.abs(f.dense), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    dense_q = jnp.clip(
        jnp.round(f.dense / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return QuantizedFusedVectors(
        dense_q,
        scale,
        SparseVec(f.learned.idx, f.learned.val.astype(jnp.float16)),
        SparseVec(f.lexical.idx, f.lexical.val.astype(jnp.float16)),
    )


def dequantize_corpus(q: QuantizedFusedVectors) -> FusedVectors:
    """Reconstruct fp32 storage from a quantized corpus. Used when sealed
    segments feed back into a rebuild (merge / compaction), which always
    runs at full precision."""
    dense = q.dense_q.astype(jnp.float32) * q.dense_scale[..., None]
    return FusedVectors(
        dense,
        SparseVec(q.learned.idx, q.learned.val.astype(jnp.float32)),
        SparseVec(q.lexical.idx, q.lexical.val.astype(jnp.float32)),
    )


def corpus_nbytes_by_leaf(corpus) -> dict:
    """Byte footprint of a corpus pytree, keyed by (leaf, dtype) — feeds the
    ``allanpoe_index_bytes_total`` gauges."""
    out: dict = {}
    if isinstance(corpus, QuantizedFusedVectors):
        named = [
            ("dense", corpus.dense_q),
            ("dense_scale", corpus.dense_scale),
            ("sparse_idx", corpus.learned.idx),
            ("sparse_val", corpus.learned.val),
            ("sparse_idx", corpus.lexical.idx),
            ("sparse_val", corpus.lexical.val),
        ]
    else:
        named = [
            ("dense", corpus.dense),
            ("sparse_idx", corpus.learned.idx),
            ("sparse_val", corpus.learned.val),
            ("sparse_idx", corpus.lexical.idx),
            ("sparse_val", corpus.lexical.val),
        ]
    for leaf, arr in named:
        key = (leaf, str(arr.dtype))
        out[key] = out.get(key, 0) + arr.size * arr.dtype.itemsize
    return out


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["dense", "sparse", "full", "kg"],
    meta_fields=[],
)
@dataclasses.dataclass
class PathWeights:
    """Runtime fusion weights [w_d, w_s, w_f, w_k] — a pytree of scalars so
    that changing weights never triggers recompilation or index rebuild."""

    dense: jax.Array
    sparse: jax.Array
    full: jax.Array
    kg: jax.Array

    @classmethod
    def make(cls, dense=1.0, sparse=0.0, full=0.0, kg=0.0) -> "PathWeights":
        f = lambda x: jnp.asarray(x, jnp.float32)
        return cls(f(dense), f(sparse), f(full), f(kg))

    @classmethod
    def three_path(cls) -> "PathWeights":
        return cls.make(1.0, 1.0, 1.0, 0.0)


def stack_weights(ws) -> "PathWeights":
    """Stack per-request PathWeights into one batched PathWeights whose
    leaves are (B,) arrays — heterogeneous fusion weights ride through one
    executable as traced data (Theorem 1)."""
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]), *ws
    )


def _expand_weight(w: jax.Array, target_ndim: int) -> jax.Array:
    """Right-pad a scalar or (B,)-batched weight with singleton axes so it
    broadcasts against (..., D)-shaped query components."""
    w = jnp.asarray(w, jnp.float32)
    return w.reshape(w.shape + (1,) * (target_ndim - w.ndim))


def weighted_query(q: FusedVectors, w: PathWeights) -> FusedVectors:
    """Theorem 1: scale query components by path weights so the hybrid score
    becomes a single inner product in the USMS. Weight leaves may be scalars
    (one weight vector for the whole batch) or (B,) arrays (per-query
    weights, as micro-batched serving requires)."""
    return FusedVectors(
        q.dense * _expand_weight(w.dense, q.dense.ndim),
        SparseVec(
            q.learned.idx,
            q.learned.val * _expand_weight(w.sparse, q.learned.val.ndim),
        ),
        SparseVec(
            q.lexical.idx,
            q.lexical.val * _expand_weight(w.full, q.lexical.val.ndim),
        ),
    )


def sparse_from_dense(x: jax.Array, nnz_cap: int) -> SparseVec:
    """Keep the top-``nnz_cap`` entries by magnitude (SEISMIC-style static
    pruning). x: (..., V) dense -> SparseVec (..., nnz_cap)."""
    mag = jnp.abs(x)
    val, idx = jax.lax.top_k(mag, nnz_cap)
    gathered = jnp.take_along_axis(x, idx, axis=-1)
    keep = val > 0
    return SparseVec(
        jnp.where(keep, idx, PAD_IDX).astype(jnp.int32),
        jnp.where(keep, gathered, 0.0),
    )


def sparse_to_dense(s: SparseVec, vocab: int) -> jax.Array:
    """Scatter an ELL sparse vector back to dense (oracle/testing only)."""
    out_shape = s.idx.shape[:-1] + (vocab,)
    flat_idx = s.idx.reshape(-1, s.idx.shape[-1])
    flat_val = s.val.reshape(-1, s.val.shape[-1])

    def scatter_row(i, v):
        z = jnp.zeros((vocab,), flat_val.dtype)
        safe = jnp.where(i >= 0, i, 0)
        return z.at[safe].add(jnp.where(i >= 0, v, 0.0))

    return jax.vmap(scatter_row)(flat_idx, flat_val).reshape(out_shape)


def concat_dense(f: FusedVectors, vocab_s: int, vocab_f: int) -> jax.Array:
    """Materialize f_concat(d) = [dense, sparse, full] as one dense vector
    (oracle/testing only — never used at scale)."""
    return jnp.concatenate(
        [
            f.dense,
            sparse_to_dense(f.learned, vocab_s),
            sparse_to_dense(f.lexical, vocab_f),
        ],
        axis=-1,
    )


def keyword_overlap(a_idx: jax.Array, b_idx: jax.Array) -> jax.Array:
    """|K(a) ∩ K(b)| for PAD_IDX-padded keyword id arrays.

    a_idx: (..., Pa), b_idx: (..., Pb) -> (...,) int32 overlap counts.
    Assumes unique ids per row (true by construction).
    """
    eq = a_idx[..., :, None] == b_idx[..., None, :]
    valid = (a_idx[..., :, None] >= 0) & (b_idx[..., None, :] >= 0)
    return jnp.sum(eq & valid, axis=(-1, -2)).astype(jnp.int32)


def has_keyword_overlap(a_idx: jax.Array, b_idx: jax.Array) -> jax.Array:
    return keyword_overlap(a_idx, b_idx) > 0
