"""Allan-Poe core: the paper's all-in-one hybrid graph index in JAX."""

from repro.core.build_pipeline import build_graph, build_index, insert, nn_descent
from repro.core.fusion import (
    FUSION_MODES,
    MINMAX,
    RRF,
    WEIGHTED_SUM,
    ZSCORE,
    FusionSpec,
    PathStats,
    adaptive_fusion,
    as_fusion_spec,
    stack_specs,
)
from repro.core.index import BuildConfig, HybridIndex, mark_deleted
from repro.core.knn_graph import KnnConfig, build_knn_graph
from repro.core.pruning import PruneConfig, rng_ip_prune
from repro.core.search import SearchParams, SearchResult, search, search_padded
from repro.core.usms import (
    PAD_IDX,
    FusedVectors,
    PathWeights,
    SparseVec,
    stack_weights,
    weighted_query,
)

__all__ = [
    "BuildConfig",
    "HybridIndex",
    "FUSION_MODES",
    "WEIGHTED_SUM",
    "MINMAX",
    "ZSCORE",
    "RRF",
    "FusionSpec",
    "PathStats",
    "adaptive_fusion",
    "as_fusion_spec",
    "stack_specs",
    "build_graph",
    "build_index",
    "nn_descent",
    "insert",
    "mark_deleted",
    "KnnConfig",
    "build_knn_graph",
    "PruneConfig",
    "rng_ip_prune",
    "SearchParams",
    "SearchResult",
    "search",
    "search_padded",
    "PAD_IDX",
    "FusedVectors",
    "PathWeights",
    "SparseVec",
    "stack_weights",
    "weighted_query",
]
