"""Synthetic corpora with planted structure for end-to-end evaluation.

The container has no internet access, so the paper's datasets (NQ, MS MARCO,
2WikiMultiHopQA, HotpotQA) are modeled by synthetic corpora that preserve the
*structure* the paper's experiments rely on:

  * topic clusters          -> dense semantic similarity (BGE-M3 analogue)
  * Zipf-weighted term pools-> learned sparse vectors (SPLADE analogue)
  * per-doc keyword sets    -> lexical/full-text vectors (BM25 analogue)
  * entity chains           -> knowledge graph with multi-hop ground truth
                               (2WikiMultiHopQA analogue)

Each query carries *planted* relevant documents, so "end-to-end accuracy"
(recall of planted docs) is measurable separately from vector-similarity
recall — the distinction the paper's §2.2 motivation builds on. Queries can
be biased so that different paths are informative for different query types
(dense-informative, sparse-informative, mixed), reproducing the paper's
finding that no single path or combination dominates everywhere.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.usms import PAD_IDX, FusedVectors, SparseVec


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 4096
    n_queries: int = 64
    n_topics: int = 64
    d_dense: int = 128
    vocab_sparse: int = 30522  # SPLADE vocab size (paper Table 1)
    vocab_lexical: int = 8192
    nnz_sparse: int = 32  # fixed-nnz cap (ELL)
    nnz_lexical: int = 16
    nnz_query_sparse: int = 16
    nnz_query_lexical: int = 8
    terms_per_topic: int = 64
    keywords_per_topic: int = 24
    relevant_per_query: int = 3
    dense_noise: float = 0.35
    # entity/KG structure: each doc has one RARE entity (unique to it — named
    # entities like "John" in the paper's example) + a few COMMON entities
    # (places, concepts) shared across docs; multi-hop chains ride on rare
    # entities so the chain tail is only reachable through the KG.
    n_common_entities: int = 128
    entities_per_doc: int = 4
    chain_len: int = 3  # multi-hop chains: e0 -r-> e1 -r-> e2
    seed: int = 0

    @property
    def n_entities(self) -> int:
        return self.n_docs + self.n_common_entities


@dataclasses.dataclass
class KnowledgeGraph:
    """Entity-level KG: triplets (src_entity, rel, dst_entity)."""

    triplets: np.ndarray  # (T, 3) int32
    n_entities: int


@dataclasses.dataclass
class SyntheticCorpus:
    config: CorpusConfig
    docs: FusedVectors  # (N, ...)
    doc_entities: np.ndarray  # (N, E) int32 PAD_IDX-padded
    doc_topics: np.ndarray  # (N,) int32
    kg: KnowledgeGraph
    queries: FusedVectors  # (Q, ...)
    query_entities: np.ndarray  # (Q, E) int32
    query_relevant: np.ndarray  # (Q, R) planted relevant doc ids
    query_keywords: np.ndarray  # (Q, K) required-keyword ids (PAD_IDX padded)
    query_multihop_target: np.ndarray  # (Q,) doc id reachable via KG chain, or -1


def _unit(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _ell_from_pairs(idx_rows, val_rows, cap: int):
    """Pack per-row (indices, values) lists into fixed-nnz ELL arrays."""
    n = len(idx_rows)
    idx = np.full((n, cap), PAD_IDX, np.int32)
    val = np.zeros((n, cap), np.float32)
    for r, (ii, vv) in enumerate(zip(idx_rows, val_rows)):
        order = np.argsort(-np.asarray(vv))[:cap]
        ii = np.asarray(ii)[order]
        vv = np.asarray(vv)[order]
        idx[r, : len(ii)] = ii
        val[r, : len(vv)] = vv
    return idx, val


def _sample_sparse(rng, pool, pool_w, nnz):
    """Sample one Zipf-weighted sparse row from a term pool. Values follow a
    BM25/SPLADE-like magnitude profile (frequent terms -> smaller weights)."""
    k = min(nnz, len(pool))
    sel = rng.choice(len(pool), size=k, replace=False, p=pool_w)
    val = np.abs(rng.normal(1.0, 0.3, size=k)).astype(np.float32) * (
        1.0 / np.sqrt(1.0 + 50.0 * pool_w[sel])
    )
    return pool[sel], val


def make_corpus(cfg: CorpusConfig) -> SyntheticCorpus:
    rng = np.random.default_rng(cfg.seed)
    nt = cfg.n_topics

    # --- topic machinery -------------------------------------------------
    # Real text shares high-frequency terms across topics (Zipf), which is
    # exactly what makes sparse-similarity landscapes navigable; topic pools
    # therefore mix a GLOBAL common-term pool with topic-specific rare terms.
    centers = _unit(rng.normal(size=(nt, cfg.d_dense)).astype(np.float32))
    n_common = max(cfg.terms_per_topic // 2, 8)
    common_terms = np.arange(n_common, dtype=np.int64)  # global frequent terms
    common_kws = np.arange(max(cfg.keywords_per_topic // 2, 4), dtype=np.int64)
    topic_terms = [
        np.concatenate(
            [
                common_terms,
                n_common
                + rng.choice(
                    cfg.vocab_sparse - n_common, size=cfg.terms_per_topic, replace=False
                ),
            ]
        )
        for _ in range(nt)
    ]
    topic_keywords = [
        np.concatenate(
            [
                common_kws,
                len(common_kws)
                + rng.choice(
                    cfg.vocab_lexical - len(common_kws),
                    size=cfg.keywords_per_topic,
                    replace=False,
                ),
            ]
        )
        for _ in range(nt)
    ]

    def zipf_for(pool_len):
        z = 1.0 / np.arange(1, pool_len + 1)
        return (z / z.sum()).astype(np.float64)

    zipf = zipf_for(len(topic_terms[0]))
    zipf_kw = zipf_for(len(topic_keywords[0]))

    # --- documents --------------------------------------------------------
    doc_topics = rng.integers(0, nt, size=cfg.n_docs).astype(np.int32)
    dense = _unit(
        centers[doc_topics]
        + cfg.dense_noise * rng.normal(size=(cfg.n_docs, cfg.d_dense)).astype(np.float32)
    )
    si, sv, fi, fv = [], [], [], []
    for t in doc_topics:
        a, b = _sample_sparse(rng, topic_terms[t], zipf, cfg.nnz_sparse)
        si.append(a)
        sv.append(b)
        a, b = _sample_sparse(rng, topic_keywords[t], zipf_kw, cfg.nnz_lexical)
        fi.append(a)
        fv.append(b)
    s_idx, s_val = _ell_from_pairs(si, sv, cfg.nnz_sparse)
    f_idx, f_val = _ell_from_pairs(fi, fv, cfg.nnz_lexical)
    docs = FusedVectors(
        dense, SparseVec(s_idx, s_val), SparseVec(f_idx, f_val)
    )

    # --- entities + KG chains ---------------------------------------------
    doc_entities = np.full((cfg.n_docs, cfg.entities_per_doc), PAD_IDX, np.int32)
    doc_entities[:, 0] = np.arange(cfg.n_docs)  # rare entity, unique per doc
    for i in range(cfg.n_docs):
        k = rng.integers(0, cfg.entities_per_doc)
        if k > 0:
            doc_entities[i, 1 : 1 + k] = cfg.n_docs + rng.choice(
                cfg.n_common_entities, size=k, replace=False
            )
    # chains: docs d0 -> d1 -> d2 linked through their rare entities
    triplets = []
    n_chains = max(cfg.n_queries, cfg.n_docs // 16)
    chain_docs = np.zeros((n_chains, cfg.chain_len), np.int32)
    for c in range(n_chains):
        ds = rng.choice(cfg.n_docs, size=cfg.chain_len, replace=False)
        chain_docs[c] = ds
        for a, b in zip(ds[:-1], ds[1:]):
            rel = int(rng.integers(0, 64))
            triplets.append((doc_entities[a, 0], rel, doc_entities[b, 0]))
    # noise triplets among common entities
    for _ in range(cfg.n_common_entities):
        e1, e2 = cfg.n_docs + rng.choice(cfg.n_common_entities, 2, replace=False)
        triplets.append((e1, int(rng.integers(0, 64)), e2))
    kg = KnowledgeGraph(np.asarray(triplets, np.int32), cfg.n_entities)

    # --- queries ------------------------------------------------------------
    qt = rng.integers(0, nt, size=cfg.n_queries).astype(np.int32)
    q_rel = np.zeros((cfg.n_queries, cfg.relevant_per_query), np.int32)
    q_dense = np.zeros((cfg.n_queries, cfg.d_dense), np.float32)
    qsi, qsv, qfi, qfv = [], [], [], []
    q_keywords = np.full((cfg.n_queries, 4), PAD_IDX, np.int32)
    q_entities = np.full((cfg.n_queries, 2), PAD_IDX, np.int32)
    q_multihop = np.full((cfg.n_queries,), -1, np.int32)
    for qi_ in range(cfg.n_queries):
        t = qt[qi_]
        members = np.nonzero(doc_topics == t)[0]
        if len(members) < cfg.relevant_per_query:
            members = np.arange(cfg.n_docs)
        rel_docs = rng.choice(members, size=cfg.relevant_per_query, replace=False)
        q_rel[qi_] = rel_docs
        # dense: perturbation of the *relevant docs* mean (not the center) so
        # that planted docs are near-optimal but not exactly top by one path
        q_dense[qi_] = _unit(
            docs.dense[rel_docs].mean(0)
            + 0.5 * cfg.dense_noise * rng.normal(size=cfg.d_dense)
        )
        # sparse: terms drawn from the relevant docs' own terms
        terms = np.unique(np.concatenate([s_idx[d][s_idx[d] >= 0] for d in rel_docs]))
        sel = rng.choice(terms, size=min(cfg.nnz_query_sparse, len(terms)), replace=False)
        qsi.append(sel)
        qsv.append(np.abs(rng.normal(1.0, 0.3, size=len(sel))).astype(np.float32))
        kws = np.unique(np.concatenate([f_idx[d][f_idx[d] >= 0] for d in rel_docs]))
        selk = rng.choice(kws, size=min(cfg.nnz_query_lexical, len(kws)), replace=False)
        qfi.append(selk)
        qfv.append(np.abs(rng.normal(1.0, 0.3, size=len(selk))).astype(np.float32))
        # required keyword: one keyword shared by all relevant docs if any
        common = set(f_idx[rel_docs[0]][f_idx[rel_docs[0]] >= 0])
        for d in rel_docs[1:]:
            common &= set(f_idx[d][f_idx[d] >= 0])
        if common:
            q_keywords[qi_, 0] = sorted(common)[0]
        # multi-hop: attach a chain; the query mentions the head entity, the
        # planted target is the tail doc (reachable only via KG edges)
        chain = rng.integers(0, n_chains)
        q_entities[qi_, 0] = doc_entities[chain_docs[chain][0], 0]
        q_multihop[qi_] = chain_docs[chain][-1]
    qs_idx, qs_val = _ell_from_pairs(qsi, qsv, cfg.nnz_query_sparse)
    qf_idx, qf_val = _ell_from_pairs(qfi, qfv, cfg.nnz_query_lexical)
    queries = FusedVectors(
        q_dense, SparseVec(qs_idx, qs_val), SparseVec(qf_idx, qf_val)
    )

    return SyntheticCorpus(
        config=cfg,
        docs=docs,
        doc_entities=doc_entities,
        doc_topics=doc_topics,
        kg=kg,
        queries=queries,
        query_entities=q_entities,
        query_relevant=q_rel,
        query_keywords=q_keywords,
        query_multihop_target=q_multihop,
    )


def recall_at_k(retrieved_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    """Mean fraction of truth ids present in retrieved ids (per query)."""
    hits = 0
    total = 0
    for r, t in zip(np.asarray(retrieved_ids), np.asarray(truth_ids)):
        t = t[t >= 0]
        if len(t) == 0:
            continue
        hits += len(set(r.tolist()) & set(t.tolist()))
        total += len(t)
    return hits / max(total, 1)


def ndcg_at_k(retrieved_ids: np.ndarray, truth_ids: np.ndarray, k: int = 10) -> float:
    """nDCG@k with binary relevance (the paper's accuracy metric)."""
    scores = []
    for r, t in zip(np.asarray(retrieved_ids)[:, :k], np.asarray(truth_ids)):
        t = set(t[t >= 0].tolist())
        if not t:
            continue
        dcg = sum(
            1.0 / np.log2(i + 2) for i, d in enumerate(r.tolist()) if d in t
        )
        idcg = sum(1.0 / np.log2(i + 2) for i in range(min(len(t), k)))
        scores.append(dcg / idcg)
    return float(np.mean(scores)) if scores else 0.0
