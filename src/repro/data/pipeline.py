"""Deterministic synthetic token pipeline with restart-exact skip.

Every batch is a pure function of (seed, step), so a restarted job resumes
bit-exact from any checkpoint step without replaying data — the determinism
contract the fault-tolerance layer relies on. The "corpus" is a synthetic
Zipf-distributed Markov stream with enough structure that a ~100M model's
loss visibly drops within a few hundred steps (examples/train_lm.py).

On a real cluster each host generates only its addressable shard of the
global batch (host_id / n_hosts slicing below); in this container there is
one host holding everything.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32_000
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    n_states: int = 64  # markov states -> learnable structure
    frontend_tokens: int = 0  # >0: also emit stub modality embeddings
    d_model: int = 0


class TokenPipeline:
    """Stateless batch generator: batch(step) is deterministic."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        rng = np.random.default_rng(cfg.seed)
        # a sparse Markov chain over states; each state emits a Zipf slice
        self._trans = rng.dirichlet(np.ones(cfg.n_states) * 0.1, size=cfg.n_states)
        self._emit_base = rng.integers(0, max(cfg.vocab - 256, 1), size=cfg.n_states)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // self.n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + self.host_id
        )
        states = rng.integers(0, cfg.n_states, size=per_host)
        toks = np.zeros((per_host, cfg.seq_len), np.int32)
        for t in range(cfg.seq_len):
            # vectorized markov step
            u = rng.random(per_host)
            cdf = np.cumsum(self._trans[states], axis=1)
            states = (u[:, None] < cdf).argmax(axis=1)
            offs = rng.zipf(1.5, size=per_host) % 256
            toks[:, t] = (self._emit_base[states] + offs) % cfg.vocab
        out = {"tokens": jnp.asarray(toks)}
        if cfg.frontend_tokens:
            out["frontend"] = jnp.asarray(
                rng.normal(0, 0.02, size=(per_host, cfg.frontend_tokens, cfg.d_model)),
                jnp.bfloat16,
            )
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
