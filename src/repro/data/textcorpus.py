"""Loader for the bundled real-text corpus (tests/data/*.jsonl).

One place defines how the bundled paragraphs/queries are read and how the
topic ground truth is formed — the ingest test suite, the CI recall gate
(benchmarks/ingest_bench.py), and the example all import it, so the
acceptance gate and the tests can never silently diverge on the corpus
format.

The corpus is a development asset checked into ``tests/data`` (120 original
topic-clustered paragraphs with recurring named entities, standing in for
the paper's real-world datasets, which the offline container cannot fetch);
pass ``data_dir`` explicitly when running from an installed package.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

import numpy as np

# src/repro/data/textcorpus.py -> repo root (editable-install layout)
DEFAULT_DATA_DIR = pathlib.Path(__file__).resolve().parents[3] / "tests" / "data"


@dataclasses.dataclass
class TextCorpus:
    texts: list[str]  # "<title>. <body>" per paragraph
    titles: list[str]
    topics: list[str]
    query_texts: list[str]
    query_topics: list[str]

    @property
    def n_docs(self) -> int:
        return len(self.texts)


def load_bundled_corpus(data_dir: Optional[str] = None) -> TextCorpus:
    data = pathlib.Path(data_dir) if data_dir is not None else DEFAULT_DATA_DIR
    paras = [json.loads(l) for l in (data / "paragraphs.jsonl").open()]
    queries = [json.loads(l) for l in (data / "queries.jsonl").open()]
    return TextCorpus(
        texts=[p["title"] + ". " + p["text"] for p in paras],
        titles=[p["title"] for p in paras],
        topics=[p["topic"] for p in paras],
        query_texts=[q["text"] for q in queries],
        query_topics=[q["topic"] for q in queries],
    )


def topic_truth(query_topics: list[str], doc_topics: list[str]) -> np.ndarray:
    """(Q, R) PAD(-1)-padded relevant doc ids: a query's relevant set is
    every paragraph of its topic."""
    width = max(doc_topics.count(t) for t in set(doc_topics))
    truth = np.full((len(query_topics), width), -1, np.int32)
    for i, t in enumerate(query_topics):
        ids = [j for j, dt in enumerate(doc_topics) if dt == t]
        truth[i, : len(ids)] = ids
    return truth
