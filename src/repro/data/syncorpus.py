"""Synthetic corpus generator for million-doc scale benches.

The bundled 120-paragraph corpus (``data/textcorpus.py``) is a quality
asset; this module is the *quantity* asset: domain-templated English-like
documents at 100k–1M scale, generated deterministically from a seed and
streamed in batches so the raw corpus never materializes in host memory.
The output feeds ``ingest.IngestPipeline`` unchanged — the documents carry
exactly the structure the analyzer stack extracts:

  * **topic clusters** — every document belongs to one of ``n_topics``
    topics; topics own pools of distinctive pseudo-terms (shared by their
    documents, rare elsewhere), so BM25/TF-IDF vectors cluster by topic the
    way real corpora do;
  * **seeded entity pools** — a global pool of multi-word capitalized
    entity names ("Venari Solari Institute") with topic affinity: documents
    mention entities of their own topic mid-sentence, so the rule-based
    extractor recovers them and co-occurrence triplets cluster;
  * **domain templates** — each topic belongs to a domain (research,
    markets, expedition, engineering, chronicle) whose sentence templates
    give documents realistic token-length and stopword distributions.

Determinism contract (pinned by ``tests/test_syncorpus.py``): document i is
a pure function of ``(config.seed, i)`` — the SAME document regardless of
batch size, iteration order, or how many other documents were generated.
That is what makes a streamed 1M-doc bench reproducible and lets replicas
of a sharded build re-derive any shard independently.

    gen = SynCorpus(SynCorpusConfig(n_docs=100_000, seed=7))
    pipe = IngestPipeline()
    pipe.fit(gen.fit_sample(2048))          # frozen stats from a sample
    for batch in gen.doc_batches(4096):     # stream; O(batch) memory
        docs, ents = pipe.encode_docs([d.text for d in batch])
        ...
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Domain templates. Slots: {term} topic pseudo-term, {entity} capitalized
# entity name, {noun}/{verb} domain vocabulary, {year}/{qty} numerals.
# Entity slots sit mid-sentence so the capitalized-span extractor keeps them.
# ---------------------------------------------------------------------------

_DOMAINS = (
    (
        "research",
        (
            "A recent survey of {term} methods by {entity} reported a {qty} "
            "percent improvement over the {year} baseline.",
            "The study measured {term} and {term2} under controlled load, "
            "and researchers at {entity} replicated the result.",
            "According to {entity}, the {term} hypothesis explains the "
            "observed {noun} without extra parameters.",
            "Follow-up work on {term} {verb} the earlier findings about "
            "{noun} published in {year}.",
        ),
        ("dataset", "protocol", "anomaly", "benchmark", "cohort"),
        ("confirmed", "contradicted", "extended", "reproduced"),
    ),
    (
        "markets",
        (
            "Quarterly {term} volumes rose {qty} percent after {entity} "
            "revised its {noun} guidance.",
            "Analysts at {entity} flagged {term} exposure as the main "
            "driver of the {year} {noun}.",
            "The {term} index {verb} while {entity} held its position in "
            "{term2} futures.",
            "Trading desks priced the {term} spread against a {qty} basis "
            "point move in {noun}.",
        ),
        ("forecast", "portfolio", "selloff", "dividend", "ledger"),
        ("rallied", "slipped", "stabilized", "diverged"),
    ),
    (
        "expedition",
        (
            "The expedition charted the {term} basin before a storm forced "
            "{entity} to winter at the {noun}.",
            "Guides from {entity} crossed the {term} pass in {year}, "
            "mapping {qty} kilometres of {term2} terrain.",
            "Supply caches of {noun} along the {term} route {verb} the "
            "survey team led by {entity}.",
            "Field notes describe {term} currents near the {noun} first "
            "recorded by {entity}.",
        ),
        ("glacier", "delta", "plateau", "moraine", "headland"),
        ("sustained", "delayed", "rescued", "rerouted"),
    ),
    (
        "engineering",
        (
            "The {term} controller shipped by {entity} cut {noun} latency "
            "by {qty} percent.",
            "Engineers at {entity} traced the {term} fault to a {term2} "
            "regression introduced in {year}.",
            "Load tests of the {term} pipeline {verb} under {qty} "
            "concurrent {noun} streams.",
            "A redesign of the {term} bus let {entity} retire the legacy "
            "{noun} interlock.",
        ),
        ("turbine", "firmware", "gearbox", "actuator", "manifold"),
        ("throttled", "saturated", "recovered", "degraded"),
    ),
    (
        "chronicle",
        (
            "Archives kept by {entity} date the {term} charter to {year}, "
            "decades before the {noun} was built.",
            "The {term} treaty {verb} after envoys from {entity} disputed "
            "the {term2} border.",
            "A ledger of {qty} {noun} entries records how {entity} "
            "administered the {term} district.",
            "Chroniclers credit {entity} with restoring the {term} "
            "aqueduct described in the {noun}.",
        ),
        ("dynasty", "garrison", "archive", "guildhall", "province"),
        ("collapsed", "endured", "unified", "fractured"),
    ),
)

_SYLLABLES = (
    "ka", "ri", "vo", "ta", "len", "mor", "sul", "dra", "fen", "gal",
    "hu", "bel", "nor", "pra", "qui", "ros", "tev", "ul", "wis", "zan",
    "cor", "dim", "eru", "fal", "gos", "hil", "jor", "kel", "lum", "mav",
)

_ENTITY_SUFFIX = (
    "Institute", "Holdings", "Expedition", "Works", "Archive",
    "Laboratory", "Exchange", "Survey", "Foundry", "Council",
)


def _pseudo_word(rng: np.random.Generator, n_syll: int) -> str:
    picks = rng.integers(0, len(_SYLLABLES), size=n_syll)
    return "".join(_SYLLABLES[int(p)] for p in picks)


@dataclasses.dataclass(frozen=True)
class SynCorpusConfig:
    n_docs: int = 100_000
    n_topics: int = 128
    n_entities: int = 384  # keep <= IngestConfig.max_entities
    terms_per_topic: int = 12
    entities_per_doc: int = 3
    min_sentences: int = 3
    max_sentences: int = 6
    n_queries: int = 256
    seed: int = 0

    def __post_init__(self):
        if self.n_topics < 1 or self.n_entities < self.n_topics:
            raise ValueError("need n_entities >= n_topics >= 1")


@dataclasses.dataclass
class SynDoc:
    doc_id: int
    text: str  # "<title>. <sentences>"
    topic: int
    entities: tuple[str, ...]  # surface forms mentioned mid-sentence


@dataclasses.dataclass
class SynQuery:
    text: str
    topic: int


class SynCorpus:
    """Deterministic streamed corpus: O(n_topics + n_entities) resident
    state, every document derived on demand from ``(seed, doc_id)``."""

    def __init__(self, config: Optional[SynCorpusConfig] = None):
        self.config = config or SynCorpusConfig()
        cfg = self.config
        rng = np.random.default_rng([cfg.seed, 0x5EED])
        # topic pseudo-term pools (distinctive, lowercase -> BM25 signal)
        self.topic_terms = [
            [_pseudo_word(rng, int(rng.integers(2, 4))) for _ in range(cfg.terms_per_topic)]
            for _ in range(cfg.n_topics)
        ]
        # seeded entity pool: two capitalized pseudo-words + a domain suffix;
        # entity e's home topic is e % n_topics (topic affinity)
        self.entity_names = [
            f"{_pseudo_word(rng, 2).capitalize()} "
            f"{_pseudo_word(rng, 2).capitalize()} "
            f"{_ENTITY_SUFFIX[int(rng.integers(len(_ENTITY_SUFFIX)))]}"
            for _ in range(cfg.n_entities)
        ]

    # -- per-document derivation (the determinism contract) -----------------

    def _topic_of(self, i: int) -> int:
        # a cheap seeded permutation-ish mix so consecutive docs spread over
        # topics (pure function of (seed, i), no resident state)
        return int((i * 2654435761 + self.config.seed * 97) % self.config.n_topics)

    def _topic_entities(self, topic: int) -> list[int]:
        cfg = self.config
        return list(range(topic, cfg.n_entities, cfg.n_topics))

    def doc(self, i: int) -> SynDoc:
        cfg = self.config
        if not (0 <= i < cfg.n_docs):
            raise IndexError(f"doc id {i} outside [0, {cfg.n_docs})")
        rng = np.random.default_rng([cfg.seed, 0xD0C, i])
        topic = self._topic_of(i)
        name, templates, nouns, verbs = _DOMAINS[topic % len(_DOMAINS)]
        terms = self.topic_terms[topic]
        home = self._topic_entities(topic)
        n_ent = min(cfg.entities_per_doc, len(home))
        ents = [
            self.entity_names[home[int(j)]]
            for j in rng.choice(len(home), size=n_ent, replace=False)
        ]
        n_sent = int(rng.integers(cfg.min_sentences, cfg.max_sentences + 1))
        sentences = []
        mentioned: list[str] = []
        for s in range(n_sent):
            t = templates[int(rng.integers(len(templates)))]
            entity = ents[s % len(ents)]
            if "{entity}" in t and entity not in mentioned:
                mentioned.append(entity)
            sentences.append(
                t.format(
                    term=terms[int(rng.integers(len(terms)))],
                    term2=terms[int(rng.integers(len(terms)))],
                    entity=entity,
                    noun=nouns[int(rng.integers(len(nouns)))],
                    verb=verbs[int(rng.integers(len(verbs)))],
                    year=1900 + int(rng.integers(0, 125)),
                    qty=int(rng.integers(2, 97)),
                )
            )
        title = (
            f"{terms[int(rng.integers(len(terms)))].capitalize()} "
            f"{name} report {i}"
        )
        return SynDoc(
            doc_id=i,
            text=title + ". " + " ".join(sentences),
            topic=topic,
            entities=tuple(mentioned),
        )

    # -- streaming access ---------------------------------------------------

    def doc_batches(
        self, batch_size: int, *, start: int = 0, stop: Optional[int] = None
    ) -> Iterator[list[SynDoc]]:
        """Yield documents in ``[start, stop)`` as lists of ``batch_size``
        (last batch may be short). Only one batch is resident at a time."""
        stop = self.config.n_docs if stop is None else min(stop, self.config.n_docs)
        for lo in range(start, stop, batch_size):
            yield [self.doc(i) for i in range(lo, min(lo + batch_size, stop))]

    def texts(self, start: int, stop: int) -> list[str]:
        return [self.doc(i).text for i in range(start, stop)]

    def fit_sample(self, n: int) -> list[str]:
        """Evenly strided sample of document texts for ``IngestPipeline.fit``
        — covers every topic/domain without materializing the corpus (the
        frozen-stats contract then lets the full corpus stream through
        ``encode_docs``)."""
        n = min(n, self.config.n_docs)
        ids = np.linspace(0, self.config.n_docs - 1, num=n, dtype=np.int64)
        return [self.doc(int(i)).text for i in np.unique(ids)]

    # -- queries ------------------------------------------------------------

    def query(self, j: int) -> SynQuery:
        """Query j: a topic-anchored question mentioning a topic term (as a
        double-quoted required keyword) and, half the time, a home entity —
        the operands ``IngestPipeline.encode_queries`` extracts."""
        cfg = self.config
        rng = np.random.default_rng([cfg.seed, 0x9E4, j])
        topic = int(rng.integers(cfg.n_topics))
        terms = self.topic_terms[topic]
        term = terms[int(rng.integers(len(terms)))]
        q = f'what did the "{term}" {_DOMAINS[topic % len(_DOMAINS)][2][0]} show'
        if j % 2 == 0:
            home = self._topic_entities(topic)
            ent = self.entity_names[home[int(rng.integers(len(home)))]]
            q += f" according to {ent}"
        return SynQuery(text=q, topic=topic)

    def queries(self, n: Optional[int] = None) -> list[SynQuery]:
        n = self.config.n_queries if n is None else n
        return [self.query(j) for j in range(n)]
