"""Per-query span trees for the serving stack.

A ``TraceContext`` rides on ``SearchRequest.trace`` through the whole
request path — admission, queue wait, batch assembly, executable lookup
(hit/miss/retrace), device dispatch, grow-segment merge, per-replica
scatter fan-out, fusion re-score — and each stage appends a ``Span``.
Stages usually record retrospectively (``add_span(name, t0, t1)``) with
timestamps they measured anyway: a batch phase is timed ONCE and attributed
to every query in the batch, instead of each query carrying live span
objects across the pump/submit thread boundary. ``span()`` is the live
context-manager form for single-owner phases.

Timestamps are ``time.perf_counter()`` seconds (monotonic, sub-µs), so a
span tree is internally ordered but not wall-clock anchored; the Chrome
trace export (``obs.export``) rebases onto the tracer epoch.

``Tracer`` is the factory plus a bounded ring of finished traces —
``export_chrome`` turns them into a perfetto-loadable trace-event JSON.
Everything is lock-protected: spans are appended from submitter, pump, and
scatter-pool threads concurrently.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Iterator, Optional


class Span:
    """One named interval with attributes and children. ``t1`` is None
    while open; ``annotate`` merges attributes at any point."""

    __slots__ = ("name", "t0", "t1", "attrs", "children")

    def __init__(self, name: str, t0: float, attrs: Optional[dict] = None):
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs: dict = dict(attrs) if attrs else {}
        self.children: list[Span] = []

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, t: Optional[float] = None) -> "Span":
        if self.t1 is None:
            self.t1 = time.perf_counter() if t is None else t
        return self

    def walk(self) -> Iterator["Span"]:
        yield self
        for child in list(self.children):
            yield from child.walk()

    def __repr__(self) -> str:
        return (
            f"Span({self.name!r}, dur={self.duration * 1e3:.3f}ms, "
            f"attrs={self.attrs}, children={len(self.children)})"
        )


class TraceContext:
    """The span tree of one query (or one background operation). Carried on
    ``SearchRequest.trace``; every instrumented stage hangs spans off the
    root. Thread-safe: the serving path appends from several threads."""

    _next_id = [0]
    _id_lock = threading.Lock()

    def __init__(self, name: str, tracer: Optional["Tracer"] = None, **attrs):
        with self._id_lock:
            self._next_id[0] += 1
            self.trace_id = self._next_id[0]
        self.name = name
        self.root = Span(name, time.perf_counter(), attrs)
        self._tracer = tracer
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------------

    def add_span(
        self,
        name: str,
        t0: float,
        t1: float,
        parent: Optional[Span] = None,
        **attrs,
    ) -> Span:
        """Retrospective span from timestamps the caller already measured
        (the batch-phase pattern: time once, attribute to every query)."""
        span = Span(name, t0, attrs)
        span.t1 = max(t1, t0)  # clamp: a span is never negative-length
        with self._lock:
            (parent or self.root).children.append(span)
        return span

    def span(self, name: str, parent: Optional[Span] = None, **attrs):
        """Live span as a context manager (single-owner phases)."""
        return _LiveSpan(self, name, parent, attrs)

    def annotate(self, **attrs) -> "TraceContext":
        with self._lock:
            self.root.attrs.update(attrs)
        return self

    def end(self) -> "TraceContext":
        self.root.end()
        if self._tracer is not None:
            self._tracer._finish(self)
        return self

    def __enter__(self) -> "TraceContext":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    # -- inspection ---------------------------------------------------------

    def spans(self) -> list[Span]:
        """Every span of the tree, pre-order (root first)."""
        with self._lock:
            return list(self.root.walk())

    def find(self, name: str) -> list[Span]:
        return [s for s in self.spans() if s.name == name]

    def span_names(self) -> list[str]:
        return [s.name for s in self.spans()]


class _LiveSpan:
    def __init__(self, ctx: TraceContext, name, parent, attrs):
        self._ctx = ctx
        self._name = name
        self._parent = parent
        self._attrs = attrs
        self.span: Optional[Span] = None

    def __enter__(self) -> Span:
        self.span = Span(self._name, time.perf_counter(), self._attrs)
        with self._ctx._lock:
            (self._parent or self._ctx.root).children.append(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.span.annotate(error=repr(exc))
        self.span.end()


class Tracer:
    """TraceContext factory + bounded ring of finished traces. ``keep``
    bounds memory: a service tracing every query forever retains only the
    most recent ``keep`` trees."""

    def __init__(self, keep: int = 256):
        self.epoch = time.perf_counter()  # chrome-export time zero
        self._lock = threading.Lock()
        self._finished: deque[TraceContext] = deque(maxlen=keep)

    def trace(self, name: str, **attrs) -> TraceContext:
        return TraceContext(name, tracer=self, **attrs)

    def _finish(self, ctx: TraceContext) -> None:
        with self._lock:
            self._finished.append(ctx)

    @property
    def finished(self) -> list[TraceContext]:
        with self._lock:
            return list(self._finished)

    def export_chrome(self, path=None) -> dict:
        """Chrome trace-event JSON over every finished trace; see
        ``obs.export.chrome_trace``."""
        from repro.obs.export import chrome_trace, write_chrome_trace

        if path is not None:
            return write_chrome_trace(path, self)
        return chrome_trace(self.finished, epoch=self.epoch)
