"""Dependency-free metrics registry for the serving stack.

Three instrument kinds — ``Counter``, ``Gauge``, and fixed-bucket streaming
``Histogram`` — live in a ``MetricsRegistry`` and share ONE lock, so every
increment is atomic with respect to every other (the ``ServiceStats``
counters this replaces were bumped from multiple submitter threads with no
lock at all). Metrics follow the ``allanpoe_<layer>_<name>`` naming
convention (DESIGN.md §12) and may declare label dimensions (bucket size,
fusion mode, replica id, segment group, ...): each distinct label-value
combination is an independent child series, Prometheus-style.

Histograms are streaming: observations land in fixed log-spaced buckets, so
p50/p90/p99 come from bucket counts by linear interpolation — no sample
array is ever stored, and the same quantile code serves both the production
registry and the benches (the "bench = production metrics" invariant:
``serving_bench``/``fig14_scale`` read their percentiles from here).

Exposition is two-format: ``render()`` emits Prometheus text,
``snapshot()`` a JSON-able dict (``dump()`` writes it; the service pump
thread flushes it periodically — ``ServiceConfig.metrics_dump_path``).

``GLOBAL`` is the process-wide registry for signals that are inherently
process-global: ``search_padded`` (re)trace counts (``core.search``) and
jitted-dispatch / build-row counts (``runtime.dispatch``).
"""

from __future__ import annotations

import json
import math
import pathlib
import threading
from typing import Optional, Sequence, Union


def time_buckets(
    lo: float = 1e-4, hi: float = 60.0, ratio: float = 1.25
) -> tuple[float, ...]:
    """Geometric latency-bucket upper bounds in seconds (~60 buckets from
    100µs to 60s at ratio 1.25 — fine enough that an interpolated p99 sits
    within 25% of the true value, the resolution the serving p99 gate
    assumes)."""
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * ratio)
    return tuple(out)


DEFAULT_TIME_BUCKETS = time_buckets()


class HistogramSnapshot:
    """Immutable (bounds, counts, sum, count) capture of one histogram
    series; quantiles interpolate within the containing bucket. Snapshots
    subtract (``minus``), so benches can scope percentiles to exactly the
    requests of one measurement window on a shared registry."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(
        self,
        bounds: tuple[float, ...],
        counts: tuple[int, ...],
        total: float,
        count: int,
    ):
        self.bounds = bounds
        self.counts = counts  # len(bounds) + 1: last is the overflow bucket
        self.sum = total
        self.count = count

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def minus(self, other: "HistogramSnapshot") -> "HistogramSnapshot":
        if other.bounds != self.bounds:
            raise ValueError("snapshot bucket bounds differ")
        return HistogramSnapshot(
            self.bounds,
            tuple(a - b for a, b in zip(self.counts, other.counts)),
            self.sum - other.sum,
            self.count - other.count,
        )

    def quantile(self, q: float) -> float:
        """q-th quantile (0..1) by linear interpolation inside the bucket
        holding the target rank. Empty series -> 0.0; overflow-bucket ranks
        clamp to the last finite bound."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count <= 0:
            return 0.0
        target = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c <= 0:
                continue
            if seen + c >= target:
                if i >= len(self.bounds):  # overflow bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                frac = (target - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.bounds[-1]


class _Metric:
    """Base of the three instrument kinds: a named family of label-keyed
    child series sharing the registry lock."""

    kind = "untyped"

    def __init__(
        self, registry: "MetricsRegistry", name: str, help: str,
        label_names: tuple[str, ...],
    ):
        self._registry = registry
        self._lock = registry._lock
        self.name = name
        self.help = help
        self.label_names = label_names
        self._children: dict[tuple, float] = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, "
                f"got {tuple(labels)}"
            )
        return tuple(str(labels[n]) for n in self.label_names)

    def value(self, **labels) -> float:
        with self._lock:
            return self._children.get(self._key(labels), 0.0)

    def total(self) -> float:
        """Sum over every child series (the unlabeled view of a labeled
        counter — what the legacy ``ServiceStats`` fields report)."""
        with self._lock:
            return sum(self._children.values())

    def values(self) -> dict[tuple, float]:
        with self._lock:
            return dict(self._children)

    def reset(self) -> None:
        with self._lock:
            self._children.clear()

    def _series(self):
        """[(label-values tuple, value-ish)] for exposition, under lock."""
        return sorted(self._children.items())


class Counter(_Metric):
    kind = "counter"

    def inc(self, n: Union[int, float] = 1, **labels) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n


class Gauge(_Metric):
    kind = "gauge"

    def set(self, v: Union[int, float], **labels) -> None:
        with self._lock:
            self._children[self._key(labels)] = float(v)

    def inc(self, n: Union[int, float] = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0.0) + n

    def dec(self, n: Union[int, float] = 1, **labels) -> None:
        self.inc(-n, **labels)


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    kind = "histogram"

    def __init__(
        self, registry, name, help, label_names,
        buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
    ):
        super().__init__(registry, name, help, label_names)
        bounds = tuple(float(b) for b in buckets)
        if list(bounds) != sorted(set(bounds)):
            raise ValueError("histogram buckets must be strictly increasing")
        self.bounds = bounds
        self._children: dict[tuple, _HistSeries] = {}

    def _child(self, key: tuple) -> _HistSeries:
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = _HistSeries(len(self.bounds) + 1)
        return child

    def observe(self, v: float, **labels) -> None:
        v = float(v)
        if math.isnan(v):
            return
        key = self._key(labels)
        # bisect by hand to stay inside the one lock acquisition
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            child = self._child(key)
            child.counts[lo] += 1
            child.sum += v
            child.count += 1

    def snapshot(self, **labels) -> HistogramSnapshot:
        with self._lock:
            child = self._children.get(self._key(labels))
            if child is None:
                return HistogramSnapshot(
                    self.bounds, (0,) * (len(self.bounds) + 1), 0.0, 0
                )
            return HistogramSnapshot(
                self.bounds, tuple(child.counts), child.sum, child.count
            )

    def quantile(self, q: float, **labels) -> float:
        return self.snapshot(**labels).quantile(q)

    def value(self, **labels) -> float:  # the family's scalar view = count
        with self._lock:
            child = self._children.get(self._key(labels))
            return float(child.count) if child is not None else 0.0

    def total(self) -> float:
        with self._lock:
            return float(sum(c.count for c in self._children.values()))


def _fmt_labels(names: tuple[str, ...], values: tuple, extra: str = "") -> str:
    parts = [f'{n}="{v}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_num(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


class MetricsRegistry:
    """Named metrics behind one lock; idempotent registration (asking for an
    existing name returns the existing instrument, but kind/labels must
    match — a name can never silently change meaning)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name, help, labels, **kw) -> _Metric:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls or existing.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name} already registered as "
                        f"{existing.kind}{existing.label_names}"
                    )
                return existing
        metric = cls(self, name, help, tuple(labels), **kw)
        with self._lock:
            return self._metrics.setdefault(name, metric)

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(
        self, name: str, help: str = "", labels: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
    ) -> Histogram:
        return self._register(Histogram, name, help, labels, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def value(self, name: str, **labels) -> float:
        """Scalar read of a series (histograms report their count); an
        unregistered name reads 0 — absent and never-incremented are the
        same thing to a gate."""
        metric = self.get(name)
        if metric is None:
            return 0.0
        return metric.value(**labels) if labels else metric.total()

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    # -- exposition ---------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able dump of every series (the METRICS_snapshot.json
        artifact format)."""
        out: dict = {}
        for m in self.metrics():
            entry: dict = {"type": m.kind, "labels": list(m.label_names)}
            if m.help:
                entry["help"] = m.help
            series = []
            with self._lock:
                rows = m._series()
                if isinstance(m, Histogram):
                    for key, child in rows:
                        series.append({
                            "labels": dict(zip(m.label_names, key)),
                            "count": child.count,
                            "sum": child.sum,
                            "buckets": list(m.bounds),
                            "counts": list(child.counts),
                        })
                else:
                    for key, v in rows:
                        series.append({
                            "labels": dict(zip(m.label_names, key)),
                            "value": v,
                        })
            if isinstance(m, Histogram):
                for s in series:
                    snap = HistogramSnapshot(
                        m.bounds, tuple(s["counts"]), s["sum"], s["count"]
                    )
                    s["p50"] = snap.quantile(0.50)
                    s["p90"] = snap.quantile(0.90)
                    s["p99"] = snap.quantile(0.99)
            entry["series"] = series
            out[m.name] = entry
        return out

    def render(self) -> str:
        """Prometheus text exposition format."""
        lines: list[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            with self._lock:
                rows = m._series()
            if isinstance(m, Histogram):
                for key, child in rows:
                    cum = 0
                    for bound, c in zip(m.bounds, child.counts):
                        cum += c
                        lab = _fmt_labels(
                            m.label_names, key, f'le="{_fmt_num(bound)}"'
                        )
                        lines.append(f"{m.name}_bucket{lab} {cum}")
                    lab = _fmt_labels(m.label_names, key, 'le="+Inf"')
                    lines.append(f"{m.name}_bucket{lab} {child.count}")
                    lab = _fmt_labels(m.label_names, key)
                    lines.append(f"{m.name}_sum{lab} {_fmt_num(child.sum)}")
                    lines.append(f"{m.name}_count{lab} {child.count}")
            else:
                for key, v in rows:
                    lab = _fmt_labels(m.label_names, key)
                    lines.append(f"{m.name}{lab} {_fmt_num(v)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def dump(self, path) -> None:
        """Atomic-enough JSON snapshot write (tmp + rename): a reader never
        sees a torn file even if the pump thread is mid-flush."""
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(p.suffix + ".tmp")
        tmp.write_text(json.dumps(self.snapshot(), indent=2) + "\n")
        tmp.replace(p)


def merged_snapshot(*registries: MetricsRegistry) -> dict:
    """One snapshot dict across several registries (e.g. a service registry
    plus ``GLOBAL``); later registries win name collisions, which cannot
    happen under the <layer> naming convention."""
    out: dict = {}
    for reg in registries:
        out.update(reg.snapshot())
    return out


# process-wide registry: search_padded trace counts (core.search) and
# dispatch / build-row accounting (runtime.dispatch) live here
GLOBAL = MetricsRegistry()
