"""Exposition glue: Chrome trace-event JSON for span trees, merged metric
snapshots for the METRICS_snapshot.json artifact.

The trace format is the Trace Event Format's complete events (``"ph": "X"``
with microsecond ``ts``/``dur``), which both ``chrome://tracing`` and
perfetto (ui.perfetto.dev) load directly. Each trace tree becomes one
``tid`` lane so concurrent queries render side by side; span attributes
land in ``args``.
"""

from __future__ import annotations

import json
import pathlib
from typing import Iterable, Optional, Union

from repro.obs.metrics import MetricsRegistry, merged_snapshot
from repro.obs.tracer import Span, TraceContext, Tracer


def _events_of(span: Span, epoch: float, pid: int, tid: int, out: list) -> None:
    t1 = span.t1 if span.t1 is not None else span.t0
    out.append({
        "name": span.name,
        "ph": "X",
        "cat": "query",
        "ts": max((span.t0 - epoch) * 1e6, 0.0),
        "dur": max((t1 - span.t0) * 1e6, 0.0),
        "pid": pid,
        "tid": tid,
        "args": {k: _jsonable(v) for k, v in span.attrs.items()},
    })
    for child in list(span.children):
        _events_of(child, epoch, pid, tid, out)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


def chrome_trace(
    traces: Iterable[TraceContext], epoch: float = 0.0, pid: int = 0
) -> dict:
    """Trace-event JSON dict over the given trace trees (one tid lane per
    trace, labeled with the trace name)."""
    events: list[dict] = []
    meta: list[dict] = []
    for ctx in traces:
        tid = ctx.trace_id
        meta.append({
            "name": "thread_name",
            "ph": "M",
            "pid": pid,
            "tid": tid,
            "args": {"name": f"{ctx.name}#{tid}"},
        })
        for span in [ctx.root]:
            _events_of(span, epoch, pid, tid, events)
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(
    path, traces: Union[Tracer, Iterable[TraceContext]]
) -> dict:
    """Write a perfetto-loadable trace file; returns the trace dict."""
    if isinstance(traces, Tracer):
        doc = chrome_trace(traces.finished, epoch=traces.epoch)
    else:
        traces = list(traces)
        epoch = min((c.root.t0 for c in traces), default=0.0)
        doc = chrome_trace(traces, epoch=epoch)
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc, indent=1) + "\n")
    return doc


def write_metrics_snapshot(
    path, *registries: MetricsRegistry, extra: Optional[dict] = None
) -> dict:
    """Merged JSON snapshot of several registries (service + GLOBAL is the
    usual pair) — the METRICS_snapshot.json CI artifact. ``extra`` merges
    top-level context keys (bench config, backend)."""
    doc = merged_snapshot(*registries)
    if extra:
        doc = {**extra, **doc}
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(doc, indent=2) + "\n")
    tmp.replace(p)
    return doc
