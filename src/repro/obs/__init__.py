"""Observability for the serving stack: per-query span trees (``tracer``),
a lock-protected metrics registry with streaming histograms (``metrics``),
and Prometheus/JSON/Chrome-trace exposition (``export``). Dependency-free
by design (stdlib only) — it imports nothing from the rest of ``repro``,
so every layer (core, runtime, serving, benches) can instrument itself
without cycles. Naming and span taxonomy: DESIGN.md §12.
"""

from repro.obs.metrics import (
    DEFAULT_TIME_BUCKETS,
    GLOBAL,
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    MetricsRegistry,
    merged_snapshot,
    time_buckets,
)
from repro.obs.tracer import Span, TraceContext, Tracer
from repro.obs.export import (
    chrome_trace,
    write_chrome_trace,
    write_metrics_snapshot,
)

__all__ = [
    "DEFAULT_TIME_BUCKETS",
    "GLOBAL",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "MetricsRegistry",
    "merged_snapshot",
    "time_buckets",
    "Span",
    "TraceContext",
    "Tracer",
    "chrome_trace",
    "write_chrome_trace",
    "write_metrics_snapshot",
]
