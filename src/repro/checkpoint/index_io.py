"""Index persistence: an ingested ``HybridIndex`` that survives restarts.

Reuses ``checkpoint.checkpoint``'s atomic manifest+leaf layout (tmp dir ->
rename -> ``.done`` commit marker) so index saves get the same crash
consistency as training checkpoints:

    <dir>/step_<N>/            manifest.json + leaf_<i>.npy  (the index;
                               N increments per save, retention keeps 1)
    <dir>/step_<N>.done        commit marker
    <dir>/ingest/              ingest_manifest.json + ingest_arrays.npz
                               (frozen vocab/corpus-stats, when given)

``load_index`` needs no caller-provided template: ``HybridIndex`` is a
registered dataclass pytree with a fixed structure, so the treedef comes
from a structural dummy and the leaf shapes come from the manifest —
``restore_checkpoint`` then does the validated load.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Optional

import numpy as np

from repro.checkpoint.checkpoint import (
    all_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core.index import HybridIndex
from repro.core.usms import (
    FusedVectors,
    QuantizedFusedVectors,
    SparseVec,
    corpus_nbytes_by_leaf,
)

INGEST_SUBDIR = "ingest"  # legacy flat layout, still readable
INGEST_STEP_PREFIX = "ingest_step_"


def _corpus_record(corpus) -> dict:
    """The manifest quantization record for one corpus: storage dtype, scale
    layout, and the achieved compression ratio vs equivalent fp32 storage."""
    quantized = isinstance(corpus, QuantizedFusedVectors)
    actual = int(sum(corpus_nbytes_by_leaf(corpus).values()))
    if quantized:
        dd = corpus.dense_q.shape[-1]
        rows = int(np.prod(corpus.dense_q.shape[:-1]))
        ps = corpus.learned.idx.shape[-1]
        pf = corpus.lexical.idx.shape[-1]
        fp32 = rows * (dd * 4 + ps * 8 + pf * 8)  # idx int32 + val f32
    else:
        fp32 = actual
    return {
        "corpus_dtype": "int8" if quantized else "float32",
        "scale_layout": "per_row_symmetric" if quantized else None,
        "corpus_bytes": actual,
        "corpus_bytes_fp32": fp32,
        "compression_ratio": (fp32 / actual) if actual else 1.0,
    }


def _manifest_extra(tree) -> dict:
    """Quantization metadata merged into the checkpoint manifest. For a
    pool, the per-group dtype list is also the load-time group template
    (a mixed fp32/int8 pool — mid-migration — has heterogeneous per-group
    leaf counts, so the legacy uniform-stride recovery cannot describe it)."""
    if hasattr(tree, "groups"):  # SegmentPool
        records = [_corpus_record(g.index.corpus) for g in tree.groups]
        actual = sum(r["corpus_bytes"] for r in records)
        fp32 = sum(r["corpus_bytes_fp32"] for r in records)
        return {
            "pool_groups": [r["corpus_dtype"] for r in records],
            "quantization": {
                "corpus_dtype": (
                    "int8"
                    if any(r["corpus_dtype"] == "int8" for r in records)
                    else "float32"
                ),
                "scale_layout": (
                    "per_row_symmetric"
                    if any(r["corpus_dtype"] == "int8" for r in records)
                    else None
                ),
                "corpus_bytes": actual,
                "corpus_bytes_fp32": fp32,
                "compression_ratio": (fp32 / actual) if actual else 1.0,
            },
        }
    return {"quantization": _corpus_record(tree.corpus)}


def save_index(
    directory: str | os.PathLike,
    index: HybridIndex,
    *,
    ingest=None,
    keep: int = 1,
) -> None:
    """Atomically persist ``index`` (and, when given, the fitted
    ``ingest.IngestPipeline`` whose frozen stats produced its vectors — an
    index queried through a DIFFERENT analyzer/stats is silently wrong).

    Each save writes a FRESH step number (like training checkpoints): the
    previous committed step is only garbage-collected by retention AFTER
    the new one's ``.done`` marker lands, so a crash mid-save always leaves
    a committed index behind. Re-using a fixed step would instead hit
    ``save_checkpoint``'s overwrite path, which deletes the old step dir
    before the rename.

    Pairing: the ingest manifest is written to ``ingest_step_<N>`` BEFORE
    index step N commits, and ``load_ingest`` reads the manifest of the
    latest COMMITTED index step — so a crash anywhere in the sequence can
    never pair a new index with stale stats (or vice versa)."""
    _save_stepped(pathlib.Path(directory), index, ingest=ingest, keep=keep)


def _save_stepped(directory: pathlib.Path, tree, *, ingest, keep: int) -> None:
    """The shared fresh-step + ingest-pairing + GC sequence (save_checkpoint
    is pytree-generic, so one crash-consistency path serves both a
    HybridIndex and a SegmentPool)."""
    steps = all_steps(directory)
    step = steps[-1] + 1 if steps else 0
    if ingest is not None:
        ingest.save(directory / f"{INGEST_STEP_PREFIX}{step}")
    save_checkpoint(directory, step, tree, keep=keep, extra=_manifest_extra(tree))
    # GC ingest manifests whose index step was retention-collected
    kept = set(all_steps(directory))
    for d in directory.glob(INGEST_STEP_PREFIX + "*"):
        try:
            s = int(d.name[len(INGEST_STEP_PREFIX):])
        except ValueError:
            continue
        if s not in kept and s != step:
            shutil.rmtree(d, ignore_errors=True)


def _structural_dummy(quantized: bool = False) -> HybridIndex:
    """Any HybridIndex: only its treedef matters (shapes come from the
    manifest). ``quantized`` selects int8 corpus storage (one extra leaf:
    the per-row dense scale)."""
    zi = np.zeros((1, 1), np.int32)
    zf = np.zeros((1, 1), np.float32)
    if quantized:
        corpus = QuantizedFusedVectors(
            np.zeros((1, 1), np.int8),
            np.zeros((1,), np.float32),
            SparseVec(zi, np.zeros((1, 1), np.float16)),
            SparseVec(zi, np.zeros((1, 1), np.float16)),
        )
    else:
        corpus = FusedVectors(zf, SparseVec(zi, zf), SparseVec(zi, zf))
    return HybridIndex(
        corpus=corpus,
        semantic_edges=zi,
        keyword_edges=zi,
        logical_edges=np.zeros((1, 1, 4), np.int32),
        doc_entities=zi,
        entity_to_docs=zi,
        entity_adj=np.zeros((1, 1), bool),
        entry_points=np.zeros((1,), np.int32),
        alive=np.zeros((1,), bool),
        self_ip=np.zeros((1,), np.float32),
    )


def load_index(
    directory: str | os.PathLike, *, step: Optional[int] = None
) -> HybridIndex:
    """Restore a saved index. Only committed steps (``.done`` marker) are
    trusted, per the checkpoint layout's atomic-rename contract."""
    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed index checkpoint under {directory}")
    step = steps[-1] if step is None else step
    if step not in steps:
        raise FileNotFoundError(f"step {step} not committed under {directory}")
    with open(directory / f"step_{step}" / "manifest.json") as f:
        manifest = json.load(f)
    import jax

    # int8 leaves appear in exactly one place — quantized dense storage —
    # so dtype presence (not leaf count alone) picks the corpus structure
    quantized = any(m["dtype"] == "int8" for m in manifest["leaves"])
    flat, treedef = jax.tree_util.tree_flatten(_structural_dummy(quantized))
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"manifest has {len(manifest['leaves'])} leaves but HybridIndex "
            f"flattens to {len(flat)} — not an index checkpoint?"
        )
    template = jax.tree_util.tree_unflatten(
        treedef,
        [
            np.zeros(tuple(m["shape"]), np.dtype(m["dtype"]))
            for m in manifest["leaves"]
        ],
    )
    return restore_checkpoint(directory, step, template)


def _pool_structural_dummy(n_groups: int, group_dtypes=None):
    """A SegmentPool with ``n_groups`` groups: only the treedef matters
    (leaf shapes come from the manifest). ``group_dtypes`` — the manifest's
    per-group ``pool_groups`` record — selects fp32/int8 corpus structure
    per group (a mid-migration pool mixes both)."""
    from repro.core.distributed import SegmentedIndex
    from repro.core.segment_pool import SegmentPool

    if group_dtypes is None:
        group_dtypes = ["float32"] * n_groups

    def one_group(dtype):
        idx = _structural_dummy(quantized=dtype == "int8")
        import jax

        stacked = jax.tree_util.tree_map(lambda a: a[None], idx)
        return SegmentedIndex(
            index=stacked, global_ids=np.zeros((1, 1), np.int32)
        )

    return SegmentPool(groups=[one_group(d) for d in group_dtypes])


def _pool_leaf_stride(quantized: bool = False) -> int:
    """Leaves per pool group (HybridIndex leaves + global_ids), derived
    from the registered pytree structure so it never drifts."""
    import jax

    return len(
        jax.tree_util.tree_leaves(
            _pool_structural_dummy(1, ["int8" if quantized else "float32"])
        )
    )


def save_pool(
    directory: str | os.PathLike,
    pool,
    *,
    ingest=None,
    keep: int = 1,
) -> None:
    """Atomically persist a heterogeneous ``SegmentPool`` (variable group
    count, per-group segment counts and capacities) with the same
    manifest+leaf crash-consistency contract as ``save_index``: a fresh
    step per save, ``.done`` commit marker last, paired ingest manifest
    written before the commit. The group structure needs no sidecar — it is
    recovered from the manifest's leaf count at load time."""
    _save_stepped(pathlib.Path(directory), pool, ingest=ingest, keep=keep)


def load_pool(directory: str | os.PathLike, *, step: Optional[int] = None):
    """Restore a saved ``SegmentPool``. The heterogeneous layout (group
    count, per-group shapes) is reconstructed from the committed manifest:
    ``SegmentedIndex`` flattens to a fixed leaf count, so the group count
    is the manifest's leaf count over that stride, and each leaf's shape
    comes from its manifest entry."""
    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed pool checkpoint under {directory}")
    step = steps[-1] if step is None else step
    if step not in steps:
        raise FileNotFoundError(f"step {step} not committed under {directory}")
    with open(directory / f"step_{step}" / "manifest.json") as f:
        manifest = json.load(f)
    n_leaves = len(manifest["leaves"])
    group_dtypes = manifest.get("pool_groups")
    if group_dtypes is None:
        # legacy manifest (no per-group record): uniform fp32 groups
        stride = _pool_leaf_stride()
        if n_leaves == 0 or n_leaves % stride:
            raise ValueError(
                f"manifest has {n_leaves} leaves, not a multiple of "
                f"{stride} — not a segment-pool checkpoint?"
            )
        group_dtypes = ["float32"] * (n_leaves // stride)
    import jax

    dummy = _pool_structural_dummy(len(group_dtypes), group_dtypes)
    flat, treedef = jax.tree_util.tree_flatten(dummy)
    if len(flat) != n_leaves:
        raise ValueError(
            f"manifest has {n_leaves} leaves but the reconstructed pool "
            f"flattens to {len(flat)}"
        )
    template = jax.tree_util.tree_unflatten(
        treedef,
        [
            np.zeros(tuple(m["shape"]), np.dtype(m["dtype"]))
            for m in manifest["leaves"]
        ],
    )
    return restore_checkpoint(directory, step, template)


def load_ingest(directory: str | os.PathLike):
    """Load the ingestion vocab/corpus-stats manifest PAIRED with the
    latest committed index step (returns a fitted ``IngestPipeline``).
    Falls back to the legacy flat ``ingest/`` layout."""
    from repro.ingest.pipeline import IngestPipeline

    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if steps:
        stepped = directory / f"{INGEST_STEP_PREFIX}{steps[-1]}"
        if stepped.exists():
            return IngestPipeline.load(stepped)
    return IngestPipeline.load(directory / INGEST_SUBDIR)
