"""Index persistence: an ingested ``HybridIndex`` that survives restarts.

Reuses ``checkpoint.checkpoint``'s atomic manifest+leaf layout (tmp dir ->
rename -> ``.done`` commit marker) so index saves get the same crash
consistency as training checkpoints:

    <dir>/step_<N>/            manifest.json + leaf_<i>.npy  (the index;
                               N increments per save, retention keeps 1)
    <dir>/step_<N>.done        commit marker
    <dir>/ingest/              ingest_manifest.json + ingest_arrays.npz
                               (frozen vocab/corpus-stats, when given)

``load_index`` needs no caller-provided template: ``HybridIndex`` is a
registered dataclass pytree with a fixed structure, so the treedef comes
from a structural dummy and the leaf shapes come from the manifest —
``restore_checkpoint`` then does the validated load.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Optional

import numpy as np

from repro.checkpoint.checkpoint import (
    all_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.core.index import HybridIndex
from repro.core.usms import FusedVectors, SparseVec

INGEST_SUBDIR = "ingest"  # legacy flat layout, still readable
INGEST_STEP_PREFIX = "ingest_step_"


def save_index(
    directory: str | os.PathLike,
    index: HybridIndex,
    *,
    ingest=None,
    keep: int = 1,
) -> None:
    """Atomically persist ``index`` (and, when given, the fitted
    ``ingest.IngestPipeline`` whose frozen stats produced its vectors — an
    index queried through a DIFFERENT analyzer/stats is silently wrong).

    Each save writes a FRESH step number (like training checkpoints): the
    previous committed step is only garbage-collected by retention AFTER
    the new one's ``.done`` marker lands, so a crash mid-save always leaves
    a committed index behind. Re-using a fixed step would instead hit
    ``save_checkpoint``'s overwrite path, which deletes the old step dir
    before the rename.

    Pairing: the ingest manifest is written to ``ingest_step_<N>`` BEFORE
    index step N commits, and ``load_ingest`` reads the manifest of the
    latest COMMITTED index step — so a crash anywhere in the sequence can
    never pair a new index with stale stats (or vice versa)."""
    _save_stepped(pathlib.Path(directory), index, ingest=ingest, keep=keep)


def _save_stepped(directory: pathlib.Path, tree, *, ingest, keep: int) -> None:
    """The shared fresh-step + ingest-pairing + GC sequence (save_checkpoint
    is pytree-generic, so one crash-consistency path serves both a
    HybridIndex and a SegmentPool)."""
    steps = all_steps(directory)
    step = steps[-1] + 1 if steps else 0
    if ingest is not None:
        ingest.save(directory / f"{INGEST_STEP_PREFIX}{step}")
    save_checkpoint(directory, step, tree, keep=keep)
    # GC ingest manifests whose index step was retention-collected
    kept = set(all_steps(directory))
    for d in directory.glob(INGEST_STEP_PREFIX + "*"):
        try:
            s = int(d.name[len(INGEST_STEP_PREFIX):])
        except ValueError:
            continue
        if s not in kept and s != step:
            shutil.rmtree(d, ignore_errors=True)


def _structural_dummy() -> HybridIndex:
    """Any HybridIndex: only its treedef matters (shapes come from the
    manifest)."""
    zi = np.zeros((1, 1), np.int32)
    zf = np.zeros((1, 1), np.float32)
    return HybridIndex(
        corpus=FusedVectors(zf, SparseVec(zi, zf), SparseVec(zi, zf)),
        semantic_edges=zi,
        keyword_edges=zi,
        logical_edges=np.zeros((1, 1, 4), np.int32),
        doc_entities=zi,
        entity_to_docs=zi,
        entity_adj=np.zeros((1, 1), bool),
        entry_points=np.zeros((1,), np.int32),
        alive=np.zeros((1,), bool),
        self_ip=np.zeros((1,), np.float32),
    )


def load_index(
    directory: str | os.PathLike, *, step: Optional[int] = None
) -> HybridIndex:
    """Restore a saved index. Only committed steps (``.done`` marker) are
    trusted, per the checkpoint layout's atomic-rename contract."""
    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed index checkpoint under {directory}")
    step = steps[-1] if step is None else step
    if step not in steps:
        raise FileNotFoundError(f"step {step} not committed under {directory}")
    with open(directory / f"step_{step}" / "manifest.json") as f:
        manifest = json.load(f)
    import jax

    flat, treedef = jax.tree_util.tree_flatten(_structural_dummy())
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"manifest has {len(manifest['leaves'])} leaves but HybridIndex "
            f"flattens to {len(flat)} — not an index checkpoint?"
        )
    template = jax.tree_util.tree_unflatten(
        treedef,
        [
            np.zeros(tuple(m["shape"]), np.dtype(m["dtype"]))
            for m in manifest["leaves"]
        ],
    )
    return restore_checkpoint(directory, step, template)


def _pool_structural_dummy(n_groups: int):
    """A SegmentPool with ``n_groups`` groups: only the treedef matters
    (leaf shapes come from the manifest)."""
    from repro.core.distributed import SegmentedIndex
    from repro.core.segment_pool import SegmentPool

    def one_group():
        idx = _structural_dummy()
        import jax

        stacked = jax.tree_util.tree_map(lambda a: a[None], idx)
        return SegmentedIndex(
            index=stacked, global_ids=np.zeros((1, 1), np.int32)
        )

    return SegmentPool(groups=[one_group() for _ in range(n_groups)])


def _pool_leaf_stride() -> int:
    """Leaves per pool group (HybridIndex leaves + global_ids), derived
    from the registered pytree structure so it never drifts."""
    import jax

    return len(jax.tree_util.tree_leaves(_pool_structural_dummy(1)))


def save_pool(
    directory: str | os.PathLike,
    pool,
    *,
    ingest=None,
    keep: int = 1,
) -> None:
    """Atomically persist a heterogeneous ``SegmentPool`` (variable group
    count, per-group segment counts and capacities) with the same
    manifest+leaf crash-consistency contract as ``save_index``: a fresh
    step per save, ``.done`` commit marker last, paired ingest manifest
    written before the commit. The group structure needs no sidecar — it is
    recovered from the manifest's leaf count at load time."""
    _save_stepped(pathlib.Path(directory), pool, ingest=ingest, keep=keep)


def load_pool(directory: str | os.PathLike, *, step: Optional[int] = None):
    """Restore a saved ``SegmentPool``. The heterogeneous layout (group
    count, per-group shapes) is reconstructed from the committed manifest:
    ``SegmentedIndex`` flattens to a fixed leaf count, so the group count
    is the manifest's leaf count over that stride, and each leaf's shape
    comes from its manifest entry."""
    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed pool checkpoint under {directory}")
    step = steps[-1] if step is None else step
    if step not in steps:
        raise FileNotFoundError(f"step {step} not committed under {directory}")
    with open(directory / f"step_{step}" / "manifest.json") as f:
        manifest = json.load(f)
    n_leaves = len(manifest["leaves"])
    stride = _pool_leaf_stride()
    if n_leaves == 0 or n_leaves % stride:
        raise ValueError(
            f"manifest has {n_leaves} leaves, not a multiple of "
            f"{stride} — not a segment-pool checkpoint?"
        )
    import jax

    dummy = _pool_structural_dummy(n_leaves // stride)
    flat, treedef = jax.tree_util.tree_flatten(dummy)
    if len(flat) != n_leaves:
        raise ValueError(
            f"manifest has {n_leaves} leaves but the reconstructed pool "
            f"flattens to {len(flat)}"
        )
    template = jax.tree_util.tree_unflatten(
        treedef,
        [
            np.zeros(tuple(m["shape"]), np.dtype(m["dtype"]))
            for m in manifest["leaves"]
        ],
    )
    return restore_checkpoint(directory, step, template)


def load_ingest(directory: str | os.PathLike):
    """Load the ingestion vocab/corpus-stats manifest PAIRED with the
    latest committed index step (returns a fitted ``IngestPipeline``).
    Falls back to the legacy flat ``ingest/`` layout."""
    from repro.ingest.pipeline import IngestPipeline

    directory = pathlib.Path(directory)
    steps = all_steps(directory)
    if steps:
        stepped = directory / f"{INGEST_STEP_PREFIX}{steps[-1]}"
        if stepped.exists():
            return IngestPipeline.load(stepped)
    return IngestPipeline.load(directory / INGEST_SUBDIR)
