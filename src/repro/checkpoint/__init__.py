from repro.checkpoint.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.index_io import load_index, load_ingest, save_index

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "save_index",
    "load_index",
    "load_ingest",
]
