from repro.checkpoint.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.index_io import (
    load_index,
    load_ingest,
    load_pool,
    save_index,
    save_pool,
)

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "save_index",
    "load_index",
    "load_ingest",
    "save_pool",
    "load_pool",
]
