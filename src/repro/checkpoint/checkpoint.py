"""Sharded, atomic, reshardable checkpointing (no orbax available offline).

Layout:  <dir>/step_<N>/
            manifest.json           tree structure, shapes, dtypes, specs
            leaf_<i>.npy            one file per leaf (host-local data)
         <dir>/step_<N>.done        commit marker (atomic rename contract)

Restore takes optional NamedShardings and device_puts each leaf with them, so
a checkpoint written on a (2,16,16) mesh restores onto (1,16,16) after a pod
loss (elastic restart) — resharding is just a different device_put. On a real
multi-host cluster each process writes only its addressable shards; the
single-host container writes full arrays through the same code path.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(k) for k in path) for path, _ in flat]


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    keep: int = 3,
    extra: Optional[dict] = None,
):
    """``extra``: caller-provided JSON-serializable metadata merged into the
    manifest (e.g. the index-io quantization record) — it rides the same
    tmp-dir -> rename -> .done commit, so it is exactly as crash-consistent
    as the leaves it describes."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = _tree_paths(tree)

    tmp = pathlib.Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_"))
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "paths": paths,
        "leaves": [],
    }
    if extra:
        manifest.update(extra)
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype == "bfloat16":
            # numpy can't serialize ml_dtypes (bf16 etc) — store raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
            logical_dtype = str(np.dtype("bfloat16")) if arr.dtype == np.uint16 else logical_dtype
            logical_dtype = "bfloat16"
        np.save(tmp / f"leaf_{i}.npy", arr)
        manifest["leaves"].append(
            {"index": i, "shape": list(arr.shape), "dtype": logical_dtype}
        )
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)

    final = directory / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit marker AFTER the directory rename: readers trust only .done
    (directory / f"step_{step}.done").touch()

    # retention
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)
        (directory / f"step_{s}.done").unlink(missing_ok=True)


def all_steps(directory: str | os.PathLike) -> list[int]:
    directory = pathlib.Path(directory)
    out = []
    for marker in directory.glob("step_*.done"):
        try:
            out.append(int(marker.stem.split("_")[1]))
        except (IndexError, ValueError):
            continue
    return sorted(out)


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str | os.PathLike,
    step: int,
    target_tree: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of target_tree (values ignored). If
    `shardings` (same-structure NamedShardings) is given, leaves are placed
    with them — this is the elastic-restart resharding path."""
    directory = pathlib.Path(directory) / f"step_{step}"
    with open(directory / "manifest.json") as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(flat_t) == len(manifest["leaves"]), (
        f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs target {len(flat_t)}"
    )
    flat_s = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat_t)
    leaves = []
    for i, (tgt, shard) in enumerate(zip(flat_t, flat_s)):
        arr = np.load(directory / f"leaf_{i}.npy")
        meta = manifest["leaves"][i]
        if meta["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        expected = tuple(tgt.shape) if hasattr(tgt, "shape") else None
        if expected is not None and tuple(arr.shape) != expected:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != target {expected}"
            )
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
