"""Sharded training step: microbatch gradient accumulation, remat'd model
forward, AdamW, optional int8-compressed DP all-reduce.

Two modes share one code path:

  * GSPMD mode (default): the whole step is one pjit program; DP gradient
    reduction is inserted by XLA from the sharding specs. Gradient
    accumulation over microbatches runs as a lax.scan, which also lets XLA
    overlap the backward of microbatch i with the reduce-scatter of i-1.
  * manual-DP mode (gradient compression on): the loss/grad is computed
    under shard_map manual over the DP axes, the DP mean runs through the
    int8 error-feedback collective (grad_compression.py), and TP stays
    automatic (GSPMD) inside the shard_map body.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import DATA, MODEL, POD, ShardCtx
from repro.training import optimizer as opt
from repro.training.grad_compression import compressed_psum_mean


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = opt.OptConfig()
    microbatches: int = 1
    grad_compression: bool = False


def dp_axes_of(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    dp = dp_axes_of(mesh)
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def make_train_state(cfg: ModelConfig, tcfg: TrainConfig, key, mesh: Optional[Mesh]):
    """Initialize (params, opt_state) with the model's shardings applied."""
    specs = tfm.param_specs(cfg, ShardCtx(
        model_size=mesh.shape[MODEL] if mesh and MODEL in mesh.axis_names else 16,
        fsdp=cfg.fsdp,
    ))
    if mesh is None:
        params = tfm.init_params(key, cfg)
        return {"params": params, "opt": opt.init_opt_state(params, tcfg.opt)}, specs
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
    params = jax.jit(
        lambda k: tfm.init_params(k, cfg), out_shardings=shardings
    )(key)
    opt_shardings = {
        "m": shardings,
        "v": shardings,
        "step": NamedSharding(mesh, P()),
    }
    opt_state = jax.jit(
        lambda p: opt.init_opt_state(p, tcfg.opt), out_shardings=opt_shardings
    )(params)
    return {"params": params, "opt": opt_state}, specs


def _accumulate_grads(loss_fn, params, batch, n_micro: int):
    """Microbatched grad accumulation via lax.scan (B must divide n_micro)."""
    if n_micro == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def reshape(a):
        b = a.shape[0]
        return a.reshape((n_micro, b // n_micro) + a.shape[1:])

    micro = jax.tree.map(reshape, batch)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        return (loss_acc + loss, g_acc), None

    (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0.0), g0), micro)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    mesh: Optional[Mesh],
    param_specs_tree,
):
    """Returns jitted fn(state, batch) -> (state, metrics)."""
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    loss_fn = tfm.make_loss_fn(cfg, mesh_axes)

    if not tcfg.grad_compression or mesh is None:

        def step(state, batch):
            loss, grads = _accumulate_grads(
                loss_fn, state["params"], batch, tcfg.microbatches
            )
            new_p, new_opt, metrics = opt.adamw_update(
                grads, state["opt"], state["params"], tcfg.opt
            )
            metrics["loss"] = loss
            return {"params": new_p, "opt": new_opt}, metrics

        if mesh is None:
            return jax.jit(step, donate_argnums=0)
        bspec = batch_spec(mesh)
        in_shard = (
            None,  # state shardings are carried by the arrays themselves
            jax.tree.map(lambda _: NamedSharding(mesh, bspec), {"tokens": 0}),
        )
        return jax.jit(step, donate_argnums=0)

    # ---- manual-DP mode with int8-compressed gradient all-reduce ----
    dp = dp_axes_of(mesh)
    bspec = batch_spec(mesh)

    def sharded_grads(params, batch, residual):
        def local(params, batch, residual):
            loss, grads = _accumulate_grads(loss_fn, params, batch, tcfg.microbatches)
            mean_grads, new_res = compressed_psum_mean(grads, dp, residual)
            loss = jax.lax.pmean(loss, dp)
            return loss, mean_grads, new_res

        # manual over DP only; TP stays automatic inside
        pspec = jax.tree.map(lambda _: P(), params)
        rspec = jax.tree.map(lambda _: P(), residual)
        return jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(pspec, jax.tree.map(lambda _: bspec, batch), rspec),
            out_specs=(P(), pspec, rspec),
            axis_names=set(dp),
            check_vma=False,
        )(params, batch, residual)

    def step(state, batch):
        residual = state.get("residual")
        if residual is None:
            residual = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
        loss, grads, new_res = sharded_grads(state["params"], batch, residual)
        new_p, new_opt, metrics = opt.adamw_update(
            grads, state["opt"], state["params"], tcfg.opt
        )
        metrics["loss"] = loss
        return {"params": new_p, "opt": new_opt, "residual": new_res}, metrics

    return jax.jit(step, donate_argnums=0)
