"""AdamW + LR schedule + global-norm clipping, in pure JAX.

Optimizer moments inherit the parameter PartitionSpecs (so FSDP shards the
optimizer state over the data axis too — the ZeRO-style memory win). Moment
dtype is configurable: f32 default, bf16 for the ≥600B configs where f32
moments alone would exceed per-device HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig) -> dict:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs) -> dict:
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads, opt_state, params, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
