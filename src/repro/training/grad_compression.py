"""int8 gradient compression with error feedback for the DP all-reduce.

The data-parallel gradient mean is the dominant training collective. With
compression on, each DP rank quantizes its local gradient to int8 (per-leaf
absmax scaling), the all-reduce runs on the int8 payload (accumulated in
int32) + f32 scales, and the residual (quantization error) is fed back into
the next step's gradient — the standard EF-SGD construction that keeps
convergence unbiased in the long run.

4x fewer bytes on the wire for the DP collective; the roofline collective
term scales accordingly. Implemented with explicit shard_map psum over the
DP axes (the train loop runs manual-DP for this path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, axis_names, residual=None):
    """Quantize -> psum(int32) -> dequantize with mean; error feedback.

    Must be called inside shard_map with `axis_names` manual. Returns
    (mean_grads, new_residual).
    """
    n = 1
    for a in axis_names:
        n *= jax.lax.axis_size(a)

    def one(g, r):
        g_in = g.astype(jnp.float32) + (r if r is not None else 0.0)
        q, scale = quantize_int8(g_in)
        local_deq = dequantize_int8(q, scale)
        new_r = g_in - local_deq  # error feedback residual (stays local)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        scale_sum = jax.lax.psum(scale, axis_names)
        # scales differ per rank; use the mean scale against the summed int
        # payload (absmax scales are within ~2x across DP ranks in practice)
        mean = q_sum.astype(jnp.float32) * (scale_sum / n) / n
        return mean.astype(g.dtype), new_r

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    pairs = jax.tree.map(one, grads, residual)
    mean_grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return mean_grads, new_res
