"""RWKV6 "Finch" — attention-free token mixing with data-dependent decay
(arXiv:2404.05892).

Two implementations of the WKV6 recurrence
    S_t = Diag(w_t) S_{t-1} + k_t v_t^T,   y_t = r_t (S_{t-1} + Diag(u) k_t v_t^T)

  * ``wkv6_scan``    — exact per-step lax.scan (oracle + decode step);
  * ``wkv6_chunked`` — chunk-parallel MXU formulation used for training:
    within a chunk the interaction matrix factorizes into two matmuls with
    per-dim decay folded into r/k (mid-chunk-centered exponents, clamped at
    ±40 — exact for all but numerically-zero contributions), inter-chunk
    state carried by a scan over chunks. This is the hardware-adapted form:
    GPU RWKV kernels serialize T=16 sub-chunks per thread block; on TPU the
    (T x T) on-diagonal block becomes an MXU matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx, dtype_of, ninit

EXP_CLAMP = 40.0


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------


def wkv6_scan(r, k, v, w, u, s0):
    """Exact recurrence. r/k/v/w: (B, L, H, K); u: (H, K); s0: (B, H, K, K).
    Returns (y (B, L, H, K), s_final)."""
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, K)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, K, V)
        y = jnp.einsum(
            "bhk,bhkv->bhv", r_t, s + u.astype(f32)[None, :, :, None] * kv
        )
        s_new = w_t[..., None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))
    s_fin, ys = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def wkv6_step(r, k, v, w, u, s):
    """Single decode step. r/k/v/w: (B, H, K)."""
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, s + u.astype(f32)[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    return y, s_new


def wkv6_chunked(r, k, v, w, u, s0, chunk: int = 64):
    """Chunk-parallel WKV6 (see module docstring)."""
    f32 = jnp.float32
    b, l, h, kdim = r.shape
    assert l % chunk == 0, f"L={l} not a multiple of chunk={chunk}"
    nc = l // chunk
    shp = (b, nc, chunk, h, kdim)
    r, k, v, w = (x.astype(f32).reshape(shp) for x in (r, k, v, w))

    logw = jnp.log(jnp.maximum(w, 1e-38))
    lc = jnp.cumsum(logw, axis=2)  # inclusive per-chunk cumulative log decay
    lexc = lc - logw  # exclusive
    mid = lc[:, :, chunk // 2 : chunk // 2 + 1]  # per-dim centering

    clamp = lambda x: jnp.clip(x, -EXP_CLAMP, EXP_CLAMP)
    rq = r * jnp.exp(clamp(lexc - mid))  # (b, nc, T, h, K)
    kk = k * jnp.exp(clamp(mid - lc))

    # intra-chunk: A[t, s] = sum_d rq[t, d] kk[s, d], strictly lower + u-diag
    a = jnp.einsum("bcthd,bcshd->bchts", rq, kk)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    a = jnp.where(mask[None, None, None], a, 0.0)
    diag = jnp.einsum("bcthd,hd,bcthd->bcth", r, u.astype(f32), k)
    y_intra = jnp.einsum("bchts,bcshv->bcthv", a, v)
    y_intra = y_intra + diag[..., None] * v

    # inter-chunk state scan
    total = lc[:, :, -1]  # (b, nc, h, K) total chunk log decay
    k_scaled = k * jnp.exp(clamp(total[:, :, None] - lc))
    chunk_kv = jnp.einsum("bcshk,bcshv->bchkv", k_scaled, v)
    decay_chunk = jnp.exp(clamp(total))  # (b, nc, h, K)

    def carry_step(s, inp):
        dc, ckv = inp  # (b, h, K), (b, h, K, V)
        s_new = dc[..., None] * s + ckv
        return s_new, s

    dc_t = jnp.moveaxis(decay_chunk, 1, 0)
    ckv_t = jnp.moveaxis(chunk_kv, 1, 0)
    s_fin, s_prevs = jax.lax.scan(carry_step, s0.astype(f32), (dc_t, ckv_t))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (b, nc, h, K, V) state before chunk

    r_inter = r * jnp.exp(clamp(lexc))
    y_inter = jnp.einsum("bcthk,bchkv->bcthv", r_inter, s_prevs)

    y = (y_intra + y_inter).reshape(b, l, h, kdim)
    return y, s_fin


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------


def init_rwkv6_block(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    d = cfg.d_model
    lora = cfg.wkv_lora
    hd = cfg.ssm_head_dim
    h = d // hd
    hidden = int(d * 3.5)
    ks = jax.random.split(key, 12)
    s = d**-0.5
    return {
        "ln1": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "ln2": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        "tm": {
            "mu_x": ninit(ks[0], (d,), 0.02, dtype),
            "mu": ninit(ks[1], (5, d), 0.02, dtype),
            "lora_a": ninit(ks[2], (d, 5 * lora), s, dtype),
            "lora_b": ninit(ks[3], (5, lora, d), lora**-0.5, dtype),
            "w0": ninit(ks[4], (d,), 0.02, jnp.float32) - 6.0,  # slow decay init
            "u": ninit(ks[5], (h, hd), 0.02, jnp.float32),
            "wr": ninit(ks[6], (d, d), s, dtype),
            "wk": ninit(ks[7], (d, d), s, dtype),
            "wv": ninit(ks[8], (d, d), s, dtype),
            "wg": ninit(ks[9], (d, d), s, dtype),
            "wo": ninit(ks[10], (d, d), s, dtype),
            "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        },
        "cm": {
            "mu_k": ninit(ks[11], (d,), 0.02, dtype),
            "mu_r": ninit(jax.random.fold_in(key, 99), (d,), 0.02, dtype),
            "wk": ninit(jax.random.fold_in(key, 100), (d, hidden), s, dtype),
            "wv": ninit(jax.random.fold_in(key, 101), (hidden, d), hidden**-0.5, dtype),
            "wr": ninit(jax.random.fold_in(key, 102), (d, d), s, dtype),
        },
    }


def rwkv6_block_specs(ctx: ShardCtx, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    hidden = int(d * 3.5)
    m_d = ctx.ff(d)
    m_h = ctx.ff(hidden)
    dd = ctx.data(d)
    ln = {"scale": P(None), "bias": P(None)}
    return {
        "ln1": ln,
        "ln2": ln,
        "tm": {
            "mu_x": P(None),
            "mu": P(None, None),
            "lora_a": P(dd, None),
            "lora_b": P(None, None, None),
            "w0": P(None),
            "u": P(None, None),
            "wr": P(dd, m_d),
            "wk": P(dd, m_d),
            "wv": P(dd, m_d),
            "wg": P(dd, m_d),
            "wo": P(m_d, dd),
            "ln_x": ln,
        },
        "cm": {
            "mu_k": P(None),
            "mu_r": P(None),
            "wk": P(dd, m_h),
            "wv": P(m_h, dd),
            "wr": P(dd, m_d),
        },
    }


def _layer_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def _group_norm_heads(p, y, h, eps=1e-5):
    """GroupNorm with one group per head over (B, L, H, K) flattened."""
    b, l, _, kdim = y.shape
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(b, l, h * kdim)
    return yn * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)


def _ddlerp(tm, x, shifted):
    """Finch data-dependent token-shift interpolation -> 5 mixed streams."""
    dx = shifted - x
    xxx = x + dx * tm["mu_x"]
    lora = tm["lora_b"].shape[1]
    a = jnp.tanh(jnp.einsum("bld,dr->blr", xxx, tm["lora_a"]))
    a = a.reshape(*a.shape[:-1], 5, lora)
    dyn = jnp.einsum("blfr,frd->blfd", a, tm["lora_b"])
    mixed = x[:, :, None] + dx[:, :, None] * (tm["mu"][None, None] + dyn)
    return [mixed[:, :, i] for i in range(5)]


def _decay(tm, xw):
    w_raw = tm["w0"].astype(jnp.float32) + xw.astype(jnp.float32)
    return jnp.exp(-jnp.exp(jnp.clip(w_raw, -20.0, 4.0)))


def apply_rwkv6_block(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, L, D)
    state: dict,  # {"tm_x": (B,D), "cm_x": (B,D), "wkv": (B,H,K,K)}
    *,
    chunked: bool = True,
) -> tuple[jax.Array, dict]:
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    b, l, _ = x.shape

    # ---- time mix ----
    xin = _layer_norm(p["ln1"], x)
    shifted = jnp.concatenate([state["tm_x"][:, None], xin[:, :-1]], axis=1)
    tm = p["tm"]
    xr, xk, xv, xg, xw = _ddlerp(tm, xin, shifted)
    r = jnp.einsum("bld,de->ble", xr, tm["wr"]).reshape(b, l, h, hd)
    k = jnp.einsum("bld,de->ble", xk, tm["wk"]).reshape(b, l, h, hd)
    v = jnp.einsum("bld,de->ble", xv, tm["wv"]).reshape(b, l, h, hd)
    g = jax.nn.silu(jnp.einsum("bld,de->ble", xg, tm["wg"]))
    w_decay_raw = jnp.einsum("bld,dr->blr", xw, tm["lora_a"][:, : cfg.wkv_lora])
    w_dyn = jnp.einsum("blr,rd->bld", jnp.tanh(w_decay_raw), tm["lora_b"][4])
    w = _decay(tm, w_dyn).reshape(b, l, h, hd)

    if chunked and l % cfg.ssm_chunk == 0 and l > 1:
        y, s_fin = wkv6_chunked(r, k, v, w, tm["u"], state["wkv"], cfg.ssm_chunk)
    else:
        y, s_fin = wkv6_scan(r, k, v, w, tm["u"], state["wkv"])
    y = _group_norm_heads(tm["ln_x"], y, h).astype(x.dtype)
    x = x + jnp.einsum("ble,ed->bld", y * g, tm["wo"])

    # ---- channel mix ----
    xin2 = _layer_norm(p["ln2"], x)
    shifted2 = jnp.concatenate([state["cm_x"][:, None], xin2[:, :-1]], axis=1)
    cm = p["cm"]
    dx2 = shifted2 - xin2
    xk2 = xin2 + dx2 * cm["mu_k"]
    xr2 = xin2 + dx2 * cm["mu_r"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bld,df->blf", xk2, cm["wk"])))
    rr = jax.nn.sigmoid(jnp.einsum("bld,de->ble", xr2, cm["wr"]))
    x = x + rr * jnp.einsum("blf,fd->bld", kk, cm["wv"])

    new_state = {"tm_x": xin[:, -1], "cm_x": xin2[:, -1], "wkv": s_fin}
    return x, new_state


def rwkv6_state_shape(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    dt = dtype_of(cfg)
    return {
        "tm_x": jax.ShapeDtypeStruct((batch, d), dt),
        "cm_x": jax.ShapeDtypeStruct((batch, d), dt),
        "wkv": jax.ShapeDtypeStruct((batch, h, hd, hd), jnp.float32),
    }
