"""Model assembly: init / sharding specs / forward / prefill / decode for all
six architecture families.

Layer stacks are *scanned* (lax.scan over stacked parameters) wherever the
stack is homogeneous — essential for compile time at 61-100 layers — with
jax.checkpoint (remat) applied to the scan body per the config policy.
Heterogeneous patterns become uniform "super-blocks":

  vlm     — scan over 20 groups of (4 self-attn layers + 1 cross-attn layer)
  hybrid  — scan over groups of (attn_every mamba2 layers + shared attn block)
  moe     — leading dense layers unrolled, MoE layers scanned
  audio   — two scans (encoder stack, decoder stack with cross-attention)
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mamba2, moe as moe_mod, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (
    DATA,
    MODEL,
    POD,
    ShardCtx,
    apply_mlp,
    dtype_of,
    embed_specs,
    embed_tokens,
    init_embed,
    init_mlp,
    init_rmsnorm,
    mlp_specs,
    ninit,
    rms_norm,
    rmsnorm_specs,
    unembed,
)

AUX_LOSS_COEF = 0.01
MTP_LOSS_COEF = 0.3


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    """Initialize n copies of a sub-tree and stack leaves on axis 0."""
    keys = jax.random.split(key, n)
    trees = [fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec_tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _constrain(h, cfg: ModelConfig, mesh_axes: tuple, seq_dim: int = 1):
    """Activation sharding: batch over DP axes (+ optional sequence parallel)."""
    if not mesh_axes:
        return h
    dp = tuple(a for a in (POD, DATA) if a in mesh_axes)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    spec = [None] * h.ndim
    spec[0] = dp_spec
    if cfg.seq_shard and MODEL in mesh_axes and h.ndim >= 3:
        spec[seq_dim] = MODEL
    return jax.lax.with_sharding_constraint(h, P(*spec))


# ---------------------------------------------------------------------------
# dense / moe blocks
# ---------------------------------------------------------------------------


def _init_dense_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = dtype_of(cfg)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_mla(k1, cfg) if cfg.use_mla else attn.init_attention(k1, cfg),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dense_block_specs(ctx, cfg: ModelConfig) -> dict:
    return {
        "ln1": rmsnorm_specs(),
        "attn": attn.mla_specs(ctx, cfg) if cfg.use_mla else attn.attention_specs(ctx, cfg),
        "ln2": rmsnorm_specs(),
        "mlp": mlp_specs(ctx, cfg.d_model, cfg.d_ff),
    }


def _init_moe_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dtype = dtype_of(cfg)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_mla(k1, cfg) if cfg.use_mla else attn.init_attention(k1, cfg),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": moe_mod.init_moe(k2, cfg),
    }


def _moe_block_specs(ctx, cfg: ModelConfig) -> dict:
    return {
        "ln1": rmsnorm_specs(),
        "attn": attn.mla_specs(ctx, cfg) if cfg.use_mla else attn.attention_specs(ctx, cfg),
        "ln2": rmsnorm_specs(),
        "moe": moe_mod.moe_specs(ctx, cfg),
    }


def _self_attn(p, cfg, h, positions, *, causal=True):
    y, cache = (
        attn.apply_mla(p, cfg, h, positions)
        if cfg.use_mla
        else attn.apply_attention(p, cfg, h, positions, causal=causal)
    )
    return y, cache


def _block_seq(p, cfg, h, positions, *, causal=True, collect_cache=False):
    """One dense/moe block over a full sequence. Returns (h, aux, cache)."""
    y, cache = _self_attn(p["attn"], cfg, rms_norm(p["ln1"], h), positions, causal=causal)
    h = h + y
    hn = rms_norm(p["ln2"], h)
    if "moe" in p:
        moe_fn = (
            moe_mod.apply_moe_ep if cfg.moe_impl == "ep_manual" else moe_mod.apply_moe
        )
        y2, aux = moe_fn(p["moe"], cfg, hn)
    else:
        y2, aux = apply_mlp(p["mlp"], hn), jnp.float32(0.0)
    return h + y2, aux, (cache if collect_cache else None)


def _block_decode(p, cfg, h, cache, pos):
    hn = rms_norm(p["ln1"], h)
    if cfg.use_mla:
        y, new_cache = attn.apply_mla_decode(p["attn"], cfg, hn, cache, pos)
    else:
        y, new_cache = attn.apply_attention_decode(p["attn"], cfg, hn, cache, pos)
    h = h + y
    hn = rms_norm(p["ln2"], h)
    if "moe" in p:
        moe_fn = (
            moe_mod.apply_moe_ep if cfg.moe_impl == "ep_manual" else moe_mod.apply_moe
        )
        y2, _ = moe_fn(p["moe"], cfg, hn)
    else:
        y2 = apply_mlp(p["mlp"], hn)
    return h + y2, new_cache


# ---------------------------------------------------------------------------
# init + specs (public)
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    k_embed, k_body, k_extra = jax.random.split(key, 3)
    params: dict[str, Any] = {"embed": init_embed(k_embed, cfg)}
    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)

    if cfg.family in ("dense",):
        params["layers"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), k_body, cfg.n_layers
        )
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["dense_layers"] = _stack_init(
                lambda k: _init_dense_block(k, cfg), jax.random.fold_in(k_body, 1), nd
            )
        params["layers"] = _stack_init(
            lambda k: _init_moe_block(k, cfg), k_body, cfg.n_layers - nd
        )
        if cfg.mtp:
            km = jax.random.fold_in(k_extra, 7)
            params["mtp"] = {
                "proj": ninit(km, (2 * cfg.d_model, cfg.d_model), (2 * cfg.d_model) ** -0.5, dtype),
                "block": _init_dense_block(jax.random.fold_in(km, 1), cfg),
                "norm": init_rmsnorm(cfg.d_model, dtype),
            }
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(
            lambda k: rwkv6.init_rwkv6_block(k, cfg), k_body, cfg.n_layers
        )
    elif cfg.family == "hybrid":
        params["layers"] = _stack_init(
            lambda k: mamba2.init_mamba2_block(k, cfg), k_body, cfg.n_layers
        )
        params["shared_attn"] = _init_dense_block(k_extra, cfg)
    elif cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self_per = cfg.cross_attn_every - 1
        params["groups"] = _stack_init(
            lambda k: {
                "self": _stack_init(
                    lambda kk: _init_dense_block(kk, cfg), k, n_self_per
                ),
                "cross": _init_dense_block(jax.random.fold_in(k, 1), cfg),
            },
            k_body,
            n_cross,
        )
    elif cfg.family == "audio":
        params["encoder"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), jax.random.fold_in(k_body, 1),
            cfg.encoder_layers,
        )
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
        params["layers"] = _stack_init(
            lambda k: {
                **_init_dense_block(k, cfg),
                "ln_x": init_rmsnorm(cfg.d_model, dtype),
                "cross": attn.init_attention(jax.random.fold_in(k, 2), cfg),
            },
            k_body,
            cfg.n_layers,
        )
    else:
        raise ValueError(cfg.family)
    return params


def param_specs(cfg: ModelConfig, ctx: Optional[ShardCtx] = None) -> dict:
    ctx = ctx or ShardCtx(fsdp=cfg.fsdp)
    specs: dict[str, Any] = {"embed": embed_specs(ctx, cfg)}
    specs["final_norm"] = rmsnorm_specs()
    if cfg.family == "dense":
        specs["layers"] = _stack_specs(_dense_block_specs(ctx, cfg))
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            specs["dense_layers"] = _stack_specs(_dense_block_specs(ctx, cfg))
        specs["layers"] = _stack_specs(_moe_block_specs(ctx, cfg))
        if cfg.mtp:
            specs["mtp"] = {
                "proj": P(None, None),
                "block": _dense_block_specs(ctx, cfg),
                "norm": rmsnorm_specs(),
            }
    elif cfg.family == "ssm":
        specs["layers"] = _stack_specs(rwkv6.rwkv6_block_specs(ctx, cfg))
    elif cfg.family == "hybrid":
        specs["layers"] = _stack_specs(mamba2.mamba2_block_specs(ctx, cfg))
        specs["shared_attn"] = _dense_block_specs(ctx, cfg)
    elif cfg.family == "vlm":
        specs["groups"] = _stack_specs(
            {
                "self": _stack_specs(_dense_block_specs(ctx, cfg)),
                "cross": _dense_block_specs(ctx, cfg),
            }
        )
    elif cfg.family == "audio":
        specs["encoder"] = _stack_specs(_dense_block_specs(ctx, cfg))
        specs["enc_norm"] = rmsnorm_specs()
        specs["layers"] = _stack_specs(
            {
                **_dense_block_specs(ctx, cfg),
                "ln_x": rmsnorm_specs(),
                "cross": attn.attention_specs(ctx, cfg),
            }
        )
    return specs


# ---------------------------------------------------------------------------
# forward (training / eval over a full sequence)
# ---------------------------------------------------------------------------


def make_forward(cfg: ModelConfig, mesh_axes: tuple = ()):
    """Returns fn(params, tokens, frontend=None) -> (logits, aux_loss).

    tokens: (B, L) int32. frontend: (B, T, D) patch/frame embeddings for
    vlm/audio (stub modality frontends per the assignment).
    """

    def fwd(params, tokens, frontend=None):
        b, l = tokens.shape
        positions = jnp.arange(l, dtype=jnp.int32)[None]
        h = embed_tokens(params["embed"], tokens)
        h = _constrain(h, cfg, mesh_axes)
        aux = jnp.float32(0.0)

        if cfg.family in ("dense", "moe"):
            if cfg.family == "moe" and cfg.first_dense_layers:
                for i in range(cfg.first_dense_layers):
                    pl_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    h, a_i, _ = _block_seq(pl_i, cfg, h, positions)
                    aux += a_i

            def body(carry, layer_p):
                h, aux = carry
                h, a_i, _ = _block_seq(layer_p, cfg, h, positions)
                h = _constrain(h, cfg, mesh_axes)
                return (h, aux + a_i), None

            (h, aux), _ = jax.lax.scan(
                _remat(body, cfg), (h, aux), params["layers"]
            )
        elif cfg.family == "ssm":

            def body(carry, layer_p):
                h, aux = carry
                st = _zero_state_rwkv(cfg, b)
                h, _ = rwkv6.apply_rwkv6_block(layer_p, cfg, h, st)
                h = _constrain(h, cfg, mesh_axes)
                return (h, aux), None

            (h, aux), _ = jax.lax.scan(_remat(body, cfg), (h, aux), params["layers"])
        elif cfg.family == "hybrid":
            h, aux = _hybrid_forward(params, cfg, h, positions, b, mesh_axes)
        elif cfg.family == "vlm":
            assert frontend is not None, "vlm needs patch embeddings"

            def body(carry, group_p):
                h, aux = carry

                def self_body(hc, lp):
                    hh, _, _ = _block_seq(lp, cfg, hc, positions)
                    return hh, None

                h, _ = jax.lax.scan(self_body, h, group_p["self"])
                cp = group_p["cross"]
                y, _ = attn.apply_attention(
                    cp["attn"], cfg, rms_norm(cp["ln1"], h), positions,
                    causal=False, kv_src=frontend,
                )
                h = h + y
                h = h + apply_mlp(cp["mlp"], rms_norm(cp["ln2"], h))
                h = _constrain(h, cfg, mesh_axes)
                return (h, aux), None

            (h, aux), _ = jax.lax.scan(_remat(body, cfg), (h, aux), params["groups"])
        elif cfg.family == "audio":
            assert frontend is not None, "audio needs frame embeddings"
            enc = _encode_audio(params, cfg, frontend, mesh_axes)

            def body(carry, layer_p):
                h, aux = carry
                h, _, _ = _block_seq(layer_p, cfg, h, positions)
                y, _ = attn.apply_attention(
                    layer_p["cross"], cfg, rms_norm(layer_p["ln_x"], h),
                    positions, causal=False, kv_src=enc,
                )
                h = h + y
                h = _constrain(h, cfg, mesh_axes)
                return (h, aux), None

            (h, aux), _ = jax.lax.scan(_remat(body, cfg), (h, aux), params["layers"])

        h = rms_norm(params["final_norm"], h)
        logits = unembed(params["embed"], h, cfg)

        if cfg.family == "moe" and cfg.mtp:
            # multi-token prediction: one extra block over [h_t ; emb(t_{t+1})]
            emb_next = jnp.roll(embed_tokens(params["embed"], tokens), -1, axis=1)
            mtp_in = jnp.einsum(
                "blf,fd->bld",
                jnp.concatenate([h.astype(dtype_of(cfg)), emb_next], axis=-1),
                params["mtp"]["proj"],
            )
            h2, _, _ = _block_seq(params["mtp"]["block"], cfg, mtp_in, positions)
            h2 = rms_norm(params["mtp"]["norm"], h2)
            logits_mtp = unembed(params["embed"], h2, cfg)
            return logits, aux, logits_mtp
        return logits, aux, None

    return fwd


def _encode_audio(params, cfg, frames, mesh_axes):
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)[None]
    h = frames

    def body(h, layer_p):
        h, _, _ = _block_seq(layer_p, cfg, h, positions, causal=False)
        return h, None

    h, _ = jax.lax.scan(_remat(body, cfg), h, params["encoder"])
    return rms_norm(params["enc_norm"], h)


def _hybrid_forward(params, cfg, h, positions, b, mesh_axes):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    rem = cfg.n_layers - n_groups * ae
    grouped = jax.tree.map(
        lambda a: a[: n_groups * ae].reshape((n_groups, ae) + a.shape[1:]),
        params["layers"],
    )
    shared = params["shared_attn"]

    def group_body(h, group_p):
        def mamba_body(hc, lp):
            st = _zero_state_mamba(cfg, b)
            hh, _ = mamba2.apply_mamba2_block(lp, cfg, hc, st)
            return hh, None

        h, _ = jax.lax.scan(mamba_body, h, group_p)
        h2, _, _ = _block_seq(shared, cfg, h, positions)
        h2 = _constrain(h2, cfg, mesh_axes)
        return h2, None

    h, _ = jax.lax.scan(_remat(group_body, cfg), h, grouped)
    for i in range(rem):
        lp = jax.tree.map(lambda a: a[n_groups * ae + i], params["layers"])
        st = _zero_state_mamba(cfg, b)
        h, _ = mamba2.apply_mamba2_block(lp, cfg, h, st)
    return h, jnp.float32(0.0)


def _zero_state_rwkv(cfg, b):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), rwkv6.rwkv6_state_shape(cfg, b)
    )


def _zero_state_mamba(cfg, b):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), mamba2.mamba2_state_shape(cfg, b)
    )


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig, mesh_axes: tuple = ()):
    fwd = make_forward(cfg, mesh_axes)

    def ce(logits, labels, mask):
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        frontend = batch.get("frontend")
        logits, aux, logits_mtp = fwd(params, tokens, frontend)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
        loss = ce(logits, labels, mask)
        if aux is not None:
            loss = loss + AUX_LOSS_COEF * aux
        if logits_mtp is not None:
            labels2 = jnp.roll(tokens, -2, axis=1)
            mask2 = mask.at[:, -2].set(0.0)
            loss = loss + MTP_LOSS_COEF * ce(logits_mtp, labels2, mask2)
        return loss

    return loss_fn


# ---------------------------------------------------------------------------
# serving: cache shapes / prefill / decode
# ---------------------------------------------------------------------------


def _attn_cache_shape(cfg, batch, max_len):
    if cfg.use_mla:
        return attn.mla_cache_shape(cfg, batch, max_len)
    return attn.kv_cache_shape(cfg, batch, max_len)


def _stackshape(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
    )


def cache_shape(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct tree of the decode cache."""
    a = _attn_cache_shape(cfg, batch, max_len)
    if cfg.family == "dense":
        return {"layers": _stackshape(a, cfg.n_layers)}
    if cfg.family == "moe":
        out = {"layers": _stackshape(a, cfg.n_layers - cfg.first_dense_layers)}
        if cfg.first_dense_layers:
            out["dense_layers"] = _stackshape(a, cfg.first_dense_layers)
        return out
    if cfg.family == "ssm":
        return {"layers": _stackshape(rwkv6.rwkv6_state_shape(cfg, batch), cfg.n_layers)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        return {
            "mamba": _stackshape(mamba2.mamba2_state_shape(cfg, batch), cfg.n_layers),
            "shared": _stackshape(a, n_groups),
        }
    if cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        t = cfg.n_frontend_tokens
        dt = dtype_of(cfg)
        cross = {
            "k": jax.ShapeDtypeStruct((batch, t, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct((batch, t, cfg.n_kv_heads, cfg.head_dim), dt),
        }
        return {
            "self": _stackshape(_stackshape(a, n_self), n_groups),
            "cross": _stackshape(cross, n_groups),
        }
    if cfg.family == "audio":
        t = cfg.n_frontend_tokens
        dt = dtype_of(cfg)
        cross = {
            "k": jax.ShapeDtypeStruct((batch, t, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct((batch, t, cfg.n_kv_heads, cfg.head_dim), dt),
        }
        return {
            "self": _stackshape(a, cfg.n_layers),
            "cross": _stackshape(cross, cfg.n_layers),
        }
    raise ValueError(cfg.family)


def cache_specs(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    *,
    dp_size: int = 32,
    model_size: int = 16,
    multi_pod: bool = True,
) -> dict:
    """Mesh-aware PartitionSpec tree matching ``cache_shape``.

    Batch shards over the DP axes when divisible. KV heads shard over the
    model axis when divisible; otherwise the cache SEQUENCE dim shards over
    model (sequence-sharded KV cache — attention contracts the sharded dim
    and XLA inserts the partial-softmax reduction), which is what keeps the
    32k/500k caches of low-kv-head models within per-device HBM. SSM states
    shard their head dim over model.
    """
    dp = (POD, DATA) if multi_pod else (DATA,)
    dp_spec = dp if len(dp) > 1 else dp[0]
    b_sh = dp_spec if batch % dp_size == 0 and batch >= dp_size else None
    kv_ok = cfg.n_kv_heads % model_size == 0 and cfg.n_kv_heads >= model_size
    seq_ok = max_len % model_size == 0

    def kv_spec(extra_lead: int):
        # (B, S, KV, hd) with extra_lead stacked layer dims in front
        lead = (None,) * extra_lead
        if kv_ok:
            return P(*lead, b_sh, None, MODEL, None)
        if seq_ok:
            return P(*lead, b_sh, MODEL, None, None)
        return P(*lead, b_sh, None, None, None)

    def seq2_spec(extra_lead: int, last_div: int):
        # (B, S, X) latent caches (mla): shard S over model when divisible
        lead = (None,) * extra_lead
        if seq_ok:
            return P(*lead, b_sh, MODEL, None)
        if last_div % model_size == 0:
            return P(*lead, b_sh, None, MODEL)
        return P(*lead, b_sh, None, None)

    def map_attn(extra_lead: int):
        if cfg.use_mla:
            return {
                "ckv": seq2_spec(extra_lead, cfg.kv_lora_rank),
                "krope": P(*((None,) * extra_lead), b_sh, MODEL if seq_ok else None, None),
            }
        return {"k": kv_spec(extra_lead), "v": kv_spec(extra_lead)}

    d = cfg.d_model
    d_sh = MODEL if d % model_size == 0 else None
    if cfg.family == "dense":
        return {"layers": map_attn(1)}
    if cfg.family == "moe":
        out = {"layers": map_attn(1)}
        if cfg.first_dense_layers:
            out["dense_layers"] = map_attn(1)
        return out
    if cfg.family == "ssm":
        h = d // cfg.ssm_head_dim
        h_sh = MODEL if h % model_size == 0 else None
        return {
            "layers": {
                "tm_x": P(None, b_sh, d_sh),
                "cm_x": P(None, b_sh, d_sh),
                "wkv": P(None, b_sh, h_sh, None, None),
            }
        }
    if cfg.family == "hybrid":
        d_inner = 2 * d
        h = d_inner // cfg.ssm_head_dim
        h_sh = MODEL if h % model_size == 0 else None
        conv_ch = d_inner + 2 * cfg.ssm_state
        return {
            "mamba": {
                "conv": P(None, b_sh, None, MODEL if conv_ch % model_size == 0 else None),
                "ssm": P(None, b_sh, h_sh, None, None),
            },
            "shared": map_attn(1),
        }
    if cfg.family == "vlm":
        t = cfg.n_frontend_tokens
        t_sh = MODEL if t % model_size == 0 and not kv_ok else (MODEL if kv_ok else None)
        cross = {
            "k": P(None, b_sh, None, MODEL, None) if kv_ok else P(None, b_sh, MODEL if t % model_size == 0 else None, None, None),
            "v": P(None, b_sh, None, MODEL, None) if kv_ok else P(None, b_sh, MODEL if t % model_size == 0 else None, None, None),
        }
        return {"self": map_attn(2), "cross": cross}
    if cfg.family == "audio":
        t = cfg.n_frontend_tokens
        cross_seq = MODEL if t % model_size == 0 and not kv_ok else None
        cross = {
            "k": P(None, b_sh, None, MODEL, None) if kv_ok else P(None, b_sh, cross_seq, None, None),
            "v": P(None, b_sh, None, MODEL, None) if kv_ok else P(None, b_sh, cross_seq, None, None),
        }
        return {"self": map_attn(1), "cross": cross}
    raise ValueError(cfg.family)


def _pad_cache_len(cache_l, max_len, axis=1):
    def pad(a):
        if a.shape[axis] == max_len:
            return a
        pw = [(0, 0)] * a.ndim
        pw[axis] = (0, max_len - a.shape[axis])
        return jnp.pad(a, pw)
    return jax.tree.map(pad, cache_l)


def make_prefill(cfg: ModelConfig, max_len: int, mesh_axes: tuple = ()):
    """Returns fn(params, tokens, frontend=None) -> (last_logits, cache).

    For attention families the cache holds K/V for positions [0, L) padded to
    max_len; for ssm/hybrid it holds the recurrent state after the prompt.
    """

    def prefill(params, tokens, frontend=None):
        b, l = tokens.shape
        positions = jnp.arange(l, dtype=jnp.int32)[None]
        h = embed_tokens(params["embed"], tokens)
        h = _constrain(h, cfg, mesh_axes)
        cache: dict[str, Any] = {}

        if cfg.family in ("dense", "moe"):
            if cfg.family == "moe" and cfg.first_dense_layers:
                dcaches = []
                for i in range(cfg.first_dense_layers):
                    pl_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    h, _, c = _block_seq(pl_i, cfg, h, positions, collect_cache=True)
                    dcaches.append(_pad_cache_len(c, max_len))
                cache["dense_layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *dcaches
                )

            def body(h, layer_p):
                h, _, c = _block_seq(layer_p, cfg, h, positions, collect_cache=True)
                h = _constrain(h, cfg, mesh_axes)
                return h, _pad_cache_len(c, max_len)

            h, caches = jax.lax.scan(body, h, params["layers"])
            cache["layers"] = caches
        elif cfg.family == "ssm":

            def body(h, layer_p):
                st = _zero_state_rwkv(cfg, b)
                h, st = rwkv6.apply_rwkv6_block(layer_p, cfg, h, st)
                h = _constrain(h, cfg, mesh_axes)
                return h, st

            h, states = jax.lax.scan(body, h, params["layers"])
            cache["layers"] = states
        elif cfg.family == "hybrid":
            h, mamba_states, shared_caches = _hybrid_prefill(
                params, cfg, h, positions, b, max_len, mesh_axes
            )
            cache["mamba"] = mamba_states
            cache["shared"] = shared_caches
        elif cfg.family == "vlm":

            def body(h, group_p):
                def self_body(hc, lp):
                    hh, _, c = _block_seq(lp, cfg, hc, positions, collect_cache=True)
                    return hh, _pad_cache_len(c, max_len)

                h, self_caches = jax.lax.scan(self_body, h, group_p["self"])
                cp = group_p["cross"]
                y, cross_c = attn.apply_attention(
                    cp["attn"], cfg, rms_norm(cp["ln1"], h), positions,
                    causal=False, kv_src=frontend,
                )
                h = h + y
                h = h + apply_mlp(cp["mlp"], rms_norm(cp["ln2"], h))
                h = _constrain(h, cfg, mesh_axes)
                return h, {"self": self_caches, "cross": cross_c}

            h, gc = jax.lax.scan(body, h, params["groups"])
            cache["self"] = gc["self"]
            cache["cross"] = gc["cross"]
        elif cfg.family == "audio":
            enc = _encode_audio(params, cfg, frontend, mesh_axes)

            def body(h, layer_p):
                h, _, c = _block_seq(layer_p, cfg, h, positions, collect_cache=True)
                y, cross_c = attn.apply_attention(
                    layer_p["cross"], cfg, rms_norm(layer_p["ln_x"], h),
                    positions, causal=False, kv_src=enc,
                )
                h = h + y
                h = _constrain(h, cfg, mesh_axes)
                return h, {"self": _pad_cache_len(c, max_len), "cross": cross_c}

            h, lc = jax.lax.scan(body, h, params["layers"])
            cache["self"] = lc["self"]
            cache["cross"] = lc["cross"]

        h = rms_norm(params["final_norm"], h[:, -1:])
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, cache

    return prefill


def _hybrid_prefill(params, cfg, h, positions, b, max_len, mesh_axes):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    rem = cfg.n_layers - n_groups * ae
    grouped = jax.tree.map(
        lambda a: a[: n_groups * ae].reshape((n_groups, ae) + a.shape[1:]),
        params["layers"],
    )
    shared = params["shared_attn"]

    def group_body(h, group_p):
        def mamba_body(hc, lp):
            st = _zero_state_mamba(cfg, b)
            hh, st = mamba2.apply_mamba2_block(lp, cfg, hc, st)
            return hh, st

        h, states = jax.lax.scan(mamba_body, h, group_p)
        h, _, c = _block_seq(shared, cfg, h, positions, collect_cache=True)
        h = _constrain(h, cfg, mesh_axes)
        return h, {"states": states, "attn": _pad_cache_len(c, max_len)}

    h, gc = jax.lax.scan(group_body, h, grouped)
    mamba_states = jax.tree.map(
        lambda a: a.reshape((n_groups * ae,) + a.shape[2:]), gc["states"]
    )
    rem_states = []
    for i in range(rem):
        lp = jax.tree.map(lambda a: a[n_groups * ae + i], params["layers"])
        st = _zero_state_mamba(cfg, b)
        h, st = mamba2.apply_mamba2_block(lp, cfg, h, st)
        rem_states.append(st)
    if rem_states:
        rem_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rem_states)
        mamba_states = jax.tree.map(
            lambda a, r: jnp.concatenate([a, r], axis=0), mamba_states, rem_stacked
        )
    return h, mamba_states, gc["attn"]


def make_decode_step(cfg: ModelConfig, mesh_axes: tuple = ()):
    """Returns fn(params, token (B,), cache, pos) -> (logits (B, V), cache)."""

    def decode(params, token, cache, pos):
        b = token.shape[0]
        h = embed_tokens(params["embed"], token[:, None])
        new_cache: dict[str, Any] = {}

        if cfg.family in ("dense", "moe"):
            if cfg.family == "moe" and cfg.first_dense_layers:
                dcs = []
                for i in range(cfg.first_dense_layers):
                    pl_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
                    lc_i = jax.tree.map(lambda a: a[i], cache["dense_layers"])
                    h, c = _block_decode(pl_i, cfg, h, lc_i, pos)
                    dcs.append(c)
                new_cache["dense_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dcs)

            def body(h, xs):
                lp, lc = xs
                h, c = _block_decode(lp, cfg, h, lc, pos)
                return h, c

            h, cs = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = cs
        elif cfg.family == "ssm":

            def body(h, xs):
                lp, st = xs
                h, st = rwkv6.apply_rwkv6_block(lp, cfg, h, st, chunked=False)
                return h, st

            h, states = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
            new_cache["layers"] = states
        elif cfg.family == "hybrid":
            h, new_cache = _hybrid_decode(params, cfg, h, cache, pos)
        elif cfg.family == "vlm":

            def body(h, xs):
                gp, sc, cc = xs

                def self_body(hc, inner):
                    lp, lc = inner
                    hh, c = _block_decode(lp, cfg, hc, lc, pos)
                    return hh, c

                h, self_cs = jax.lax.scan(self_body, h, (gp["self"], sc))
                cp = gp["cross"]
                y = attn.apply_cross_attention_decode(
                    cp["attn"], cfg, rms_norm(cp["ln1"], h), cc
                )
                h = h + y
                h = h + apply_mlp(cp["mlp"], rms_norm(cp["ln2"], h))
                return h, self_cs

            h, self_cs = jax.lax.scan(
                body, h, (params["groups"], cache["self"], cache["cross"])
            )
            new_cache = {"self": self_cs, "cross": cache["cross"]}
        elif cfg.family == "audio":

            def body(h, xs):
                lp, sc, cc = xs
                h, c = _block_decode(lp, cfg, h, sc, pos)
                y = attn.apply_cross_attention_decode(
                    lp["cross"], cfg, rms_norm(lp["ln_x"], h), cc
                )
                h = h + y
                return h, c

            h, cs = jax.lax.scan(
                body, h, (params["layers"], cache["self"], cache["cross"])
            )
            new_cache = {"self": cs, "cross": cache["cross"]}

        h = rms_norm(params["final_norm"], h)
        logits = unembed(params["embed"], h, cfg)[:, 0]
        return logits, new_cache

    return decode


def _hybrid_decode(params, cfg, h, cache, pos):
    ae = cfg.attn_every
    n_groups = cfg.n_layers // ae
    rem = cfg.n_layers - n_groups * ae
    grouped_p = jax.tree.map(
        lambda a: a[: n_groups * ae].reshape((n_groups, ae) + a.shape[1:]),
        params["layers"],
    )
    grouped_s = jax.tree.map(
        lambda a: a[: n_groups * ae].reshape((n_groups, ae) + a.shape[1:]),
        cache["mamba"],
    )
    shared = params["shared_attn"]

    def group_body(h, xs):
        gp, gs, ac = xs

        def mamba_body(hc, inner):
            lp, st = inner
            hh, st = mamba2.apply_mamba2_block(lp, cfg, hc, st, chunked=False)
            return hh, st

        h, states = jax.lax.scan(mamba_body, h, (gp, gs))
        h, c = _block_decode(shared, cfg, h, ac, pos)
        return h, {"states": states, "attn": c}

    h, gc = jax.lax.scan(group_body, h, (grouped_p, grouped_s, cache["shared"]))
    mamba_states = jax.tree.map(
        lambda a: a.reshape((n_groups * ae,) + a.shape[2:]), gc["states"]
    )
    rem_states = []
    for i in range(rem):
        li = n_groups * ae + i
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        st = jax.tree.map(lambda a: a[li], cache["mamba"])
        h, st = mamba2.apply_mamba2_block(lp, cfg, h, st, chunked=False)
        rem_states.append(st)
    if rem_states:
        rem_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *rem_states)
        mamba_states = jax.tree.map(
            lambda a, r: jnp.concatenate([a, r], axis=0), mamba_states, rem_stacked
        )
    return h, {"mamba": mamba_states, "shared": gc["attn"]}
