"""Attention variants: GQA self-attention (train / prefill / cached decode),
cross-attention (VLM, enc-dec), and MLA (DeepSeek-V3) with compressed-cache
decode (the projection-absorption trick — the KV cache stores only the
512-dim latent + shared rope key, not per-head K/V)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import (
    ShardCtx,
    apply_rope,
    dtype_of,
    init_rmsnorm,
    ninit,
    rms_norm,
    rmsnorm_specs,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    dtype = dtype_of(cfg)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d**-0.5
    p = {
        "wq": ninit(ks[0], (d, h, hd), s, dtype),
        "wk": ninit(ks[1], (d, kv, hd), s, dtype),
        "wv": ninit(ks[2], (d, kv, hd), s, dtype),
        "wo": ninit(ks[3], (h, hd, d), (h * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def attention_specs(ctx: ShardCtx, cfg: ModelConfig, cross: bool = False) -> dict:
    h_sh = ctx.heads(cfg.n_heads)
    kv_sh = ctx.heads(cfg.n_kv_heads)
    dd = ctx.data(cfg.d_model)
    p = {
        "wq": P(dd, h_sh, None),
        "wk": P(dd, kv_sh, None),
        "wv": P(dd, kv_sh, None),
        "wo": P(h_sh, None, dd),
    }
    if cfg.qkv_bias:
        p["bq"] = P(h_sh, None)
        p["bk"] = P(kv_sh, None)
        p["bv"] = P(kv_sh, None)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x, kv_src):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", kv_src, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", kv_src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _gqa_scores(q, k):
    """q: (B, L, H, hd); k: (B, S, KV, hd) -> (B, KV, G, L, S)."""
    b, l, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, l, kvh, g, hd)
    return jnp.einsum("blkgd,bskd->bkgls", qg, k) / jnp.sqrt(hd).astype(jnp.float32)


def _gqa_out(weights, v, p):
    """weights: (B, KV, G, L, S); v: (B, S, KV, hd) -> (B, L, D)."""
    b, kvh, g, l, s = weights.shape
    ctx = jnp.einsum("bkgls,bskd->blkgd", weights, v)
    ctx = ctx.reshape(b, l, kvh * g, v.shape[-1])
    return jnp.einsum("blhd,hdk->blk", ctx, p["wo"])


def _flash_scaled(q, k, v, cfg: ModelConfig, causal: bool, scale: float) -> jax.Array:
    """Pallas flash attention on (B, L, H, d)-layout tensors."""
    from repro.kernels.flash_attention import flash_attention

    interpret = jax.default_backend() == "cpu"
    qt = jnp.swapaxes(q, 1, 2)  # (B, H, L, dk)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(
        qt, kt, vt, causal, scale,
        cfg.flash_block_q, cfg.flash_block_k, interpret,
    )
    return jnp.swapaxes(out, 1, 2)


def _flash(q, k, v, cfg: ModelConfig, causal: bool) -> jax.Array:
    return _flash_scaled(q, k, v, cfg, causal, q.shape[-1] ** -0.5)


def apply_attention(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, L, D)
    positions: jax.Array,  # (B, L) or (L,)
    *,
    causal: bool = True,
    kv_src: Optional[jax.Array] = None,  # cross-attention context (B, T, D)
    use_rope: bool = True,
) -> tuple[jax.Array, dict]:
    """Full-sequence attention (train / prefill). Returns (y, cache_kv)."""
    kv_in = x if kv_src is None else kv_src
    q, k, v = _project_qkv(p, cfg, x, kv_in)
    if use_rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_impl == "flash":
        ctx = _flash(q, k, v, cfg, causal and kv_src is None)
        y = jnp.einsum("blhd,hdk->blk", ctx.astype(x.dtype), p["wo"])
        return y, {"k": k, "v": v}
    scores = _gqa_scores(q, k).astype(jnp.float32)
    if causal and kv_src is None:
        l, s = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((l, s), bool), k=s - l)
        scores = jnp.where(mask, scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = _gqa_out(weights, v, p)
    return y, {"k": k, "v": v}


def apply_attention_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # {"k": (B, S, KV, hd), "v": ...}
    pos: jax.Array,  # scalar int32 — current position
    *,
    use_rope: bool = True,
) -> tuple[jax.Array, dict]:
    """Single-token cached decode; writes the new K/V at `pos`."""
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if use_rope:
        posv = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    scores = _gqa_scores(q, k).astype(jnp.float32)  # (B, KV, G, 1, S)
    s = k.shape[1]
    valid = (jnp.arange(s) <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    y = _gqa_out(weights, v, p)
    return y, {"k": k, "v": v}


def apply_cross_attention_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, ctx_cache: dict
) -> jax.Array:
    """Decode-time cross-attention against a fixed precomputed context."""
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    scores = _gqa_scores(q, ctx_cache["k"]).astype(jnp.float32)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return _gqa_out(weights, ctx_cache["v"], p)


def kv_cache_shape(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    shp = (batch, max_len, kv, hd)
    dt = dtype_of(cfg)
    return {
        "k": jax.ShapeDtypeStruct(shp, dt),
        "v": jax.ShapeDtypeStruct(shp, dt),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ql, kl, rh = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": ninit(ks[0], (d, ql), d**-0.5, dtype),
        "q_norm": init_rmsnorm(ql, dtype),
        "wq_b": ninit(ks[1], (ql, h, hd + rh), ql**-0.5, dtype),
        "wkv_a": ninit(ks[2], (d, kl + rh), d**-0.5, dtype),
        "kv_norm": init_rmsnorm(kl, dtype),
        "wk_b": ninit(ks[3], (kl, h, hd), kl**-0.5, dtype),
        "wv_b": ninit(ks[4], (kl, h, hd), kl**-0.5, dtype),
        "wo": ninit(ks[5], (h, hd, d), (h * hd) ** -0.5, dtype),
    }


def mla_specs(ctx: ShardCtx, cfg: ModelConfig) -> dict:
    h_sh = ctx.heads(cfg.n_heads)
    dd = ctx.data(cfg.d_model)
    return {
        "wq_a": P(dd, None),
        "q_norm": rmsnorm_specs(),
        "wq_b": P(None, h_sh, None),
        "wkv_a": P(dd, None),
        "kv_norm": rmsnorm_specs(),
        "wk_b": P(None, h_sh, None),
        "wv_b": P(None, h_sh, None),
        "wo": P(h_sh, None, dd),
    }


def _mla_q(p, cfg, x, positions):
    cq = rms_norm(p["q_norm"], jnp.einsum("bld,dq->blq", x, p["wq_a"]))
    q = jnp.einsum("blq,qhk->blhk", cq, p["wq_b"])
    q_nope, q_rope = jnp.split(q, [cfg.head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(p, cfg, x, positions):
    kv = jnp.einsum("bld,dk->blk", x, p["wkv_a"])
    ckv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(p["kv_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def apply_mla(
    p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
) -> tuple[jax.Array, dict]:
    """Full-sequence MLA (train / prefill), expanded form. Returns
    (y, cache) with the COMPRESSED cache {"ckv", "krope"}."""
    hd, rh = cfg.head_dim, cfg.rope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, k_rope = _mla_latents(p, cfg, x, positions)
    k_nope = jnp.einsum("blk,khd->blhd", ckv, p["wk_b"])
    v = jnp.einsum("blk,khd->blhd", ckv, p["wv_b"])
    scale = (hd + rh) ** -0.5
    if cfg.attn_impl == "flash":
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B, L, H, hd+rh)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (rh,))],
            axis=-1,
        )
        ctx = _flash_scaled(q_full, k_full, v, cfg, True, scale)
        y = jnp.einsum("blhd,hdk->blk", ctx.astype(x.dtype), p["wo"])
        return y, {"ckv": ckv, "krope": k_rope}
    scores = (
        jnp.einsum("blhd,bshd->bhls", q_nope, k_nope)
        + jnp.einsum("blhr,bsr->bhls", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    l, s = scores.shape[-2], scores.shape[-1]
    mask = jnp.tril(jnp.ones((l, s), bool), k=s - l)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhls,bshd->blhd", w, v)
    y = jnp.einsum("blhd,hdk->blk", ctx, p["wo"])
    return y, {"ckv": ckv, "krope": k_rope}


def apply_mla_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, cache: dict, pos: jax.Array
) -> tuple[jax.Array, dict]:
    """Compressed-cache MLA decode via projection absorption: attention runs
    in the 512-dim latent space; per-head K/V are never materialized."""
    hd, rh = cfg.head_dim, cfg.rope_head_dim
    posv = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, posv)  # (B, 1, H, hd/rh)
    ckv_new, krope_new = _mla_latents(p, cfg, x, posv)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1
    )
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1
    )
    # absorb W_uk into the query: q_eff = W_uk^T q_nope  (B, 1, H, kv_lora)
    q_eff = jnp.einsum("blhd,khd->blhk", q_nope, p["wk_b"])
    scale = 1.0 / jnp.sqrt(hd + rh).astype(jnp.float32)
    scores = (
        jnp.einsum("blhk,bsk->bhls", q_eff, ckv)
        + jnp.einsum("blhr,bsr->bhls", q_rope, krope)
    ).astype(jnp.float32) * scale
    s = ckv.shape[1]
    valid = (jnp.arange(s) <= pos)[None, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhls,bsk->blhk", w, ckv)  # latent context
    v = jnp.einsum("blhk,khd->blhd", ctx, p["wv_b"])  # absorb W_uv
    y = jnp.einsum("blhd,hdk->blk", v, p["wo"])
    return y, {"ckv": ckv, "krope": krope}


def mla_cache_shape(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = dtype_of(cfg)
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
        "krope": jax.ShapeDtypeStruct((batch, max_len, cfg.rope_head_dim), dt),
    }
