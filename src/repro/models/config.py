"""Model configuration covering every assigned architecture family.

One frozen dataclass drives parameter shapes, sharding specs, and the
forward/prefill/decode programs. Families:

  dense   — llama3.2-1b, qwen2-1.5b, deepseek-7b, starcoder2-15b
  moe     — kimi-k2 (384e top-8), deepseek-v3 (MLA, 1 shared + 256 routed)
  ssm     — rwkv6-7b (attention-free, data-dependent decay)
  hybrid  — zamba2-1.2b (Mamba2 + shared attention block)
  vlm     — llama-3.2-vision-90b (interleaved cross-attention layers)
  audio   — whisper-large-v3 (encoder-decoder, mel-frame stub frontend)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert ffn width
    first_dense_layers: int = 0  # deepseek-v3: leading dense layers
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    mtp: bool = False  # multi-token-prediction auxiliary head

    # --- SSM / hybrid ---
    ssm_state: int = 0  # mamba2 state size N
    ssm_head_dim: int = 64  # P (mamba2) / wkv head dim (rwkv6)
    ssm_chunk: int = 64  # chunked-scan block length
    attn_every: int = 0  # zamba2: shared attn block after every k ssm layers
    wkv_lora: int = 64  # rwkv6 data-dependent decay LoRA rank

    # --- VLM ---
    cross_attn_every: int = 0  # every Nth layer cross-attends (vlm/audio dec)
    n_frontend_tokens: int = 0  # patches (vlm) / frames (audio) from the stub

    # --- audio enc-dec ---
    encoder_layers: int = 0

    # --- numerics / policy ---
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True  # lax.scan over homogeneous layer stacks
    fsdp: bool = False  # shard params/optimizer over the data axis
    seq_shard: bool = False  # sequence-parallel activation sharding
    attn_impl: str = "naive"  # naive | flash (Pallas, §Perf optimization)
    flash_block_q: int = 512
    flash_block_k: int = 512
    moe_impl: str = "gspmd"  # gspmd | ep_manual (shard_map EP, §Perf)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.family in ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.family == "moe":
            assert self.n_experts > 0 and self.experts_per_token > 0

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode (O(1) state): ssm + hybrid families."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            per = _rwkv6_layer_params(self)
            return embed + self.n_layers * per
        if self.family == "hybrid":
            per = _mamba2_layer_params(self)
            shared = _attn_params(self) + 2 * d * self.d_ff + d * self.d_ff
            return embed + self.n_layers * per + shared
        attn = _attn_params(self)
        ffn_dense = 3 * d * self.d_ff
        if self.family == "moe":
            ffn_moe = 3 * d * self.moe_d_ff * self.n_experts
            ffn_shared = 3 * d * self.moe_d_ff * self.n_shared_experts
            router = d * self.n_experts
            n_moe = self.n_layers - self.first_dense_layers
            body = (
                self.n_layers * attn
                + self.first_dense_layers * ffn_dense
                + n_moe * (ffn_moe + ffn_shared + router)
            )
            return embed + body
        n_cross = self.n_layers // self.cross_attn_every if self.cross_attn_every else 0
        enc = self.encoder_layers * (attn + ffn_dense) if self.encoder_layers else 0
        return embed + self.n_layers * (attn + ffn_dense) + n_cross * attn + enc

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.n_params
        d = self.d_model
        ffn_active = 3 * d * self.moe_d_ff * (
            self.experts_per_token + self.n_shared_experts
        )
        ffn_all = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
        n_moe = self.n_layers - self.first_dense_layers
        return self.n_params - n_moe * (ffn_all - ffn_active)


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.use_mla:
        rh = cfg.rope_head_dim
        return (
            d * cfg.q_lora_rank
            + cfg.q_lora_rank * h * (hd + rh)
            + d * (cfg.kv_lora_rank + rh)
            + cfg.kv_lora_rank * h * (hd + hd)
            + h * hd * d
        )
    return d * h * hd + 2 * d * kv * hd + h * hd * d


def _rwkv6_layer_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    lora = cfg.wkv_lora
    # time-mix: r,k,v,g,o projections + decay/mix LoRAs; channel-mix: 2 mats
    return 5 * d * d + 6 * 2 * d * lora + 2 * d * int(d * 3.5)


def _mamba2_layer_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner = 2 * d
    n = cfg.ssm_state
    heads = d_inner // cfg.ssm_head_dim
    return d * (2 * d_inner + 2 * n + heads) + d_inner * d + 3 * d_inner


# ---------------------------------------------------------------------------
# Input shape sets (the assignment's per-arch shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
