"""Mixture-of-Experts with sort-based capacity dispatch (Megablocks-style,
TPU-adapted): tokens are sorted by expert id, scattered into a dense
(E, C, D) buffer, processed with one batched einsum per projection (experts
sharded over the model axis = expert parallelism), and combined by gather +
weighted scatter-add. No (N, E, C) one-hot tensors (GShard) — the dispatch is
O(N·k) memory.

Used by kimi-k2 (384 routed, top-8) and deepseek-v3 (1 shared + 256 routed,
top-8). Returns the load-balancing auxiliary loss (Switch-style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx, dtype_of, init_mlp, mlp_specs, ninit, apply_mlp


def init_moe(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": ninit(ks[0], (d, e), d**-0.5, jnp.float32),
        "w_gate": ninit(ks[1], (e, d, f), d**-0.5, dtype),
        "w_up": ninit(ks[2], (e, d, f), d**-0.5, dtype),
        "w_down": ninit(ks[3], (e, f, d), f**-0.5, dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def moe_specs(ctx: ShardCtx, cfg: ModelConfig) -> dict:
    e_sh = ctx.heads(cfg.n_experts)  # experts over the model axis (EP)
    dd = ctx.data(cfg.d_model)
    p = {
        "router": P(dd, None),
        "w_gate": P(e_sh, dd, None),
        "w_up": P(e_sh, dd, None),
        "w_down": P(e_sh, None, dd),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_specs(ctx, cfg.d_model, cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, D) -> (y, aux_loss)."""
    b, l, d = x.shape
    n = b * l
    k = cfg.experts_per_token
    e = cfg.n_experts
    c = capacity(n, cfg)
    xf = x.reshape(n, d)

    # --- routing ---
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, k)  # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(0)
    assign = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(assign * me)

    # --- sort-based dispatch ---
    flat_e = expert_ids.reshape(-1)  # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(n * k, dtype=jnp.int32) - group_start
    slot = jnp.where(pos_in_e < c, pos_in_e, c)  # c -> dropped
    tok = order // k  # source token per assignment

    buf = jnp.zeros((e, c, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(xf[tok], mode="drop")

    # --- expert FFN (batched over experts; E sharded over "model") ---
    h_gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jnp.einsum("ecf,efd->ecd", h_gate * h_up, p["w_down"])

    # --- combine ---
    kept = slot < c
    slot_safe = jnp.minimum(slot, c - 1)
    out_per_assign = h[sorted_e, slot_safe]  # (N*k, D)
    gate_sorted = gate.reshape(-1)[order]
    contrib = jnp.where(
        kept[:, None], out_per_assign * gate_sorted[:, None].astype(x.dtype), 0.0
    )
    y = jnp.zeros((n, d), x.dtype).at[tok].add(contrib)

    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], xf)
    return y.reshape(b, l, d), aux


# ---------------------------------------------------------------------------
# manual expert parallelism (§Perf iteration)
# ---------------------------------------------------------------------------


def apply_moe_ep(
    p: dict, cfg: ModelConfig, x: jax.Array, axis: str = "model"
) -> tuple[jax.Array, jax.Array]:
    """Expert parallelism with explicit shard_map over the model axis.

    GSPMD partitions the sort/scatter dispatch pathologically: the
    token-assignment dimension gets replicated across the expert shards and
    the positional scatters turn into full-width u32 all-reduces (measured
    ~200TB/step HBM traffic for deepseek-v3 train_4k — see EXPERIMENTS.md
    §Perf). Here each model-rank routes the (model-replicated) token block,
    keeps only assignments that target its local experts, dispatches LOCALLY
    (unsharded scatter -> no partitioner pathology), and a single psum over
    the model axis combines expert outputs. Per-layer comm = one activation
    psum, the same as a Megatron TP all-reduce.
    """
    e = cfg.n_experts
    mesh = jax.sharding.get_abstract_mesh()
    all_axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in all_axes if a != axis)
    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def local(xb, router, w_gate, w_up, w_down, _shared):
        # xb: (B/dp, L, D) model-replicated; expert weights: local (E/m, D, F)
        rank = jax.lax.axis_index(axis)
        n_ranks = jax.lax.axis_size(axis)
        e_loc = e // n_ranks
        b, l, d = xb.shape
        n = b * l
        k = cfg.experts_per_token
        c = capacity(n, cfg)
        xf = xb.reshape(n, d)

        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_ids = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        assign = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (n * k)
        aux = e * jnp.sum(assign * me)

        # keep only assignments routed to MY experts
        flat_e = expert_ids.reshape(-1)
        mine = (flat_e >= rank * e_loc) & (flat_e < (rank + 1) * e_loc)
        local_e = jnp.where(mine, flat_e - rank * e_loc, e_loc)  # e_loc -> dropped
        order = jnp.argsort(jnp.where(mine, local_e, e_loc), stable=True)
        sorted_e = jnp.where(mine[order], local_e[order], e_loc)
        group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_in_e = jnp.arange(n * k, dtype=jnp.int32) - group_start
        slot = jnp.where((pos_in_e < c) & (sorted_e < e_loc), pos_in_e, c)
        tok = order // k

        buf = jnp.zeros((e_loc, c, d), xb.dtype)
        buf = buf.at[jnp.minimum(sorted_e, e_loc - 1), slot].set(
            jnp.where((slot < c)[:, None], xf[tok], 0.0), mode="drop"
        )
        h_gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
        h_up = jnp.einsum("ecd,edf->ecf", buf, w_up)
        h = jnp.einsum("ecf,efd->ecd", h_gate * h_up, w_down)

        kept = slot < c
        out_pa = h[jnp.minimum(sorted_e, e_loc - 1), jnp.minimum(slot, c - 1)]
        gate_sorted = gate.reshape(-1)[order]
        contrib = jnp.where(
            kept[:, None], out_pa * gate_sorted[:, None].astype(xb.dtype), 0.0
        )
        y = jnp.zeros((n, d), xb.dtype).at[tok].add(contrib)
        y = jax.lax.psum(y, axis)  # combine expert shards
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(b, l, d), aux

    x_spec = P(dp_spec, None, None)  # batch over DP, replicated over model
    in_specs = (
        x_spec,
        P(),  # router (FSDP shards gathered at the boundary)
        P(axis), P(axis), P(axis),  # expert weights: EP over the model axis
        None,
    )
    fn = jax.shard_map(
        local,
        in_specs=in_specs,
        out_specs=(x_spec, P()),
        axis_names=set(all_axes),
        check_vma=False,
    )
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], None)
    if cfg.n_shared_experts:
        # shared expert stays OUTSIDE the manual region: auto-TP shards its
        # d_ff over the model axis instead of replicating the flops 16x
        y = y + apply_mlp(p["shared"], x)
    return y, aux
