"""Transformer substrate: the embedding/generation model zoo served alongside
the Allan-Poe hybrid index (see DESIGN.md §3)."""

from repro.models.config import ModelConfig
from repro.models.transformer import (
    init_params,
    make_decode_step,
    make_forward,
    make_prefill,
    param_specs,
)

__all__ = [
    "ModelConfig",
    "init_params",
    "param_specs",
    "make_forward",
    "make_prefill",
    "make_decode_step",
]
