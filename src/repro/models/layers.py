"""Shared building blocks: norms, RoPE, MLPs, initializers, sharding helpers.

Parameters are plain nested dicts of jax.Arrays; every ``init_*`` has a
matching ``*_specs`` returning an identically-shaped tree of PartitionSpecs.
Sharding rule: a tensor dim is sharded over an axis only when divisible —
otherwise replicated (see ``shard_if``) — so architectures whose head counts
don't divide the TP axis (qwen2: 12 heads, whisper: 20) still compile on the
16-way model axis with replicated attention weights.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# mesh axis names (fixed by launch/mesh.py)
POD, DATA, MODEL = "pod", "data", "model"
DP = (POD, DATA)  # data-parallel axes (pod may be absent; specs still valid)


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def shard_if(dim: int, size: int, axis: str) -> Optional[str]:
    """Shard `dim` over `axis` (of `size` devices) only when divisible."""
    return axis if dim % size == 0 and dim >= size else None


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-dependent context for building PartitionSpec trees."""

    model_size: int = 16
    fsdp: bool = False

    def heads(self, n: int) -> Optional[str]:
        return shard_if(n, self.model_size, MODEL)

    def ff(self, n: int) -> Optional[str]:
        return shard_if(n, self.model_size, MODEL)

    def data(self, n: int) -> Optional[str]:
        # FSDP shards a replicated-over-model dim over the data axis
        return DATA if self.fsdp and n % 16 == 0 else None


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def ninit(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zinit(shape, dtype) -> jax.Array:
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_specs() -> dict:
    return {"scale": P(None)}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., L, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d**-0.5
    s_out = d_ff**-0.5
    return {
        "w_gate": ninit(k1, (d, d_ff), s_in, dtype),
        "w_up": ninit(k2, (d, d_ff), s_in, dtype),
        "w_down": ninit(k3, (d_ff, d), s_out, dtype),
    }


def mlp_specs(ctx: ShardCtx, d: int, d_ff: int) -> dict:
    m = ctx.ff(d_ff)
    dd = ctx.data(d)
    return {
        "w_gate": P(dd, m),
        "w_up": P(dd, m),
        "w_down": P(m, dd),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w_gate"]))
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", gate * up, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": ninit(k1, (cfg.vocab, cfg.d_model), 0.02, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = ninit(k2, (cfg.d_model, cfg.vocab), cfg.d_model**-0.5, dtype)
    return p


def embed_specs(ctx: ShardCtx, cfg: ModelConfig) -> dict:
    v_shard = ctx.heads(cfg.vocab)  # vocab over model axis
    p = {"tok": P(v_shard, ctx.data(cfg.d_model))}
    if not cfg.tie_embeddings:
        p["head"] = P(ctx.data(cfg.d_model), v_shard)
    return p


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return jnp.einsum("...d,dv->...v", h, w)
