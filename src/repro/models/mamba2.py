"""Mamba2 (SSD — state-space duality) block, used by zamba2-1.2b.

Chunked SSD algorithm (Dao & Gu 2024, "ssd_minimal" form): within-chunk
contributions are an MXU matmul against the masked decay kernel; cross-chunk
state is a short scan over chunks. Scalar-per-head decay makes the log-space
factorization exact (exponent differences are clamped only on masked
entries). Single-token decode keeps (conv_state, ssm_state) and is O(1) in
sequence length — this is what makes the long_500k cell runnable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import ShardCtx, dtype_of, init_rmsnorm, ninit, rms_norm, rmsnorm_specs

CONV_W = 4  # causal depthwise conv window


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    n = cfg.ssm_state
    p = cfg.ssm_head_dim
    h = d_inner // p
    conv_ch = d_inner + 2 * n
    return d_inner, n, p, h, conv_ch


def init_mamba2_block(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    d = cfg.d_model
    d_inner, n, pdim, h, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    s = d**-0.5
    return {
        "norm": init_rmsnorm(d, dtype),
        "in_proj": ninit(ks[0], (d, 2 * d_inner + 2 * n + h), s, dtype),
        "conv_w": ninit(ks[1], (CONV_W, conv_ch), 0.5, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": init_rmsnorm(d_inner, dtype),
        "out_proj": ninit(ks[2], (d_inner, d), d_inner**-0.5, dtype),
    }


def mamba2_block_specs(ctx: ShardCtx, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, n, pdim, h, conv_ch = _dims(cfg)
    dd = ctx.data(d)
    return {
        "norm": rmsnorm_specs(),
        "in_proj": P(dd, None),
        "conv_w": P(None, None),
        "conv_b": P(None),
        "a_log": P(None),
        "d_skip": P(None),
        "dt_bias": P(None),
        "gate_norm": rmsnorm_specs(),
        "out_proj": P(None, dd),
    }


def _causal_conv_seq(w, b, x, init_state):
    """Depthwise causal conv. x: (B, L, C); init_state: (B, CONV_W-1, C).

    One depthwise conv instruction (one read of x) instead of CONV_W shifted
    full-tensor slices — the §Perf iteration that removed the dominant
    HBM-traffic term of the hybrid/ssm train cells (see EXPERIMENTS.md)."""
    padded = jnp.concatenate([init_state, x], axis=1)
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        padded,
        w[:, None, :],  # (W, 1, C) depthwise filters
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c,
    )
    new_state = padded[:, -(CONV_W - 1) :]
    return jax.nn.silu(out + b), new_state


def _causal_conv_step(w, b, x1, state):
    """x1: (B, C); state: (B, CONV_W-1, C)."""
    window = jnp.concatenate([state, x1[:, None]], axis=1)  # (B, CONV_W, C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return jax.nn.silu(out + b), window[:, 1:]


def ssd_chunked(x, dt, a_neg, bmat, cmat, s0, chunk: int):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H); a_neg: (H,) negative decay rates;
    bmat/cmat: (B, L, N); s0: (B, H, P, N). Returns (y, s_final).
    """
    f32 = jnp.float32
    b, l, h, pdim = x.shape
    n = bmat.shape[-1]
    assert l % chunk == 0
    nc = l // chunk
    x = x.astype(f32).reshape(b, nc, chunk, h, pdim)
    dt = dt.astype(f32).reshape(b, nc, chunk, h)
    bmat = bmat.astype(f32).reshape(b, nc, chunk, n)
    cmat = cmat.astype(f32).reshape(b, nc, chunk, n)

    loga = dt * a_neg[None, None, None]  # (b, nc, T, h), <= 0
    lc = jnp.cumsum(loga, axis=2)  # inclusive
    xdt = x * dt[..., None]

    # intra-chunk: M[t, s] = (C_t . B_s) * exp(lc_t - lc_s), s <= t
    cb = jnp.einsum("bctn,bcsn->bcts", cmat, bmat)
    ldiff = lc[:, :, :, None, :] - lc[:, :, None, :, :]  # (b, nc, t, s, h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(jnp.minimum(ldiff, 0.0)) * mask[None, None, :, :, None]
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp", cb, decay, xdt)

    # chunk states: S_c = sum_s exp(lc_T - lc_s) B_s (x dt)_s
    total = lc[:, :, -1]  # (b, nc, h)
    k_decay = jnp.exp(jnp.minimum(total[:, :, None] - lc, 0.0))  # (b, nc, T, h)
    chunk_state = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bmat, k_decay, xdt)

    def carry(s, inp):
        dc, cs = inp  # (b, h), (b, h, p, n)
        s_new = jnp.exp(dc)[..., None, None] * s + cs
        return s_new, s

    s_fin, s_prev = jax.lax.scan(
        carry,
        s0.astype(f32),
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)),
    )
    s_prev = jnp.moveaxis(s_prev, 0, 1)  # state before each chunk

    # inclusive decay: h_t applies a_t to the carried state before C_t reads it
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp", cmat, jnp.exp(lc), s_prev)
    y = (y_intra + y_inter).reshape(b, l, h, pdim)
    return y, s_fin


def ssd_scan(x, dt, a_neg, bmat, cmat, s0):
    """Exact per-step oracle."""
    f32 = jnp.float32

    def step(s, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        a_t = jnp.exp(dt_t * a_neg[None])  # (B,H)
        s_new = a_t[..., None, None] * s + jnp.einsum(
            "bhp,bn->bhpn", x_t * dt_t[..., None], b_t
        )
        y = jnp.einsum("bhpn,bn->bhp", s_new, c_t)
        return s_new, y

    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(bmat.astype(f32), 1, 0),
        jnp.moveaxis(cmat.astype(f32), 1, 0),
    )
    s_fin, ys = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def _split_proj(cfg: ModelConfig, proj):
    d_inner, n, pdim, h, _ = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def apply_mamba2_block(
    p: dict,
    cfg: ModelConfig,
    x_in: jax.Array,  # (B, L, D)
    state: dict,  # {"conv": (B, CONV_W-1, C), "ssm": (B, H, P, N)}
    *,
    chunked: bool = True,
) -> tuple[jax.Array, dict]:
    d_inner, n, pdim, h, conv_ch = _dims(cfg)
    b, l, _ = x_in.shape
    xn = rms_norm(p["norm"], x_in)
    proj = jnp.einsum("bld,de->ble", xn, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv_seq(p["conv_w"], p["conv_b"], xbc, state["conv"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, l, h, pdim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    a_neg = -jnp.exp(p["a_log"])
    if chunked and l % cfg.ssm_chunk == 0 and l > 1:
        y, s_fin = ssd_chunked(xs, dt, a_neg, bmat, cmat, state["ssm"], cfg.ssm_chunk)
    else:
        y, s_fin = ssd_scan(xs, dt, a_neg, bmat, cmat, state["ssm"])
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, l, d_inner).astype(x_in.dtype)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z))
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    return x_in + out, {"conv": conv_state, "ssm": s_fin}


def mamba2_state_shape(cfg: ModelConfig, batch: int) -> dict:
    d_inner, n, pdim, h, conv_ch = _dims(cfg)
    dt = dtype_of(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, CONV_W - 1, conv_ch), dt),
        "ssm": jax.ShapeDtypeStruct((batch, h, pdim, n), jnp.float32),
    }
