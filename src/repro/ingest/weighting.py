"""BM25 / TF-IDF term weighting emitted directly as fixed-nnz ELL vectors.

Corpus statistics (document frequency per hashed id, average document
length) are computed in ONE pass over the fitted corpus and then frozen —
the streaming-insert contract: documents ingested later are weighted with
the *fitted* statistics, so already-indexed vectors never change value and
sealed-segment executables (keyed on shapes, fed by values) stay warm.
"Balancing the Blend" (arXiv:2508.01405) is the motivation for carrying an
honest lexical weighting next to the dense path rather than a 0/1 term mask.

Output layout matches ``core.usms.SparseVec`` exactly: top-P terms per row
by weight, ids unique per row (hash collisions merged upstream), PAD_IDX in
unused id slots, 0.0 in unused value slots.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.core.usms import PAD_IDX, SparseVec


@dataclasses.dataclass
class CorpusStats:
    """Frozen one-pass corpus statistics for both hashed id spaces."""

    n_docs: int
    avg_dl: float  # average analyzed-token count per document
    df_learned: np.ndarray  # (vocab_size,) int32 document frequency
    df_lexical: np.ndarray  # (lexical_vocab_size,) int32

    @classmethod
    def from_docs(
        cls,
        learned_counts: Iterable[dict[int, int]],
        lexical_counts: Iterable[dict[int, int]],
        doc_lengths: Iterable[int],
        vocab_size: int,
        lexical_vocab_size: int,
    ) -> "CorpusStats":
        df_l = np.zeros(vocab_size, np.int32)
        df_f = np.zeros(lexical_vocab_size, np.int32)
        n = 0
        total_dl = 0
        for lc, fc, dl in zip(learned_counts, lexical_counts, doc_lengths):
            for i in lc:
                df_l[i] += 1
            for i in fc:
                df_f[i] += 1
            n += 1
            total_dl += dl
        return cls(
            n_docs=n,
            avg_dl=total_dl / max(n, 1),
            df_learned=df_l,
            df_lexical=df_f,
        )


def tfidf_weights(counts: dict[int, int], stats: CorpusStats) -> dict[int, float]:
    """Sublinear TF * smoothed IDF over the learned hashed vocab (the
    SPLADE-analogue magnitude profile: frequent terms -> small weights)."""
    n = max(stats.n_docs, 1)
    out = {}
    for i, tf in counts.items():
        idf = math.log((1.0 + n) / (1.0 + float(stats.df_learned[i]))) + 1.0
        out[i] = (1.0 + math.log(tf)) * idf
    return out


def bm25_weights(
    counts: dict[int, int],
    dl: int,
    stats: CorpusStats,
    k1: float = 1.2,
    b: float = 0.75,
) -> dict[int, float]:
    """Okapi BM25 over the lexical hashed vocab. ``dl`` is the document's
    analyzed length; df/avg_dl come from the FROZEN stats."""
    n = max(stats.n_docs, 1)
    norm = k1 * (1.0 - b + b * dl / max(stats.avg_dl, 1e-9))
    out = {}
    for i, tf in counts.items():
        df = float(stats.df_lexical[i])
        idf = math.log(1.0 + (n - df + 0.5) / (df + 0.5))
        out[i] = max(idf, 1e-6) * tf * (k1 + 1.0) / (tf + norm)
    return out


def to_ell(rows: list[dict[int, float]], cap: int, normalize: bool = True) -> SparseVec:
    """Pack per-row {id: weight} dicts into a fixed-nnz ELL ``SparseVec``:
    top-``cap`` ids by weight, PAD_IDX/0.0 in unused slots, ids unique per
    row (guaranteed by the dict). ``normalize`` L2-scales each row so the
    three USMS paths contribute on comparable magnitudes and the query-time
    path weights mean what they say (the blend-balancing concern of
    arXiv:2508.01405 — raw BM25 magnitudes would drown a unit-norm dense
    path ~10x)."""
    n = len(rows)
    idx = np.full((n, cap), PAD_IDX, np.int32)
    val = np.zeros((n, cap), np.float32)
    for r, weights in enumerate(rows):
        if not weights:
            continue
        items = sorted(weights.items(), key=lambda kv: (-kv[1], kv[0]))[:cap]
        for c, (i, w) in enumerate(items):
            if w <= 0.0:
                break
            idx[r, c] = i
            val[r, c] = w
    if normalize:
        norms = np.maximum(np.linalg.norm(val, axis=-1, keepdims=True), 1e-9)
        val = (val / norms).astype(np.float32)
    return SparseVec(idx, val)


def hashed_dense_embedding(
    rows: list[dict[int, float]],
    projection: np.ndarray,  # (vocab_size, d) float32
) -> np.ndarray:
    """Deterministic dense embedding: weighted sum of per-term random
    projections, unit-normalized — the offline-friendly stand-in for a
    neural embedder (collisions and the low dimension supply realistic
    semantic blur; exact term evidence lives in the sparse paths)."""
    d = projection.shape[1]
    out = np.zeros((len(rows), d), np.float32)
    for r, weights in enumerate(rows):
        for i, w in weights.items():
            out[r] += w * projection[i]
    norms = np.maximum(np.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
    return (out / norms).astype(np.float32)


def make_projection(vocab_size: int, d: int, seed: int) -> np.ndarray:
    """The (vocab_size, d) token projection table, reproducible from its
    seed (persistence stores the seed, never the 8MB table)."""
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((vocab_size, d)) / np.sqrt(d)).astype(np.float32)
