"""Hashed-vocab text analyzer: raw strings -> shape-stable term ids.

The index's sparse paths (learned + lexical) need *fixed* id spaces so every
``SparseVec`` stays ELL shape-stable across streaming inserts — a growing
string vocabulary would change array widths and evict compiled executables.
Feature hashing (Weinberger et al.; what Vowpal Wabbit and SEISMIC-style
pipelines ship) gives that for free: a term's id is a stable 64-bit FNV-1a
hash folded into a fixed ``vocab_size``, so any document ever seen maps into
the same id space with zero vocabulary state. Collisions merge term counts,
which BM25/TF-IDF tolerate gracefully at the vocab sizes used here.

Two id spaces are derived from the same token stream:

  * ``learned_id`` — the big hashed vocab (SPLADE-analogue learned-sparse
    path, ``FusedVectors.learned``);
  * ``lexical_id`` — a smaller keyword vocab (BM25/full-text path,
    ``FusedVectors.lexical``) whose ids double as the keyword set K(·) used
    by ``pruning.keyword_flags`` and keyword-constrained search.

Analysis is lowercase + stopword removal + optional char n-grams; it is a
pure function of (text, config) — the determinism the round-trip tests and
the frozen-corpus-stats streaming contract both rely on.
"""

from __future__ import annotations

import dataclasses
import functools
import re

# a compact English stopword list (function words only — deliberately small
# so domain terms are never swallowed)
STOPWORDS = frozenset(
    """a an and are as at be been but by for from had has have he her his i if
    in into is it its me my nor not of on or our she so that the their them
    then there these they this to was we were what when where which who will
    with you your""".split()
)

_TOKEN_RE = re.compile(r"[A-Za-z][A-Za-z']*|[0-9]+")
_QUOTED_RE = re.compile(r'"([^"]+)"')

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a(s: str) -> int:
    """Stable 64-bit FNV-1a hash (platform/process independent, unlike
    Python's salted ``hash``)."""
    h = _FNV_OFFSET
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return h


@dataclasses.dataclass(frozen=True)
class AnalyzerConfig:
    vocab_size: int = 32768  # learned-sparse hashed vocab
    lexical_vocab_size: int = 8192  # keyword/full-text hashed vocab
    lowercase: bool = True
    min_token_len: int = 2
    char_ngrams: int = 0  # 0 or 1 = off; n >= 2 also emits "#<gram>" n-grams
    use_stopwords: bool = True
    extra_stopwords: tuple[str, ...] = ()

    def stopword_set(self) -> frozenset:
        return _stopword_set(self.use_stopwords, self.extra_stopwords)


@functools.lru_cache(maxsize=64)
def _stopword_set(use_stopwords: bool, extra: tuple[str, ...]) -> frozenset:
    # cached: tokenize() runs once per document on the ingestion hot path
    base = STOPWORDS if use_stopwords else frozenset()
    return base | frozenset(extra)


def raw_tokens(text: str) -> list[str]:
    """Case-preserving word tokens (the entity extractor's view)."""
    return _TOKEN_RE.findall(text)


def tokenize(text: str, cfg: AnalyzerConfig) -> list[str]:
    """Analyzed terms: lowercased, stopword-filtered, length-filtered, plus
    optional char n-grams (prefixed ``#`` so they never collide with words
    at the string level)."""
    stop = cfg.stopword_set()
    out: list[str] = []
    for tok in _TOKEN_RE.findall(text):
        if cfg.lowercase:
            tok = tok.lower()
        if len(tok) < cfg.min_token_len or tok in stop:
            continue
        out.append(tok)
        if cfg.char_ngrams > 1 and len(tok) > cfg.char_ngrams:
            n = cfg.char_ngrams
            out.extend(f"#{tok[i:i + n]}" for i in range(len(tok) - n + 1))
    return out


def learned_id(term: str, cfg: AnalyzerConfig) -> int:
    return fnv1a(term) % cfg.vocab_size


def lexical_id(term: str, cfg: AnalyzerConfig) -> int:
    # salt the lexical space so the two hashed vocabs fold independently
    return fnv1a("kw\x00" + term) % cfg.lexical_vocab_size


def term_counts(terms: list[str], id_fn, cfg: AnalyzerConfig) -> dict[int, int]:
    """term list -> {hashed id: count}; hash collisions merge counts, so ids
    are unique per document by construction (the ELL row invariant)."""
    counts: dict[int, int] = {}
    for t in terms:
        i = id_fn(t, cfg)
        counts[i] = counts.get(i, 0) + 1
    return counts


def quoted_phrases(text: str) -> list[str]:
    """Phrases the user put in double quotes — the analyzer's convention for
    *required* keywords (query side only)."""
    return _QUOTED_RE.findall(text)
