"""Corpus-level ingestion: raw documents -> everything the index consumes.

``IngestPipeline`` batches documents through the analyzer, the BM25/TF-IDF
weighting, and the entity extractor, producing in one fitting pass:

  * ``FusedVectors`` — hashed-projection dense + TF-IDF learned-sparse +
    BM25 lexical ELL vectors (the lexical ids double as the keyword set
    K(·) consumed by keyword edges and keyword-constrained search);
  * ``doc_entities`` (N, Ed) + ``KnowledgeGraph``-compatible (s, r, t)
    triplets for ``logical_edges.build_logical_edges``;
  * frozen ``CorpusStats`` (df, avg doc length) + frozen ``EntityVocab``.

After ``fit`` the statistics are FROZEN: ``encode_docs``/``encode_queries``
weight new text with the fitted df/avg_dl and only recognize fitted
entities. That is the streaming contract — vectors of already-indexed
documents never change value, inserts through ``SegmentRouter.insert`` stay
pure appends, and sealed-segment executables stay warm (DESIGN.md §7).

Query side: the SAME tokenizer produces the query ``SparseVec`` pair,
double-quoted phrases become *required* keywords, and capitalized spans
matched against the frozen entity vocab become query entities — the three
operands ``search``/``HybridSearchService.search`` take.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
from typing import Optional, Sequence

import numpy as np

from repro.core.build_pipeline import build_index
from repro.core.index import BuildConfig, HybridIndex
from repro.core.usms import PAD_IDX, FusedVectors
from repro.data.corpus import KnowledgeGraph
from repro.ingest.analyzer import (
    AnalyzerConfig,
    learned_id,
    lexical_id,
    quoted_phrases,
    term_counts,
    tokenize,
)
from repro.ingest.entities import (
    EntityVocab,
    cooccurrence_triplets,
    doc_entity_ids,
    extract_entity_spans,
)
from repro.ingest.weighting import (
    CorpusStats,
    bm25_weights,
    hashed_dense_embedding,
    make_projection,
    tfidf_weights,
    to_ell,
)


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    analyzer: AnalyzerConfig = AnalyzerConfig()
    d_dense: int = 64
    nnz_learned: int = 32  # doc-side ELL caps (top-P terms per doc)
    nnz_lexical: int = 16
    nnz_query_learned: int = 16
    nnz_query_lexical: int = 8
    query_keyword_cap: int = 4  # required-keyword slots per query
    query_entity_cap: int = 2
    max_entities: int = 512
    entities_per_doc: int = 4
    min_cooc: int = 2  # docs an entity pair must share to earn a triplet
    normalize_sparse: bool = True  # L2-balance sparse rows against dense
    embed_seed: int = 0
    gazetteer: tuple[str, ...] = ()


@dataclasses.dataclass
class IngestedCorpus:
    """Fit output: exactly what ``build_index``/``build_segmented_index``
    consume, plus the KG for the router."""

    docs: FusedVectors
    doc_entities: np.ndarray  # (N, Ed) int32 PAD-padded
    kg: KnowledgeGraph
    doc_lengths: np.ndarray  # (N,) analyzed token counts (diagnostics)

    @property
    def n_docs(self) -> int:
        return self.docs.dense.shape[0]


@dataclasses.dataclass
class EncodedQueries:
    """Query-side encoding: the three operands the search path takes."""

    vectors: FusedVectors
    keywords: np.ndarray  # (B, Kw) required keyword ids, PAD-padded
    entities: np.ndarray  # (B, Eq) entity ids, PAD-padded


def adaptive_fusion_for(enc: EncodedQueries, *, stats=None):
    """Per-query ``FusionSpec`` from an encoded query batch — the ingest
    side of the adaptive fusion selector (``core.fusion.adaptive_fusion``):
    required-keyword count, live lexical nnz, and entity presence pick the
    mode and weights per row. Pass a service's running ``PathStats`` to pin
    normalization; otherwise it resolves downstream."""
    from repro.core.fusion import adaptive_fusion, query_nnz

    return adaptive_fusion(
        enc.keywords, enc.entities, query_nnz(enc.vectors), stats=stats
    )


class NotFittedError(RuntimeError):
    pass


class IngestPipeline:
    """One-pass fit, frozen-stats encode, and index assembly."""

    def __init__(self, config: Optional[IngestConfig] = None):
        self.config = config or IngestConfig()
        self.stats: Optional[CorpusStats] = None
        self.entity_vocab: Optional[EntityVocab] = None
        self.n_triplets: int = 0  # 0 => indexes built from this fit carry no KG
        self._projection: Optional[np.ndarray] = None

    # -- fitting ------------------------------------------------------------

    @property
    def fitted(self) -> bool:
        return self.stats is not None

    def _require_fitted(self):
        if not self.fitted:
            raise NotFittedError(
                "IngestPipeline.fit(texts) must run before encoding: the "
                "frozen corpus stats (df, avg_dl) and entity vocab are what "
                "keep streamed vectors consistent with the sealed index"
            )

    @property
    def projection(self) -> np.ndarray:
        if self._projection is None:
            self._projection = make_projection(
                self.config.analyzer.vocab_size, self.config.d_dense,
                self.config.embed_seed,
            )
        return self._projection

    def _check_dense(
        self, dense_vectors, n: int
    ) -> Optional[np.ndarray]:
        """Validate caller-supplied embeddings (the embedder plug-in point:
        any real model's vectors replace the hashed-projection stub)."""
        if dense_vectors is None:
            return None
        dense = np.asarray(dense_vectors, np.float32)
        if dense.shape != (n, self.config.d_dense):
            raise ValueError(
                f"dense_vectors must be ({n}, {self.config.d_dense}) to "
                f"match the document count and IngestConfig.d_dense; got "
                f"{dense.shape}"
            )
        return dense

    def fit(
        self, texts: Sequence[str], *, dense_vectors=None
    ) -> IngestedCorpus:
        """One pass over the corpus: analyze, accumulate df/avg_dl, build
        the entity vocab + co-occurrence triplets, then encode every doc
        with the just-frozen statistics. ``dense_vectors`` (N, d_dense)
        supplies precomputed embeddings in place of the hashed-projection
        stub — queries and later inserts must then come from the SAME
        embedder."""
        if self.fitted:
            raise RuntimeError(
                "pipeline already fitted; stats are frozen — use "
                "encode_docs() for new documents or a fresh pipeline to refit"
            )
        cfg = self.config
        acfg = cfg.analyzer
        learned, lexical, lengths = self._analyze(texts)
        self.stats = CorpusStats.from_docs(
            learned, lexical, lengths, acfg.vocab_size, acfg.lexical_vocab_size
        )

        from collections import Counter

        spans = [
            extract_entity_spans(t, gazetteer=cfg.gazetteer or None)
            for t in texts
        ]
        self.entity_vocab = EntityVocab.build(
            Counter(s for doc in spans for s in doc), cfg.max_entities
        )
        doc_ents = doc_entity_ids(spans, self.entity_vocab, cfg.entities_per_doc)
        triplets = cooccurrence_triplets(
            doc_ents, len(self.entity_vocab), cfg.min_cooc
        )
        self.n_triplets = int(len(triplets))
        kg = KnowledgeGraph(triplets, n_entities=max(len(self.entity_vocab), 1))

        docs = self._encode_counts(
            learned, lexical, lengths, cfg.nnz_learned, cfg.nnz_lexical,
            dense=self._check_dense(dense_vectors, len(texts)),
        )
        return IngestedCorpus(
            docs=docs,
            doc_entities=doc_ents,
            kg=kg,
            doc_lengths=np.asarray(lengths, np.int32),
        )

    # -- frozen-stats encoding ----------------------------------------------

    def _analyze(self, texts: Sequence[str]):
        """The one analysis path (docs AND queries): tokenize once, fold
        into both hashed id spaces, keep analyzed lengths."""
        acfg = self.config.analyzer
        analyzed = [tokenize(t, acfg) for t in texts]
        return (
            [term_counts(a, learned_id, acfg) for a in analyzed],
            [term_counts(a, lexical_id, acfg) for a in analyzed],
            [len(a) for a in analyzed],
        )

    def _encode_counts(
        self, learned, lexical, lengths, nnz_l, nnz_f, *, dense=None
    ) -> FusedVectors:
        tfidf_rows = [tfidf_weights(c, self.stats) for c in learned]
        bm25_rows = [
            bm25_weights(c, dl, self.stats) for c, dl in zip(lexical, lengths)
        ]
        if dense is None:  # the hashed-projection stub is only the fallback
            dense = hashed_dense_embedding(tfidf_rows, self.projection)
        norm = self.config.normalize_sparse
        return FusedVectors(
            dense,
            to_ell(tfidf_rows, nnz_l, normalize=norm),
            to_ell(bm25_rows, nnz_f, normalize=norm),
        )

    def encode_docs(
        self, texts: Sequence[str], *, dense_vectors=None
    ) -> tuple[FusedVectors, np.ndarray]:
        """Encode new documents with the FROZEN stats (streaming path).
        Entities unseen at fit time map to PAD (dropped until a refit).
        ``dense_vectors`` (N, d_dense) plugs in a real embedder's vectors
        for these docs (use the same embedder the index was built with)."""
        self._require_fitted()
        cfg = self.config
        learned, lexical, lengths = self._analyze(texts)
        docs = self._encode_counts(
            learned, lexical, lengths, cfg.nnz_learned, cfg.nnz_lexical,
            dense=self._check_dense(dense_vectors, len(texts)),
        )
        spans = [
            extract_entity_spans(t, gazetteer=cfg.gazetteer or None)
            for t in texts
        ]
        ents = doc_entity_ids(spans, self.entity_vocab, cfg.entities_per_doc)
        return docs, ents

    def encode_queries(
        self, texts: Sequence[str], *, dense_vectors=None
    ) -> EncodedQueries:
        """Same tokenizer on the query side: TF-IDF/BM25 query vectors,
        double-quoted phrases -> required keywords, capitalized spans
        matched against the frozen vocab -> query entities.

        Keyword semantics: a doc's keyword set K(doc) is its TOP-
        ``nnz_lexical`` BM25 terms (the fixed-nnz ELL contract), not its
        full term set — a required keyword only matches docs where the term
        ranks among their strongest; quote *distinctive* terms. Raising
        ``IngestConfig.nnz_lexical`` widens the set at index-build time."""
        self._require_fitted()
        cfg = self.config
        acfg = cfg.analyzer
        learned, lexical, lengths = self._analyze(texts)
        vectors = self._encode_counts(
            learned, lexical, lengths, cfg.nnz_query_learned, cfg.nnz_query_lexical,
            dense=self._check_dense(dense_vectors, len(texts)),
        )

        b = len(texts)
        kw = np.full((b, max(cfg.query_keyword_cap, 1)), PAD_IDX, np.int32)
        en = np.full((b, max(cfg.query_entity_cap, 1)), PAD_IDX, np.int32)
        for i, text in enumerate(texts):
            req: list[int] = []
            for phrase in quoted_phrases(text):
                for term in tokenize(phrase, acfg):
                    tid = lexical_id(term, acfg)
                    if tid not in req:
                        req.append(tid)
            kw[i, : len(req[: cfg.query_keyword_cap])] = req[: cfg.query_keyword_cap]
            ents: list[int] = []
            for span in extract_entity_spans(
                text, gazetteer=cfg.gazetteer or None
            ):
                e = self.entity_vocab.lookup(span)
                if e != PAD_IDX and e not in ents:
                    ents.append(e)
            en[i, : len(ents[: cfg.query_entity_cap])] = ents[: cfg.query_entity_cap]
        return EncodedQueries(vectors=vectors, keywords=kw, entities=en)

    # -- index assembly -----------------------------------------------------

    def _kg_kwargs(self, ingested: IngestedCorpus) -> dict:
        if len(ingested.kg.triplets) == 0:
            return {}
        return dict(
            kg_triplets=ingested.kg.triplets,
            doc_entities=ingested.doc_entities,
            n_entities=ingested.kg.n_entities,
        )

    def build(
        self,
        ingested: IngestedCorpus,
        build_cfg: Optional[BuildConfig] = None,
        *,
        key=None,
    ) -> HybridIndex:
        """Hand the fitted corpus to ``build_index`` (Algorithm 1)."""
        return build_index(
            ingested.docs, build_cfg or BuildConfig(), key=key,
            **self._kg_kwargs(ingested),
        )

    def build_sharded(
        self,
        ingested: IngestedCorpus,
        n_segments: int,
        build_cfg: Optional[BuildConfig] = None,
        *,
        mesh=None,
        key=None,
    ):
        """Segment-sharded build (``SegmentedIndex`` for the serving layer):
        with a ``mesh``, every segment builds in parallel across the devices
        (``build_index_sharded``); without one, the same per-segment program
        runs sequentially (``build_segmented_index``)."""
        from repro.core.distributed import (
            build_index_sharded,
            build_segmented_index,
        )

        if mesh is not None:
            return build_index_sharded(
                ingested.docs, n_segments, build_cfg or BuildConfig(),
                mesh=mesh, key=key, **self._kg_kwargs(ingested),
            )
        return build_segmented_index(
            ingested.docs, n_segments, build_cfg or BuildConfig(), key=key,
            **self._kg_kwargs(ingested),
        )

    def stream_into(
        self,
        target,
        texts: Sequence[str],
        *,
        key=None,
        with_entities: Optional[bool] = None,
        dense_vectors=None,
    ) -> int:
        """Streaming ingestion: encode ``texts`` with the frozen stats and
        insert them through ``target.insert`` (a ``HybridSearchService`` or
        ``SegmentRouter``). Entities ride along exactly when the fit
        produced triplets — the same condition under which ``build``/
        ``build_sharded`` gave the index a KG (and the router its entity
        width); a triplet-less fit built a KG-less index whose inserts must
        not carry entity rows. Override with ``with_entities``. Pass
        ``dense_vectors`` (N, d_dense) when the index was built from a real
        embedder rather than the hashed stub. Returns the target's new
        snapshot version."""
        self._require_fitted()
        docs, ents = self.encode_docs(texts, dense_vectors=dense_vectors)
        if with_entities is None:
            with_entities = self.n_triplets > 0
        kwargs = {"new_doc_entities": ents} if with_entities else {}
        return target.insert(docs, key=key, **kwargs)

    # -- persistence (the ingestion side of save_index/load_index) ----------

    MANIFEST = "ingest_manifest.json"
    ARRAYS = "ingest_arrays.npz"

    @staticmethod
    def _old_prefix(directory: pathlib.Path) -> str:
        # recovery copies are namespaced per target directory, so sibling
        # ingest dirs under one parent can never clean up or recover each
        # other's copies
        return f".old_{directory.name}_"

    def save(self, directory: str | os.PathLike) -> None:
        """Vocab/corpus-stats manifest written crash-safely (tmp dir +
        rename, with any previous manifest renamed aside rather than
        deleted, so no failure window destroys the only copy)."""
        self._require_fitted()
        directory = pathlib.Path(directory)
        directory.parent.mkdir(parents=True, exist_ok=True)
        tmp = pathlib.Path(
            tempfile.mkdtemp(dir=directory.parent, prefix=".tmp_ingest_")
        )
        cfg = dataclasses.asdict(self.config)
        manifest = {
            "config": cfg,
            "stats": {"n_docs": self.stats.n_docs, "avg_dl": self.stats.avg_dl},
            "entity_names": list(self.entity_vocab.names),
            "n_triplets": self.n_triplets,
        }
        (tmp / self.MANIFEST).write_text(json.dumps(manifest))
        np.savez(
            tmp / self.ARRAYS,
            df_learned=self.stats.df_learned,
            df_lexical=self.stats.df_lexical,
        )
        # crash safety: the old manifest is renamed aside (never deleted in
        # place) before the new one swings in, and ``load`` falls back to
        # the newest ``.old_ingest_*`` sibling — so a crash at ANY point
        # leaves a loadable copy (old or new)
        old = None
        if directory.exists():
            old = pathlib.Path(
                tempfile.mkdtemp(
                    dir=directory.parent, prefix=self._old_prefix(directory)
                )
            )
            os.rmdir(old)
            os.rename(directory, old)
        os.rename(tmp, directory)
        # clean our renamed-aside copy AND any stale one a crashed earlier
        # save of THIS directory left behind — a successful save means the
        # committed copy at ``directory`` supersedes every recovery copy
        for stale in directory.parent.glob(self._old_prefix(directory) + "*"):
            shutil.rmtree(stale, ignore_errors=True)

    @classmethod
    def load(cls, directory: str | os.PathLike) -> "IngestPipeline":
        directory = pathlib.Path(directory)
        if not (directory / cls.MANIFEST).exists():
            # a save crashed between its two renames: the committed copy
            # lives in the newest renamed-aside copy OF THIS directory
            olds = sorted(
                (d for d in directory.parent.glob(cls._old_prefix(directory) + "*")
                 if (d / cls.MANIFEST).exists()),
                key=lambda d: d.stat().st_mtime,
            )
            if not olds:
                raise FileNotFoundError(
                    f"no ingest manifest at {directory} (and no "
                    f"renamed-aside copy to recover)"
                )
            directory = olds[-1]
        manifest = json.loads((directory / cls.MANIFEST).read_text())
        cfg_d = dict(manifest["config"])
        a = dict(cfg_d.pop("analyzer"))
        a["extra_stopwords"] = tuple(a.get("extra_stopwords", ()))
        cfg_d["gazetteer"] = tuple(cfg_d.get("gazetteer", ()))
        pipe = cls(IngestConfig(analyzer=AnalyzerConfig(**a), **cfg_d))
        arrays = np.load(directory / cls.ARRAYS)
        pipe.stats = CorpusStats(
            n_docs=int(manifest["stats"]["n_docs"]),
            avg_dl=float(manifest["stats"]["avg_dl"]),
            df_learned=arrays["df_learned"],
            df_lexical=arrays["df_lexical"],
        )
        pipe.entity_vocab = EntityVocab(names=list(manifest["entity_names"]))
        pipe.n_triplets = int(manifest.get("n_triplets", 0))
        return pipe
