"""Text ingestion: raw documents -> USMS vectors, keywords, KG triplets."""

from repro.ingest.analyzer import AnalyzerConfig, tokenize
from repro.ingest.entities import EntityVocab, extract_entity_spans
from repro.ingest.pipeline import (
    EncodedQueries,
    IngestConfig,
    IngestedCorpus,
    IngestPipeline,
    NotFittedError,
    adaptive_fusion_for,
)
from repro.ingest.weighting import CorpusStats

__all__ = [
    "AnalyzerConfig",
    "tokenize",
    "EntityVocab",
    "extract_entity_spans",
    "EncodedQueries",
    "IngestConfig",
    "IngestedCorpus",
    "IngestPipeline",
    "NotFittedError",
    "adaptive_fusion_for",
    "CorpusStats",
]
