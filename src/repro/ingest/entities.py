"""Rule-based entity extraction + co-occurrence knowledge-graph triplets.

The paper builds its KG offline with LLMs (§3.4); HMGI (arXiv:2510.10123)
makes the case that the entity/relational side should be extracted and
indexed *alongside* the vectors. Offline and dependency-free, the classic
rule stack still recovers most named entities in clean prose:

  * capitalized spans — maximal runs of Capitalized/ACRONYM tokens, with
    single sentence-initial capitalized words discarded (sentence case, not
    a name) unless the same surface form also appears mid-sentence;
  * an optional gazetteer (exact surface-form dictionary) that always wins.

Entity *ids* are dictionary-coded corpus-wide (top ``max_entities`` by
frequency) rather than hashed: ``logical_edges.build_logical_edges`` holds a
dense (E, E) adjacency, so E must stay small and known. The id table is
frozen at fit time — streamed documents only match known entities (the
frozen-stats contract; unseen names are dropped until the next refit).

Triplets are doc-level co-occurrence: entities appearing together in ≥
``min_cooc`` documents get a symmetric ``(e1, REL_COOCCURS, e2)`` edge —
exactly the ``KnowledgeGraph``-compatible (s, r, t) rows ``build_index``
feeds to ``build_logical_edges``.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Optional, Sequence

import numpy as np

from repro.core.usms import PAD_IDX

REL_COOCCURS = 0

_SENT_SPLIT = re.compile(r"[.!?]+\s+|\n+")
_WORD_RE = re.compile(r"[A-Za-z][A-Za-z']*")
# sentence-case function words glue onto name runs ("In October 1520
# Magellan", "The Endeavour") — strip them from the front of a run so the
# surface form matches its mid-sentence spelling
_LEADING_SKIP = frozenset(
    """the a an in on at by of for from into onto after before during with
    within without when where while as and but or nor so yet both
    either""".split()
)


def _is_cap(tok: str) -> bool:
    return (tok[0].isupper() and tok[1:].islower() and len(tok) > 1) or (
        tok.isupper() and len(tok) >= 2
    )


def extract_entity_spans(
    text: str, *, gazetteer: Optional[Sequence[str]] = None, max_span: int = 3
) -> list[str]:
    """Entity surface forms in ``text`` (duplicates preserved — callers
    count them). Spans are runs of capitalized tokens up to ``max_span``
    long; a lone sentence-initial capitalized word only counts if the same
    form shows up mid-sentence somewhere in the document."""
    gaz = set(gazetteer) if gazetteer else set()
    spans: list[str] = []
    initial_singles: list[str] = []
    seen_mid: set[str] = set()
    for sent in _SENT_SPLIT.split(text):
        toks = _WORD_RE.findall(sent)
        run: list[str] = []
        run_start = 0
        for pos, tok in enumerate(toks):
            if _is_cap(tok):
                if not run:
                    run_start = pos
                run.append(tok)
                continue
            if run:
                _flush(run, run_start, max_span, spans, initial_singles, seen_mid)
                run = []
        if run:
            _flush(run, run_start, max_span, spans, initial_singles, seen_mid)
    # sentence-initial singles count only with mid-sentence corroboration
    spans.extend(s for s in initial_singles if s in seen_mid or s in gaz)
    if gaz:
        for name in gaz:
            # word-bounded so "Rome" never fires inside "Romeo"
            hits = len(re.findall(rf"\b{re.escape(name)}\b", text))
            already = spans.count(name)
            if hits > already:
                spans.extend([name] * (hits - already))
    return spans


def _flush(run, run_start, max_span, spans, initial_singles, seen_mid):
    while run and run[0].lower() in _LEADING_SKIP:
        run = run[1:]
        run_start += 1
    if not run:
        return
    span = " ".join(run[:max_span])
    if len(run) == 1 and run_start == 0:
        initial_singles.append(span)
    else:
        spans.append(span)
        if run_start > 0:
            seen_mid.update(run[:max_span])
            seen_mid.add(span)


@dataclasses.dataclass
class EntityVocab:
    """Frozen surface-form -> id table (id order = frequency rank)."""

    names: list[str]

    def __post_init__(self):
        self._ids = {n: i for i, n in enumerate(self.names)}

    def __len__(self) -> int:
        return len(self.names)

    def lookup(self, name: str) -> int:
        return self._ids.get(name, PAD_IDX)

    @classmethod
    def build(cls, counts: Counter, max_entities: int, min_count: int = 1):
        kept = [
            name
            for name, c in sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
            if c >= min_count
        ][:max_entities]
        return cls(names=kept)


def doc_entity_ids(
    spans_per_doc: list[list[str]], vocab: EntityVocab, entities_per_doc: int
) -> np.ndarray:
    """(N, entities_per_doc) int32, PAD-padded: each doc's most frequent
    known entities, unique per row."""
    n = len(spans_per_doc)
    out = np.full((n, max(entities_per_doc, 1)), PAD_IDX, np.int32)
    for d, spans in enumerate(spans_per_doc):
        counts = Counter(
            e for e in (vocab.lookup(s) for s in spans) if e != PAD_IDX
        )
        for c, (e, _) in enumerate(
            sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))[:entities_per_doc]
        ):
            out[d, c] = e
    return out


def cooccurrence_triplets(
    doc_entities: np.ndarray, n_entities: int, min_cooc: int = 2
) -> np.ndarray:
    """(T, 3) int32 (src, REL_COOCCURS, dst) rows for entity pairs sharing
    ≥ ``min_cooc`` documents. One direction per pair — ``logical_edges``
    materializes both traversal directions itself."""
    pair_counts: Counter = Counter()
    for row in doc_entities:
        ents = sorted(int(e) for e in row if e >= 0)
        for i, a in enumerate(ents):
            for b in ents[i + 1:]:
                pair_counts[(a, b)] += 1
    trips = [
        (a, REL_COOCCURS, b)
        for (a, b), c in sorted(pair_counts.items())
        if c >= min_cooc and a < n_entities and b < n_entities
    ]
    if not trips:
        return np.zeros((0, 3), np.int32)
    return np.asarray(trips, np.int32)
