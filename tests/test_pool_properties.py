"""Hypothesis properties for the segment pool: any interleaving of
streaming inserts, deletions, incremental compactions, and background
merges yields search results equivalent (up to tie order) to ONE full
rebuild of the surviving docs — including tombstone exclusion and
knowledge-graph reachability — and global-id routing stays consistent."""

from __future__ import annotations

import numpy as np
import pytest

import jax

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    build_segmented_index,
    place_segmented_index,
)
from repro.core.search import SearchParams, search  # noqa: E402
from repro.core.segment_pool import (  # noqa: E402
    live_counts,
    resolve_global_ids_pool,
)
from repro.core.usms import PathWeights  # noqa: E402
from repro.data.corpus import CorpusConfig, make_corpus  # noqa: E402
from repro.serving.batcher import BatcherConfig  # noqa: E402
from repro.serving.hybrid_service import (  # noqa: E402
    HybridSearchService,
    ServiceConfig,
)
from repro.serving.segment_router import RouterConfig, SegmentRouter  # noqa: E402

CFG = BuildConfig(
    knn=KnnConfig(k=8, iters=2, node_chunk=128),
    prune=PruneConfig(degree=8, keyword_degree=3, node_chunk=64),
    path_refine_iters=0,
)
# saturating search: pool covers the whole tiny corpus, so both layouts
# degenerate to (the same) exact scoring and results must agree
PARAMS = SearchParams(k=10, iters=48, pool_size=128)
W = PathWeights.make(1.0, 1.0, 1.0)

N_TOTAL = 128
N_QUERIES = 6


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=N_TOTAL, n_queries=N_QUERIES, n_topics=8,
                     d_dense=16, nnz_sparse=8, nnz_lexical=6, seed=41)
    )


def _canonical(ids: np.ndarray, scores: np.ndarray):
    """Rows as score-descending groups of id-sets: equal-score ties compare
    as sets, so layouts that order ties differently still compare equal."""
    rows = []
    for row_ids, row_sc in zip(ids, scores):
        valid = row_ids >= 0
        groups: dict[float, set[int]] = {}
        for i, s in zip(row_ids[valid], np.round(row_sc[valid], 4)):
            groups.setdefault(float(s), set()).add(int(i))
        rows.append(sorted(groups.items(), reverse=True))
    return rows


def _pool_service(corpus, n0: int):
    from jax.sharding import Mesh

    sealed = build_segmented_index(
        corpus.docs[:n0], 1, CFG,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities[:n0],
        n_entities=corpus.kg.n_entities,
    )
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sealed = place_segmented_index(sealed, mesh)
    svc = HybridSearchService(
        sealed, PARAMS,
        ServiceConfig(batcher=BatcherConfig(
            flush_size=N_QUERIES, max_batch=8, flush_deadline_s=60.0)),
        mesh=mesh,
    )
    router = SegmentRouter(
        svc, CFG,
        RouterConfig(seal_threshold=10**9, compaction="incremental",
                     tier_fanout=2, auto_merge=False),
        kg_triplets=corpus.kg.triplets,
        n_entities=corpus.kg.n_entities,
    )
    return svc, router


@settings(
    max_examples=5, deadline=None, derandomize=True,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(data=st.data())
def test_incremental_compaction_equals_full_rebuild(corpus, data):
    n0 = data.draw(st.sampled_from([24, 32]), label="n0")
    n_batches = data.draw(st.integers(1, 3), label="n_batches")
    batch_size = data.draw(st.sampled_from([8, 16]), label="batch_size")
    total = n0 + n_batches * batch_size
    deletes = sorted(
        data.draw(
            st.sets(st.integers(0, total - 1), max_size=6), label="deletes"
        )
    )
    merge_after = data.draw(st.booleans(), label="merge_after")

    svc, router = _pool_service(corpus, n0)
    for b in range(n_batches):
        lo = n0 + b * batch_size
        svc.insert(
            corpus.docs[lo:lo + batch_size],
            new_doc_entities=corpus.doc_entities[lo:lo + batch_size],
        )
        router.compact_incremental()
    if deletes:
        svc.mark_deleted(deletes)
    if merge_after:
        router.maybe_merge_segments()
    pool = router.pool
    assert pool is not None

    # reference: ONE monolithic rebuild of exactly the surviving docs
    live = np.asarray([g for g in range(total) if g not in deletes])
    ref_rows = jax.tree.map(lambda a: a[live], corpus.docs)
    ref_idx = build_index(
        ref_rows, CFG,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities[live],
        n_entities=corpus.kg.n_entities,
    )

    got = svc.search(corpus.queries, W, k=PARAMS.k)
    ref = search(ref_idx, corpus.queries, W, PARAMS)
    ref_ids_local = np.asarray(ref.ids)
    ref_ids = np.where(
        ref_ids_local >= 0,
        live[np.clip(ref_ids_local, 0, live.size - 1)],
        -1,
    )
    assert _canonical(np.asarray(got.ids), np.asarray(got.scores)) == \
        _canonical(ref_ids, np.asarray(ref.scores))

    # tombstoned ids never surface, survivors resolve, tombstones of the
    # SEALED part may still occupy rows but must not resolve post-merge
    for d in deletes:
        assert d not in np.asarray(got.ids)
    alive_total = sum(lc[3] for lc in live_counts(pool))
    grow_alive = (
        0 if svc.grow_index is None
        else int(np.asarray(svc.grow_index.alive).sum())
    )
    assert alive_total + grow_alive == live.size

    # KG reachability: a surviving doc is reachable through its unique rare
    # entity in the pooled layout exactly like in the monolithic one
    kg_w = PathWeights.make(0.2, 0.2, 0.2, kg=2.0)
    kg_params = SearchParams(
        k=PARAMS.k, iters=PARAMS.iters, pool_size=PARAMS.pool_size,
        use_kg=True,
    )
    svc_kg = HybridSearchService(
        router.pool if svc.grow_index is None else svc.index,
        kg_params,
        ServiceConfig(batcher=BatcherConfig(flush_size=1, max_batch=2)),
        mesh=svc._mesh,
    )
    probe = data.draw(st.sampled_from(sorted(set(range(total)) - set(deletes))),
                      label="probe")
    res = svc_kg.search(
        corpus.queries[:1], kg_w,
        entities=np.asarray([[probe]], np.int32), k=PARAMS.k,
    )
    assert probe in np.asarray(res.ids)[0]


_ROUTING_POOL_CACHE: dict = {}


@settings(max_examples=20, deadline=None, derandomize=True,
          suppress_health_check=[HealthCheck.too_slow])
@given(ids=st.lists(st.integers(-5, 200), min_size=1, max_size=32))
def test_pool_routing_total_and_exclusive(corpus, ids):
    """Every global id resolves to at most one pooled location; resolved
    ids round-trip through the pool's gid tables."""
    if "pool" not in _ROUTING_POOL_CACHE:
        svc, router = _pool_service(corpus, 32)
        svc.insert(corpus.docs[32:48])
        router.compact_incremental()
        svc.insert(corpus.docs[48:80])
        router.compact_incremental()
        _ROUTING_POOL_CACHE["pool"] = router.pool
    pool = _ROUTING_POOL_CACHE["pool"]
    arr = np.asarray(ids, np.int64)
    grp, seg, loc = resolve_global_ids_pool(pool, arr)
    known = {g for group in pool.groups
             for g in np.asarray(group.global_ids).reshape(-1) if g >= 0}
    for i, g in enumerate(arr):
        if g in known:
            assert grp[i] >= 0 and seg[i] >= 0 and loc[i] >= 0
            back = int(
                np.asarray(pool.groups[grp[i]].global_ids)[seg[i], loc[i]]
            )
            assert back == g
        else:
            assert grp[i] == -1 and seg[i] == -1 and loc[i] == -1
