"""Distributed retrieval tests. The shard_map equivalence check needs fake
devices, so it runs in a subprocess with its own XLA_FLAGS (the main pytest
process keeps 1 CPU device for everything else)."""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_search_matches_reference():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "dist_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "DIST_CHECK_PASS" in proc.stdout


@pytest.mark.slow
def test_sharded_build_matches_sequential():
    """build_index_sharded on a 2-host CPU mesh produces the same per-segment
    graphs (and search recall) as the sequential build_segmented_index."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "build_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "BUILD_CHECK_PASS" in proc.stdout


def test_shard_corpus_roundtrip():
    from repro.core.distributed import shard_corpus
    from repro.data.corpus import CorpusConfig, make_corpus

    corpus = make_corpus(CorpusConfig(n_docs=103, n_queries=4, n_topics=4, d_dense=8))
    parts, gids = shard_corpus(corpus.docs, 4)
    assert gids.shape == (4, 26)
    flat = np.asarray(gids).reshape(-1)
    valid = flat[flat >= 0]
    assert sorted(valid.tolist()) == list(range(103))
    # padded rows are zero
    last = np.asarray(parts[-1].dense)
    n_pad = (gids[-1] < 0).sum()
    if n_pad:
        assert (last[-n_pad:] == 0).all()


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(__import__("jax"), "set_mesh"),
    reason="ep_manual uses mesh-less shard_map + jax.set_mesh (jax >= 0.5)",
)
def test_moe_ep_manual_matches_gspmd():
    """moe_impl=ep_manual (the §Perf EP path) is numerically identical to the
    GSPMD baseline — forward and gradients (subprocess, 8 fake devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "ep_check.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "EP_CHECK_PASS" in proc.stdout
