"""Distributed retrieval tests. The shard_map equivalence check needs fake
devices, so it runs in a subprocess with its own XLA_FLAGS (the main pytest
process keeps 1 CPU device for everything else)."""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_search_matches_reference():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "dist_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "DIST_CHECK_PASS" in proc.stdout


@pytest.mark.slow
def test_sharded_build_matches_sequential():
    """build_index_sharded on a 2-host CPU mesh produces the same per-segment
    graphs (and search recall) as the sequential build_segmented_index."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "build_check.py")],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "BUILD_CHECK_PASS" in proc.stdout


def test_shard_corpus_roundtrip():
    from repro.core.distributed import shard_corpus
    from repro.data.corpus import CorpusConfig, make_corpus

    corpus = make_corpus(CorpusConfig(n_docs=103, n_queries=4, n_topics=4, d_dense=8))
    parts, gids = shard_corpus(corpus.docs, 4)
    assert gids.shape == (4, 26)
    flat = np.asarray(gids).reshape(-1)
    valid = flat[flat >= 0]
    assert sorted(valid.tolist()) == list(range(103))
    # padded rows are zero
    last = np.asarray(parts[-1].dense)
    n_pad = (gids[-1] < 0).sum()
    if n_pad:
        assert (last[-n_pad:] == 0).all()


def test_segment_slices_allow_empty_trailing_segments():
    from repro.core.distributed import segment_slices

    # 5 docs over 4 segments: per=2, the last segment is EMPTY — slices
    # must stay well-formed (lo <= hi), not go negative-width
    assert segment_slices(5, 4) == [(0, 2), (2, 4), (4, 5), (5, 5)]
    assert segment_slices(1, 2) == [(0, 1), (1, 1)]
    assert all(lo <= hi for lo, hi in segment_slices(7, 8))


def test_compact_fewer_survivors_than_segments():
    """Compaction can shrink the corpus below the segment layout (heavy
    deletions): empty trailing segments build as all-pad, never crash, and
    the surviving ids stay searchable."""
    from repro.core import BuildConfig, KnnConfig, PruneConfig
    from repro.core.distributed import (
        compact_segmented_index,
        resolve_global_ids,
    )
    from repro.data.corpus import CorpusConfig, make_corpus

    cfg = BuildConfig(
        knn=KnnConfig(k=4, iters=1, node_chunk=64),
        prune=PruneConfig(degree=4, keyword_degree=2, node_chunk=32),
        path_refine_iters=0,
    )
    corpus = make_corpus(
        CorpusConfig(n_docs=64, n_queries=4, n_topics=4, d_dense=8,
                     nnz_sparse=4, nnz_lexical=4, seed=3)
    )
    survivors = corpus.docs[0:5]
    gids = np.asarray([3, 17, 30, 41, 63], np.int32)
    seg = compact_segmented_index(survivors, gids, 4, cfg)
    g = np.asarray(seg.global_ids)
    assert g.shape[0] == 4
    assert set(g[g >= 0].tolist()) == set(gids.tolist())
    # the empty segment is fully dead
    alive = np.asarray(seg.index.alive)
    assert alive.sum() == 5 and not alive[-1].any()
    s, l = resolve_global_ids(seg, gids)
    assert (s >= 0).all() and (l >= 0).all()


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(__import__("jax"), "set_mesh"),
    reason="ep_manual uses mesh-less shard_map + jax.set_mesh (jax >= 0.5)",
)
def test_moe_ep_manual_matches_gspmd():
    """moe_impl=ep_manual (the §Perf EP path) is numerically identical to the
    GSPMD baseline — forward and gradients (subprocess, 8 fake devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tests" / "ep_check.py")],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "EP_CHECK_PASS" in proc.stdout
