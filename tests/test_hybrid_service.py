"""HybridSearchService: bucket padding correctness, compiled-executable
cache behavior, micro-batcher flush semantics, and copy-on-write snapshot
swaps under interleaved insert/search."""

from __future__ import annotations

import time

import numpy as np
import pytest

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PAD_IDX, PathWeights, stack_weights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.serving.batcher import (
    BatcherConfig,
    MicroBatcher,
    PendingResult,
    QueueFullError,
    SearchRequest,
)
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=512),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=256),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=8, iters=16, pool_size=48, use_keywords=True)

THREE_WEIGHTS = [
    PathWeights.make(1.0, 0.0, 0.0),
    PathWeights.make(0.0, 1.0, 1.0),
    PathWeights.make(0.5, 0.25, 1.0),
]


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=384, n_queries=16, n_topics=12, d_dense=24,
                     nnz_sparse=10, nnz_lexical=8, seed=31)
    )


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(corpus.docs[:352], BUILD_CFG)


def _service(index, **batcher_kw):
    kw = dict(flush_size=8, max_batch=8, kw_cap=4, ent_cap=2,
              flush_deadline_s=60.0)
    kw.update(batcher_kw)
    return HybridSearchService(
        index, PARAMS, ServiceConfig(batcher=BatcherConfig(**kw)),
        build_cfg=BUILD_CFG,
    )


def test_bucket_padding_matches_direct_search(corpus, index):
    """A heterogeneous padded batch returns exactly what per-request direct
    search() returns: padding rows/width never leak into results."""
    svc = _service(index)
    reqs = []
    for i in range(6):  # 6 requests -> padded to the 8-slot bucket
        kws = None
        if i % 3 == 0:  # some requests carry required keywords
            kws = np.asarray(corpus.docs.lexical.idx[i, :2])
            kws = kws[kws >= 0]
        reqs.append(SearchRequest(
            query=corpus.queries[i],
            weights=THREE_WEIGHTS[i % 3],
            k=5,
            keywords=kws if kws is not None and len(kws) else None,
        ))
    pendings = [svc.submit(r) for r in reqs]
    svc.flush()
    assert svc.stats.padded_slots == 2
    for i, (r, p) in enumerate(zip(reqs, pendings)):
        ids, scores = p.result()
        assert ids.shape == (5,)
        kw2d = None if r.keywords is None else np.asarray(r.keywords)[None, :]
        ref = search(index, corpus.queries[i:i + 1], r.weights, PARAMS,
                     keywords=kw2d)
        np.testing.assert_array_equal(ids, np.asarray(ref.ids[0, :5]))
        np.testing.assert_allclose(scores, np.asarray(ref.scores[0, :5]),
                                   rtol=1e-6)


def test_one_executable_per_bucket_across_weights(corpus, index):
    """≥3 distinct PathWeights combinations through one bucket shape hit ONE
    compiled executable — weights are traced data (Theorem 1), so changing
    them never recompiles."""
    svc = _service(index)
    for rep in range(3):
        for w in THREE_WEIGHTS:
            svc.submit(SearchRequest(query=corpus.queries[rep], weights=w, k=4))
    svc.flush()
    assert svc.stats.requests == 9
    assert len(svc.executable_cache) == 2  # 8-slot bucket + forced 1-slot tail
    # replay all weight mixes through the now-warm cache: zero new compiles
    before = svc.stats.compiles
    for w in THREE_WEIGHTS + [PathWeights.make(0.1, 0.9, 0.4)]:
        for i in range(8):
            svc.submit(SearchRequest(query=corpus.queries[i], weights=w, k=4))
    svc.flush()
    assert svc.stats.compiles == before
    assert len(svc.executable_cache) == 2


def test_bucket_shapes_get_separate_executables(corpus, index):
    """Distinct shapes (batch bucket / keyword width) compile separately and
    are all retained."""
    svc = _service(index, flush_size=4, max_batch=8)
    for i in range(4):  # 4-slot bucket, no keywords -> kw width 1
        svc.submit(SearchRequest(query=corpus.queries[i],
                                 weights=THREE_WEIGHTS[0], k=4))
    svc.flush()
    assert len(svc.executable_cache) == 1
    kws = np.asarray([3, 5, 7])  # kw width bucket 4
    for i in range(4):
        svc.submit(SearchRequest(query=corpus.queries[i],
                                 weights=THREE_WEIGHTS[1], k=4, keywords=kws))
    svc.flush()
    assert len(svc.executable_cache) == 2


def test_flush_on_size_and_deadline(corpus, index):
    svc = _service(index, flush_size=4, max_batch=4, flush_deadline_s=0.05)
    pend = [svc.submit(SearchRequest(query=corpus.queries[i],
                                     weights=THREE_WEIGHTS[0], k=3))
            for i in range(3)]
    assert not any(p.done for p in pend)  # below flush_size, fresh deadline
    p4 = svc.submit(SearchRequest(query=corpus.queries[3],
                                  weights=THREE_WEIGHTS[0], k=3))
    assert all(p.done for p in pend + [p4])  # size trigger fired
    # deadline trigger: a lone request (below flush_size) runs via poll()
    # once its deadline lapses — the deadline is the ONLY trigger that can
    # fire here, so completion itself proves the semantics; no timing
    # assertions that could flake on a stalled CI scheduler
    t0 = time.monotonic()
    p5 = svc.submit(SearchRequest(query=corpus.queries[4],
                                  weights=THREE_WEIGHTS[1], k=3))
    while not p5.done and time.monotonic() - t0 < 10.0:
        svc.poll()
        time.sleep(0.005)
    assert p5.done
    assert time.monotonic() - t0 >= 0.05  # never ran before the deadline


def test_bounded_queue_rejects_overflow(corpus, index):
    svc = _service(index, max_queue=2, flush_size=8, max_batch=8)
    svc.submit(SearchRequest(query=corpus.queries[0], weights=THREE_WEIGHTS[0], k=3))
    svc.submit(SearchRequest(query=corpus.queries[1], weights=THREE_WEIGHTS[0], k=3))
    with pytest.raises(QueueFullError):
        svc.submit(SearchRequest(query=corpus.queries[2],
                                 weights=THREE_WEIGHTS[0], k=3))
    # queue-full rejects are counted, distinctly from admission rejects and
    # NOT as accepted requests
    assert svc.stats.rejected_queue_full == 1
    assert svc.stats.rejected_admission == 0
    assert svc.stats.rejected == 1
    assert svc.stats.requests == 2
    svc.flush()


def test_token_bucket_deterministic():
    from repro.serving.batcher import QuotaConfig, TokenBucket

    tb = TokenBucket(QuotaConfig(rate=2.0, burst=4.0), now=0.0)
    assert all(tb.try_acquire(1.0, now=0.0) for _ in range(4))  # full burst
    assert not tb.try_acquire(1.0, now=0.0)
    assert tb.try_acquire(1.0, now=0.5)  # 0.5s * 2/s refilled one token
    assert not tb.try_acquire(1.0, now=0.5)
    assert tb.try_acquire(4.0, now=100.0)  # refill is capped at burst
    assert not tb.try_acquire(1.0, now=100.0)


def test_admission_controller_tenant_quotas():
    from repro.serving.batcher import (
        AdmissionConfig,
        AdmissionController,
        QuotaConfig,
    )

    cfg = AdmissionConfig(
        global_quota=QuotaConfig(rate=0.0, burst=3.0),
        default_tenant_quota=QuotaConfig(rate=0.0, burst=1.0),
        tenant_quotas=(("vip", QuotaConfig(rate=0.0, burst=2.0)),),
    )
    ac = AdmissionController(cfg, now=0.0)
    assert ac.try_admit("basic", now=0.0)
    assert not ac.try_admit("basic", now=0.0)  # default tenant quota spent
    assert ac.try_admit("vip", now=0.0)
    assert ac.try_admit("vip", now=0.0)  # named quota is wider...
    assert not ac.try_admit("vip", now=0.0)  # ...but not infinite
    assert not ac.try_admit(None, now=0.0)  # global ceiling (3) also spent

    # a global reject refunds the tenant bucket (quota is not silently
    # drained while the service is saturated)
    cfg2 = AdmissionConfig(
        global_quota=QuotaConfig(rate=0.0, burst=1.0),
        default_tenant_quota=QuotaConfig(rate=0.0, burst=5.0),
    )
    ac2 = AdmissionController(cfg2, now=0.0)
    assert ac2.try_admit("t", now=0.0)
    assert not ac2.try_admit("t", now=0.0)  # global empty
    assert ac2._tenants["t"].tokens == 4.0  # refunded, only 1 truly spent

    # high-cardinality tenant ids never grow the bucket map without bound
    cfg3 = AdmissionConfig(
        default_tenant_quota=QuotaConfig(rate=1.0, burst=2.0),
        max_tenant_buckets=2,
    )
    ac3 = AdmissionController(cfg3, now=0.0)
    for i in range(10):
        assert ac3.try_admit(f"tenant-{i}", now=0.0)
    assert len(ac3._tenants) == 2  # oldest evicted, cap held


def test_service_admission_rejects_counted_distinctly(corpus, index):
    from repro.serving.batcher import AdmissionConfig, AdmissionError, QuotaConfig

    assert not issubclass(AdmissionError, QueueFullError)
    svc = HybridSearchService(
        index, PARAMS,
        ServiceConfig(
            batcher=BatcherConfig(flush_size=8, max_batch=8,
                                  flush_deadline_s=60.0),
            admission=AdmissionConfig(
                global_quota=QuotaConfig(rate=0.0, burst=2.0)
            ),
        ),
    )
    req = lambda i, t=None: SearchRequest(
        query=corpus.queries[i], weights=THREE_WEIGHTS[0], k=3, tenant=t)
    p0, p1 = svc.submit(req(0)), svc.submit(req(1))
    with pytest.raises(AdmissionError):
        svc.submit(req(2))
    assert svc.stats.rejected_admission == 1
    assert svc.stats.rejected_queue_full == 0
    assert svc.stats.requests == 2  # rejects never count as requests
    svc.flush()
    assert p0.result()[0].shape == (3,) and p1.result()[0].shape == (3,)


def test_queue_full_reject_refunds_admission_tokens(corpus, index):
    """A request that passes admission but dies on the bounded queue gets
    its tokens back: backpressure rejects never drain rate quota."""
    from repro.serving.batcher import AdmissionConfig, QuotaConfig

    svc = HybridSearchService(
        index, PARAMS,
        ServiceConfig(
            batcher=BatcherConfig(flush_size=8, max_batch=8, max_queue=1,
                                  flush_deadline_s=60.0),
            admission=AdmissionConfig(
                global_quota=QuotaConfig(rate=0.0, burst=5.0)
            ),
        ),
    )
    req = lambda i: SearchRequest(
        query=corpus.queries[i % 16], weights=THREE_WEIGHTS[0], k=3)
    svc.submit(req(0))  # queue now full; 4 tokens left
    with pytest.raises(QueueFullError):
        svc.submit(req(1))  # token taken AND refunded -> still 4 left
    assert svc.stats.rejected_queue_full == 1
    svc.flush()  # drain the queue
    for i in range(4):  # all 4 remaining tokens usable, one at a time
        svc.submit(req(2 + i))
        svc.flush()
    from repro.serving.batcher import AdmissionError

    with pytest.raises(AdmissionError):  # burst of 5 truly spent now
        svc.submit(req(6))
    assert svc.stats.requests == 5
    assert svc.stats.rejected_admission == 1


def test_request_validation(corpus, index):
    svc = _service(index)
    with pytest.raises(ValueError):  # k above the service cap
        svc.submit(SearchRequest(query=corpus.queries[0],
                                 weights=THREE_WEIGHTS[0], k=PARAMS.k + 1))
    with pytest.raises(ValueError):  # keyword width above the bucket cap
        svc.submit(SearchRequest(query=corpus.queries[0],
                                 weights=THREE_WEIGHTS[0],
                                 keywords=np.arange(5)))
    with pytest.raises(ValueError):  # entities require use_kg params
        svc.submit(SearchRequest(query=corpus.queries[0],
                                 weights=THREE_WEIGHTS[0],
                                 entities=np.asarray([1])))


def test_snapshot_swap_interleaved_insert_search(corpus, index):
    """Streaming inserts swap a consistent snapshot: every batch runs against
    exactly one index version, and results always match a direct search on
    the snapshot that served them."""
    svc = _service(index, flush_size=2, max_batch=2)
    w = PathWeights.make(1.0, 1.0, 1.0)
    new_docs = corpus.docs[352:384]

    r0 = svc.search(corpus.queries[:2], w, k=5)
    assert svc.snapshot_version == 0

    version = svc.insert(new_docs)
    assert version == 1
    assert svc.index.n == 384
    # stale executables for the old index shape were dropped
    assert all(k[0] == ("single", 384) for k in svc.executable_cache)

    r1 = svc.search(corpus.queries[:2], w, k=5)
    ref = search(svc.index, corpus.queries[:2], w, PARAMS)
    np.testing.assert_array_equal(np.asarray(r1.ids),
                                  np.asarray(ref.ids[:, :5]))
    # old results were served by the old snapshot (n=352): all ids in range
    assert np.asarray(r0.ids).max() < 352

    # inserted docs are reachable: query with an inserted doc's own vector
    probe = jax.tree.map(lambda a: a[:1], new_docs)
    res = svc.search(probe, w, k=5)
    assert 352 <= int(np.asarray(res.ids)[0, 0]) < 384


def test_mark_deleted_swaps_without_recompiling(corpus, index):
    svc = _service(index, flush_size=2, max_batch=2)
    w = PathWeights.make(1.0, 0.5, 0.5)
    r0 = svc.search(corpus.queries[:2], w, k=3)
    compiles = svc.stats.compiles
    top = int(np.asarray(r0.ids)[0, 0])
    svc.mark_deleted(np.asarray([top]))
    assert svc.snapshot_version == 1
    r1 = svc.search(corpus.queries[:2], w, k=3)
    assert top not in np.asarray(r1.ids)[0]
    assert svc.stats.compiles == compiles  # same shapes, same executables


def test_segmented_index_service(corpus):
    """The same service front-end drives a sharded SegmentedIndex through
    make_distributed_search_padded (single-device mesh smoke)."""
    from jax.sharding import Mesh

    from repro.core.distributed import build_segmented_index, place_segmented_index

    seg = build_segmented_index(corpus.docs[:352], 1, BUILD_CFG)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(seg, mesh)
    svc = HybridSearchService(
        seg, PARAMS,
        ServiceConfig(batcher=BatcherConfig(flush_size=4, max_batch=4)),
        mesh=mesh,
    )
    res = svc.search(
        corpus.queries[:4], THREE_WEIGHTS + [PathWeights.make(1.0, 1.0, 1.0)], k=4
    )
    assert res.ids.shape == (4, 4)
    assert len(svc.executable_cache) == 1
    with pytest.raises(NotImplementedError):
        svc.insert(corpus.docs[:1])
    with pytest.raises(NotImplementedError):
        svc.mark_deleted(np.asarray([0]))


def test_failed_batch_fails_waiters_and_spares_siblings(corpus, index):
    """A batch that dies mid-execution fails ITS waiters with the real error
    (no hanging result() calls) while sibling batches from the same drain
    still run and deliver."""
    svc = _service(index, flush_size=2, max_batch=2)
    # stage 3 entries without triggering submit()'s size flush, so flush()
    # drains a 2-slot batch + a 1-slot batch in one _drain pass
    pend = []
    for i in range(3):
        p = PendingResult(service=svc)
        svc._batcher.enqueue(
            SearchRequest(query=corpus.queries[i],
                          weights=THREE_WEIGHTS[0], k=3), p)
        pend.append(p)
    orig = svc._assemble
    state = {"calls": 0}

    def boom(bucket, entries):
        state["calls"] += 1
        if state["calls"] == 1:
            raise RuntimeError("injected batch failure")
        return orig(bucket, entries)

    svc._assemble = boom
    with pytest.raises(RuntimeError, match="injected batch failure"):
        svc.flush()
    assert all(p.done for p in pend)  # nobody left hanging
    with pytest.raises(RuntimeError, match="injected batch failure"):
        pend[0].result()
    assert pend[2].result()[0].shape == (3,)  # sibling batch still ran


def test_service_search_strips_pad_keywords(corpus, index):
    """2-D PAD_IDX-padded keyword arrays (the core search() convention) work
    through the service: pad slots are stripped per row, never counted
    against kw_cap, and results match the direct path."""
    svc = _service(index, flush_size=4, max_batch=4)
    w = PathWeights.make(1.0, 1.0, 1.0)
    kw2d = np.full((4, 8), PAD_IDX, np.int32)  # wider than kw_cap=4 ...
    lex = np.asarray(corpus.docs.lexical.idx[:4, :2])
    kw2d[:, :2] = np.where(lex >= 0, lex, PAD_IDX)  # ... but <=2 real ids/row
    res = svc.search(corpus.queries[:4], w, keywords=kw2d, k=5)
    ref = search(index, corpus.queries[:4], w, PARAMS, keywords=kw2d)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids[:, :5]))
    assert (np.asarray(res.expanded) > 0).all()  # real work measure delivered


def test_service_search_accepts_batched_weight_leaves(corpus, index):
    """service.search mirrors core search() for the batched PathWeights form
    too: one PathWeights with (B,) leaves is split per row."""
    svc = _service(index, flush_size=4, max_batch=4)
    wb = stack_weights(THREE_WEIGHTS + [PathWeights.make(0.2, 0.8, 0.5)])
    res = svc.search(corpus.queries[:4], wb, k=4)
    ref = search(index, corpus.queries[:4], wb, PARAMS)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids[:, :4]))


def test_concurrent_submit_and_poll(corpus, index):
    """submit() from worker threads while a timer thread pumps poll():
    every request is delivered exactly once, none lost or split."""
    import threading

    svc = _service(index, flush_size=4, max_batch=8, flush_deadline_s=0.001,
                   max_queue=4096)
    results = [None] * 48
    stop = threading.Event()

    def pump():
        while not stop.is_set():
            svc.poll()
            time.sleep(0.001)

    def client(base):
        for i in range(16):
            p = svc.submit(SearchRequest(
                query=corpus.queries[(base + i) % 16],
                weights=THREE_WEIGHTS[i % 3], k=3))
            results[base + i] = p

    pumper = threading.Thread(target=pump)
    pumper.start()
    workers = [threading.Thread(target=client, args=(b,)) for b in (0, 16, 32)]
    for t in workers:
        t.start()
    for t in workers:
        t.join()
    svc.flush()
    stop.set()
    pumper.join()
    assert all(p.done for p in results)
    assert svc.stats.requests == 48
    for p in results:
        assert p.result()[0].shape == (3,)


def test_batcher_bucket_shapes():
    cfg = BatcherConfig(flush_size=8, max_batch=16, kw_cap=8, ent_cap=4)
    mb = MicroBatcher(cfg)
    for i in range(5):
        mb.enqueue(
            SearchRequest(
                query=None, weights=None,
                keywords=np.arange(3) if i == 0 else None,
            ),
            PendingResult(),
            now=float(i),
        )
    [(bucket, entries)] = mb.take_ready(force=True)
    assert len(entries) == 5
    assert (bucket.batch, bucket.kw_width, bucket.ent_width) == (8, 4, 1)
    assert len(mb) == 0
