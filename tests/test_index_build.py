"""Construction pipeline: NN-Descent quality, pruning invariants, recycling."""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import knn_graph, pruning
from repro.core import BuildConfig, build_index
from repro.core.knn_graph import KnnConfig, build_knn_graph, dedup_mask, reverse_neighbors
from repro.core.pruning import PruneConfig, detour_counts, ip_keep_scan, unique_take
from repro.core.usms import PAD_IDX
from repro.data.corpus import CorpusConfig, make_corpus
from repro.kernels import ops


def small_corpus(n=512, seed=0):
    return make_corpus(
        CorpusConfig(
            n_docs=n, n_queries=16, n_topics=16, d_dense=32,
            nnz_sparse=16, nnz_lexical=8, seed=seed,
        )
    )


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_dedup_mask():
    ids = jnp.array([3, 1, 3, PAD_IDX, 1, 7], jnp.int32)
    mask = np.asarray(dedup_mask(ids))
    # one True per distinct non-pad id
    kept = ids[np.nonzero(mask)]
    assert sorted(np.asarray(kept).tolist()) == [1, 3, 7]
    assert not mask[3]


def test_reverse_neighbors():
    nbrs = jnp.array([[1, 2], [0, 2], [0, PAD_IDX]], jnp.int32)
    rev = np.asarray(reverse_neighbors(nbrs, cap=4))
    assert set(rev[0][rev[0] >= 0].tolist()) == {1, 2}
    assert set(rev[1][rev[1] >= 0].tolist()) == {0}
    assert set(rev[2][rev[2] >= 0].tolist()) == {0, 1}


def test_unique_take():
    ids = jnp.array([5, 5, 2, PAD_IDX, 2, 9, 1], jnp.int32)
    sc = jnp.zeros(7)
    out = np.asarray(unique_take(ids, sc, 4))
    assert out.tolist() == [5, 2, 9, 1]


def test_detour_counts_simple():
    # 3 candidates sorted by sim desc: sims to u = [.9, .8, .7]
    cand = jnp.array([0.9, 0.8, 0.7])
    # pair[i, j] = sim(v_i, v_j); v_2 reachable from v_0 with sim .95 > .7
    pair = jnp.array([[1.0, 0.1, 0.95], [0.1, 1.0, 0.2], [0.95, 0.2, 1.0]])
    routes = np.asarray(detour_counts(cand, pair))
    assert routes.tolist() == [0, 0, 1]


def test_ip_keep_scan_norm_rule():
    # candidate 1 has small self-IP; kept 0 dominates it -> pruned
    order = jnp.array([0, 1, 2])
    pair = jnp.array([[4.0, 3.0, 0.1], [3.0, 2.0, 0.1], [0.1, 0.1, 5.0]])
    self_ip = jnp.array([4.0, 2.0, 5.0])  # IP(v, v)
    valid = jnp.ones(3, bool)
    kept = np.asarray(ip_keep_scan(order, pair, self_ip, valid, cap=3))
    assert kept[0] and kept[2]
    assert not kept[1]  # IP(v0, v1)=3.0 >= IP(v1, v1)=2.0 -> pruned


# ---------------------------------------------------------------------------
# NN-Descent
# ---------------------------------------------------------------------------


def test_nn_descent_recall():
    corpus = small_corpus()
    cfg = KnnConfig(k=16, iters=5, node_chunk=512)
    ids, scores = build_knn_graph(corpus.docs, cfg, jax.random.key(0))
    n = corpus.docs.n
    assert ids.shape == (n, 16)
    # ground truth: brute-force fused top-k (exclude self)
    full = ops.pairwise_scores_chunked(corpus.docs, corpus.docs)
    full = full.at[jnp.arange(n), jnp.arange(n)].set(-jnp.inf)
    _, truth = jax.lax.top_k(full, 16)
    rec = knn_graph.knn_recall(ids, truth)
    assert rec > 0.80, f"NN-Descent recall too low: {rec}"
    # rows are sorted by score desc
    s = np.asarray(scores)
    assert (np.diff(s, axis=1) <= 1e-5).all()
    # no self-loops, no duplicates
    idn = np.asarray(ids)
    assert not (idn == np.arange(n)[:, None]).any()
    for r in idn[:32]:
        v = r[r >= 0]
        assert len(set(v.tolist())) == len(v)


def test_nn_descent_improves_over_iterations():
    corpus = small_corpus(n=256, seed=1)
    n = corpus.docs.n
    full = ops.pairwise_scores_chunked(corpus.docs, corpus.docs)
    full = full.at[jnp.arange(n), jnp.arange(n)].set(-jnp.inf)
    _, truth = jax.lax.top_k(full, 8)
    recalls = []
    for iters in (0, 2, 5):
        ids, _ = build_knn_graph(
            corpus.docs, KnnConfig(k=8, iters=iters, node_chunk=256), jax.random.key(0)
        )
        recalls.append(knn_graph.knn_recall(ids, truth))
    assert recalls[1] > recalls[0]
    assert recalls[2] >= recalls[1] - 0.02


# ---------------------------------------------------------------------------
# pruning + full build
# ---------------------------------------------------------------------------


def test_full_build_invariants():
    corpus = small_corpus()
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=512),
        prune=PruneConfig(degree=12, keyword_degree=6, node_chunk=256),
    )
    index = build_index(
        corpus.docs,
        cfg,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities,
        n_entities=corpus.kg.n_entities,
    )
    n = corpus.docs.n
    sem = np.asarray(index.semantic_edges)
    assert sem.shape == (n, 12)
    # unique, no self, in-range
    for u in range(0, n, 37):
        row = sem[u][sem[u] >= 0]
        assert len(set(row.tolist())) == len(row)
        assert u not in row.tolist()
        assert (row < n).all()
    # every node has at least one edge (connectivity floor)
    assert ((sem >= 0).sum(1) > 0).all()
    # keyword edges disjoint from semantic edges per node
    kw = np.asarray(index.keyword_edges)
    for u in range(0, n, 53):
        s = set(sem[u][sem[u] >= 0].tolist())
        kwu = kw[u][kw[u] >= 0]
        assert (kwu < n).all()
    # logical edges reference real docs and valid entities
    log = np.asarray(index.logical_edges)
    valid = log[..., 0] >= 0
    assert (log[..., 0][valid] < n).all()
    # entry points are valid unique node ids and include the top fused-norm node
    sip = np.asarray(index.self_ip)
    entries = np.asarray(index.entry_points)
    assert ((entries >= 0) & (entries < n)).all()
    assert len(set(entries.tolist())) == len(entries)
    assert int(np.argmax(sip)) in entries.tolist()


def test_keyword_recycling_preserves_navigation():
    """Flagged keyword edges must contribute keywords shared with the source
    node that the kept semantic neighbors do not cover."""
    corpus = small_corpus(n=256, seed=3)
    knn_ids, knn_scores = build_knn_graph(
        corpus.docs, KnnConfig(k=16, iters=4, node_chunk=256), jax.random.key(0)
    )
    cfg = PruneConfig(degree=8, keyword_degree=8, node_chunk=256)
    sem, kw = pruning.rng_ip_prune(corpus.docs, knn_ids, knn_scores, cfg)
    kwn = np.asarray(kw)
    f_idx = np.asarray(corpus.docs.lexical.idx)
    checked = 0
    for u in range(256):
        for v in kwn[u][kwn[u] >= 0]:
            ku = set(f_idx[u][f_idx[u] >= 0].tolist())
            kv = set(f_idx[v][f_idx[v] >= 0].tolist())
            shared = ku & kv
            assert shared, f"keyword edge {u}->{v} shares no keywords"
            checked += 1
    assert checked > 0, "no keyword edges recycled at all"
