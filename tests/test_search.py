"""Query processing (Algorithm 2): recall vs brute force, dynamic weights,
keyword augmentation, KG multi-hop, updates."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index, insert, mark_deleted
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights, weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, ndcg_at_k, recall_at_k
from repro.kernels import ops


@pytest.fixture(scope="module")
def built():
    corpus = make_corpus(
        CorpusConfig(
            n_docs=1024, n_queries=32, n_topics=24, d_dense=48,
            nnz_sparse=16, nnz_lexical=8, seed=5,
        )
    )
    cfg = BuildConfig(
        knn=KnnConfig(k=32, iters=5, node_chunk=1024),
        prune=PruneConfig(degree=32, keyword_degree=8, node_chunk=256),
        path_refine_iters=3,
    )
    index = build_index(
        corpus.docs,
        cfg,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities,
        n_entities=corpus.kg.n_entities,
    )
    return corpus, index, cfg


def vector_recall(index, corpus, weights, params, k=10):
    """Recall vs brute-force hybrid top-k under the same weights."""
    res = search(index, corpus.queries, weights, params)
    qw = weighted_query(corpus.queries, weights)
    scores = ops.pairwise_scores_chunked(qw, corpus.docs)
    _, truth = jax.lax.top_k(scores, k)
    return recall_at_k(np.asarray(res.ids[:, :k]), np.asarray(truth))


def test_three_path_recall(built):
    corpus, index, _ = built
    params = SearchParams(k=10, iters=48, pool_size=64)
    rec = vector_recall(index, corpus, PathWeights.three_path(), params)
    assert rec > 0.85, f"three-path recall {rec}"


def test_single_path_recall_dense(built):
    corpus, index, _ = built
    params = SearchParams(k=10, iters=64, pool_size=96)
    rec = vector_recall(index, corpus, PathWeights.make(1.0, 0.0, 0.0), params)
    assert rec > 0.75, f"dense-only recall {rec}"


def test_single_path_recall_sparse(built):
    corpus, index, _ = built
    params = SearchParams(k=10, iters=48, pool_size=64)
    rec = vector_recall(index, corpus, PathWeights.make(0.0, 1.0, 0.0), params)
    assert rec > 0.7, f"sparse-only recall {rec}"


def test_arbitrary_weights_no_rebuild(built):
    """Flexibility: the same index must serve any weight vector (Figure 12)."""
    corpus, index, _ = built
    params = SearchParams(k=10, iters=48, pool_size=64)
    for w in [(0.3, 0.7, 0.0), (0.7, 0.3, 0.2), (0.5, 0.5, 0.5), (0.0, 0.0, 1.0)]:
        rec = vector_recall(index, corpus, PathWeights.make(*w), params)
        assert rec > 0.5, f"weights {w}: recall {rec}"


def test_results_sorted_unique_alive(built):
    corpus, index, _ = built
    params = SearchParams(k=10, iters=32)
    res = search(index, corpus.queries, PathWeights.three_path(), params)
    ids = np.asarray(res.ids)
    scores = np.asarray(res.scores)
    assert (np.diff(scores, axis=1) <= 1e-5).all()
    for row in ids:
        v = row[row >= 0]
        assert len(set(v.tolist())) == len(v)


def test_end_to_end_hybrid_beats_single_path(built):
    """The paper's central claim: fusing paths improves end-to-end accuracy
    (planted-relevant-doc nDCG) over single-path retrieval."""
    corpus, index, _ = built
    params = SearchParams(k=10, iters=48, pool_size=64)
    truth = corpus.query_relevant

    def ndcg(w):
        res = search(index, corpus.queries, w, params)
        return ndcg_at_k(np.asarray(res.ids), truth, k=10)

    nd_dense = ndcg(PathWeights.make(1.0, 0.0, 0.0))
    nd_three = ndcg(PathWeights.three_path())
    assert nd_three >= nd_dense - 0.02, f"three {nd_three} vs dense {nd_dense}"
    assert nd_three > 0.5


def test_keyword_filter_honored(built):
    corpus, index, _ = built
    params = SearchParams(k=5, iters=48, pool_size=64, use_keywords=True)
    kw = jnp.asarray(corpus.query_keywords)
    res = search(
        index, corpus.queries, PathWeights.three_path(), params, keywords=kw
    )
    ids = np.asarray(res.ids)
    f_idx = np.asarray(corpus.docs.lexical.idx)
    q_kw = np.asarray(corpus.query_keywords)
    violations = 0
    for qi in range(len(ids)):
        req = q_kw[qi][q_kw[qi] >= 0]
        if len(req) == 0:
            continue
        for d in ids[qi][ids[qi] >= 0]:
            if not set(req.tolist()) & set(f_idx[d][f_idx[d] >= 0].tolist()):
                violations += 1
    assert violations == 0


def test_kg_multihop_improves(built):
    """Logical edges should surface chain-tail docs that pure semantic search
    misses (paper §5.5, Table 3/4)."""
    corpus, index, _ = built
    truth = corpus.query_multihop_target[:, None]

    base = search(
        index, corpus.queries, PathWeights.three_path(),
        SearchParams(k=10, iters=48, pool_size=64),
    )
    rec_base = recall_at_k(np.asarray(base.ids), truth)

    w_kg = PathWeights.make(1.0, 1.0, 1.0, kg=30.0)
    kg = search(
        index, corpus.queries, w_kg,
        SearchParams(k=10, iters=48, pool_size=64, use_kg=True),
        entities=jnp.asarray(corpus.query_entities),
    )
    rec_kg = recall_at_k(np.asarray(kg.ids), truth)
    assert rec_kg > rec_base + 0.1, f"KG {rec_kg} vs base {rec_base}"


def test_mark_deletion_filters_results(built):
    corpus, index, _ = built
    params = SearchParams(k=10, iters=32)
    res = search(index, corpus.queries, PathWeights.three_path(), params)
    victim = int(np.asarray(res.ids)[0, 0])
    index2 = mark_deleted(index, jnp.array([victim]))
    res2 = search(index2, corpus.queries, PathWeights.three_path(), params)
    assert victim not in np.asarray(res2.ids)[0].tolist()


def test_insert_preserves_quality(built):
    """Paper §5.8: inserting 20% new data keeps recall within ~a point of a
    full rebuild."""
    corpus, index, cfg = built
    n = corpus.docs.n
    n_keep = int(n * 0.8)
    base_docs = corpus.docs[slice(0, n_keep)]
    new_docs = corpus.docs[slice(n_keep, n)]

    part_index = build_index(base_docs, cfg)
    upd = insert(part_index, new_docs, cfg)
    assert upd.n == n

    params = SearchParams(k=10, iters=48, pool_size=64)
    w = PathWeights.three_path()
    res = search(upd, corpus.queries, w, params)
    qw = weighted_query(corpus.queries, w)
    scores = ops.pairwise_scores_chunked(qw, corpus.docs)
    _, truth = jax.lax.top_k(scores, 10)
    rec_upd = recall_at_k(np.asarray(res.ids), np.asarray(truth))

    res_full = search(index, corpus.queries, w, params)
    rec_full = recall_at_k(np.asarray(res_full.ids), np.asarray(truth))
    assert rec_upd > rec_full - 0.12, f"insert {rec_upd} vs rebuild {rec_full}"
    # new docs are actually reachable
    new_hit = (np.asarray(res.ids) >= n_keep).any()
    assert new_hit
