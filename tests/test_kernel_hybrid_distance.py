"""Pallas hybrid-distance kernel vs pure-jnp oracle.

Sweeps shapes/dtypes (interpret=True on CPU) and drives the padding / Theorem-1
invariants with hypothesis.
"""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import usms
from repro.core.usms import PAD_IDX, FusedVectors, PathWeights, SparseVec
from repro.kernels import ops, ref
from tests.helpers import random_fused


SHAPES = [
    # (B, C, Dd, Ps, Pf)
    (1, 1, 8, 4, 2),
    (2, 7, 16, 8, 4),
    (3, 128, 64, 16, 8),
    (4, 130, 128, 32, 16),  # C not a multiple of the tile
    (8, 256, 256, 64, 32),  # production-like nnz caps
    (1, 129, 33, 5, 3),  # awkward unaligned dims
]


@pytest.mark.parametrize("b,c,dd,ps,pf", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_kernel_matches_oracle(b, c, dd, ps, pf, dtype):
    rng = np.random.default_rng(hash((b, c, dd, ps, pf)) % 2**31)
    q = random_fused(rng, (b,), d_dense=dd, ps=ps, pf=pf, dtype=np.float32)
    cands = random_fused(rng, (b, c), d_dense=dd, ps=ps, pf=pf, dtype=np.float32)
    if dtype == jnp.bfloat16:
        cast = lambda f: FusedVectors(
            f.dense.astype(jnp.bfloat16),
            SparseVec(f.learned.idx, f.learned.val.astype(jnp.bfloat16)),
            SparseVec(f.lexical.idx, f.lexical.val.astype(jnp.bfloat16)),
        )
        q, cands = cast(q), cast(cands)
    got = ops.hybrid_scores(q, cands, c_tile=64, use_kernel=True, interpret=True)
    want = ref.hybrid_scores_ref(q, cands)
    assert got.shape == (b, c)
    assert got.dtype == jnp.float32
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_kernel_various_tiles():
    rng = np.random.default_rng(7)
    q = random_fused(rng, (2,), d_dense=32, ps=8, pf=4)
    cands = random_fused(rng, (2, 96), d_dense=32, ps=8, pf=4)
    want = ref.hybrid_scores_ref(q, cands)
    for c_tile in (8, 32, 128, 256):
        got = ops.hybrid_scores(q, cands, c_tile=c_tile, use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_scores_vs_ids_masks_padding():
    rng = np.random.default_rng(3)
    corpus = random_fused(rng, (50,), d_dense=16, ps=4, pf=4)
    q = random_fused(rng, (2,), d_dense=16, ps=4, pf=4)
    ids = np.array([[0, 3, PAD_IDX, 7], [49, PAD_IDX, PAD_IDX, 1]], np.int32)
    scores = ops.hybrid_scores_vs_ids(
        q, corpus, jnp.asarray(ids), use_kernel=True
    )
    assert np.isneginf(np.asarray(scores)[0, 2])
    assert np.isneginf(np.asarray(scores)[1, 1])
    # valid entries match a direct gather+score
    cands = corpus.take(jnp.asarray(ids).reshape(-1))
    cands = jax.tree.map(lambda a: a.reshape(2, 4, *a.shape[1:]), cands)
    want = ref.hybrid_scores_ref(q, cands)
    valid = ids >= 0
    np.testing.assert_allclose(
        np.asarray(scores)[valid], np.asarray(want)[valid], rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Property tests (hypothesis)
# ---------------------------------------------------------------------------


@st.composite
def fused_pair(draw):
    b = draw(st.integers(1, 3))
    c = draw(st.integers(1, 9))
    dd = draw(st.sampled_from([4, 16, 33]))
    ps = draw(st.sampled_from([2, 5, 8]))
    pf = draw(st.sampled_from([1, 4]))
    seed = draw(st.integers(0, 2**20))
    rng = np.random.default_rng(seed)
    q = random_fused(rng, (b,), d_dense=dd, ps=ps, pf=pf, vs=97, vf=31)
    cands = random_fused(rng, (b, c), d_dense=dd, ps=ps, pf=pf, vs=97, vf=31)
    return q, cands


@settings(max_examples=25, deadline=None)
@given(fused_pair())
def test_property_kernel_equals_oracle(pair):
    q, cands = pair
    got = ops.hybrid_scores(q, cands, c_tile=8, use_kernel=True, interpret=True)
    want = ref.hybrid_scores_ref(q, cands)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    fused_pair(),
    st.tuples(
        st.floats(0.0, 4.0), st.floats(0.0, 4.0), st.floats(0.0, 4.0)
    ),
)
def test_property_theorem1_weighted_mips(pair, weights):
    """Theorem 1: hybrid score with weights == inner product of the
    weight-scaled concatenated query with the concatenated document."""
    q, cands = pair
    wd, ws, wf = weights
    w = PathWeights.make(wd, ws, wf)
    qw = usms.weighted_query(q, w)
    got = ops.hybrid_scores(qw, cands, c_tile=8, use_kernel=True, interpret=True)

    # oracle: materialize concatenated dense vectors and take inner products
    vs, vf_ = 97, 31
    qcat = usms.concat_dense(qw, vs, vf_)  # (B, Dtot)
    b, c = cands.dense.shape[:2]
    flat = jax.tree.map(lambda a: a.reshape((b * c,) + a.shape[2:]), cands)
    dcat = usms.concat_dense(flat, vs, vf_).reshape(b, c, -1)
    want = jnp.einsum("bd,bcd->bc", qcat, dcat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(fused_pair())
def test_property_sparse_ip_equals_dense_scatter(pair):
    """sparse_ip(a, b) == <scatter(a), scatter(b)> for the ELL format."""
    q, cands = pair
    vs = 97
    got = ref.sparse_ip_ref(
        q.learned.idx, q.learned.val, cands.learned.idx, cands.learned.val
    )
    qd = usms.sparse_to_dense(q.learned, vs)
    b, c = cands.learned.idx.shape[:2]
    dd = usms.sparse_to_dense(
        SparseVec(
            cands.learned.idx.reshape(b * c, -1), cands.learned.val.reshape(b * c, -1)
        ),
        vs,
    ).reshape(b, c, vs)
    want = jnp.einsum("bv,bcv->bc", qd, dd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_zero_weights_isolate_paths():
    """Setting one weight to 1 and the rest to 0 reproduces single-path IP."""
    rng = np.random.default_rng(11)
    q = random_fused(rng, (2,), d_dense=16, ps=4, pf=4)
    cands = random_fused(rng, (2, 5), d_dense=16, ps=4, pf=4)
    dense_only = ops.hybrid_scores(
        usms.weighted_query(q, PathWeights.make(1.0, 0.0, 0.0)), cands, c_tile=8, use_kernel=True, interpret=True
    )
    want = jnp.einsum("bd,bcd->bc", q.dense, cands.dense)
    np.testing.assert_allclose(np.asarray(dense_only), np.asarray(want), rtol=1e-5, atol=1e-5)
    sparse_only = ops.hybrid_scores(
        usms.weighted_query(q, PathWeights.make(0.0, 1.0, 0.0)), cands, c_tile=8, use_kernel=True, interpret=True
    )
    want_s = ref.sparse_ip_ref(q.learned.idx, q.learned.val, cands.learned.idx, cands.learned.val)
    np.testing.assert_allclose(np.asarray(sparse_only), np.asarray(want_s), rtol=1e-5, atol=1e-5)
