"""Text ingestion subsystem: analyzer determinism + round-trip, ELL
invariants (hypothesis), end-to-end ingest -> build -> search recall on the
bundled real-text corpus, and streaming ingest with frozen corpus stats
through the SegmentRouter (sealed-executable cache stability)."""

from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.search import SearchParams, search
from repro.core.usms import PAD_IDX, PathWeights
from repro.data.corpus import recall_at_k
from repro.data.textcorpus import load_bundled_corpus, topic_truth
from repro.ingest import IngestConfig, IngestPipeline, NotFittedError
from repro.ingest.analyzer import AnalyzerConfig, fnv1a, learned_id, tokenize
from repro.ingest.entities import extract_entity_spans

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=16, iters=4, node_chunk=128),
    prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
    path_refine_iters=1,
)
PARAMS = SearchParams(k=10, iters=48, pool_size=64)


@pytest.fixture(scope="module")
def fitted():
    corpus = load_bundled_corpus()
    pipe = IngestPipeline(IngestConfig(d_dense=64))
    ingested = pipe.fit(corpus.texts)
    return pipe, ingested, corpus.texts, corpus.topics


@pytest.fixture(scope="module")
def text_index(fitted):
    pipe, ingested, _, _ = fitted
    return pipe.build(ingested, BUILD_CFG)


# -- analyzer ---------------------------------------------------------------


def test_analyzer_deterministic_and_stable():
    cfg = AnalyzerConfig()
    text = "The Rocket outran every rival at Rainhill in 1829."
    assert tokenize(text, cfg) == tokenize(text, cfg)
    # FNV-1a is specified, not platform hash: pin a known vector
    assert fnv1a("rocket") == fnv1a("rocket")
    assert fnv1a("") == 0xCBF29CE484222325
    ids = [learned_id(t, cfg) for t in tokenize(text, cfg)]
    assert all(0 <= i < cfg.vocab_size for i in ids)
    # stopwords and short tokens are gone, case is folded
    toks = tokenize(text, cfg)
    assert "the" not in toks and "at" not in toks and "rocket" in toks


def test_char_ngrams_optional():
    cfg = AnalyzerConfig(char_ngrams=3)
    toks = tokenize("weaving", cfg)
    assert "weaving" in toks and "#wea" in toks and "#ing" in toks
    assert "#wea" not in tokenize("weaving", AnalyzerConfig())


def test_entity_extraction_rules():
    spans = extract_entity_spans(
        "In 1520 Magellan entered the strait. The fleet followed Magellan "
        "to the Pacific. Storms wrecked the rigging."
    )
    assert "Magellan" in spans and "Pacific" in spans
    # sentence-initial single capitalized words need corroboration
    assert "Storms" not in spans
    # leading determiners never glue onto a name run
    assert all(not s.startswith("The ") for s in spans)


def test_encode_requires_fit():
    pipe = IngestPipeline()
    with pytest.raises(NotFittedError):
        pipe.encode_docs(["some text"])
    with pytest.raises(NotFittedError):
        pipe.encode_queries(["some text"])


def test_precomputed_dense_vectors_plugin(fitted):
    """The embedder plug-in point: caller-supplied (N, d_dense) vectors
    replace the hashed-projection stub verbatim (docs AND queries), and
    wrong shapes are rejected before anything is encoded."""
    pipe, _, texts, _ = fitted
    d = pipe.config.d_dense
    rng = np.random.default_rng(3)
    mine = rng.standard_normal((2, d)).astype(np.float32)

    docs, _ = pipe.encode_docs(texts[:2], dense_vectors=mine)
    np.testing.assert_array_equal(np.asarray(docs.dense), mine)
    # the sparse paths are untouched by the dense override
    stub_docs, _ = pipe.encode_docs(texts[:2])
    np.testing.assert_array_equal(
        np.asarray(docs.learned.idx), np.asarray(stub_docs.learned.idx)
    )
    assert not np.array_equal(np.asarray(stub_docs.dense), mine)

    enc = pipe.encode_queries(texts[:2], dense_vectors=mine)
    np.testing.assert_array_equal(np.asarray(enc.vectors.dense), mine)

    with pytest.raises(ValueError, match="d_dense"):
        pipe.encode_docs(texts[:2], dense_vectors=mine[:, :-1])
    with pytest.raises(ValueError, match="d_dense"):
        pipe.encode_docs(texts[:3], dense_vectors=mine)

    # a fresh fit accepts corpus-wide precomputed vectors end to end
    pipe2 = IngestPipeline(IngestConfig(d_dense=8))
    vecs = rng.standard_normal((len(texts), 8)).astype(np.float32)
    ingested = pipe2.fit(texts, dense_vectors=vecs)
    np.testing.assert_array_equal(np.asarray(ingested.docs.dense), vecs)


# -- ELL invariants (the exhaustive hypothesis variant lives in
# tests/test_ingest_properties.py; this keeps a deterministic smoke check
# in the hypothesis-less tier) ----------------------------------------------


def test_ell_invariants_bundled_corpus(fitted):
    _, ingested, texts, _ = fitted
    for sv in (ingested.docs.learned, ingested.docs.lexical):
        idx, val = np.asarray(sv.idx), np.asarray(sv.val)
        assert idx.dtype == np.int32
        assert (val[idx == PAD_IDX] == 0).all()
        assert (val[idx != PAD_IDX] > 0).all()
        for row in idx:
            real = row[row >= 0]
            assert len(set(real.tolist())) == len(real)  # unique ids per row
            real_mask = row >= 0  # PAD only ever trails real ids
            assert not (~real_mask[:-1] & real_mask[1:]).any()
    norms = np.linalg.norm(np.asarray(ingested.docs.dense), axis=-1)
    assert ((np.abs(norms - 1.0) < 1e-4) | (norms == 0)).all()


# -- round-trip persistence of the vocab/corpus-stats manifest ---------------


def test_pipeline_save_load_roundtrip(fitted, tmp_path):
    pipe, _, texts, _ = fitted
    pipe.save(tmp_path / "ingest")
    loaded = IngestPipeline.load(tmp_path / "ingest")
    assert loaded.fitted
    assert loaded.entity_vocab.names == pipe.entity_vocab.names
    a_docs, a_ents = pipe.encode_docs(texts[:5])
    b_docs, b_ents = loaded.encode_docs(texts[:5])
    for a, b in zip(jax.tree.leaves(a_docs), jax.tree.leaves(b_docs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(a_ents, b_ents)
    qa = pipe.encode_queries(['"rye" sourdough starter'])
    qb = loaded.encode_queries(['"rye" sourdough starter'])
    np.testing.assert_array_equal(qa.keywords, qb.keywords)


# -- end-to-end: ingest -> build -> search on the bundled corpus -------------


def test_e2e_recall_floor_and_hybrid_lift(fitted, text_index):
    pipe, ingested, texts, topics = fitted
    corpus = load_bundled_corpus()
    enc = pipe.encode_queries(corpus.query_texts)
    truth = topic_truth(corpus.query_topics, topics)

    dense = search(
        text_index, enc.vectors, PathWeights.make(1, 0, 0), PARAMS
    )
    hybrid = search(
        text_index, enc.vectors, PathWeights.three_path(), PARAMS
    )
    r_dense = recall_at_k(np.asarray(dense.ids), truth)
    r_hybrid = recall_at_k(np.asarray(hybrid.ids), truth)
    # the lexical path must lift accuracy on real text (acceptance criterion)
    assert r_hybrid >= r_dense
    assert r_hybrid >= 0.25  # absolute floor on the bundled corpus


def test_query_keywords_constrain_results(fitted, text_index):
    pipe, ingested, texts, topics = fitted
    # the quoted phrase becomes a REQUIRED keyword: every returned doc must
    # contain its lexical id
    enc = pipe.encode_queries(['the voyage home "scurvy"'])
    assert (enc.keywords[0] >= 0).sum() == 1
    res = search(
        text_index, enc.vectors, PathWeights.three_path(),
        SearchParams(k=10, iters=48, pool_size=64, use_keywords=True),
        keywords=enc.keywords,
    )
    kw = int(enc.keywords[0, 0])
    lex = np.asarray(ingested.docs.lexical.idx)
    for doc in np.asarray(res.ids)[0]:
        if doc >= 0:
            assert kw in lex[doc]


def test_query_entities_resolve_against_frozen_vocab(fitted):
    pipe, ingested, _, _ = fitted
    enc = pipe.encode_queries(
        ["What did Amundsen find at the pole?", "no entities here at all"]
    )
    assert enc.entities[0, 0] == pipe.entity_vocab.lookup("Amundsen")
    assert (enc.entities[1] == PAD_IDX).all()


# -- streaming ingest: frozen stats -> SegmentRouter.insert ------------------


def test_streaming_ingest_preserves_sealed_executables(fitted):
    """The acceptance criterion: new raw documents stream through the frozen
    pipeline into the grow segment; already-ingested vectors are unchanged
    (frozen stats) and NO sealed-segment executable is evicted."""
    from jax.sharding import Mesh

    from repro.core.distributed import place_segmented_index
    from repro.serving.batcher import BatcherConfig
    from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
    from repro.serving.segment_router import RouterConfig, SegmentRouter

    pipe, ingested, texts, topics = fitted
    n0 = 100  # sealed docs; the rest stream in

    sealed_pipe = IngestPipeline(IngestConfig(d_dense=64))
    sealed_ing = sealed_pipe.fit(texts[:n0])
    seg = sealed_pipe.build_sharded(sealed_ing, 1, BUILD_CFG)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(seg, mesh)
    svc = HybridSearchService(
        seg, PARAMS,
        ServiceConfig(batcher=BatcherConfig(flush_size=4, max_batch=4,
                                            flush_deadline_s=60.0)),
        mesh=mesh,
    )
    SegmentRouter(
        svc, BUILD_CFG, RouterConfig(seal_threshold=10**9),
        kg_triplets=sealed_ing.kg.triplets,
        n_entities=sealed_ing.kg.n_entities,
    )

    q = sealed_pipe.encode_queries([t[:80] for t in texts[:4]])
    svc.search(q.vectors, PathWeights.three_path(), k=5)  # warm sealed exe
    sealed_keys = set(svc.executable_cache)
    sealed_exes = {k: svc.executable_cache[k] for k in sealed_keys}
    assert sealed_keys

    # frozen stats: streaming must not mutate df/avg_dl
    df_before = sealed_pipe.stats.df_lexical.copy()
    v = sealed_pipe.stream_into(svc, texts[n0:])
    assert v >= 1
    np.testing.assert_array_equal(df_before, sealed_pipe.stats.df_lexical)

    # sealed executables: the SAME objects, not recompiles
    for k in sealed_keys:
        assert svc.executable_cache[k] is sealed_exes[k]

    # a streamed doc is retrievable by its own text (global id = n0 + i)
    probe_i = 5  # texts[n0 + 5]
    enc = sealed_pipe.encode_queries([texts[n0 + probe_i]])
    res = svc.search(enc.vectors, PathWeights.three_path(), k=5)
    assert n0 + probe_i in np.asarray(res.ids)[0]

    # and the sealed cache is STILL intact after the read
    for k in sealed_keys:
        assert svc.executable_cache[k] is sealed_exes[k]
