"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement),
plus prefill/decode consistency against the full forward."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.models import transformer as tfm
from repro.models.config import ModelConfig

B, L = 2, 32


def _batch(cfg: ModelConfig, key, l=L):
    k1, k2 = jax.random.split(jax.random.key(7))
    tokens = jax.random.randint(k1, (B, l), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = (
            jax.random.normal(k2, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(jax.random.key(0), cfg)
    fwd = jax.jit(tfm.make_forward(cfg))
    batch = _batch(cfg, jax.random.key(1))
    logits, aux, mtp = fwd(params, batch["tokens"], batch.get("frontend"))
    assert logits.shape == (B, L, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), "NaN logits"
    if cfg.mtp:
        assert mtp.shape == (B, L, cfg.vocab)


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_grad(arch):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(jax.random.key(0), cfg)
    loss_fn = tfm.make_loss_fn(cfg)
    batch = _batch(cfg, jax.random.key(1))
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert np.isfinite(float(loss)), f"loss {loss}"
    # rough sanity: initialized models should be near uniform CE
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab) + 1
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode after prefill must reproduce the full-sequence
    forward logits (the classic KV-cache correctness test)."""
    cfg = get_smoke_config(arch)
    # float32 for a tight comparison; no-drop MoE capacity — capacity-based
    # dispatch legitimately drops overflow tokens in sequence mode but never
    # in single-token decode, so exact equality needs headroom (the standard
    # train/serve divergence of capacity-MoE).
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    params = tfm.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg, jax.random.key(1))
    tokens, frontend = batch["tokens"], batch.get("frontend")

    fwd = jax.jit(tfm.make_forward(cfg))
    full_logits, _, _ = fwd(params, tokens, frontend)

    l_prefill = L // 2
    max_len = L
    prefill = jax.jit(tfm.make_prefill(cfg, max_len))
    decode = jax.jit(tfm.make_decode_step(cfg))
    logits_p, cache = prefill(params, tokens[:, :l_prefill], frontend)
    np.testing.assert_allclose(
        np.asarray(logits_p),
        np.asarray(full_logits[:, l_prefill - 1]),
        rtol=2e-3, atol=2e-3,
    )
    # teacher-forced single-token decode for the second half
    for pos in range(l_prefill, L):
        logits_d, cache = decode(params, tokens[:, pos], cache, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d),
            np.asarray(full_logits[:, pos]),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch} decode mismatch at pos {pos}",
        )


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_cover_params(arch):
    cfg = get_smoke_config(arch)
    params = tfm.init_params(jax.random.key(0), cfg)
    specs = tfm.param_specs(cfg)
    # same tree structure
    jax.tree.map(lambda a, s: None, params, specs)
    # spec rank matches array rank
    def check(a, s):
        assert len(s) <= a.ndim, f"spec {s} too long for shape {a.shape}"

    jax.tree.map(check, params, specs)


def test_analytic_param_count_close():
    """cfg.n_params (used for MODEL_FLOPS) tracks the real parameter count on
    reduced configs within 20%."""
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        params = tfm.init_params(jax.random.key(0), cfg)
        real = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
        est = cfg.n_params
        ratio = est / real
        assert 0.6 < ratio < 1.55, f"{arch}: est {est} vs real {real} ({ratio:.2f})"
