"""Serving engine + RAG pipeline integration (the paper's index wired into
the generation path)."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.models import transformer as tfm
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.rag import RagConfig, RagPipeline


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), vocab=256)
    params = tfm.init_params(jax.random.key(0), cfg)
    return cfg, ServingEngine(cfg, params, ServeConfig(max_len=256, batch=4))


def test_generate_shapes_greedy(engine):
    cfg, eng = engine
    prompts = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab, dtype=jnp.int32)
    out = eng.generate(prompts, 12)
    assert out.shape == (4, 20)
    np.testing.assert_array_equal(np.asarray(out[:, :8]), np.asarray(prompts))
    # greedy is deterministic
    out2 = eng.generate(prompts, 12)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_generate_matches_incremental_forward(engine):
    """Generation via KV cache equals generation via repeated full forwards."""
    cfg, eng = engine
    prompts = jax.random.randint(jax.random.key(2), (2, 6), 0, cfg.vocab, dtype=jnp.int32)
    out = eng.generate(prompts, 5)
    fwd = jax.jit(tfm.make_forward(cfg))
    seq = prompts
    for _ in range(5):
        logits, _, _ = fwd(eng.params, seq)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))


def test_rag_pipeline_end_to_end(engine):
    cfg, eng = engine
    corpus = make_corpus(
        CorpusConfig(n_docs=512, n_queries=8, n_topics=16, d_dense=32,
                     nnz_sparse=12, nnz_lexical=8, seed=9)
    )
    index = build_index(
        corpus.docs,
        BuildConfig(
            knn=KnnConfig(k=16, iters=4, node_chunk=512),
            prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=256),
            path_refine_iters=1,
        ),
    )
    # map each doc to a token span (synthetic "detokenized context")
    rng = np.random.default_rng(0)
    doc_tokens = jnp.asarray(
        rng.integers(0, cfg.vocab, size=(512, 8)), jnp.int32
    )
    rag = RagPipeline(
        eng, index, doc_tokens,
        RagConfig(top_k=2, ctx_tokens_per_doc=8,
                  search=SearchParams(k=5, iters=40, pool_size=64)),
    )
    queries = corpus.queries
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(8, 4)), jnp.int32)
    out, res = rag.answer(queries, prompts, n_tokens=6)
    assert out.shape == (8, 2 * 8 + 4 + 6)
    # retrieval quality: planted relevant docs should appear in the results
    rec = recall_at_k(np.asarray(res.ids), corpus.query_relevant[:, :1])
    assert rec >= 0.5, rec

    # the same pipeline retrieving through the micro-batched serving layer
    # returns identical docs (padding/bucketing never changes results)
    from repro.serving.batcher import BatcherConfig
    from repro.serving.hybrid_service import HybridSearchService, ServiceConfig

    service = HybridSearchService(
        index,
        dataclasses.replace(rag.cfg.search, k=rag.cfg.top_k),
        ServiceConfig(batcher=BatcherConfig(flush_size=8, max_batch=8)),
    )
    rag_svc = RagPipeline(eng, index, doc_tokens, rag.cfg, service=service)
    res_svc = rag_svc.retrieve(queries)
    np.testing.assert_array_equal(
        np.asarray(res_svc.ids), np.asarray(res.ids[:, : rag.cfg.top_k])
    )
    assert service.stats.batches == 1 and len(service.executable_cache) == 1
