"""Replica-tier properties: scatter-gather top-k over N consistent-hash
replicas equals a single service holding every document (up to tie order),
including tombstone exclusion and KG entity paths; plus the router
mechanics — stable placement, least-outstanding dispatch, degraded reads
when a replica is down, and pinned-global-id validation."""

from __future__ import annotations

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams, search
from repro.core.segment_pool import (
    SegmentPool,
    build_pool_segment,
    place_pool,
)
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.serving.batcher import BatcherConfig, _next_pow2
from repro.serving.hybrid_service import (
    HybridSearchService,
    ServiceConfig,
)
from repro.serving.replica_router import (
    Replica,
    ReplicaRouter,
    ReplicaTierConfig,
    build_ring,
    ring_homes,
)
from repro.serving.segment_router import RouterConfig, SegmentRouter

CFG = BuildConfig(
    knn=KnnConfig(k=8, iters=2, node_chunk=128),
    prune=PruneConfig(degree=8, keyword_degree=3, node_chunk=64),
    path_refine_iters=0,
)
# saturating search: the pool covers the whole tiny corpus, so any layout
# degenerates to (the same) exact scoring and results must agree
PARAMS = SearchParams(k=10, iters=48, pool_size=128, use_kg=True)
W = PathWeights.make(1.0, 1.0, 1.0)
VNODES = 16

N_TOTAL = 96
N_QUERIES = 6


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=N_TOTAL, n_queries=N_QUERIES, n_topics=8,
                     d_dense=16, nnz_sparse=8, nnz_lexical=6, seed=43)
    )


def _canonical(ids: np.ndarray, scores: np.ndarray):
    """Rows as score-descending groups of id-sets: equal-score ties compare
    as sets, so layouts that order ties differently still compare equal."""
    rows = []
    for row_ids, row_sc in zip(ids, scores):
        valid = row_ids >= 0
        groups: dict[float, set[int]] = {}
        for i, s in zip(row_ids[valid], np.round(row_sc[valid], 4)):
            groups.setdefault(float(s), set()).add(int(i))
        rows.append(sorted(groups.items(), reverse=True))
    return rows


def _make_tier(corpus, n0: int, n_replicas: int, **tier_kw) -> ReplicaRouter:
    """Shard docs [0, n0) over n_replicas by the SAME ring the live tier
    routes with, one sealed pool segment per replica."""
    names = [f"replica{i}" for i in range(n_replicas)]
    homes = ring_homes(build_ring(names, VNODES), np.arange(n0))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    reps = []
    for i, name in enumerate(names):
        rows = np.flatnonzero(homes == i)
        assert rows.size, f"{name} got an empty shard — reseed the test"
        seg = build_pool_segment(
            jax.tree.map(lambda a: a[rows], corpus.docs),
            rows,
            CFG,
            capacity=_next_pow2(int(rows.size)),
            key=jax.random.key(5 + i),
            kg_triplets=corpus.kg.triplets,
            doc_entities=corpus.doc_entities[rows],
            n_entities=corpus.kg.n_entities,
        )
        pool = place_pool(SegmentPool.from_segmented(seg), mesh)
        svc = HybridSearchService(
            pool, PARAMS,
            ServiceConfig(batcher=BatcherConfig(
                flush_size=N_QUERIES, max_batch=8, flush_deadline_s=60.0)),
            mesh=mesh,
        )
        router = SegmentRouter(
            svc, CFG,
            RouterConfig(seal_threshold=10**9, compaction="incremental",
                         tier_fanout=2, auto_merge=False),
            kg_triplets=corpus.kg.triplets,
            n_entities=corpus.kg.n_entities,
        )
        reps.append(Replica(svc, router, name=name))
    return ReplicaRouter(
        reps, ReplicaTierConfig(virtual_nodes=VNODES, **tier_kw)
    )


@pytest.mark.parametrize(
    "n_replicas,n0,n_insert,deletes,compact,probe",
    [
        # plain sharded read, no mutation after the tier insert
        (2, 48, 16, [], False, 10),
        # deletes spanning sealed and inserted ranges, with compaction
        (2, 64, 32, [3, 50, 90], True, 70),
        # three replicas, deletes at both shard boundaries
        (3, 48, 32, [0, 47, 48, 79], True, 60),
    ],
)
def test_scatter_gather_equals_single_service(
    corpus, n_replicas, n0, n_insert, deletes, compact, probe
):
    """The equivalence contract: tier reads over any replica partition ==
    one service over all surviving docs, up to equal-score tie order —
    with streamed inserts, deletes, and per-replica compaction mixed in."""
    total = n0 + n_insert
    tier = _make_tier(corpus, n0, n_replicas)
    try:
        gids = tier.insert(
            corpus.docs[n0:total],
            new_doc_entities=corpus.doc_entities[n0:total],
        )
        assert gids.tolist() == list(range(n0, total))
        if compact:
            # compaction is per-replica and must not change tier results
            tier.replicas[0].router.compact_incremental()
        if deletes:
            tier.delete(deletes)

        live = np.asarray([g for g in range(total) if g not in deletes])
        ref_idx = build_index(
            jax.tree.map(lambda a: a[live], corpus.docs), CFG,
            kg_triplets=corpus.kg.triplets,
            doc_entities=corpus.doc_entities[live],
            n_entities=corpus.kg.n_entities,
        )
        got = tier.search(corpus.queries, W, k=PARAMS.k)
        ref = search(ref_idx, corpus.queries, W, PARAMS)
        ref_ids_local = np.asarray(ref.ids)
        ref_ids = np.where(
            ref_ids_local >= 0,
            live[np.clip(ref_ids_local, 0, live.size - 1)],
            -1,
        )
        assert _canonical(np.asarray(got.ids), np.asarray(got.scores)) == \
            _canonical(ref_ids, np.asarray(ref.scores))
        for d in deletes:
            assert d not in np.asarray(got.ids)

        # KG reachability through the tier: a surviving doc's unique rare
        # entity (entity id == doc id in make_corpus) reaches it across
        # whichever replica holds it
        assert probe not in deletes
        kg_w = PathWeights.make(0.2, 0.2, 0.2, kg=2.0)
        res = tier.search(
            corpus.queries[:1], kg_w,
            entities=np.asarray([[probe]], np.int32), k=PARAMS.k,
        )
        assert probe in np.asarray(res.ids)[0]
    finally:
        tier.close()


def test_consistent_hash_placement_stable_and_minimal(corpus):
    """Placement is a pure function of (names, id); removing a replica
    remaps ONLY the ids homed on it."""
    names = ["replica0", "replica1", "replica2"]
    ids = np.arange(500)
    h1 = ring_homes(build_ring(names, 64), ids)
    h2 = ring_homes(build_ring(names, 64), ids)
    assert (h1 == h2).all()
    # all replicas get a meaningful share at 64 vnodes
    counts = np.bincount(h1, minlength=3)
    assert (counts > 50).all()

    tier = _make_tier(corpus, 48, 3)
    try:
        before = tier.homes_of(ids)
        tier.mark_down(1)
        after = tier.homes_of(ids)
        moved = before != after
        assert (before[moved] == 1).all()  # only replica1's ids rehash
        assert not (after == 1).any()
        tier.mark_up(1)
        assert (tier.homes_of(ids) == before).all()
    finally:
        tier.close()


def test_degraded_reads_when_replica_down(corpus):
    tier = _make_tier(corpus, 48, 2)
    try:
        down = 1
        shard_gids = [
            g for g in range(48) if ring_homes(
                build_ring(["replica0", "replica1"], VNODES), [g]
            )[0] == down
        ]
        tier.mark_down(down)
        res = tier.search(corpus.queries, W, k=PARAMS.k)
        assert tier.stats.partial_searches == 1
        got = set(np.asarray(res.ids).ravel().tolist())
        assert not (got & set(shard_gids))  # the down shard never surfaces
        assert got - {-1}  # but the surviving shard still answers
    finally:
        tier.close()


def test_fail_on_partial_raises(corpus):
    tier = _make_tier(corpus, 48, 2, fail_on_partial=True)
    try:
        tier.mark_down(0)
        with pytest.raises(RuntimeError, match="replicas down"):
            tier.search(corpus.queries, W, k=PARAMS.k)
    finally:
        tier.close()


def test_mirror_tier_least_outstanding_dispatch(corpus):
    """Mirror placement: identical full copies, each query batch goes to
    exactly ONE replica — the least-loaded one."""
    names = ["replica0", "replica1"]
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    reps = []
    for name in names:
        seg = build_pool_segment(
            corpus.docs[:48], np.arange(48), CFG,
            capacity=64, key=jax.random.key(9),
            kg_triplets=corpus.kg.triplets,
            doc_entities=corpus.doc_entities[:48],
            n_entities=corpus.kg.n_entities,
        )
        pool = place_pool(SegmentPool.from_segmented(seg), mesh)
        svc = HybridSearchService(
            pool, PARAMS,
            ServiceConfig(batcher=BatcherConfig(
                flush_size=N_QUERIES, max_batch=8, flush_deadline_s=60.0)),
            mesh=mesh,
        )
        reps.append(Replica(svc, name=name))
    tier = ReplicaRouter(
        reps, ReplicaTierConfig(placement="mirror", virtual_nodes=VNODES)
    )
    try:
        r1 = tier.search(corpus.queries, W, k=PARAMS.k)
        # pretend replica0 is busy: dispatch must pick replica1
        tier.replicas[0].outstanding = 5
        r2 = tier.search(corpus.queries, W, k=PARAMS.k)
        assert tier.stats.dispatched[1] >= 1
        assert _canonical(np.asarray(r1.ids), np.asarray(r1.scores)) == \
            _canonical(np.asarray(r2.ids), np.asarray(r2.scores))
    finally:
        tier.close()


def test_pinned_global_ids_validation(corpus):
    tier = _make_tier(corpus, 48, 2)
    try:
        router = tier.replicas[0].router
        docs = corpus.docs[48:52]
        with pytest.raises(ValueError, match="strictly increasing"):
            router.insert(
                docs, global_ids=np.asarray([60, 59, 61, 62]),
                new_doc_entities=corpus.doc_entities[48:52],
            )
        with pytest.raises(ValueError, match="strictly increasing"):
            # ids below the router's watermark would corrupt the sorted map
            router.insert(
                docs, global_ids=np.asarray([0, 1, 2, 3]),
                new_doc_entities=corpus.doc_entities[48:52],
            )
        with pytest.raises(ValueError, match="map every new doc"):
            router.insert(
                docs, global_ids=np.asarray([100, 101]),
                new_doc_entities=corpus.doc_entities[48:52],
            )
    finally:
        tier.close()
