"""Quantized corpus storage (DESIGN.md §13): per-row symmetric int8 dense +
fp16 ELL values, dequant-in-tile kernels vs the jnp oracles, seal-time
quantization through the router, the full-precision-rescore recall floor on
the bundled corpus, corpus_dtype as an executable-cache-key property, and
manifest-tagged persistence round-trips."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import (
    BuildConfig,
    FusionSpec,
    KnnConfig,
    PruneConfig,
    build_index,
)
from repro.core.distributed import (
    build_segmented_index,
    place_segmented_index,
)
from repro.core.search import SearchParams, resolve_params
from repro.core.usms import (
    PAD_IDX,
    QuantizedFusedVectors,
    corpus_nbytes_by_leaf,
    dequantize_corpus,
    quantize_corpus,
)
from repro.data.corpus import CorpusConfig, make_corpus
from repro.kernels import ops, ref
from repro.serving.batcher import BatcherConfig
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
from repro.serving.segment_router import RouterConfig, SegmentRouter
from tests.helpers import random_fused

try:  # property tests only when hypothesis is available (optional dep)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=512),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=256),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=8, iters=16, pool_size=48)
PARAMS_Q = dataclasses.replace(PARAMS, corpus_dtype="int8")
W = FusionSpec.weighted(1.0, 1.0, 1.0)
N_SEALED = 320


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=416, n_queries=16, n_topics=12, d_dense=24,
                     nnz_sparse=10, nnz_lexical=8, seed=43)
    )


@pytest.fixture(scope="module")
def sealed(corpus):
    return build_segmented_index(corpus.docs[:N_SEALED], 1, BUILD_CFG)


def _service(sealed, params=PARAMS):
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(sealed, mesh)
    return HybridSearchService(
        seg, params,
        ServiceConfig(batcher=BatcherConfig(
            flush_size=4, max_batch=4, flush_deadline_s=60.0)),
        mesh=mesh,
    )


def _probe(corpus, i):
    return jax.tree.map(lambda a: a[i:i + 1], corpus.docs)


# ---------------------------------------------------------------------------
# quantize/dequantize contract
# ---------------------------------------------------------------------------


def _assert_quant_bounds(f, q):
    """The §13 error contract for one FusedVectors -> quantized pair."""
    dense = np.asarray(f.dense, np.float32)
    dq = np.asarray(q.dense_q)
    scale = np.asarray(q.dense_scale)
    assert dq.dtype == np.int8 and scale.dtype == np.float32
    assert np.all(np.abs(dq.astype(np.int32)) <= 127)
    # per-row symmetric: |x - scale*round(x/scale)| <= scale/2 elementwise
    err = np.abs(dense - dq.astype(np.float32) * scale[..., None])
    assert np.all(err <= scale[..., None] / 2 + 1e-6)
    # fp16 sparse values: half-ulp relative error, padding slots exactly 0
    for name in ("learned", "lexical"):
        sv, sv_q = getattr(f, name), getattr(q, name)
        assert sv_q.val.dtype == np.float16
        np.testing.assert_array_equal(np.asarray(sv.idx), np.asarray(sv_q.idx))
        np.testing.assert_allclose(
            np.asarray(sv_q.val, np.float32), np.asarray(sv.val),
            rtol=5e-4, atol=1e-7,
        )
        assert np.all(np.asarray(sv_q.val)[np.asarray(sv_q.idx) == PAD_IDX] == 0)


def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(11)
    f = random_fused(rng, (37,), d_dense=24, ps=10, pf=8)
    q = quantize_corpus(f)
    assert isinstance(q, QuantizedFusedVectors) and q.n == f.dense.shape[0]
    _assert_quant_bounds(f, q)
    back = dequantize_corpus(q)
    np.testing.assert_allclose(
        np.asarray(back.dense),
        np.asarray(q.dense_q, np.float32) * np.asarray(q.dense_scale)[:, None],
        rtol=1e-6, atol=1e-7,
    )


def test_quantize_zero_and_extreme_rows():
    rng = np.random.default_rng(12)
    f = random_fused(rng, (8,), d_dense=16, ps=6, pf=4)
    dense = np.asarray(f.dense).copy()
    dense[0] = 0.0           # all-zero row: scale must default to 1.0
    dense[1] = 1e-30         # denormal-ish row still round-trips finitely
    dense[2] = -1e4          # large-magnitude row
    f = dataclasses.replace(f, dense=dense)
    q = quantize_corpus(f)
    scale = np.asarray(q.dense_scale)
    assert scale[0] == 1.0 and np.all(np.asarray(q.dense_q)[0] == 0)
    assert np.all(np.isfinite(scale)) and np.all(scale > 0)
    _assert_quant_bounds(f, q)


def test_corpus_nbytes_by_leaf_compression():
    rng = np.random.default_rng(13)
    f = random_fused(rng, (64,), d_dense=32, ps=8, pf=4)
    by_fp32 = corpus_nbytes_by_leaf(f)
    by_q = corpus_nbytes_by_leaf(quantize_corpus(f))
    assert ("dense", "float32") in by_fp32
    assert ("dense", "int8") in by_q and ("dense_scale", "float32") in by_q
    assert ("sparse_val", "float16") in by_q
    assert sum(by_q.values()) < sum(by_fp32.values())
    # idx arrays are untouched by quantization
    assert by_q[("sparse_idx", "int32")] == by_fp32[("sparse_idx", "int32")]


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        rows=st.integers(1, 12),
        dd=st.integers(1, 24),
        seed=st.integers(0, 2**20),
        mag=st.floats(1e-6, 1e6),
    )
    def test_quantize_error_bound_property(rows, dd, seed, mag):
        """Property: for ANY finite corpus the per-element dequantized dense
        error is at most half the per-row scale (the §13 bound the
        full-precision rescore relies on)."""
        rng = np.random.default_rng(seed)
        f = random_fused(rng, (rows,), d_dense=dd, ps=4, pf=3)
        f = dataclasses.replace(
            f, dense=(np.asarray(f.dense) * mag).astype(np.float32)
        )
        _assert_quant_bounds(f, quantize_corpus(f))


# ---------------------------------------------------------------------------
# dequant-in-tile kernels vs oracles
# ---------------------------------------------------------------------------


def test_quant_hybrid_scores_kernel_matches_oracle():
    rng = np.random.default_rng(21)
    q = random_fused(rng, (3,), d_dense=40, ps=9, pf=5)
    cands = quantize_corpus(random_fused(rng, (3, 130), d_dense=40, ps=9, pf=5))
    got = ops.hybrid_scores(q, cands, c_tile=64, use_kernel=True, interpret=True)
    want = ref.hybrid_scores_quant_ref(q, cands)
    assert got.shape == (3, 130) and got.dtype == np.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quant_fused_topk_kernel_matches_oracle():
    rng = np.random.default_rng(22)
    q = random_fused(rng, (2,), d_dense=32, ps=8, pf=4)
    cands = quantize_corpus(random_fused(rng, (2, 96), d_dense=32, ps=8, pf=4))
    cid = rng.permutation(4096)[: 2 * 96].reshape(2, 96).astype(np.int32)
    s_k, i_k = ops.fused_topk(q, cands, cid, k=10, c_tile=32,
                              use_kernel=True, interpret=True)
    s_r, i_r = ref.fused_topk_quant_ref(q, cands, cid, None, k=10)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


def test_quant_scores_close_to_fp32_scores():
    """Quantized traversal scores track the fp32 scores within the summed
    per-path error budget — the reason graph traversal order survives."""
    rng = np.random.default_rng(23)
    q = random_fused(rng, (2,), d_dense=24, ps=8, pf=4)
    cands = random_fused(rng, (2, 64), d_dense=24, ps=8, pf=4)
    s32 = np.asarray(ref.hybrid_scores_ref(q, cands))
    s8 = np.asarray(ref.hybrid_scores_quant_ref(q, quantize_corpus(cands)))
    # dense error <= sum_d |q_d| * scale/2; normal(0,1) rows at Dd=24 keep
    # scale ~ 3.5/127, so a generous absolute envelope suffices
    assert np.max(np.abs(s8 - s32)) < 0.5
    # ranking agreement at the top: the argmax candidate stays in the top-4
    for b in range(2):
        assert np.argmax(s32[b]) in np.argsort(s8[b])[-4:]


# ---------------------------------------------------------------------------
# params / cache-key contract
# ---------------------------------------------------------------------------


def test_corpus_dtype_validated_and_distinguishes_resolved_params():
    with pytest.raises(ValueError, match="corpus_dtype"):
        resolve_params(dataclasses.replace(PARAMS, corpus_dtype="int4"))
    r32, r8 = resolve_params(PARAMS), resolve_params(PARAMS_Q)
    assert r32 != r8          # distinct executable-cache keys...
    assert hash(r32) != hash(r8)
    assert len({r32, r8}) == 2  # ...and usable as dict keys side by side


def test_cache_key_distinguishes_corpus_dtype(corpus, sealed):
    """Two services over the SAME placed index, differing only in
    corpus_dtype, must compile into disjoint executable-cache entries —
    dtype is a cache-key property, not traced data."""
    svc32 = _service(sealed, PARAMS)
    svc8 = _service(sealed, PARAMS_Q)  # int8 params over fp32 parts: allowed
    r32 = svc32.search(corpus.queries[:4], W, k=5)
    r8 = svc8.search(corpus.queries[:4], W, k=5)
    np.testing.assert_array_equal(np.asarray(r32.ids), np.asarray(r8.ids))
    keys32, keys8 = set(svc32.executable_cache), set(svc8.executable_cache)
    assert keys32 and keys8 and not (keys32 & keys8)
    # the only differing key component is the resolved params
    (k32,), (k8,) = keys32, keys8
    assert k32[0] == k8[0] and k32[1] == k8[1] and k32[2] != k8[2]


def test_service_rejects_quantized_parts_under_fp32_params(corpus):
    idx = build_index(corpus.docs[:64], BUILD_CFG)
    idx_q = dataclasses.replace(idx, corpus=quantize_corpus(idx.corpus))
    with pytest.raises(ValueError, match="corpus_dtype"):
        HybridSearchService(idx_q, PARAMS, ServiceConfig(
            batcher=BatcherConfig(flush_size=4, max_batch=4,
                                  flush_deadline_s=60.0)))


# ---------------------------------------------------------------------------
# seal-time quantization through the router
# ---------------------------------------------------------------------------


def test_router_seal_and_compact_quantizes_pool(corpus, sealed):
    svc = _service(sealed, PARAMS_Q)
    router = SegmentRouter(svc, BUILD_CFG,
                           RouterConfig(seal_threshold=10**9,
                                        background_merge=False))
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 24])
    # grow segment stays fp32 (builds are full precision)
    assert not isinstance(svc._snap.grow.corpus, QuantizedFusedVectors)
    router.seal_and_compact()
    # the resealed segmented index stores its stacked corpus quantized
    assert isinstance(svc._snap.index.index.corpus, QuantizedFusedVectors)
    # quantized traversal + fp32 rescore still nails the probe's own vector
    res = svc.search(_probe(corpus, N_SEALED + 7), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 7


def test_router_incremental_compact_quantizes_new_segment(corpus, sealed):
    svc = _service(sealed, PARAMS_Q)
    router = SegmentRouter(
        svc, BUILD_CFG,
        RouterConfig(seal_threshold=10**9, compaction="incremental",
                     background_merge=False),
    )
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])
    router.compact_incremental()
    pool = svc._snap.index
    flags = [isinstance(g.index.corpus, QuantizedFusedVectors)
             for g in pool.groups]
    assert flags[-1]  # the compacted pool segment sealed quantized
    res = svc.search(_probe(corpus, N_SEALED + 3), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 3


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def test_save_load_quantized_roundtrip(corpus, tmp_path):
    import json

    from repro.checkpoint import load_index, save_index

    idx = build_index(corpus.docs[:96], BUILD_CFG)
    idx_q = dataclasses.replace(idx, corpus=quantize_corpus(idx.corpus))
    save_index(tmp_path / "idx", idx_q)

    manifest = json.loads(
        (tmp_path / "idx" / "step_0" / "manifest.json").read_text()
    )
    rec = manifest["quantization"]
    assert rec["corpus_dtype"] == "int8"
    assert rec["scale_layout"] == "per_row_symmetric"
    assert rec["compression_ratio"] > 1.0

    loaded = load_index(tmp_path / "idx")
    assert isinstance(loaded.corpus, QuantizedFusedVectors)
    for a, b in zip(jax.tree.leaves(idx_q), jax.tree.leaves(loaded)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bundled-corpus recall floor (the committed gate invariant)
# ---------------------------------------------------------------------------


def test_bundled_recall_floor_and_trace_budget():
    """Quantized traversal + full-precision rescore on the bundled
    120-paragraph corpus: recall@10 within the committed floor of fp32, one
    search_padded trace per storage type, ZERO retraces on repeats (the
    quantized gate in check_regression.py enforces the same numbers)."""
    import benchmarks.kernel_bench as kb

    out = kb.run_quantized_recall()
    assert out["recall_at_10_int8"] >= out["recall_at_10_fp32"] - 0.02
    # trace counters are process-global: earlier suite tests may have
    # already traced the fp32 combination, so the in-suite bound is "at
    # most one NEW trace per storage type"; the quantized gate pins the
    # exact fresh-process count (2) against the committed baseline
    assert out["sweep_traces"] <= 2
    assert out["repeat_traces"] == 0
