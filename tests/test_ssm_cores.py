"""Chunk-parallel SSM cores vs exact per-step scans (WKV6 + Mamba2 SSD) —
the hardware-adapted chunked forms must match the recurrence oracles."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.models import mamba2, rwkv6


def _wkv_inputs(rng, b, l, h, k, w_lo=0.5, w_hi=0.999):
    r = jnp.asarray(rng.normal(size=(b, l, h, k)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, l, h, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, h, k)), jnp.float32)
    w = jnp.asarray(rng.uniform(w_lo, w_hi, size=(b, l, h, k)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, k)), jnp.float32)
    return r, kk, v, w, u


@pytest.mark.parametrize("chunk,l", [(8, 32), (16, 64), (16, 16)])
def test_wkv6_chunked_matches_scan(chunk, l):
    rng = np.random.default_rng(l)
    b, h, k = 2, 3, 8
    r, kk, v, w, u = _wkv_inputs(rng, b, l, h, k)
    s0 = jnp.asarray(rng.normal(size=(b, h, k, k)), jnp.float32)
    y1, s1 = rwkv6.wkv6_scan(r, kk, v, w, u, s0)
    y2, s2 = rwkv6.wkv6_chunked(r, kk, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_wkv6_strong_decay_stable():
    """Strong decay (w -> 0.05) must not produce inf/nan in the chunked path."""
    rng = np.random.default_rng(3)
    r, kk, v, w, u = _wkv_inputs(rng, 1, 32, 2, 8, w_lo=0.05, w_hi=0.3)
    s0 = jnp.zeros((1, 2, 8, 8))
    y, s = rwkv6.wkv6_chunked(r, kk, v, w, u, s0, chunk=16)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
    y1, s1 = rwkv6.wkv6_scan(r, kk, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y1), rtol=1e-3, atol=1e-3)


def test_wkv6_step_matches_scan():
    rng = np.random.default_rng(5)
    r, kk, v, w, u = _wkv_inputs(rng, 2, 8, 2, 4)
    s = jnp.zeros((2, 2, 4, 4))
    ys = []
    for t in range(8):
        y, s = rwkv6.wkv6_step(r[:, t], kk[:, t], v[:, t], w[:, t], u, s)
        ys.append(y)
    y_steps = jnp.stack(ys, axis=1)
    y_scan, s_scan = rwkv6.wkv6_scan(r, kk, v, w, u, jnp.zeros((2, 2, 4, 4)))
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_scan), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_scan), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk,l", [(8, 32), (16, 64)])
def test_ssd_chunked_matches_scan(chunk, l):
    rng = np.random.default_rng(l + 1)
    b, h, p, n = 2, 3, 8, 8
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.2, size=(b, l, h))), jnp.float32)
    a_neg = -jnp.asarray(np.abs(rng.normal(1.0, 0.5, size=(h,))), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
    y1, s1 = mamba2.ssd_scan(x, dt, a_neg, bm, cm, s0)
    y2, s2 = mamba2.ssd_chunked(x, dt, a_neg, bm, cm, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**20), st.sampled_from([8, 16]), st.sampled_from([16, 32]))
def test_property_ssd_causal(seed, chunk, l):
    """Changing inputs at time t must not affect outputs before t."""
    rng = np.random.default_rng(seed)
    b, h, p, n = 1, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.2, size=(b, l, h))), jnp.float32)
    a_neg = -jnp.ones((h,), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    s0 = jnp.zeros((b, h, p, n))
    y1, _ = mamba2.ssd_chunked(x, dt, a_neg, bm, cm, s0, chunk=chunk)
    t = l // 2
    x2 = x.at[:, t:].set(100.0)
    y2, _ = mamba2.ssd_chunked(x2, dt, a_neg, bm, cm, s0, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(y1[:, :t]), np.asarray(y2[:, :t]), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**20))
def test_property_wkv6_causal(seed):
    rng = np.random.default_rng(seed)
    b, l, h, k = 1, 32, 2, 4
    r, kk, v, w, u = _wkv_inputs(rng, b, l, h, k)
    s0 = jnp.zeros((b, h, k, k))
    y1, _ = rwkv6.wkv6_chunked(r, kk, v, w, u, s0, chunk=8)
    t = 16
    kk2 = kk.at[:, t:].set(50.0)
    y2, _ = rwkv6.wkv6_chunked(r, kk2, v, w, u, s0, chunk=8)
    np.testing.assert_allclose(
        np.asarray(y1[:, :t]), np.asarray(y2[:, :t]), rtol=1e-5, atol=1e-5
    )
