"""Dynamic fusion framework (DESIGN.md §11): FusionSpec API surface,
zero-recompile contract across modes/weights/rrf_k, numpy oracles for RRF
and normalized fusion over the final candidate pool, the cross-part merge
contract, the adaptive selector, and the PathWeights deprecation shim."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.fusion import (
    RRF,
    WEIGHTED_SUM,
    ZSCORE,
    FusionSpec,
    PathStats,
    adaptive_fusion,
    as_fusion_spec,
    merge_fused_host,
    stack_specs,
)
from repro.core.search import SearchParams, search, search_padded_trace_count
from repro.core.usms import PAD_IDX, PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.serving.batcher import BatcherConfig, SearchRequest
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=512),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=256),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=10, iters=32, pool_size=48, kw_pool_size=16)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=512, n_queries=8, n_topics=12, d_dense=32,
                     nnz_sparse=10, nnz_lexical=8, seed=7)
    )


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(corpus.docs, BUILD_CFG)


@pytest.fixture(scope="module")
def stats(index):
    return PathStats.from_corpus(index.corpus, index.alive)


# ---------------------------------------------------------------------------
# API surface: bit-compatible default, deprecation shim, spec stacking.
# ---------------------------------------------------------------------------


def test_weighted_sum_bit_compatible_with_pathweights(corpus, index):
    """FusionSpec(mode=weighted_sum) must return EXACTLY what the legacy
    PathWeights path returns — same executable, same floats."""
    spec = FusionSpec.weighted(0.7, 0.3, 0.2)
    res_new = search(index, corpus.queries, spec, PARAMS)
    with pytest.deprecated_call():
        res_old = search(
            index, corpus.queries, PathWeights.make(0.7, 0.3, 0.2), PARAMS
        )
    assert np.array_equal(np.asarray(res_new.ids), np.asarray(res_old.ids))
    assert np.array_equal(
        np.asarray(res_new.scores), np.asarray(res_old.scores)
    )


def test_pathweights_shim_warns_and_converts():
    with pytest.deprecated_call():
        spec = as_fusion_spec(PathWeights.three_path())
    assert isinstance(spec, FusionSpec)
    assert int(spec.mode) == WEIGHTED_SUM
    with pytest.raises(TypeError):
        as_fusion_spec((1.0, 1.0, 1.0))


def test_stack_specs_preserves_mode_dtype_and_rejects_mixed_stats():
    stacked = stack_specs([FusionSpec.three_path(), FusionSpec.rrf()])
    assert stacked.mode.dtype == jnp.int32
    assert stacked.mode.shape == (2,)
    assert stacked.rrf_k.shape == (2,)
    with pytest.raises(ValueError, match="mixed stats"):
        stack_specs(
            [FusionSpec.three_path(),
             FusionSpec.minmax(stats=PathStats.identity())]
        )


# ---------------------------------------------------------------------------
# Zero-recompile contract: mode/weights/rrf_k/stats are traced data.
# ---------------------------------------------------------------------------


def test_zero_retrace_across_fusion_params(corpus, index, stats):
    """One compiled executable serves every (mode, weights, rrf_k) mix of a
    pytree structure: after the first call, switching fusion parameters must
    never retrace search_padded."""
    search(index, corpus.queries, FusionSpec.weighted(1, 0, 0), PARAMS)
    warm = search_padded_trace_count()
    for spec in [
        FusionSpec.three_path(),
        FusionSpec.weighted(0.3, 0.9, 0.2, kg=2.0),
        FusionSpec.rrf(),
        FusionSpec.rrf(rrf_k=7.0),
        FusionSpec.make("minmax", 1.0, 1.0, 1.0),
        FusionSpec.make("zscore", 0.5, 1.0, 1.0),
    ]:
        search(index, corpus.queries, spec, PARAMS)
    assert search_padded_trace_count() == warm, (
        "switching fusion mode/weights/rrf_k retraced search_padded"
    )
    # stats=None -> stats=PathStats is a different pytree structure (one
    # extra trace, by design); after that, stats VALUES are traced data too
    search(index, corpus.queries, FusionSpec.minmax(stats=stats), PARAMS)
    warm2 = search_padded_trace_count()
    search(index, corpus.queries, FusionSpec.zscore(stats=stats), PARAMS)
    search(
        index, corpus.queries,
        FusionSpec.minmax(stats=PathStats.identity()), PARAMS,
    )
    assert search_padded_trace_count() == warm2, (
        "switching normalization stats values retraced search_padded"
    )


def test_service_exec_cache_excludes_fusion(corpus, index):
    """The AOT executable cache is keyed on (index, bucket, params) ONLY:
    requests with different fusion modes share one compiled executable."""
    svc = HybridSearchService(
        index, PARAMS,
        ServiceConfig(batcher=BatcherConfig(
            flush_size=4, max_batch=4, kw_cap=4, ent_cap=2,
            flush_deadline_s=60.0,
        )),
        build_cfg=BUILD_CFG,
    )
    specs = [
        FusionSpec.three_path(),
        FusionSpec.rrf(),
        FusionSpec.zscore(),
        FusionSpec.weighted(0.2, 0.9, 0.1),
    ]
    pend = [
        svc.submit(SearchRequest(query=corpus.queries[i], fusion=specs[i], k=5))
        for i in range(4)
    ]
    svc.flush()
    assert len(svc._exec_cache) == 1
    # a second wave of mode-mixed requests reuses the same executable
    pend += [
        svc.submit(SearchRequest(
            query=corpus.queries[i], fusion=specs[3 - i], k=5,
        ))
        for i in range(4)
    ]
    svc.flush()
    assert len(svc._exec_cache) == 1, (
        "fusion leaked into the executable-cache key"
    )
    for p in pend:
        ids, _ = p.result()
        assert (np.asarray(ids) >= 0).any()


# ---------------------------------------------------------------------------
# Numpy oracles: RRF / minmax / zscore re-score the SAME final pool the
# weighted traversal produced (weights fixed at 1,1,1 so the traversal —
# and hence the pool — is identical across modes).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def final_pool(corpus, index):
    """Recover the whole final candidate pool (ids + per-path raw scores)
    by asking the weighted run for k = pool_size + kw_pool_size."""
    full_k = PARAMS.pool_size + PARAMS.kw_pool_size
    res = search(
        index, corpus.queries, FusionSpec.three_path(),
        dataclasses.replace(PARAMS, k=full_k),
    )
    ids = np.asarray(res.ids)
    ps = np.asarray(res.path_scores)
    return ids, ps, ids >= 0


def _np_ranks(ps, valid):
    """Reference ranks: rank_p(i) = #valid j with higher score (ties by
    position) — the definition fusion.ranks_desc implements."""
    m = ps.shape[0]
    r = np.zeros_like(ps)
    pos = np.arange(m)
    for p in range(ps.shape[1]):
        col = ps[:, p]
        for i in range(m):
            beats = ((col > col[i]) | ((col == col[i]) & (pos < i))) & valid
            r[i, p] = beats.sum()
    return r


def _assert_matches_oracle(res, oracle, ids_full, k, atol=1e-4):
    """Mode-run output == numpy top-k of the oracle scores, up to tie
    order (random float scores make exact ties vanishingly rare)."""
    for b in range(oracle.shape[0]):
        order = np.argsort(-oracle[b], kind="stable")[:k]
        got_scores = np.asarray(res.scores[b])
        assert np.allclose(got_scores, oracle[b][order], atol=atol), (
            f"row {b}: fused scores diverge from the numpy oracle"
        )
        assert set(np.asarray(res.ids[b]).tolist()) == set(
            ids_full[b][order].tolist()
        ), f"row {b}: fused top-{k} ids diverge from the numpy oracle"


def test_rrf_matches_numpy_oracle(corpus, index, final_pool):
    ids_full, ps_full, valid = final_pool
    rrf_k = 13.0
    res = search(index, corpus.queries, FusionSpec.rrf(rrf_k=rrf_k), PARAMS)
    oracle = np.full(ids_full.shape, -np.inf, np.float32)
    for b in range(ids_full.shape[0]):
        ranks = _np_ranks(ps_full[b], valid[b])
        scores = (1.0 / (rrf_k + 1.0 + ranks)).sum(-1)
        oracle[b] = np.where(valid[b], scores, -np.inf)
    _assert_matches_oracle(res, oracle, ids_full, PARAMS.k, atol=1e-6)


def test_minmax_matches_numpy_oracle(corpus, index, stats, final_pool):
    ids_full, ps_full, valid = final_pool
    res = search(
        index, corpus.queries, FusionSpec.minmax(stats=stats), PARAMS
    )
    minv = np.asarray(stats.minv, np.float32)
    scale = np.maximum(np.asarray(stats.maxv) - minv, 1e-6).astype(np.float32)
    scores = ((ps_full - minv) / scale).sum(-1)
    oracle = np.where(valid, scores, -np.inf).astype(np.float32)
    _assert_matches_oracle(res, oracle, ids_full, PARAMS.k)


def test_zscore_matches_numpy_oracle(corpus, index, stats, final_pool):
    ids_full, ps_full, valid = final_pool
    res = search(
        index, corpus.queries, FusionSpec.zscore(stats=stats), PARAMS
    )
    mean = np.asarray(stats.mean, np.float32)
    std = np.maximum(np.asarray(stats.std), 1e-6).astype(np.float32)
    scores = ((ps_full - mean) / std).sum(-1)
    oracle = np.where(valid, scores, -np.inf).astype(np.float32)
    _assert_matches_oracle(res, oracle, ids_full, PARAMS.k)


def test_per_query_modes_match_whole_batch_runs(corpus, index, stats):
    """A batched spec mixing modes row-wise returns, per row, exactly what
    the whole-batch run of that row's mode returns."""
    b = corpus.queries.dense.shape[0]
    row_specs = [
        [FusionSpec.three_path(), FusionSpec.rrf(),
         FusionSpec.zscore(stats=stats), FusionSpec.minmax(stats=stats)][i % 4]
        for i in range(b)
    ]
    resolved = [
        s if s.stats is not None else dataclasses.replace(s, stats=stats)
        for s in row_specs
    ]
    mixed = search(index, corpus.queries, stack_specs(resolved), PARAMS)
    for i, spec in enumerate(resolved):
        solo = search(index, corpus.queries, spec, PARAMS)
        assert np.array_equal(
            np.asarray(mixed.ids[i]), np.asarray(solo.ids[i])
        ), f"row {i}: per-query mode result diverges from whole-batch run"


# ---------------------------------------------------------------------------
# Merge contract: RRF merges recompute ranks over the union — never compare
# raw local scores (the regression the old raw-score merge had).
# ---------------------------------------------------------------------------


def test_merge_host_rrf_recomputes_ranks_over_union():
    # two shards, dense-path-only RRF with rrf_k=0: local scores are
    # 1/(1+local_rank), so BOTH shard winners carry the same raw score 1.0
    ids_parts = [np.array([[0, 1]]), np.array([[2, 3]])]
    score_parts = [
        np.array([[1.0, 0.5]], np.float32),
        np.array([[1.0, 0.5]], np.float32),
    ]
    path_parts = [
        np.array([[[10.0, 0, 0], [9.0, 0, 0]]], np.float32),
        np.array([[[8.0, 0, 0], [7.0, 0, 0]]], np.float32),
    ]
    spec = FusionSpec.rrf(1.0, 0.0, 0.0, rrf_k=0.0)
    ids, scores, ps = merge_fused_host(
        ids_parts, score_parts, path_parts, spec, 2
    )
    # union ranks on the dense path: doc0 < doc1 < doc2 < doc3, so the
    # correct top-2 is [0, 1] with scores [1, 1/2]
    assert ids[0].tolist() == [0, 1]
    assert np.allclose(scores[0], [1.0, 0.5])
    assert np.allclose(ps[0, :, 0], [10.0, 9.0])
    # the old raw-score merge would have tie-picked [0, 2] — the corruption
    # this contract prevents
    naive = np.concatenate(score_parts, axis=1)
    naive_ids = np.concatenate(ids_parts, axis=1)
    naive_top = naive_ids[0][np.argsort(-naive[0], kind="stable")[:2]]
    assert naive_top.tolist() == [0, 2]
    assert naive_top.tolist() != ids[0].tolist()


def test_merge_host_rrf_without_path_scores_raises():
    ids_parts = [np.array([[0, 1]]), np.array([[2, 3]])]
    score_parts = [np.ones((1, 2), np.float32), np.ones((1, 2), np.float32)]
    with pytest.raises(ValueError, match="merge contract"):
        merge_fused_host(ids_parts, score_parts, None, FusionSpec.rrf(), 2)


def test_merge_host_weighted_matches_raw_score_merge():
    """Non-RRF rows still merge by score (raw weighted sums ARE globally
    comparable) — including legacy callers that pass spec=None."""
    ids_parts = [np.array([[4, 2]]), np.array([[7, 5]])]
    score_parts = [
        np.array([[9.0, 3.0]], np.float32),
        np.array([[8.0, 6.0]], np.float32),
    ]
    for spec in (None, FusionSpec.three_path()):
        ids, scores, _ = merge_fused_host(
            ids_parts, score_parts, None, spec, 3
        )
        assert ids[0].tolist() == [4, 7, 5]
        assert np.allclose(scores[0], [9.0, 8.0, 6.0])


# ---------------------------------------------------------------------------
# Adaptive selector.
# ---------------------------------------------------------------------------


def test_adaptive_fusion_policy():
    kw = np.array([[3, 8], [PAD_IDX, PAD_IDX],
                   [PAD_IDX, PAD_IDX], [PAD_IDX, PAD_IDX]])
    en = np.array([[PAD_IDX], [5], [PAD_IDX], [PAD_IDX]])
    nnz = np.array([0, 0, 9, 1])
    spec = adaptive_fusion(kw, en, nnz)
    assert np.asarray(spec.mode).tolist() == [
        RRF, WEIGHTED_SUM, ZSCORE, WEIGHTED_SUM
    ]
    # entity row turns the KG path on; the others leave it off
    assert np.asarray(spec.weights.kg).tolist() == [0.0, 1.0, 0.0, 0.0]
    assert spec.stats is None  # unpinned: resolves downstream
    pinned = adaptive_fusion(kw, en, nnz, stats=PathStats.identity())
    assert pinned.stats.minv.shape == (4, 3)
    # deterministic: same inputs -> identical spec
    again = adaptive_fusion(kw, en, nnz)
    assert np.array_equal(np.asarray(again.mode), np.asarray(spec.mode))
