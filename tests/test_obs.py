"""Observability layer: metrics registry semantics (atomic counters under
concurrency, streaming-histogram quantiles, Prometheus/JSON exposition),
span trees through the serving stack, queue-wait attribution under
saturation, and the degraded replica-tier read audit trail."""

from __future__ import annotations

import json
import threading
import time

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.fusion import as_fusion_spec
from repro.core.search import SearchParams
from repro.core.segment_pool import SegmentPool, build_pool_segment, place_pool
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.obs.export import chrome_trace, write_chrome_trace
from repro.obs.metrics import (
    GLOBAL,
    MetricsRegistry,
    merged_snapshot,
    time_buckets,
)
from repro.obs.tracer import TraceContext, Tracer
from repro.runtime import dispatch
from repro.serving.batcher import BatcherConfig, SearchRequest, _next_pow2
from repro.serving.hybrid_service import (
    HybridSearchService,
    ServiceConfig,
    ServiceStats,
)
from repro.serving.replica_router import (
    Replica,
    ReplicaRouter,
    ReplicaTierConfig,
    build_ring,
    ring_homes,
)
from repro.serving.segment_router import RouterConfig, SegmentRouter

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=8, iters=2, node_chunk=128),
    prune=PruneConfig(degree=8, keyword_degree=3, node_chunk=64),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=6, iters=12, pool_size=32)
W = PathWeights.make(1.0, 1.0, 1.0)
SPEC = as_fusion_spec(W, warn=False)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=96, n_queries=8, n_topics=8, d_dense=16,
                     nnz_sparse=8, nnz_lexical=6, seed=29)
    )


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(corpus.docs, BUILD_CFG)


def _service(index, **batcher_kw):
    kw = dict(flush_size=4, max_batch=4, flush_deadline_s=60.0)
    kw.update(batcher_kw)
    return HybridSearchService(
        index, PARAMS, ServiceConfig(batcher=BatcherConfig(**kw))
    )


# -- metrics registry ---------------------------------------------------------


def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "things", labels=("kind",))
    c.inc(kind="a")
    c.inc(2, kind="b")
    assert c.value(kind="a") == 1
    assert c.value(kind="b") == 2
    assert c.total() == 3
    with pytest.raises(ValueError):
        c.inc(-1, kind="a")
    with pytest.raises(ValueError):
        c.inc(bogus="a")  # undeclared label name


def test_registry_rejects_kind_and_label_mismatch():
    reg = MetricsRegistry()
    reg.counter("m", "", labels=("x",))
    assert reg.counter("m", "", labels=("x",)) is reg.get("m")  # idempotent
    with pytest.raises(ValueError):
        reg.gauge("m")
    with pytest.raises(ValueError):
        reg.counter("m", "", labels=("y",))


def test_counter_increments_are_atomic_across_8_threads():
    # the ServiceStats regression: rejected counters used to be bare ints
    # bumped from submitter threads without a lock
    reg = MetricsRegistry()
    c = reg.counter("hammer_total", "", labels=("reason",))
    n_threads, n_incs = 8, 5000

    def hammer():
        for _ in range(n_incs):
            c.inc(reason="queue_full")

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert int(c.value(reason="queue_full")) == n_threads * n_incs


def test_service_stats_facade_concurrent_rejects():
    stats = ServiceStats(MetricsRegistry())
    n_threads, n_incs = 8, 2000

    def hammer(reason):
        for _ in range(n_incs):
            stats._rejected.inc(reason=reason)

    threads = [
        threading.Thread(
            target=hammer,
            args=("queue_full" if i % 2 else "admission",),
        )
        for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.rejected_queue_full == 4 * n_incs
    assert stats.rejected_admission == 4 * n_incs
    assert stats.rejected == n_threads * n_incs


def test_histogram_quantiles_close_to_numpy():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "")
    rng = np.random.default_rng(3)
    samples = rng.lognormal(mean=-4.0, sigma=1.0, size=4000)
    for s in samples:
        h.observe(float(s))
    snap = h.snapshot()
    assert snap.count == len(samples)
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(samples, q))
        est = snap.quantile(q)
        # geometric buckets at ratio 1.25: interpolation error stays within
        # one bucket width
        assert abs(est - exact) / exact < 0.15, (q, est, exact)


def test_histogram_snapshot_delta_isolates_a_window():
    reg = MetricsRegistry()
    h = reg.histogram("w_seconds", "")
    for _ in range(10):
        h.observe(1e-3)
    before = h.snapshot()
    for _ in range(5):
        h.observe(1.0)
    delta = h.snapshot().minus(before)
    assert delta.count == 5
    assert delta.quantile(0.5) > 0.5  # only the big observations remain


def test_time_buckets_monotone():
    b = time_buckets(1e-4, 60.0, ratio=1.25)
    assert all(x < y for x, y in zip(b, b[1:]))
    assert b[0] <= 1e-4 * 1.25 and b[-1] >= 60.0 / 1.25


def test_prometheus_render_and_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("allanpoe_test_requests_total", "reqs", labels=("mode",))
    g = reg.gauge("allanpoe_test_depth", "queue depth")
    h = reg.histogram("allanpoe_test_wait_seconds", "queue wait")
    c.inc(3, mode="rrf")
    g.set(7)
    h.observe(0.01)
    text = reg.render()
    assert "# TYPE allanpoe_test_requests_total counter" in text
    assert 'allanpoe_test_requests_total{mode="rrf"} 3' in text
    assert "allanpoe_test_depth 7" in text
    assert 'allanpoe_test_wait_seconds_bucket{le="+Inf"} 1' in text
    assert "allanpoe_test_wait_seconds_count 1" in text
    snap = reg.snapshot()
    assert snap["allanpoe_test_requests_total"]["series"][0]["value"] == 3
    hist = snap["allanpoe_test_wait_seconds"]["series"][0]
    assert hist["count"] == 1 and "p99" in hist
    json.dumps(snap)  # artifact must be JSON-able
    merged = merged_snapshot(reg, MetricsRegistry())
    assert "allanpoe_test_depth" in merged


def test_dispatch_counters_live_in_global_registry():
    before = GLOBAL.value("allanpoe_runtime_dispatches_total")
    with dispatch.track() as t:
        dispatch.tick(4)
    assert t.count == 4
    assert GLOBAL.value("allanpoe_runtime_dispatches_total") - before == 4


# -- tracer -------------------------------------------------------------------


def test_trace_context_tree_and_chrome_export(tmp_path):
    tracer = Tracer()
    with tracer.trace("query", tenant="t0") as ctx:
        with ctx.span("phase_a") as a:
            a.annotate(rows=3)
        t0 = time.perf_counter()
        ctx.add_span("phase_b", t0, t0 + 0.01, hit=True)
    assert ctx.root.t1 is not None
    names = ctx.span_names()
    assert names[0] == "query" and "phase_a" in names and "phase_b" in names
    for s in ctx.spans():
        assert s.t1 is not None and s.t1 >= s.t0
    doc = tracer.export_chrome(tmp_path / "trace.json")
    loaded = json.loads((tmp_path / "trace.json").read_text())
    assert loaded == json.loads(json.dumps(doc))
    events = [e for e in loaded["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in events} >= {"query", "phase_a", "phase_b"}
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in events)
    assert any(e["args"].get("hit") is True for e in events)


def test_add_span_clamps_negative_duration():
    ctx = TraceContext("q")
    s = ctx.add_span("x", 5.0, 4.0)
    assert s.t1 == s.t0 == 5.0


# -- serving integration ------------------------------------------------------


def test_service_query_span_tree_and_metrics(corpus, index):
    svc = _service(index)
    tracer = svc.tracer
    with tracer.trace("request") as ctx:
        for i in range(4):
            svc.submit(SearchRequest(
                query=corpus.queries[i], fusion=SPEC,
                k=PARAMS.k, trace=ctx,
            ))
        svc.flush()
    names = set(ctx.span_names())
    assert {"admission", "queue_wait", "batch_assembly",
            "executable_lookup", "device_dispatch"} <= names
    for s in ctx.spans():
        assert s.t1 is not None and s.t1 >= s.t0 >= 0
    lookups = ctx.find("executable_lookup")
    # first batch compiles: the lookup span records the miss
    assert lookups and lookups[0].attrs.get("hit") is False
    assert svc.stats.requests == 4 and svc.stats.batches == 1
    assert svc.metrics.value("allanpoe_serving_requests_total",
                             mode="weighted_sum") == 4
    assert svc.metrics.value("allanpoe_serving_executable_cache_total",
                             outcome="miss") == 1
    lat = svc.metrics.get("allanpoe_serving_request_latency_seconds")
    assert lat.snapshot().count == 4
    # warm second batch: cache hit recorded on both the span and the counter
    with tracer.trace("request2") as ctx2:
        for i in range(4):
            svc.submit(SearchRequest(
                query=corpus.queries[i], fusion=SPEC,
                k=PARAMS.k, trace=ctx2,
            ))
        svc.flush()
    assert ctx2.find("executable_lookup")[0].attrs.get("hit") is True
    assert svc.metrics.value("allanpoe_serving_executable_cache_total",
                             outcome="hit") == 1


def test_queue_wait_dominates_under_saturation(corpus, index):
    # saturate: requests sit queued (no size trigger) while the client
    # sleeps, then one flush runs the batch — queue wait must dominate the
    # measured end-to-end latency, and the histograms must attribute it
    svc = _service(index, flush_size=16, max_batch=16)
    # warm the measured bucket shape so compile time doesn't blur the
    # attribution
    for i in range(8):
        svc.submit(SearchRequest(query=corpus.queries[i],
                                 fusion=SPEC, k=PARAMS.k))
    svc.flush()
    wait_h = svc.metrics.get("allanpoe_serving_queue_wait_seconds")
    lat_h = svc.metrics.get("allanpoe_serving_request_latency_seconds")
    wait0, lat0 = wait_h.snapshot(), lat_h.snapshot()
    for i in range(8):
        svc.submit(SearchRequest(query=corpus.queries[i],
                                 fusion=SPEC, k=PARAMS.k))
    time.sleep(0.25)
    svc.flush()
    wait = wait_h.snapshot().minus(wait0)
    lat = lat_h.snapshot().minus(lat0)
    assert wait.count == 8 and lat.count == 8
    assert lat.mean >= 0.25
    assert wait.mean / lat.mean > 0.8, (wait.mean, lat.mean)


# -- replica tier -------------------------------------------------------------


def _make_tier(corpus, n_replicas=2):
    names = [f"replica{i}" for i in range(n_replicas)]
    homes = ring_homes(build_ring(names, 16), np.arange(corpus.docs.n))
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    reps = []
    for i, name in enumerate(names):
        rows = np.flatnonzero(homes == i)
        seg = build_pool_segment(
            jax.tree.map(lambda a: a[rows], corpus.docs),
            rows, BUILD_CFG,
            capacity=_next_pow2(int(rows.size)),
            key=jax.random.key(11 + i),
        )
        pool = place_pool(SegmentPool.from_segmented(seg), mesh)
        svc = HybridSearchService(
            pool, PARAMS,
            ServiceConfig(batcher=BatcherConfig(
                flush_size=4, max_batch=4, flush_deadline_s=60.0)),
            mesh=mesh,
        )
        router = SegmentRouter(
            svc, BUILD_CFG,
            RouterConfig(seal_threshold=10**9, compaction="incremental",
                         auto_merge=False),
        )
        reps.append(Replica(svc, router, name=name))
    return ReplicaRouter(reps, ReplicaTierConfig(virtual_nodes=16))


def test_degraded_tier_read_audit_trail(corpus, tmp_path):
    # the ISSUE acceptance path: 2 replicas, 1 down — the query must yield
    # a full span tree, the down replica in the result AND as a labeled
    # counter, and a valid Chrome trace
    tier = _make_tier(corpus, 2)
    try:
        queries = jax.tree.map(lambda a: a[:4], corpus.queries)
        healthy = tier.search(queries, W, k=PARAMS.k)
        assert healthy.down_replicas is None
        assert tier.stats.dispatched == [1, 1]

        tier.mark_down(1)
        with tier.tracer.trace("degraded_read") as ctx:
            res = tier.search(queries, W, k=PARAMS.k, trace=ctx)
        assert res.down_replicas == ("replica1",)
        assert ctx.root.attrs.get("down_replicas") == ["replica1"]
        assert tier.stats.partial_searches == 1
        assert tier.stats.degraded_reads("replica1") == 1
        assert tier.stats.degraded_reads("replica0") == 0
        assert tier.metrics.value(
            "allanpoe_replica_degraded_reads_total", replica="replica1"
        ) == 1

        names = set(ctx.span_names())
        assert {"admission", "queue_wait", "batch_assembly",
                "executable_lookup", "device_dispatch", "replica_dispatch",
                "scatter_gather", "fusion_rescore"} <= names
        dispatches = ctx.find("replica_dispatch")
        assert [s.attrs["replica"] for s in dispatches] == ["replica0"]
        for s in ctx.spans():
            assert s.t1 is not None and s.t1 >= s.t0 >= 0

        doc = chrome_trace([ctx], epoch=ctx.root.t0)
        events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in events)
        write_chrome_trace(tmp_path / "degraded.json", [ctx])
        json.loads((tmp_path / "degraded.json").read_text())

        # recovery: marked back up, reads are whole again
        tier.mark_up(1)
        whole = tier.search(queries, W, k=PARAMS.k)
        assert whole.down_replicas is None
        assert tier.stats.partial_searches == 1
    finally:
        tier.close()
