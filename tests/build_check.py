"""Standalone sharded-build equivalence check (2-host CPU mesh).

Run in a subprocess with fake devices (the main test process must keep the
default single CPU device):

    XLA_FLAGS=--xla_force_host_platform_device_count=2 python tests/build_check.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.distributed import (
    build_index_sharded,
    build_segmented_index,
    make_distributed_search,
)
from repro.core.search import SearchParams
from repro.core.usms import PathWeights, weighted_query
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.kernels import ops


def main():
    assert jax.device_count() == 2, jax.devices()
    corpus = make_corpus(
        CorpusConfig(
            n_docs=700,  # deliberately not divisible by 2 (padding path)
            n_queries=16,
            n_topics=16,
            d_dense=32,
            nnz_sparse=12,
            nnz_lexical=8,
            seed=9,
        )
    )
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=256),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=128),
        path_refine_iters=1,
    )
    mesh = jax.make_mesh((2,), ("data",))
    key = jax.random.key(3)

    seg_par = build_index_sharded(corpus.docs, 2, cfg, mesh=mesh, key=key)
    seg_ref = build_segmented_index(corpus.docs, 2, cfg, key=key)

    # the sharded build runs the same per-segment program with the same
    # fold_in(key, s) keys; under shard_map XLA may fuse differently, so
    # float tie-breaks can diverge — require structural agreement (shapes,
    # id map) and a high edge overlap rather than bitwise equality
    sem_par = np.asarray(seg_par.index.semantic_edges)
    sem_ref = np.asarray(seg_ref.index.semantic_edges)
    assert sem_par.shape == sem_ref.shape
    np.testing.assert_array_equal(
        np.asarray(seg_par.global_ids), np.asarray(seg_ref.global_ids)
    )
    overlap = np.mean(
        [
            len(set(a[a >= 0]) & set(b[b >= 0])) / max(len(set(a[a >= 0])), 1)
            for seg_a, seg_b in zip(sem_par, sem_ref)
            for a, b in zip(seg_a, seg_b)
        ]
    )
    assert overlap > 0.75, f"edge overlap too low: {overlap:.3f}"
    print(f"sharded build: edge overlap vs sequential build = {overlap:.3f}")

    # end to end: distributed search over the sharded build reaches the same
    # recall as over the sequential build
    weights = PathWeights.three_path()
    params = SearchParams(k=10, iters=32, pool_size=64)
    run = make_distributed_search(mesh, weights, params)
    qw = weighted_query(corpus.queries, weights)
    full = ops.pairwise_scores_chunked(qw, corpus.docs)
    _, truth = jax.lax.top_k(full, 10)
    rec_par = recall_at_k(
        np.asarray(run(seg_par, corpus.queries).ids), np.asarray(truth)
    )
    rec_ref = recall_at_k(
        np.asarray(run(seg_ref, corpus.queries).ids), np.asarray(truth)
    )
    assert rec_par > 0.8, f"sharded-build recall too low: {rec_par}"
    assert abs(rec_par - rec_ref) < 0.05, (rec_par, rec_ref)
    print(f"recall: sharded={rec_par:.3f} sequential={rec_ref:.3f}")

    # multi-segment-per-device (segment-pool contract): S=4 on the 2-device
    # mesh — each device builds AND searches 2 segments (lax.map in the
    # builder, the vmapped local pre-merge in the search). Same per-segment
    # keys as the sequential build, so the id maps must agree exactly.
    seg4_par = build_index_sharded(corpus.docs, 4, cfg, mesh=mesh, key=key)
    seg4_ref = build_segmented_index(corpus.docs, 4, cfg, key=key)
    np.testing.assert_array_equal(
        np.asarray(seg4_par.global_ids), np.asarray(seg4_ref.global_ids)
    )
    sem4_par = np.asarray(seg4_par.index.semantic_edges)
    sem4_ref = np.asarray(seg4_ref.index.semantic_edges)
    overlap4 = np.mean(
        [
            len(set(a[a >= 0]) & set(b[b >= 0])) / max(len(set(a[a >= 0])), 1)
            for seg_a, seg_b in zip(sem4_par, sem4_ref)
            for a, b in zip(seg_a, seg_b)
        ]
    )
    assert overlap4 > 0.75, f"S=4 edge overlap too low: {overlap4:.3f}"
    rec4 = recall_at_k(
        np.asarray(run(seg4_par, corpus.queries).ids), np.asarray(truth)
    )
    assert rec4 > 0.8, f"2-segments-per-device recall too low: {rec4}"
    print(
        f"S=4 on 2 devices: edge overlap={overlap4:.3f} recall={rec4:.3f}"
    )
    print("BUILD_CHECK_PASS")


if __name__ == "__main__":
    main()
