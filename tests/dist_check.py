"""Standalone distributed-search equivalence check.

Run in a subprocess with fake devices (the main test process must keep the
default single CPU device):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python tests/dist_check.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BuildConfig, KnnConfig, PruneConfig
from repro.core.distributed import (
    build_segmented_index,
    make_distributed_descent_round,
    make_distributed_search,
    place_segmented_index,
    shard_corpus,
)
from repro.core.search import SearchParams, _search_batch
from repro.core.usms import PAD_IDX, PathWeights
from repro.data.corpus import CorpusConfig, make_corpus, recall_at_k
from repro.kernels import ops


def reference_merge(seg_index, queries, weights, params):
    """Sequential per-segment search + global top-k merge (no shard_map)."""
    b = queries.dense.shape[0]
    gs, ss = [], []
    pad_kw = jnp.full((b, 1), PAD_IDX, jnp.int32)
    for s in range(seg_index.n_segments):
        idx = jax.tree.map(lambda a: a[s], seg_index.index)
        res = _search_batch(idx, queries, weights, pad_kw, pad_kw, params)
        gids = seg_index.global_ids[s]
        g = jnp.where(
            res.ids >= 0, gids[jnp.clip(res.ids, 0, gids.shape[0] - 1)], PAD_IDX
        )
        gs.append(g)
        ss.append(jnp.where(g >= 0, res.scores, -jnp.inf))
    g_all = jnp.concatenate(gs, axis=1)
    s_all = jnp.concatenate(ss, axis=1)
    top, pos = jax.lax.top_k(s_all, params.k)
    ids = jnp.where(jnp.isfinite(top), jnp.take_along_axis(g_all, pos, -1), PAD_IDX)
    return ids, top


def main():
    assert jax.device_count() == 8, jax.devices()
    corpus = make_corpus(
        CorpusConfig(
            n_docs=1000,  # deliberately not divisible by 4 (padding path)
            n_queries=16,
            n_topics=16,
            d_dense=32,
            nnz_sparse=12,
            nnz_lexical=8,
            seed=7,
        )
    )
    cfg = BuildConfig(
        knn=KnnConfig(k=16, iters=4, node_chunk=512),
        prune=PruneConfig(degree=16, keyword_degree=4, node_chunk=256),
        path_refine_iters=1,
    )
    weights = PathWeights.three_path()
    params = SearchParams(k=10, iters=32, pool_size=64)

    for axes, shape in [
        (("data", "model"), (4, 2)),
        (("pod", "data", "model"), (2, 2, 2)),
    ]:
        mesh = jax.make_mesh(shape, axes)
        n_segments = int(np.prod(shape[:-1]))
        seg = build_segmented_index(corpus.docs, n_segments, cfg)
        seg_placed = place_segmented_index(seg, mesh)
        run = make_distributed_search(mesh, weights, params)
        res = run(seg_placed, corpus.queries)
        ref_ids, ref_scores = reference_merge(seg, corpus.queries, weights, params)
        np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref_ids))
        np.testing.assert_allclose(
            np.asarray(res.scores), np.asarray(ref_scores), rtol=1e-5, atol=1e-5
        )
        # sanity: global recall vs brute force stays high despite 4-way segmenting
        from repro.core.usms import weighted_query

        qw = weighted_query(corpus.queries, weights)
        full = ops.pairwise_scores_chunked(qw, corpus.docs)
        _, truth = jax.lax.top_k(full, 10)
        rec = recall_at_k(np.asarray(res.ids), np.asarray(truth))
        assert rec > 0.8, f"distributed recall {rec} on mesh {shape}"
        print(f"mesh {dict(zip(axes, shape))}: ids match reference, recall={rec:.3f}")

    # distributed construction round lowers + runs
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    knn_cfg = KnnConfig(k=8, iters=1, extra_random=4, node_chunk=256)
    parts, gids = shard_corpus(corpus.docs, 4)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
    n_seg = gids.shape[1]
    rng = np.random.default_rng(0)
    nbr = jnp.asarray(
        rng.integers(0, n_seg, size=(4, n_seg, 8)), jnp.int32
    )
    scores = jnp.zeros((4, n_seg, 8), jnp.float32)
    rand_ids = jnp.asarray(rng.integers(0, n_seg, size=(4, n_seg, 4)), jnp.int32)
    round_fn = make_distributed_descent_round(mesh, knn_cfg)
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = NamedSharding(mesh, P("data"))
    stacked = jax.tree.map(lambda a: jax.device_put(a, sh), stacked)
    ids2, sc2 = round_fn(
        stacked,
        jax.device_put(nbr, sh),
        jax.device_put(scores, sh),
        jax.device_put(rand_ids, sh),
    )
    assert ids2.shape == (4, n_seg, 8)
    print("distributed descent round: OK")
    print("DIST_CHECK_PASS")


if __name__ == "__main__":
    main()
