"""Candidate-pairwise tile kernel: oracle equivalence, masking contract, and
agreement with the double-gather formulation it replaced."""

from __future__ import annotations

import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

sys.path.insert(0, str(Path(__file__).parent))
from helpers import random_fused  # noqa: E402

from repro.core.usms import PAD_IDX  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402


@pytest.fixture(scope="module")
def tile():
    rng = np.random.default_rng(3)
    t = random_fused(rng, (6, 8), d_dense=24, ps=7, pf=5)
    return jax.tree.map(jnp.asarray, t)


def test_tile_kernel_matches_ref(tile):
    want = ref.pairwise_tile_ref(tile)
    got = ops.pairwise_tile_scores(tile, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_tile_ref_matches_pairwise_oracle(tile):
    """Each (K, K) tile equals the brute-force all-pairs oracle over its rows."""
    out = np.asarray(ref.pairwise_tile_ref(tile))
    for c in range(out.shape[0]):
        rows = jax.tree.map(lambda a: a[c], tile)
        want = np.asarray(ref.pairwise_hybrid_scores_ref(rows, rows))
        np.testing.assert_allclose(out[c], want, rtol=1e-4, atol=1e-4)


def test_tile_matches_double_gather_formulation():
    """The tile path reproduces what the old `corpus.take` + repeat + vs_ids
    computation produced, including the invalid-candidate -inf masking."""
    rng = np.random.default_rng(7)
    corpus = jax.tree.map(jnp.asarray, random_fused(rng, (40,), d_dense=24, ps=7, pf=5))
    c, k = 5, 6
    cand_ids = jnp.asarray(rng.integers(0, 40, size=(c, k)), jnp.int32)
    cand_ids = cand_ids.at[0, -2:].set(PAD_IDX).at[3, 0].set(PAD_IDX)

    # old formulation: gather C*K query rows, score each against its K ids
    cand_rows = corpus.take(cand_ids.reshape(-1))
    pair_ids = jnp.repeat(cand_ids, k, axis=0).reshape(c * k, k)
    old = ops.hybrid_scores_vs_ids(
        cand_rows, corpus, pair_ids, use_kernel=False
    ).reshape(c, k, k)

    # new formulation: single gather + in-tile all-pairs + column mask
    tile = jax.tree.map(lambda a: a.reshape((c, k) + a.shape[1:]), cand_rows)
    new = ops.pairwise_tile_scores(tile, use_kernel=False)
    new = jnp.where(cand_ids[:, None, :] >= 0, new, -jnp.inf)

    np.testing.assert_allclose(np.asarray(new), np.asarray(old), rtol=1e-4, atol=1e-4)
