"""SegmentRouter: grow-segment streaming inserts that never evict sealed
executables, global-id deletion routing, seal-and-compact tombstone
reclamation, and the background pump thread (no lost PendingResult)."""

from __future__ import annotations

import threading
import time

import numpy as np
import pytest

import jax

from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.distributed import (
    build_segmented_index,
    place_segmented_index,
    resolve_global_ids,
)
from repro.core.search import SearchParams, search, search_padded_trace_count
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus
from repro.serving.batcher import BatcherConfig, SearchRequest
from repro.serving.hybrid_service import HybridSearchService, ServiceConfig
from repro.serving.segment_router import RouterConfig, SegmentRouter

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=512),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=256),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=8, iters=16, pool_size=48)
W = PathWeights.make(1.0, 1.0, 1.0)
N_SEALED = 320  # docs in the sealed segment; the rest stream in


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=416, n_queries=16, n_topics=12, d_dense=24,
                     nnz_sparse=10, nnz_lexical=8, seed=31)
    )


@pytest.fixture(scope="module")
def sealed(corpus):
    return build_segmented_index(corpus.docs[:N_SEALED], 1, BUILD_CFG)


def _service(sealed, **kw):
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    seg = place_segmented_index(sealed, mesh)
    svc_kw = dict(flush_size=4, max_batch=4, flush_deadline_s=60.0)
    svc_kw.update(kw.pop("batcher", {}))
    svc = HybridSearchService(
        seg, PARAMS,
        ServiceConfig(batcher=BatcherConfig(**svc_kw), **kw),
        mesh=mesh,
    )
    return svc


def _probe(corpus, i):
    """A query that IS doc i's own vector — the doc must come back first."""
    return jax.tree.map(lambda a: a[i:i + 1], corpus.docs)


def test_router_requires_segmented_service(corpus):
    index = build_index(corpus.docs[:64], BUILD_CFG)
    svc = HybridSearchService(index, PARAMS)
    with pytest.raises(ValueError):
        SegmentRouter(svc, BUILD_CFG)


def test_streaming_insert_preserves_sealed_executables(corpus, sealed):
    """The acceptance criterion: inserts land in the grow segment, searches
    see the new docs immediately, and NO sealed-segment executable is
    evicted or recompiled along the way."""
    svc = _service(sealed)
    SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))

    svc.search(corpus.queries[:4], W, k=5)  # warm the sealed executable
    sealed_keys = set(svc.executable_cache)
    sealed_exes = {k: svc.executable_cache[k] for k in sealed_keys}
    assert sealed_keys  # the 4-slot bucket compiled

    v1 = svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])
    assert v1 == 1
    # sealed entries still cached — the SAME objects, not recompiles
    for k in sealed_keys:
        assert svc.executable_cache[k] is sealed_exes[k]

    # the inserted docs are immediately searchable (probe = own vector)
    res = svc.search(_probe(corpus, N_SEALED + 7), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 7

    # a second insert extends the grow segment in place
    v2 = svc.insert(corpus.docs[N_SEALED + 32:N_SEALED + 64])
    assert v2 == 2
    res = svc.search(_probe(corpus, N_SEALED + 40), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 40

    # and the original 4-slot sealed executable is STILL the same object
    compiles = svc.stats.compiles
    svc.search(corpus.queries[:4], W, k=5)
    assert svc.stats.compiles == compiles
    for k in sealed_keys:
        assert svc.executable_cache[k] is sealed_exes[k]


def test_merged_topk_matches_reference_merge(corpus, sealed):
    """Service results over sealed+grow equal a host-side merge of direct
    searches on each part (same snapshot, global-id space)."""
    svc = _service(sealed)
    SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])

    snap = svc._snap
    sealed_local = jax.tree.map(lambda a: a[0], snap.index.index)
    queries = corpus.queries[:4]
    r_sealed = search(sealed_local, queries, W, PARAMS)  # local ids == global
    r_grow = search(snap.grow, queries, W, PARAMS)
    ggids = np.asarray(snap.grow_gids)
    g_ids = np.where(np.asarray(r_grow.ids) >= 0,
                     ggids[np.clip(np.asarray(r_grow.ids), 0, len(ggids) - 1)],
                     -1)
    all_ids = np.concatenate([np.asarray(r_sealed.ids), g_ids], axis=1)
    all_sc = np.concatenate(
        [np.where(np.asarray(r_sealed.ids) >= 0, np.asarray(r_sealed.scores), -np.inf),
         np.where(g_ids >= 0, np.asarray(r_grow.scores), -np.inf)], axis=1)
    order = np.argsort(-all_sc, axis=1, kind="stable")[:, :5]
    want = np.take_along_axis(all_ids, order, axis=1)

    got = svc.search(queries, W, k=5)
    np.testing.assert_array_equal(np.asarray(got.ids), want)
    # merged rows contain no duplicate ids
    for row in np.asarray(got.ids):
        real = row[row >= 0]
        assert len(set(real.tolist())) == len(real)


def test_delete_routes_to_sealed_and_grow_tombstones(corpus, sealed):
    svc = _service(sealed)
    router = SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])
    keys_before = set(svc.executable_cache)

    r0 = svc.search(corpus.queries[:4], W, k=5)
    top_sealed = int(np.asarray(r0.ids)[0, 0])
    assert top_sealed < N_SEALED
    grow_victim = N_SEALED + 3

    svc.mark_deleted([top_sealed, grow_victim, 10**6])  # one unknown id
    assert router.stats.deleted_sealed == 1
    assert router.stats.deleted_grow == 1
    assert router.stats.unknown_deletes == 1

    r1 = svc.search(corpus.queries[:4], W, k=5)
    assert top_sealed not in np.asarray(r1.ids)[0]
    res = svc.search(_probe(corpus, grow_victim), W, k=5)
    assert grow_victim not in np.asarray(res.ids)[0]
    # tombstones are shape-preserving: nothing evicted
    assert keys_before <= set(svc.executable_cache)


def test_delete_then_compact_drops_tombstoned_ids(corpus, sealed):
    """Compaction physically reclaims tombstoned rows: the new sealed index
    contains every surviving id and none of the deleted ones, and the grow
    segment is cleared."""
    svc = _service(sealed)
    router = SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])

    deleted = [5, 17, N_SEALED + 1, N_SEALED + 30]
    svc.mark_deleted(deleted)
    v = router.seal_and_compact()
    assert router.stats.compactions == 1
    assert svc.grow_index is None

    gids = np.asarray(svc.index.global_ids)
    live = set(gids[gids >= 0].tolist())
    expected = set(range(N_SEALED + 32)) - set(deleted)
    assert live == expected
    assert svc.snapshot_version == v

    # compacted docs stay reachable under their ORIGINAL global ids
    res = svc.search(_probe(corpus, N_SEALED + 12), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 12
    # deleted ids never come back
    res = svc.search(_probe(corpus, N_SEALED + 1), W, k=5)
    assert N_SEALED + 1 not in np.asarray(res.ids)[0]

    # the routing table resolves survivors and rejects the reclaimed ids
    seg, loc = resolve_global_ids(svc.index, np.asarray([6, 5, N_SEALED + 1]))
    assert seg[0] == 0 and loc[0] >= 0
    assert seg[1] == -1 and seg[2] == -1


def test_auto_compact_on_seal_threshold(corpus, sealed):
    svc = _service(sealed)
    router = SegmentRouter(
        svc, BUILD_CFG, RouterConfig(seal_threshold=48, auto_compact=True)
    )
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])
    assert router.stats.compactions == 0  # 32 < 48: still growing
    assert router.grow_size == 32
    svc.insert(corpus.docs[N_SEALED + 32:N_SEALED + 64])
    assert router.stats.compactions == 1  # 64 >= 48: sealed + compacted
    assert svc.grow_index is None
    gids = np.asarray(svc.index.global_ids)
    assert set(gids[gids >= 0].tolist()) == set(range(N_SEALED + 64))
    # post-compaction inserts start a fresh grow segment
    svc.insert(corpus.docs[N_SEALED + 64:N_SEALED + 80])
    assert router.grow_size == 16
    res = svc.search(_probe(corpus, N_SEALED + 70), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 70


def test_kg_survives_insert_and_compaction():
    """A KG-bearing deployment keeps its entity paths end-to-end: entity
    queries work on sealed docs, on grow docs inserted WITH entities, and
    still work after delete + seal_and_compact (logical edges are rebuilt
    over the survivors). A triplet-less router over a KG index fails fast."""
    corpus = make_corpus(
        CorpusConfig(n_docs=224, n_queries=8, n_topics=8, d_dense=16,
                     nnz_sparse=8, nnz_lexical=6, seed=13)
    )
    n0 = 192
    sealed = build_segmented_index(
        corpus.docs[:n0], 1, BUILD_CFG,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities[:n0],
        n_entities=corpus.kg.n_entities,
    )
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sealed = place_segmented_index(sealed, mesh)
    params = SearchParams(k=8, iters=16, pool_size=64, use_kg=True)
    svc = HybridSearchService(
        sealed, params,
        ServiceConfig(batcher=BatcherConfig(flush_size=2, max_batch=2)),
        mesh=mesh,
    )
    # a triplet-less router over this index would drop the KG at compaction
    with pytest.raises(ValueError, match="kg_triplets"):
        SegmentRouter(svc, BUILD_CFG)
    SegmentRouter(
        svc, BUILD_CFG, RouterConfig(seal_threshold=10**9),
        kg_triplets=corpus.kg.triplets, n_entities=corpus.kg.n_entities,
    )
    w = PathWeights.make(0.2, 0.2, 0.2, kg=2.0)

    def entity_hits(doc):
        # make_corpus gives doc i the unique rare entity i: an entity query
        # must surface that doc through the logical path
        res = svc.search(
            corpus.queries[:1], w,
            entities=np.asarray([[doc]], np.int32), k=8,
        )
        return np.asarray(res.ids)[0]

    assert 100 in entity_hits(100)  # sealed doc via entity

    # entities REQUIRE a kg-configured router; wrong shapes are rejected
    with pytest.raises(ValueError):
        svc.insert(corpus.docs[n0:n0 + 32],
                   new_doc_entities=corpus.doc_entities[:3])
    svc.insert(corpus.docs[n0:n0 + 32],
               new_doc_entities=corpus.doc_entities[n0:n0 + 32])
    assert 200 in entity_hits(200)  # grow doc via entity (birth batch)

    svc.mark_deleted([200])
    svc._router.seal_and_compact()
    assert svc.grow_index is None
    assert 210 in entity_hits(210)  # grow doc's entity path survived compact
    assert 100 in entity_hits(100)  # sealed doc's entity path survived
    assert 200 not in entity_hits(200)  # deleted doc physically gone

    # an entity-LESS insert births the next grow segment with the sealed
    # entity width, so a later entity-carrying insert into it must work
    # (and those entities land in the logical edges at the next compaction)
    svc.insert(corpus.docs[192:200])  # fresh grow, no entities (ids 224..)
    svc.insert(corpus.docs[200:208],
               new_doc_entities=corpus.doc_entities[200:208])
    svc._router.seal_and_compact()
    # the second batch's docs got ids 232..239 and carry entities 200..207
    assert 236 in entity_hits(204)


def test_grow_pow2_bucketing_limits_retraces(corpus, sealed):
    """Shape-bucketed grow segment: publishing the grow segment padded to
    power-of-two capacity means the read path's ``search_padded`` retraces
    once per CAPACITY (O(log growth)) between compactions, not once per
    insert batch — and dead pad rows never surface in results."""
    svc = _service(sealed)
    router = SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    svc.search(corpus.queries[:4], W, k=5)  # warm the sealed executable
    t0 = search_padded_trace_count()

    caps = []
    for b in range(6):
        lo = N_SEALED + 8 * b
        svc.insert(corpus.docs[lo:lo + 8])
        res = svc.search(corpus.queries[:4], W, k=5)  # grow read each insert
        assert (np.asarray(res.ids) < router.grow_size + N_SEALED).all()
        caps.append(router.grow_capacity)

    # raw sizes 8..48 bucket to capacities {8, 16, 32, 64}
    assert caps == [8, 16, 32, 32, 64, 64]
    assert router.grow_size == 48  # real rows, pads excluded
    # retrace accounting: 6 grow reads hit only 4 distinct capacities, and
    # inserts 2..6 each retrace once for their raw-shape probe search.
    # Unbucketed, the same sequence costs 6 + 5 = 11 traces.
    retraces = search_padded_trace_count() - t0
    assert retraces <= 4 + 5

    # every real doc is reachable, pad rows are not (ids stay < grow_size)
    res = svc.search(_probe(corpus, N_SEALED + 44), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 44

    # a second read at an already-seen capacity adds NO trace
    t1 = search_padded_trace_count()
    svc.search(corpus.queries[4:8], W, k=5)
    assert search_padded_trace_count() == t1

    # tombstones apply to both the published and the raw grow segment, so a
    # later insert (which extends the raw one) cannot resurrect them
    victim = N_SEALED + 10
    svc.mark_deleted([victim])
    svc.insert(corpus.docs[N_SEALED + 48:N_SEALED + 56])
    res = svc.search(_probe(corpus, victim), W, k=5)
    assert victim not in np.asarray(res.ids)[0]


def test_insert_search_override_with_small_pool(corpus, sealed):
    """A caller-tuned insert probe with a pool SMALLER than the build k must
    not die at trace time: insert() drags the pool up with the forced k."""
    svc = _service(sealed)
    SegmentRouter(
        svc, BUILD_CFG,
        RouterConfig(seal_threshold=10**9,
                     insert_search=SearchParams(k=4, iters=8, pool_size=8)),
    )
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 16])  # birth (no probe)
    svc.insert(corpus.docs[N_SEALED + 16:N_SEALED + 32])  # probe runs here
    res = svc.search(_probe(corpus, N_SEALED + 20), W, k=5)
    assert int(np.asarray(res.ids)[0, 0]) == N_SEALED + 20


def test_reattached_router_never_reissues_grow_gids(corpus, sealed):
    """A new router over a service with a LIVE grow segment must continue
    the id sequence past the grow ids, not restart at sealed max + 1."""
    svc = _service(sealed)
    SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])  # gids 320..351
    router2 = SegmentRouter(  # re-attach (e.g. config change)
        svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    assert router2._next_gid == N_SEALED + 32
    svc.insert(corpus.docs[N_SEALED + 32:N_SEALED + 48])
    gids = np.asarray(svc._snap.grow_gids)
    assert len(set(gids.tolist())) == len(gids)  # unique
    assert (np.diff(gids) > 0).all()  # still sorted (delete routing relies on it)


def test_start_pump_concurrent_and_idempotent(corpus, sealed):
    svc = _service(sealed)
    threads = [threading.Thread(target=svc.start_pump, args=(0.01,))
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    alive = [t for t in threading.enumerate()
             if t.name == "hybrid-service-pump" and t.is_alive()]
    assert len(alive) == 1  # exactly one pump, no orphans
    svc.start_pump(0.01)  # idempotent while running
    assert len([t for t in threading.enumerate()
                if t.name == "hybrid-service-pump" and t.is_alive()]) == 1
    svc.stop_pump()
    time.sleep(0.05)
    assert not any(t.name == "hybrid-service-pump" and t.is_alive()
                   for t in threading.enumerate())


def test_pump_thread_no_lost_results(corpus, sealed):
    """Worker threads submit WITHOUT ever flushing; the background pump
    thread alone must deliver every PendingResult (deadline flushes no
    longer depend on the submit path)."""
    svc = _service(
        sealed,
        batcher=dict(flush_size=4, max_batch=4, flush_deadline_s=0.001,
                     max_queue=4096),
        pump_interval_s=0.002,
    )
    SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    try:
        n_per, n_workers = 8, 3
        results = [None] * (n_per * n_workers)

        def client(base):
            for i in range(n_per):
                results[base + i] = svc.submit(SearchRequest(
                    query=corpus.queries[(base + i) % 16],
                    weights=W, k=3))

        workers = [threading.Thread(target=client, args=(b * n_per,))
                   for b in range(n_workers)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        # wait on done flags only — result() would force a flush and mask a
        # dead pump; the pump must deliver on its own
        deadline = time.monotonic() + 60.0
        while (not all(p.done for p in results)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert all(p.done for p in results), "pump thread lost results"
        assert svc.stats.requests == n_per * n_workers
        for p in results:
            assert p.result()[0].shape == (3,)
    finally:
        svc.stop_pump()
    assert svc._pump_thread is None


def test_pump_delivers_during_streaming_inserts(corpus, sealed):
    """Submissions racing a concurrent insert (snapshot publish) all
    deliver; results reference a consistent snapshot either side of the
    swap."""
    svc = _service(
        sealed,
        batcher=dict(flush_size=4, max_batch=4, flush_deadline_s=0.001,
                     max_queue=4096),
        pump_interval_s=0.002,
    )
    SegmentRouter(svc, BUILD_CFG, RouterConfig(seal_threshold=10**9))
    try:
        svc.insert(corpus.docs[N_SEALED:N_SEALED + 32])  # grow exists
        pendings = []
        done = threading.Event()

        def client():
            for i in range(12):
                pendings.append(svc.submit(SearchRequest(
                    query=corpus.queries[i % 16], weights=W, k=3)))
                time.sleep(0.002)
            done.set()

        t = threading.Thread(target=client)
        t.start()
        svc.insert(corpus.docs[N_SEALED + 32:N_SEALED + 48])  # racing insert
        t.join()
        assert done.wait(1.0)
        deadline = time.monotonic() + 60.0
        while (not all(p.done for p in pendings)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert all(p.done for p in pendings)
        for p in pendings:
            ids, _ = p.result()
            assert ids.shape == (3,)
    finally:
        svc.stop_pump()
