"""save_index/load_index: atomic manifest+leaf persistence of a HybridIndex
plus the ingestion vocab/corpus-stats manifest (checkpoint/index_io.py)."""

from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.checkpoint import load_index, load_ingest, save_index
from repro.core import BuildConfig, KnnConfig, PruneConfig, build_index
from repro.core.search import SearchParams, search
from repro.core.usms import PathWeights
from repro.data.corpus import CorpusConfig, make_corpus

BUILD_CFG = BuildConfig(
    knn=KnnConfig(k=12, iters=3, node_chunk=256),
    prune=PruneConfig(degree=12, keyword_degree=4, node_chunk=128),
    path_refine_iters=0,
)
PARAMS = SearchParams(k=8, iters=16, pool_size=48)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(
        CorpusConfig(n_docs=160, n_queries=8, n_topics=8, d_dense=24,
                     nnz_sparse=10, nnz_lexical=8, seed=5)
    )


@pytest.fixture(scope="module")
def index(corpus):
    return build_index(
        corpus.docs, BUILD_CFG,
        kg_triplets=corpus.kg.triplets,
        doc_entities=corpus.doc_entities,
        n_entities=corpus.kg.n_entities,
    )


def test_save_load_roundtrip_exact(corpus, index, tmp_path):
    save_index(tmp_path / "idx", index)
    # the atomic layout: committed step dir + .done marker
    assert (tmp_path / "idx" / "step_0" / "manifest.json").exists()
    assert (tmp_path / "idx" / "step_0.done").exists()

    loaded = load_index(tmp_path / "idx")
    for a, b in zip(jax.tree.leaves(index), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the restored index answers searches identically
    w = PathWeights.three_path()
    r0 = search(index, corpus.queries, w, PARAMS)
    r1 = search(loaded, corpus.queries, w, PARAMS)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_allclose(
        np.asarray(r0.scores), np.asarray(r1.scores), rtol=1e-6
    )


def test_load_missing_or_uncommitted_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_index(tmp_path / "nope")
    # an uncommitted step (no .done marker) is invisible to readers
    d = tmp_path / "torn"
    (d / "step_0").mkdir(parents=True)
    (d / "step_0" / "manifest.json").write_text("{}")
    with pytest.raises(FileNotFoundError):
        load_index(d)


def test_save_index_with_ingest_manifest(tmp_path):
    from repro.ingest import IngestConfig, IngestPipeline

    texts = [
        "Galileo pointed the telescope at Jupiter and drew the moons.",
        "The sourdough starter wants rye flour and warm water.",
        "Magellan crossed the Pacific after the strait.",
        "Stephenson's Rocket won the trials at Rainhill.",
        "Amundsen laid depots across the Ross Ice Shelf.",
        "The Jacquard loom read punched cards to weave silk.",
        "Krakatoa collapsed into a caldera under the sea.",
        "Capablanca steered the game into a rook endgame.",
    ] * 4
    pipe = IngestPipeline(IngestConfig(d_dense=16, nnz_learned=8, nnz_lexical=6))
    ingested = pipe.fit(texts)
    idx = pipe.build(ingested, BUILD_CFG)

    save_index(tmp_path / "idx", idx, ingest=pipe)
    loaded_idx = load_index(tmp_path / "idx")
    loaded_pipe = load_ingest(tmp_path / "idx")

    # the restored (index, pipeline) pair serves text queries equivalently
    q0 = pipe.encode_queries(["who drew the moons of Jupiter?"])
    q1 = loaded_pipe.encode_queries(["who drew the moons of Jupiter?"])
    for a, b in zip(jax.tree.leaves(q0.vectors), jax.tree.leaves(q1.vectors)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r0 = search(idx, q0.vectors, PathWeights.three_path(), PARAMS)
    r1 = search(loaded_idx, q1.vectors, PathWeights.three_path(), PARAMS)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
