import os

# Keep the default single CPU device for smoke tests / benches. Distributed
# tests that need fake devices spawn subprocesses with their own XLA_FLAGS
# (see tests/_subproc.py). Never set device-count flags here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
