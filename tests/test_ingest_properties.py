"""Hypothesis property tests for the ingestion ELL contract: any document
set, however degenerate, must encode to fixed-nnz ELL SparseVecs with unique
ids per row, zero-valued PAD slots, and bit-for-bit determinism."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.usms import PAD_IDX
from repro.ingest import IngestConfig, IngestPipeline
from repro.ingest.analyzer import AnalyzerConfig, tokenize

_WORDS = st.sampled_from(
    "loom warp weft magma ash crater queen hive nectar espresso crema "
    "sledge crevasse gambit endgame starter crumb boiler gauge Jupiter "
    "Magellan Krakatoa Langstroth the and of a in x".split()
)
_DOC = st.lists(_WORDS, min_size=0, max_size=40).map(" ".join)


def _cfg():
    return IngestConfig(
        d_dense=8, nnz_learned=6, nnz_lexical=4, max_entities=8, min_cooc=1
    )


@settings(max_examples=25, deadline=None)
@given(st.lists(_DOC, min_size=1, max_size=8))
def test_ell_invariants_random_docs(docs):
    ingested = IngestPipeline(_cfg()).fit(docs)
    for sv, cap in ((ingested.docs.learned, 6), (ingested.docs.lexical, 4)):
        idx, val = np.asarray(sv.idx), np.asarray(sv.val)
        assert idx.shape == (len(docs), cap) and val.shape == (len(docs), cap)
        assert idx.dtype == np.int32
        assert (val[idx == PAD_IDX] == 0).all()
        assert (val[idx != PAD_IDX] > 0).all()
        for row in idx:
            real = row[row >= 0]
            assert len(set(real.tolist())) == len(real)  # unique ids per row
            real_mask = row >= 0  # PAD only ever trails real ids
            assert not (~real_mask[:-1] & real_mask[1:]).any()
    # dense rows are unit (or exactly zero for empty/stopword-only docs)
    norms = np.linalg.norm(np.asarray(ingested.docs.dense), axis=-1)
    assert ((np.abs(norms - 1.0) < 1e-4) | (norms == 0)).all()
    # entity slots are valid ids or PAD
    ents = ingested.doc_entities
    assert ((ents == PAD_IDX) | (ents >= 0)).all()
    assert ents.max(initial=PAD_IDX) < ingested.kg.n_entities


@settings(max_examples=25, deadline=None)
@given(st.lists(_DOC, min_size=1, max_size=6))
def test_fit_is_deterministic(docs):
    a = IngestPipeline(_cfg()).fit(docs)
    b = IngestPipeline(_cfg()).fit(docs)
    np.testing.assert_array_equal(
        np.asarray(a.docs.learned.idx), np.asarray(b.docs.learned.idx)
    )
    np.testing.assert_array_equal(
        np.asarray(a.docs.lexical.val), np.asarray(b.docs.lexical.val)
    )
    np.testing.assert_array_equal(
        np.asarray(a.docs.dense), np.asarray(b.docs.dense)
    )
    np.testing.assert_array_equal(a.doc_entities, b.doc_entities)
    np.testing.assert_array_equal(a.kg.triplets, b.kg.triplets)


@settings(max_examples=40, deadline=None)
@given(_DOC)
def test_tokenize_deterministic_and_filtered(text):
    cfg = AnalyzerConfig()
    toks = tokenize(text, cfg)
    assert toks == tokenize(text, cfg)
    stop = cfg.stopword_set()
    assert all(t not in stop and len(t) >= cfg.min_token_len for t in toks)
