"""Shared test helpers: random fused-vector generators honoring the ELL
padding contract (idx == PAD_IDX  <=>  val == 0, unique idx per row)."""

from __future__ import annotations

import numpy as np

from repro.core.usms import PAD_IDX, FusedVectors, SparseVec


def random_sparse(rng, shape, nnz_cap, vocab, dtype=np.float32, min_nnz=0):
    """Random ELL sparse batch. shape: leading dims, e.g. (B,) or (B, C)."""
    n = int(np.prod(shape))
    idx = np.full((n, nnz_cap), PAD_IDX, np.int32)
    val = np.zeros((n, nnz_cap), np.float32)
    for r in range(n):
        k = rng.integers(min_nnz, nnz_cap + 1)
        if k > 0:
            idx[r, :k] = rng.choice(vocab, size=k, replace=False)
            val[r, :k] = rng.normal(size=k)
            # contract: padded slots have val exactly 0, valid slots nonzero
            val[r, :k] = np.where(val[r, :k] == 0.0, 1.0, val[r, :k])
    return SparseVec(
        idx.reshape(*shape, nnz_cap),
        val.reshape(*shape, nnz_cap).astype(dtype),
    )


def random_fused(rng, shape, d_dense=64, ps=16, pf=8, vs=997, vf=251, dtype=np.float32):
    dense = rng.normal(size=(*shape, d_dense)).astype(dtype)
    return FusedVectors(
        dense,
        random_sparse(rng, shape, ps, vs, dtype),
        random_sparse(rng, shape, pf, vf, dtype),
    )
